"""Render the EXPERIMENTS.md roofline tables from results/dryrun/*.json."""

import glob
import json
import os

HERE = os.path.dirname(__file__)


def load(variant=""):
    out = {}
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        r = json.load(open(f))
        is_opt = f.endswith("__opt.json")
        if (variant == "opt") != is_opt:
            continue
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_row(r):
    if r["status"] == "skipped":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | — | — | — | "
                f"skipped ({r['reason'][:42]}) | — | — | — |")
    t = r["roofline"]
    coll = max(t["collective_s"], t["collective_wire_s"])
    mem_gib = r["memory_analysis"].get("temp_size_in_bytes", 0) / 2 ** 30
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{t['compute_s'] * 1e3:.0f} | {t['memory_s'] * 1e3:.0f} | "
            f"{t['collective_s'] * 1e3:.0f} / {t['collective_wire_s'] * 1e3:.0f} | "
            f"{t['dominant']} | {t['useful_ratio']:.2f} | "
            f"{t['roofline_fraction']:.2%} | {mem_gib:.1f} |")


HEADER = ("| arch | shape | mesh | compute (ms) | memory (ms) | "
          "collective op/wire (ms) | dominant | useful | roofline | "
          "temp GiB/dev |\n|---|---|---|---|---|---|---|---|---|---|")


def main():
    base = load()
    print("### Single-pod (16x16 = 256 chips)\n")
    print(HEADER)
    for (a, s, m), r in sorted(base.items()):
        if m == "single":
            print(fmt_row(r))
    print("\n### Multi-pod (2x16x16 = 512 chips)\n")
    print(HEADER)
    for (a, s, m), r in sorted(base.items()):
        if m == "pod2":
            print(fmt_row(r))
    opt = load("opt")
    if opt:
        print("\n### Optimized variants (§Perf)\n")
        print(HEADER)
        for (a, s, m), r in sorted(opt.items()):
            print(fmt_row(r))


if __name__ == "__main__":
    main()
