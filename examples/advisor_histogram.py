"""Rediscovering ``hist2`` automatically: the advisor on the paper's §5 case.

The paper explains *why* ``hist2``'s per-lane channel rotation beats the
naive ``hist`` kernel (up to 30% on contended inputs) — but a user of
the diagnosis still has to invent that fix.  This example starts from
the plain ``hist`` workload on contended (solid-color) images and lets
``Session.advise`` search the transform catalog:

  * the top-ranked candidate must come from the channel-padding /
    rotation family — the advisor *rediscovers* ``hist2``,
  * its predicted speedup must sit inside the paper's up-to-30% band on
    these contended sizes, and
  * the top candidate is re-validated through the instrumented-kernel
    provider: modeled counters must agree bit-for-bit (e rel err == 0),
    the paper-§5 model-vs-measured check.

Run: PYTHONPATH=src python examples/advisor_histogram.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import Session, WorkloadSpec  # noqa: E402
from repro.data.images import make_image  # noqa: E402

# Contended setting: solid images (every lane of a commit group hits the
# same bin, e = 32) at sizes where the scatter unit leads but launch
# overhead keeps the modeled gain inside the paper's measured band.
CONTENDED_PIXELS = (1 << 15, 1 << 16)
WAVES_PER_TILE = 8
OVERHEAD_CYCLES = 2500.0
PAPER_BAND = (1.0, 1.30)    # "up to 30%"


def main() -> int:
    sess = Session("v5e", persistent_cache=True)
    reports = {}
    for px in CONTENDED_PIXELS:
        img = make_image("solid", px)
        spec = WorkloadSpec.from_histogram(
            img, label=f"solid-{px}px", variant="hist",
            waves_per_tile=WAVES_PER_TILE,
            overhead_cycles=OVERHEAD_CYCLES)
        # validate the larger (headline) size's winner against the real
        # instrumented kernel; the smaller one stays modeled-only
        validate = 1 if px == max(CONTENDED_PIXELS) else 0
        report = sess.advise(spec, depth=2, top_k=5, validate_top=validate)
        reports[px] = report
        print(report.render("text"))
        print()

    ok = True
    for px, report in reports.items():
        top = report.best
        if "rotation" not in top.families:
            print(f"FAIL {px}px: top candidate {top.label!r} is "
                  f"{top.families}, not the rotation family")
            ok = False
            continue
        lo, hi = PAPER_BAND
        if not (lo < top.speedup <= hi):
            print(f"FAIL {px}px: predicted speedup x{top.speedup:.3f} "
                  f"outside the paper's up-to-30% band")
            ok = False
            continue
        print(f"OK {px}px: advisor rediscovered hist2 "
              f"({'+'.join(top.names)}), predicted x{top.speedup:.3f} "
              f"(paper band: up to x{hi:.2f})")

    top = reports[max(CONTENDED_PIXELS)].best
    if top.validation is None:
        print("FAIL: top candidate was not validated")
        ok = False
    else:
        e_err = top.validation.rel_err("kernel", "e")
        if e_err != 0.0 or top.validation.max_rel_err != 0.0:
            print(f"FAIL: kernel-provider validation disagrees "
                  f"(e rel err {e_err:.2%}, "
                  f"max {top.validation.max_rel_err:.2%})")
            ok = False
        else:
            print("OK validation: instrumented-kernel counters match the "
                  "batch-path prediction bit for bit (e rel err == 0)")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
