"""End-to-end training driver (deliverable b): trains a reduced-config
MoE LM for a few hundred steps with checkpointing, a mid-run injected
failure + restore, and straggler reports.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""

import argparse
import os
import sys
import tempfile
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import train as train_cli


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    args = ap.parse_args()
    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    out = train_cli.main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps), "--batch", "8", "--seq", "128",
        "--lr", "3e-3", "--ckpt-dir", ckpt, "--save-every", "25",
        "--simulate-failure-at", str(args.steps // 2),
    ])
    hist = out["history"]
    print(f"final loss {hist[-1]['xent']:.3f} after {len(hist)} executed "
          f"steps with {out['restarts']} restart(s); checkpoints in {ckpt}")


if __name__ == "__main__":
    main()
