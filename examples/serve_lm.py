"""Serving example: batched generation with an attention-free (O(1)-state)
model and a windowed hybrid — the two long_500k-capable families.

Run: PYTHONPATH=src python examples/serve_lm.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch import serve as serve_cli


def main():
    for arch in ("rwkv6-7b", "zamba2-1.2b"):
        serve_cli.main(["--arch", arch, "--reduced", "--batch", "4",
                        "--prompt-len", "12", "--gen", "20"])


if __name__ == "__main__":
    main()
