"""Full paper-§4 case study: both kernels x both image kinds x sizes
32px..4Mpx x three launch-occupancy settings, utilization + speedup +
bottleneck-shift detection.  Writes results/casestudy.csv.

Uses the ``repro.analysis`` session API: a derived device carries the
case-study cache emulation, traces are built once per (kind, variant,
size) and re-geometried per occupancy point via frozen ``WorkloadSpec``s —
no post-construction trace mutation.

Run: PYTHONPATH=src python examples/histogram_casestudy.py [--fast]

The headline hist-vs-hist2 comparison (same LLC emulation, same Session
numbers) is also available without Python:

    PYTHONPATH=src python -m repro compare --device v5e
"""

import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.analysis import Session, WorkloadSpec, get_device
from repro.core import bottleneck
from repro.core.profiler import CacheModel
from repro.data.images import make_image
from repro.kernels.histogram import ops

OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "casestudy.csv")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    device = get_device("v5e").with_(
        cache=CacheModel(llc_bytes=1 << 21, miss_latency_cycles=800,
                         hide_concurrency=48))
    sess = Session(device)
    sizes = [2 ** p for p in range(5, 23, 3 if args.fast else 1)]
    waves_opts = [8, 32] if args.fast else [4, 8, 16, 32]

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    rows = ["kind,variant,pixels,waves_per_tile,e,utilization,bottleneck"]
    shift_profiles = []
    for kind in ("solid", "uniform"):
        for variant in ("hist", "hist2"):
            for n in sizes:
                img = jnp.asarray(make_image(kind, n))
                # run the instrumented kernel once; re-geometry the trace
                # per occupancy point instead of re-running it
                _, tr = ops.histogram_instrumented(
                    img, variant=variant, force_fao=True)
                for wpt in waves_opts:
                    spec = WorkloadSpec.from_trace(
                        tr, label=f"{kind}/{variant}/{n}/{wpt}",
                        waves_per_tile=wpt, bytes_read=float(n * 4))
                    prof = sess.profile(spec)
                    rows.append(
                        f"{kind},{variant},{n},{wpt},"
                        f"{prof.e:.2f},"
                        f"{prof.scatter_utilization:.4f},{prof.bottleneck}")
                    if kind == "uniform" and variant == "hist" and wpt == 8:
                        shift_profiles.append(prof)

    with open(OUT, "w") as f:
        f.write("\n".join(rows) + "\n")
    print(f"wrote {len(rows) - 1} rows to {OUT}")

    # headline numbers (mirror the paper's narrative)
    def util(kind, variant, n, wpt=32):
        for r in rows[1:]:
            k, v, px, w, e, u, b = r.split(",")
            if (k, v, int(px), int(w)) == (kind, variant, n, wpt):
                return float(u), b
        raise KeyError

    big = sizes[-1]
    u_solid, _ = util("solid", "hist", big)
    u_uni, _ = util("uniform", "hist", big)
    u_solid2, _ = util("solid", "hist2", big)
    print(f"large solid: U={u_solid:.2f} (paper: ~1.0); "
          f"large uniform: U={u_uni:.2f} (paper: ~0.76)")
    print(f"reorder on solid: U {u_solid:.2f} -> {u_solid2:.2f}")
    # the profiles are already computed: detect shifts on them directly
    # instead of re-profiling via sess.sweep
    for s in bottleneck.detect_shifts(shift_profiles):
        print(f"bottleneck shift at sweep idx {s.index}: "
              f"{s.unit_before} -> {s.unit_after} "
              f"({s.label_before} -> {s.label_after})")


if __name__ == "__main__":
    main()
