"""Beyond-paper application: the queuing model watching a *live* MoE
router.

Trains the reduced qwen3-MoE for a few steps, extracts the router's
dispatch stream each step via the instrumented scatter kernel, and reports
scatter-unit utilization.  A collapsing router (simulated by scaling
router logits) is flagged as a scatter-unit bottleneck by the model before
it would show up as step-time regression — the MoE-age version of the
paper's solid-image histogram.

Run: PYTHONPATH=src python examples/moe_dispatch_profile.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import Session, WorkloadSpec
from repro.configs import get_config
from repro.models import moe
from repro.models.registry import build_model, make_batch

# provider="kernel": counters come from the instrumented Pallas
# scatter-add launch itself, not from a host-synthesized trace — this is
# a *live* router, so measure it
SESSION = Session(device="v5e", provider="kernel")


def profile_dispatch(ids: np.ndarray, num_experts: int, label: str):
    spec = WorkloadSpec.from_scatter_add(
        ids.astype(np.int32), np.ones((ids.size, 1), np.float32),
        num_experts, label=label, waves_per_tile=32)
    prof = SESSION.profile(spec)
    v = SESSION.last.verdicts[0]
    print(f"  {label:24s} e={prof.e:5.2f} "
          f"U={prof.scatter_utilization:6.2%}  {v.comment}")
    return prof


def main():
    cfg = get_config("qwen3-moe-235b-a22b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 8, 128)
    mcfg = moe.MoEConfig(d_model=cfg.d_model, d_expert=cfg.d_expert,
                         num_experts=cfg.num_experts, top_k=cfg.top_k,
                         dtype=cfg.dtype)

    # grab one layer's MoE params and route real activations through it
    p_moe = jax.tree.map(lambda a: a[0], params["groups"]["sub0"]["ffn"])
    h = jax.random.normal(jax.random.PRNGKey(1),
                          (8 * 128, cfg.d_model), jnp.float32) * 0.3

    print("router health via scatter-unit utilization:")
    for bias, label in ((0.0, "healthy router"),
                        (0.5, "drifting router"),
                        (50.0, "collapsed router")):
        # router collapse = systematic bias toward a few experts (top-k is
        # invariant to logit *scaling*, so collapse manifests as bias)
        w = p_moe["router"]["w"]
        w = w.at[:, :mcfg.top_k].add(bias)
        p_biased = dict(p_moe, router={"w": w})
        _, _, disp = moe.apply_local(p_biased, h.astype(jnp.float32), mcfg)
        profile_dispatch(np.asarray(disp), cfg.num_experts,
                         f"{label} (bias {bias:g})")


if __name__ == "__main__":
    main()
