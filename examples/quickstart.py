"""Quickstart: the paper in one page.

1. Tool 1 — build the once-per-chip service-time table S(n, e, c).
2. Run the instrumented Pallas histogram kernel on a solid and a uniform
   image (paper §4's two extremes).
3. Tool 2 — instantiate the single-server model from the counters and
   print per-core utilization + the bottleneck verdict.

Run: PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import bottleneck, microbench, profiler
from repro.data.images import make_image
from repro.kernels.histogram import ops


def main():
    # Tool 1: the S(n, e, c) table (analytic v5e timing model on CPU;
    # wall-clock microbenchmark on real hardware).
    table = microbench.build_table()
    print(f"service-time table: n<= {int(table.n_grid[-1])}, "
          f"e<={int(table.e_grid[-1])}, "
          f"S range {float(table.service_time(64, 1, 0)):.1f}.."
          f"{float(table.service_time(1, 32, 1)):.1f} cycles\n")

    for kind in ("solid", "uniform"):
        img = make_image(kind, 1 << 18)
        hist, trace = ops.histogram_instrumented(jnp.asarray(img),
                                                 variant="hist",
                                                 force_fao=True)
        trace.waves_per_tile = 32
        prof = profiler.profile_scatter_workload(
            trace, table, label=f"{kind} 256Kpx",
            bytes_read=ops.image_bytes(jnp.asarray(img)),
            overhead_cycles=500.0)
        print(prof.render())
        verdict = bottleneck.classify(prof)
        print(f"verdict: {verdict.bottleneck} ({verdict.utilization:.0%}) — "
              f"{verdict.comment}\n")
        assert int(hist.sum()) == img.shape[0] * 4

    # The fix the model recommends for the solid case: channel reorder.
    img = make_image("solid", 1 << 18)
    _, tr1 = ops.histogram_instrumented(jnp.asarray(img), variant="hist",
                                        force_fao=True)
    _, tr2 = ops.histogram_instrumented(jnp.asarray(img), variant="hist2",
                                        force_fao=True)
    tr1.waves_per_tile = tr2.waves_per_tile = 32
    p1 = profiler.profile_scatter_workload(
        tr1, table, label="hist", bytes_read=float(img.shape[0] * 4),
        overhead_cycles=500.0)
    p2 = profiler.profile_scatter_workload(
        tr2, table, label="hist2", bytes_read=float(img.shape[0] * 4),
        overhead_cycles=500.0)
    print(f"channel reorder on solid: e {tr1.degree.mean():.0f} -> "
          f"{tr2.degree.mean():.0f}, predicted speedup "
          f"{bottleneck.speedup_estimate(p1, p2):.2f}x "
          f"(paper: ~30% on large monochrome images)")


if __name__ == "__main__":
    main()
