"""Quickstart: the paper's two tools in five lines.

1. ``Session(device="v5e")`` — Tool 1: resolves the once-per-chip
   service-time table S(n, e, c) (built on first ever use, then loaded
   from the ``.npz`` cache under results/tables/).
2. ``WorkloadSpec.from_histogram(...)`` — describe an instrumented Pallas
   histogram launch declaratively (no trace mutation, no kwarg sprawl).
3. ``sess.profile(spec)`` / ``sess.classify(spec)`` — Tool 2: per-core
   utilization + the bottleneck verdict.
4. ``sess.validate(spec)`` — the paper's §5 check: the modeled counter
   path ("trace" provider) against the measured one ("kernel" provider,
   counters read back from the instrumented Pallas launch).

Run: PYTHONPATH=src python examples/quickstart.py
"""

import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.analysis import Session, WorkloadSpec
from repro.data.images import make_image
from repro.kernels.histogram import ops


def main():
    sess = Session(device="v5e")
    table = sess.table
    print(f"service-time table [{sess.device.name}]: "
          f"n<={int(table.n_grid[-1])}, e<={int(table.e_grid[-1])}, "
          f"S range {float(table.service_time(64, 1, 0)):.1f}.."
          f"{float(table.service_time(1, 32, 1)):.1f} cycles\n")

    # The paper §4's two extremes: solid (fully serialized) vs uniform.
    for kind in ("solid", "uniform"):
        img = jnp.asarray(make_image(kind, 1 << 18))
        # kernel-correctness smoke: every pixel's 4 channels land somewhere
        assert int(ops.histogram(img).sum()) == img.shape[0] * 4
        spec = WorkloadSpec.from_histogram(
            img, label=f"{kind} 256Kpx", force_fao=True, waves_per_tile=32)
        prof = sess.profile(spec)
        print(prof.render())
        verdict = sess.last.verdicts[0]
        print(f"verdict: {verdict.bottleneck} ({verdict.utilization:.0%}) — "
              f"{verdict.comment}\n")

    # The fix the model recommends for the solid case: channel reorder
    # (the paper's hist2 kernel).  One sweep call gives both profiles,
    # the per-point verdicts, and the predicted speedup.
    img = jnp.asarray(make_image("solid", 1 << 18))
    specs = [WorkloadSpec.from_histogram(img, label=v, variant=v,
                                         force_fao=True, waves_per_tile=32)
             for v in ("hist", "hist2")]
    result = sess.sweep(specs)
    e0 = result.profiles[0].e
    e1 = result.profiles[1].e
    print(f"channel reorder on solid: e {e0:.0f} -> {e1:.0f}, "
          f"predicted speedup {float(result.speedup_vs_first[1]):.2f}x "
          f"(paper: ~30% on large monochrome images)")
    print()
    print(sess.report())

    # Model vs measured (paper §5): the default "trace" provider
    # synthesizes the committed index stream on the host; the "kernel"
    # provider runs the instrumented Pallas kernel and reads the counters
    # back.  They must agree exactly.
    small = jnp.asarray(make_image("solid", 1 << 14))
    spec = WorkloadSpec.from_histogram(small, label="solid 16Kpx",
                                       force_fao=True, waves_per_tile=32)
    print(sess.validate(spec, providers=("trace", "kernel")).render())


if __name__ == "__main__":
    main()
