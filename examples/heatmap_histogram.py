"""The §5 hist-vs-hist2 skew difference as two contention heat maps.

The paper's utilization model says *that* the naive ``hist`` kernel
saturates the scatter unit on contended (solid-color) images and that
``hist2``'s per-lane channel rotation relieves it; the heat map shows
*where*.  Both variants commit exactly the same multiset of bin updates
(identical per-bin hit counts — rotation only reshuffles commit
groups), so the separating signal is serialized *replays*: updates that
queued behind an earlier hit to the same bin within one commit group.

This example renders both heat maps side by side and checks the §5
localization story end to end:

  * ``hist`` concentrates: each commit group is 32 lanes of one channel
    hitting one bin, so the hottest bin serializes 31/32 of its hits
    (top-bin share 31/128 of the whole stream, max wave degree 32);
  * ``hist2`` disperses: a rotated commit group spreads over all 4
    channel bins, the worst wave degree drops to 8 and the top-bin
    share falls strictly below ``hist``'s;
  * per-bin totals stay consistent with the profile path: the heat
    map's embedded ``CounterSet`` is bitwise-equal to what
    ``Session.profile`` collects for the same spec, and the per-bin
    hits sum to the committed stream length exactly.

Run: PYTHONPATH=src python examples/heatmap_histogram.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402

from repro.analysis import Session, WorkloadSpec  # noqa: E402
from repro.core.counters import bitwise_equal  # noqa: E402
from repro.data.images import make_image  # noqa: E402

# The paper's contended setting: solid images, every lane of a naive
# commit group hits the same bin (e = 32).
PIXELS = 1 << 16
WAVES_PER_TILE = 8


def main() -> int:
    sess = Session("v5e")
    img = make_image("solid", PIXELS)
    maps = {}
    for variant in ("hist", "hist2"):
        spec = WorkloadSpec.from_histogram(
            img, label=f"solid-{PIXELS}px-{variant}", variant=variant,
            waves_per_tile=WAVES_PER_TILE)
        hm = maps[variant] = sess.heatmap(spec)
        print(hm.render("text", top_k=8))
        print()

        # bit-consistency with the profile path: same stream, same
        # degree kernels, same aggregation -> identical counters
        cset = sess.collect(spec)
        if not bitwise_equal(hm.counters, cset):
            print(f"FAIL {variant}: heat-map counters diverge from "
                  f"the provider's collect()")
            return 1
        if int(hm.hits.sum()) != PIXELS * img.shape[1]:
            print(f"FAIL {variant}: per-bin hits sum to "
                  f"{int(hm.hits.sum())}, expected the committed stream "
                  f"length {PIXELS * img.shape[1]}")
            return 1

    hist, hist2 = maps["hist"], maps["hist2"]
    if hist.hits.sum() != hist2.hits.sum() \
            or not np.array_equal(hist.bins, hist2.bins) \
            or not np.array_equal(hist.hits, hist2.hits):
        print("FAIL: rotation changed per-bin hit totals — it must only "
              "reshuffle commit groups")
        return 1
    if not (hist.peak_degree > hist2.peak_degree):
        print(f"FAIL: expected hist wave degree ({hist.peak_degree}) "
              f"above hist2 ({hist2.peak_degree})")
        return 1
    if not (hist2.top_bin_share < hist.top_bin_share):
        print(f"FAIL: hist2 top-bin share {hist2.top_bin_share:.4f} not "
              f"strictly below hist {hist.top_bin_share:.4f}")
        return 1
    if len(hist.hot_bins) < 1:
        print("FAIL: contended hist run surfaced no hot bins")
        return 1

    print(f"hist  top-bin share {100 * hist.top_bin_share:.1f}% "
          f"(peak wave degree {hist.peak_degree:.0f})")
    print(f"hist2 top-bin share {100 * hist2.top_bin_share:.1f}% "
          f"(peak wave degree {hist2.peak_degree:.0f})")
    print("OK: hist2's rotation disperses the hot bins hist localizes; "
          "counters bit-identical to the profile path")
    return 0


if __name__ == "__main__":
    sys.exit(main())
