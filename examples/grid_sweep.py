"""Grid-sweep engine demo: one base workload x a cartesian parameter grid
x two devices, collected concurrently with per-point memoization.

The same sweep is available without Python:

    PYTHONPATH=src python -m repro sweep --workload indices \
        --size 2^16 2^18 --dist uniform \
        --waves-per-tile 4 8 16 32 --pipeline-depth 2 4 \
        --devices v5e v5p --jobs 8 --format csv

Run: PYTHONPATH=src python examples/grid_sweep.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.analysis import WorkloadSpec, sweep_grid

OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "grid_sweep.csv")


def main():
    rng = np.random.default_rng(0)
    base = WorkloadSpec.from_indices(
        rng.integers(0, 256, 1 << 18), 256, label="uniform-256K")
    results = sweep_grid(
        base,
        {"waves_per_tile": [4, 8, 16, 32], "pipeline_depth": [2, 4]},
        devices=("v5e", "v5p"),
        parallel=8)

    os.makedirs(os.path.dirname(OUT), exist_ok=True)
    with open(OUT, "w") as f:
        for name, result in results.items():
            print(result.render("text"))
            f.write(result.render("csv"))
    print(f"wrote per-device sweep csv to {OUT}")

    # the engine's point: same verdict machinery, now over a whole grid —
    # occupancy (waves_per_tile x pipeline_depth) moves utilization, and
    # the device axis shows hardware balance moving the bottleneck
    for name, result in results.items():
        peak = max(result.profiles, key=lambda p: p.scatter_utilization)
        print(f"{name}: peak scatter U={peak.scatter_utilization:.2%} "
              f"at {peak.label}")


if __name__ == "__main__":
    main()
