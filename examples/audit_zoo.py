"""Static audit of a zoo config: MoE dispatch + KV-cache findings, no kernels.

The audit is the paper's utilization model turned into a *linter*: it
never runs a kernel and never collects counters from a provider.  A
config is lowered to its pre-optimization HLO (global shapes, no
``.compile()``), the scanner walks the instruction graph for
atomic-shaped sites — MoE token-dispatch scatters, expert-count
histograms, KV-cache decode writes, one-hot/sort-segment lowerings —
and every matched rule scores a synthesized worst-plausible index
stream in one columnar model pass.  Each finding carries the predicted
scatter-unit utilization, its contention ratio over a conflict-free
baseline, and the advisor transform that would fix it.

This example audits ``qwen3-moe-235b-a22b`` (128-expert MoE with a
32k-token KV cache) and asserts the two headline hazards are found:

  * a ``dispatch_scatter`` site — the MoE token-dispatch scatter that
    routes token rows into expert buffers, and
  * a ``histogram_scatter`` site — the per-expert token-count
    accumulation the router needs,

and that the session's collection stats stay at zero: the whole audit
is static.

The same audit is available without Python:

    PYTHONPATH=src python -m repro audit --config qwen3_moe_235b_a22b

Run: PYTHONPATH=src python examples/audit_zoo.py
"""

import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.analysis import Session  # noqa: E402
from repro.audit import audit_config  # noqa: E402

CONFIG = "qwen3-moe-235b-a22b"


def main() -> int:
    sess = Session("v5e")
    # reduced=True lowers the smoke-geometry variant (same scatter idioms,
    # sub-second lowering); drop it to audit the full production shapes.
    report = audit_config(CONFIG, session=sess, reduced=True)
    print(report.render("text"))

    kinds = {f.site.kind for f in report.findings if f.site is not None}
    assert "dispatch_scatter" in kinds, (
        f"MoE token-dispatch scatter not found (kinds: {sorted(kinds)})")
    assert "histogram_scatter" in kinds, (
        f"expert-count histogram not found (kinds: {sorted(kinds)})")
    assert "kv_cache_write" in kinds, (
        f"KV-cache decode write not found (kinds: {sorted(kinds)})")

    for f in report.findings:
        if f.site is not None:
            assert f.utilization is not None and f.fixit, f
    assert sess.stats == {"collected": 0, "memo_hits": 0, "disk_hits": 0,
                          "batch_calls": 0}, (
        f"audit must be static, but providers ran: {sess.stats}")

    print(f"\naudit found {len(report.findings)} finding(s) across "
          f"{sorted(kinds)} — zero kernel executions ({sess.stats})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
