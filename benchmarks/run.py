"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (spec format):
  * fig1_service_time_table   — S(n,e,c) corners + dynamic range (paper Fig 1)
  * fig3_utilization_sweep    — solid/uniform utilization vs image size
                                (paper Fig 3, v5e-adapted)
  * fig4_popc_vs_fao          — instruction-class effect (paper Fig 4)
  * fig5_reorder_speedup      — hist2-vs-hist predicted speedup (paper Fig 5)
  * sec5_model_vs_measured    — trace-vs-kernel provider counter validation
                                (paper §5) + acquisition-cost asymmetry
  * lint_static_vs_trace      — symbolic static counter derivation vs
                                TraceProvider synthesis on the §5
                                hist/hist2 kernels (bit-for-bit equal,
                                zero kernel executions)
  * moe_dispatch_profile      — router balance -> scatter-unit utilization
                                (framework integration of the model)
  * sweep_grid_parallel       — grid-sweep engine: serial vs concurrent
                                vs memoized collection (CLI fast path)
  * profile_batch_vs_loop     — columnar batch profiler vs the per-point
                                scalar loop on a 64-point grid, plus
                                cold/warm persistent sweep-cache timings
                                (CI perf canary via --min-batch-speedup)
  * collect_batch_vs_loop     — columnar provider collection vs the
                                per-point scalar ``collect`` loop on a
                                256-point trace grid (row-wise bitwise
                                equality asserted), plus a cold/warm
                                sharded-cache sweep
                                (CI perf canary via --min-collect-speedup)
  * advise_search             — optimization advisor over a 32-candidate
                                frontier: one batch evaluation per
                                frontier, zero scalar profiling, warm
                                cache re-advise collects nothing
                                (CI gate via --advise-gate)
  * service_load              — profiling-service burst load: cold and
                                warm req/s, warm-hit p50/p99 latency,
                                and breaker-trip recovery under
                                injected faults
                                (CI gate via --service-gate)
  * heatmap_overhead          — telemetry-on vs telemetry-off sweep
                                wall-clock (the observability layer must
                                cost < 3%) plus heat-map/CounterSet
                                bit-consistency and the §5 hist-vs-hist2
                                localization check
                                (CI gate via --obs-gate)
  * kernel_walltime           — interpret-mode Pallas kernel wall times
                                (regression canary; not TPU numbers)
  * roofline_table            — per (arch x shape x mesh) terms from the
                                dry-run artifacts (results/dryrun/*.json)

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME]
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import time

import jax.numpy as jnp
import numpy as np

from repro.analysis import Session, WorkloadSpec
from repro.core import bottleneck
from repro.data.images import make_image
from repro.kernels.histogram import ops as hist_ops
from repro.kernels.scatter_add import ops as scat_ops

_SESSION: Session | None = None
ROWS: list[str] = []


def session() -> Session:
    """Lazy shared session: ``--only`` runs and test imports of this module
    never pay the full-grid table build (it comes from the .npz cache, or
    is built once on first profiling use)."""
    global _SESSION
    if _SESSION is None:
        _SESSION = Session(device="v5e")
    return _SESSION


def emit(name: str, us: float, derived: str) -> None:
    row = f"{name},{us:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _timeit(fn, repeats=3):
    fn()  # compile/warm
    t0 = time.perf_counter()
    for _ in range(repeats):
        fn()
    return (time.perf_counter() - t0) / repeats * 1e6


def _profile(kind, n_pixels, variant="hist", force_fao=True,
             waves_per_tile=32):
    img = jnp.asarray(make_image(kind, n_pixels))
    spec = WorkloadSpec.from_histogram(
        img, label=f"{kind}-{variant}", variant=variant,
        force_fao=force_fao, waves_per_tile=waves_per_tile,
        bytes_read=float(n_pixels * 4))
    return session().profile(spec)


def fig1_service_time_table() -> None:
    # refresh=True forces a real grid build: this benchmark *measures*
    # Tool 1's cost, so the .npz cache must not short-circuit it.  The
    # session (and any cold-cache table build of its own) is resolved
    # before the timer so only one grid build lands in the window.
    device = session().device
    t0 = time.perf_counter()
    tab = device.table(refresh=True)
    us = (time.perf_counter() - t0) * 1e6
    corners = {
        "S(1,1,0)": tab.service_time(1, 1, 0),
        "S(64,1,0)": tab.service_time(64, 1, 0),
        "S(64,32,0)": tab.service_time(64, 32, 0),
        "S(64,32,c=64)": tab.service_time(64, 32, 64),
        "S_popc(64,32)": tab.popc_service_time(64, 32),
    }
    rng = float(tab.service_time(1, 32, 1) / tab.service_time(64, 1, 0))
    emit("fig1_service_time_table", us,
         ";".join(f"{k}={float(v):.2f}cyc" for k, v in corners.items())
         + f";dynamic_range={rng:.1f}x")


def fig3_utilization_sweep() -> None:
    for kind in ("solid", "uniform"):
        for p in (12, 16, 20):
            t0 = time.perf_counter()
            prof = _profile(kind, 1 << p)
            us = (time.perf_counter() - t0) * 1e6
            emit(f"fig3_utilization_{kind}_2^{p}", us,
                 f"U={prof.scatter_utilization:.3f};"
                 f"e={prof.e:.2f};"
                 f"bottleneck={prof.bottleneck}")


def fig4_popc_vs_fao() -> None:
    fao = _profile("solid", 1 << 18, force_fao=True)
    popc = _profile("solid", 1 << 18, force_fao=False)
    emit("fig4_popc_vs_fao", 0.0,
         f"U_fao={fao.scatter_utilization:.3f};"
         f"U_popc={popc.scatter_utilization:.3f};"
         f"ratio={popc.scatter_utilization / fao.scatter_utilization:.2f}")


def fig5_reorder_speedup() -> None:
    for kind in ("solid", "uniform"):
        base = _profile(kind, 1 << 18, variant="hist")
        reord = _profile(kind, 1 << 18, variant="hist2")
        sp = bottleneck.speedup_estimate(base, reord)
        emit(f"fig5_reorder_speedup_{kind}", 0.0,
             f"speedup={sp:.3f};U_before={base.scatter_utilization:.2f};"
             f"U_after={reord.scatter_utilization:.2f}")


def moe_dispatch_profile() -> None:
    """Router balance as the 'image color distribution' of MoE dispatch."""
    rng = np.random.default_rng(0)
    n_tokens, experts = 1 << 16, 128
    for label, ids in (
            ("balanced", rng.integers(0, experts, n_tokens)),
            ("skewed", rng.zipf(1.3, n_tokens) % experts),
            ("collapsed", np.zeros(n_tokens, np.int64))):
        spec = WorkloadSpec.from_scatter_add(
            ids.astype(np.int32), np.ones((n_tokens, 1), np.float32),
            experts, label=label, waves_per_tile=32,
            bytes_read=float(n_tokens * 4))
        prof = session().profile(spec)
        emit(f"moe_dispatch_{label}", 0.0,
             f"e={prof.e:.2f};U={prof.scatter_utilization:.3f};"
             f"bottleneck={prof.bottleneck}")


def sec5_model_vs_measured() -> None:
    """Paper §5 validation: trace-provider counters vs instrumented-kernel
    counters on the histogram case, plus the acquisition-cost asymmetry
    (the modeled path must be far cheaper than an interpret-mode run)."""
    img = jnp.asarray(make_image("solid", 1 << 16))
    spec = WorkloadSpec.from_histogram(
        img, label="solid-64Kpx", force_fao=True, waves_per_tile=32,
        bytes_read=float((1 << 16) * 4))
    sess = session()
    t0 = time.perf_counter()
    report = sess.validate(spec, providers=("trace", "kernel"))
    us = (time.perf_counter() - t0) * 1e6
    us_trace = _timeit(lambda: sess.collect(spec, provider="trace"), 1)
    us_kernel = _timeit(lambda: sess.collect(spec, provider="kernel"), 1)
    emit("sec5_model_vs_measured", us,
         f"e_rel_err={report.rel_err('kernel', 'e'):.4f};"
         f"max_rel_err={report.max_rel_err:.4f};"
         f"trace_us={us_trace:.0f};kernel_us={us_kernel:.0f};"
         f"speedup={us_kernel / max(us_trace, 1e-9):.1f}x")


def lint_static_vs_trace() -> None:
    """Static lint derivation vs dynamic trace synthesis (§5 kernels).

    ``repro.lint`` proves the hist/hist2 index streams affine and
    derives their counters symbolically; this row pins the bit-for-bit
    equality with ``TraceProvider`` and compares acquisition cost.  The
    one-time jaxpr trace (``target_from_spec`` + ``analyze_target``) is
    reported separately from the steady-state derivation, which reuses
    the traced model the way ``lint_registry`` does.
    """
    from repro.analysis.providers.trace import TraceProvider
    from repro.lint.analysis import (analyze_target, derive_counters,
                                     target_from_spec)

    dev = session().device
    provider = TraceProvider()
    for variant in ("hist", "hist2"):
        img = make_image("solid", 1 << 15)
        spec = WorkloadSpec.from_histogram(
            img, label=f"{variant}-solid", variant=variant,
            waves_per_tile=8, overhead_cycles=2500.0)
        target = target_from_spec(spec)
        t0 = time.perf_counter()
        models = analyze_target(target)
        us_trace_jaxpr = (time.perf_counter() - t0) * 1e6
        model = next(m for m in models if m.sites)
        derived, deriv = derive_counters(spec, target=target, model=model)
        assert deriv.is_static
        us_static = _timeit(
            lambda: derive_counters(spec, target=target, model=model))
        us_dynamic = _timeit(lambda: provider.collect(spec, dev))
        expected = provider.collect(spec, dev)
        for field, b in vars(expected).items():
            a = getattr(derived, field)
            if isinstance(a, np.ndarray):
                assert a.dtype == b.dtype and np.array_equal(a, b), field
            else:
                assert a == b, field
        emit(f"lint_static_vs_trace_{variant}", us_static,
             f"bitwise_equal=1;trace_jaxpr_us={us_trace_jaxpr:.0f};"
             f"static_us={us_static:.0f};dynamic_us={us_dynamic:.0f};"
             f"speedup={us_dynamic / max(us_static, 1e-9):.2f}x")


def sweep_grid_parallel() -> None:
    """Grid-sweep engine: serial vs concurrent collection vs memoized
    re-run on a 16-point occupancy grid (the CLI 'sweep' fast path)."""
    from repro.analysis import Session

    rng = np.random.default_rng(0)
    base = WorkloadSpec.from_indices(
        rng.integers(0, 256, 1 << 17), 256, label="uniform-128K")
    specs = base.grid(waves_per_tile=[2, 4, 8, 16, 32, 64, 128, 256],
                      pipeline_depth=[2, 4])
    serial_sess = Session(device="v5e")
    t0 = time.perf_counter()
    serial_sess.sweep(specs, parallel=1)
    us_serial = (time.perf_counter() - t0) * 1e6
    par_sess = Session(device="v5e")
    t0 = time.perf_counter()
    par_sess.sweep(specs, parallel=8)
    us_parallel = (time.perf_counter() - t0) * 1e6
    t0 = time.perf_counter()
    par_sess.sweep(specs, parallel=8)   # every point memoized now
    us_memo = (time.perf_counter() - t0) * 1e6
    emit("sweep_grid_16pt", us_parallel,
         f"serial_us={us_serial:.0f};parallel_us={us_parallel:.0f};"
         f"memo_us={us_memo:.0f};"
         f"parallel_speedup={us_serial / max(us_parallel, 1e-9):.2f}x;"
         f"memo_speedup={us_serial / max(us_memo, 1e-9):.1f}x")


LAST_BATCH_SPEEDUP: float | None = None
LAST_WARM_COLLECTED: int | None = None


def profile_batch_vs_loop() -> None:
    """Columnar batch profiler vs the scalar per-point loop (PR 4).

    Model-evaluation phase only, on the reference 64-point grid: the same
    collected ``CounterSet``s go through (a) ``profile_counters`` point by
    point and (b) one ``CounterFrame`` + ``profile_batch`` pass (frame
    construction included — it is part of the batch path).  Also times a
    cold vs warm persistent sweep cache in a throwaway directory.  The
    measured batch speedup and the warm-re-sweep collection count both
    feed the ``--min-batch-speedup`` CI canary (which fails on a
    sub-threshold speedup OR a warm re-sweep that collected anything).
    """
    import shutil
    import tempfile

    from repro.core import profiler as prof_mod
    from repro.core.counters import CounterFrame

    rng = np.random.default_rng(0)
    base = WorkloadSpec.from_indices(
        rng.integers(0, 256, 1 << 15), 256, label="uniform-32K")
    specs = base.grid(waves_per_tile=[1, 2, 4, 8, 16, 32, 64, 128],
                      pipeline_depth=[1, 2, 4, 8],
                      overhead_cycles=[500.0, 2000.0])
    assert len(specs) == 64
    sess = session()
    csets = [sess.collect(s) for s in specs]
    dev = sess.device
    kw = dict(params=dev.scatter, chip=dev.chip, cache=dev.cache)

    us_loop = _timeit(lambda: [prof_mod.profile_counters(c, sess.table, **kw)
                               for c in csets])
    us_batch = _timeit(lambda: prof_mod.profile_batch(
        CounterFrame.from_sets(csets), sess.table, **kw))
    speedup = us_loop / max(us_batch, 1e-9)
    global LAST_BATCH_SPEEDUP
    LAST_BATCH_SPEEDUP = speedup

    tmp = tempfile.mkdtemp(prefix="repro-bench-sweepcache-")
    try:
        cold_sess = Session(device="v5e", persistent_cache=tmp)
        t0 = time.perf_counter()
        cold_sess.sweep(specs)
        us_cold = (time.perf_counter() - t0) * 1e6
        warm_sess = Session(device="v5e", persistent_cache=tmp)
        t0 = time.perf_counter()
        warm_sess.sweep(specs)
        us_warm = (time.perf_counter() - t0) * 1e6
        warm_collected = warm_sess.stats["collected"]
        global LAST_WARM_COLLECTED
        LAST_WARM_COLLECTED = warm_collected
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    emit("profile_batch_vs_loop_64pt", us_batch,
         f"loop_us={us_loop:.0f};batch_us={us_batch:.0f};"
         f"batch_speedup={speedup:.1f}x;"
         f"cold_cache_sweep_us={us_cold:.0f};"
         f"warm_cache_sweep_us={us_warm:.0f};"
         f"warm_collected={warm_collected};"
         f"warm_speedup={us_cold / max(us_warm, 1e-9):.1f}x")


LAST_COLLECT_SPEEDUP: float | None = None
LAST_COLLECT_WARM: int | None = None


def collect_batch_vs_loop() -> None:
    """Columnar ``collect_batch`` vs the scalar ``collect`` loop (PR 8).

    Counter-acquisition phase, on a 256-point grid of *distinct* index
    streams (so nothing memoizes away): the same specs go through (a)
    ``TraceProvider.collect`` point by point and (b) one
    ``TraceProvider.collect_batch`` call.  Row-wise bitwise equality of
    the two paths is asserted — the batch path is an acceleration, never
    a reinterpretation.  Also times a cold sharded sweep (two shards
    merging through one persistent cache directory) against the warm
    merged re-sweep, which must collect nothing.  The measured speedup
    and the warm collection count feed the ``--min-collect-speedup`` CI
    canary.
    """
    import shutil
    import tempfile

    from repro.analysis.providers.trace import TraceProvider
    from repro.core import counters as counters_mod

    rng = np.random.default_rng(0)
    streams = rng.integers(0, 256, size=(256, 1 << 10))
    specs = [WorkloadSpec.from_indices(streams[i], 256, label=f"pt{i:03d}",
                                       waves_per_tile=4)
             for i in range(256)]
    provider = TraceProvider()
    dev = session().device

    us_loop = _timeit(
        lambda: [provider.collect(s, dev) for s in specs], 1)
    us_batch = _timeit(
        lambda: provider.collect_batch(specs, dev), 1)
    speedup = us_loop / max(us_batch, 1e-9)
    global LAST_COLLECT_SPEEDUP
    LAST_COLLECT_SPEEDUP = speedup

    loop_sets = [provider.collect(s, dev) for s in specs]
    frame = provider.collect_batch(specs, dev)
    mismatches = sum(
        not counters_mod.bitwise_equal(frame.row(i), loop_sets[i])
        for i in range(len(specs)))
    assert mismatches == 0, \
        f"collect_batch differs from collect on {mismatches}/256 rows"

    tmp = tempfile.mkdtemp(prefix="repro-bench-collectcache-")
    try:
        t0 = time.perf_counter()
        for i in range(2):
            shard_sess = Session(device="v5e", persistent_cache=tmp)
            shard_sess.sweep(specs, shards=2, shard_index=i)
        us_cold = (time.perf_counter() - t0) * 1e6
        warm_sess = Session(device="v5e", persistent_cache=tmp)
        t0 = time.perf_counter()
        warm_sess.sweep(specs)
        us_warm = (time.perf_counter() - t0) * 1e6
        warm_collected = warm_sess.stats["collected"]
        global LAST_COLLECT_WARM
        LAST_COLLECT_WARM = warm_collected
    finally:
        shutil.rmtree(tmp, ignore_errors=True)

    emit("collect_batch_vs_loop_256pt", us_batch,
         f"loop_us={us_loop:.0f};batch_us={us_batch:.0f};"
         f"collect_speedup={speedup:.1f}x;bitwise_mismatches={mismatches};"
         f"cold_sharded_sweep_us={us_cold:.0f};"
         f"warm_merged_sweep_us={us_warm:.0f};"
         f"warm_collected={warm_collected}")


LAST_ADVISE: dict | None = None


def advise_search() -> None:
    """Advisor search over a 32-candidate frontier (PR 5).

    The advisor's scoring contract: every enumerated candidate is
    evaluated through ONE columnar ``CounterFrame``/``profile_batch``
    pass per frontier — never per-candidate scalar profiling — and a
    warm re-run against the persistent sweep cache collects nothing.
    Both invariants are measured here (by wrapping the profiler entry
    points and re-advising from a second session) and enforced in CI via
    ``--advise-gate``.
    """
    import shutil
    import tempfile

    from repro.advisor import (CasToFao, LaneInterleave, Replicate,
                               SetPipelineDepth, SetWavesPerTile)
    from repro.core import profiler as prof_mod
    from repro.core import timing

    # catalog sized so a depth-1 frontier enumerates exactly 32 legal
    # candidates on a CAS-class index stream at waves_per_tile=8
    catalog = (
        [SetWavesPerTile(w) for w in (1, 2, 3, 4, 5, 6, 7, 12, 16, 20, 24,
                                      28, 32, 40, 48, 56, 64, 96, 128, 192,
                                      256)]           # 21 (8 excluded)
        + [SetPipelineDepth(d) for d in (1, 4, 8)]    # 3 (2 is current)
        + [Replicate(f) for f in (2, 4, 8, 16, 32, 64)]   # 6
        + [LaneInterleave(), CasToFao()]              # 2
    )
    # clustered runs (sorted stream): maximal within-group contention,
    # and — unlike an all-equal stream — every catalog entry rewrites the
    # content (an interleave of all-zeros would dedup against the base)
    idx = np.repeat(np.arange(256, dtype=np.int64), (1 << 15) // 256)
    spec = WorkloadSpec.from_indices(
        idx, 256, label="clustered-32K-cas", job_class=timing.CAS,
        waves_per_tile=8)

    counts = {"batch": 0, "scalar": 0}
    orig_batch = prof_mod.profile_batch
    orig_scalar = prof_mod.profile_counters

    def counting_batch(*a, **kw):
        counts["batch"] += 1
        return orig_batch(*a, **kw)

    def counting_scalar(*a, **kw):
        counts["scalar"] += 1
        return orig_scalar(*a, **kw)

    tmp = tempfile.mkdtemp(prefix="repro-bench-advise-")
    prof_mod.profile_batch = counting_batch
    prof_mod.profile_counters = counting_scalar
    try:
        cold_sess = Session(device="v5e", persistent_cache=tmp)
        t0 = time.perf_counter()
        report = cold_sess.advise(spec, catalog=catalog, depth=1,
                                  beam_width=8, top_k=5)
        us_cold = (time.perf_counter() - t0) * 1e6
        warm_sess = Session(device="v5e", persistent_cache=tmp)
        t0 = time.perf_counter()
        warm_sess.advise(spec, catalog=catalog, depth=1, beam_width=8,
                         top_k=5)
        us_warm = (time.perf_counter() - t0) * 1e6
    finally:
        prof_mod.profile_batch = orig_batch
        prof_mod.profile_counters = orig_scalar
        shutil.rmtree(tmp, ignore_errors=True)

    top = report.best
    global LAST_ADVISE
    LAST_ADVISE = {
        "candidates": report.stats["candidates"],
        "frontiers": report.stats["frontiers"],
        "batch_evals": counts["batch"] // 2,   # two identical advise runs
        "scalar_evals": counts["scalar"],
        "warm_collected": warm_sess.stats["collected"],
    }
    emit("advise_search_32cand", us_cold,
         f"candidates={report.stats['candidates']};"
         f"frontiers={report.stats['frontiers']};"
         f"batch_evals_per_run={counts['batch'] // 2};"
         f"scalar_evals={counts['scalar']};"
         f"top={'+'.join(top.names)};speedup={top.speedup:.3f};"
         f"cold_us={us_cold:.0f};warm_us={us_warm:.0f};"
         f"warm_collected={warm_sess.stats['collected']}")


LAST_SERVICE: dict | None = None


def service_load() -> None:
    """Profiling-service load test (PR 9).

    Drives an in-process ``ProfilingService`` (the exact object behind
    ``repro serve``, minus the HTTP socket) through three phases: a cold
    48-job profile burst from 8 client threads (req/s), the same burst
    warm (per-job p50/p99 — every point must be a memo hit, zero new
    provider batches), and a breaker-trip/recovery cycle driven through
    ``FaultInjectionProvider.configure`` — fault_rate=1.0 on fresh specs
    until the primary breaker opens (requests keep answering 200, just
    degraded onto the fallback), then 0.0 and measure the time until the
    first non-degraded response.  ``--service-gate`` turns the
    invariants — never a non-200, zero warm collections, the breaker
    actually tripped, recovery after the faults clear, and a generous
    warm-p99 bound — into a CI gate.
    """
    import concurrent.futures

    from repro.service import ProfilingService, ServiceConfig

    def job(size_log2: int, seed: int) -> dict:
        return {"kind": "profile", "device": "v5e",
                "workload": {"workload": "indices", "size": 1 << size_log2,
                             "dist": "uniform", "seed": seed,
                             "waves_per_tile": 8}}

    burst = [job(10 + (i % 3), i) for i in range(48)]
    # nonzero construction-time rate so the fault wrapper exists at all;
    # zeroed before any measurement, then driven via configure()
    cfg = ServiceConfig(workers=4, queue_depth=64, retries=1,
                        backoff_base_s=0.001, call_timeout_s=5.0,
                        breaker_threshold=3, breaker_cooldown_s=0.2,
                        persistent_cache=False, fault_rate=0.5,
                        fault_seed=0)
    statuses: list[int] = []

    with ProfilingService(cfg) as svc, \
            concurrent.futures.ThreadPoolExecutor(8) as pool:
        svc.fault.configure(fault_rate=0.0)

        def run(payload):
            t0 = time.perf_counter()
            status, body = svc.handle(payload)
            return status, body, (time.perf_counter() - t0) * 1e3

        t0 = time.perf_counter()
        cold = list(pool.map(run, burst))
        cold_s = time.perf_counter() - t0
        statuses += [s for s, _, _ in cold]

        stats0 = svc.session("v5e").stats_snapshot()
        t0 = time.perf_counter()
        warm = list(pool.map(run, burst))
        warm_s = time.perf_counter() - t0
        statuses += [s for s, _, _ in warm]
        warm_batches = (svc.session("v5e").stats_snapshot()["batch_calls"]
                        - stats0["batch_calls"])
        p50, p99 = np.percentile([ms for _, _, ms in warm], [50, 99])

        # trip the primary's breaker: every attempt faults, so each
        # fresh (unmemoized) spec degrades onto the fallback and the
        # consecutive-failure count crosses breaker_threshold=3 within
        # two jobs at retries=1
        svc.fault.configure(fault_rate=1.0)
        trip = [run(job(10, 1000 + i)) for i in range(6)]
        statuses += [s for s, _, _ in trip]
        degraded = sum(bool(b.get("degraded")) for _, b, _ in trip)
        tripped = any(st["state"] == "open"
                      for st in svc.provider.breaker_states().values())

        svc.fault.configure(fault_rate=0.0)
        t0 = time.perf_counter()
        recovered = False
        recovery_ms = float("nan")
        for i in range(50):
            status, body, _ = run(job(10, 2000 + i))
            statuses.append(status)
            if status == 200 and not body["degraded"]:
                recovered = True
                recovery_ms = (time.perf_counter() - t0) * 1e3
                break
            time.sleep(cfg.breaker_cooldown_s / 2)

    global LAST_SERVICE
    LAST_SERVICE = {
        "not_200": sum(s != 200 for s in statuses),
        "warm_batches": int(warm_batches),
        "warm_p99_ms": float(p99),
        "tripped": tripped,
        "degraded_under_faults": degraded,
        "recovered": recovered,
    }
    emit("service_load_48job", warm_s / len(burst) * 1e6,
         f"req_per_s_cold={len(burst) / cold_s:.1f};"
         f"req_per_s_warm={len(burst) / warm_s:.1f};"
         f"warm_p50_ms={p50:.2f};warm_p99_ms={p99:.2f};"
         f"warm_batches={warm_batches};"
         f"not_200={LAST_SERVICE['not_200']};"
         f"breaker_tripped={int(tripped)};"
         f"degraded_under_faults={degraded};"
         f"recovery_ms={recovery_ms:.0f}")


LAST_OBS: dict | None = None


def heatmap_overhead() -> None:
    """Observability cost + heat-map consistency (PR 10).

    Times the same cold 16-point indices sweep through fresh sessions
    with telemetry enabled and disabled (interleaved, min over repeats
    so scheduler noise cancels); the instrumented pipeline may cost at
    most 3% over the uninstrumented one.  Alongside, runs the §5
    heat-map case: per-bin attribution must stay bitwise-consistent
    with the provider's counters, surface hot bins on the contended
    input, and show ``hist2``'s rotation strictly lowering the top-bin
    replay share.  ``--obs-gate`` turns all four into a CI gate.
    """
    from repro.core.counters import bitwise_equal
    from repro.obs import heatmap_for_spec, telemetry

    base = WorkloadSpec.from_indices(
        np.zeros(1 << 17, np.int64), 256, label="obs-overhead")
    specs = base.grid(waves_per_tile=[2, 4, 8, 16],
                      pipeline_depth=[2, 4, 6, 8])
    session()   # resolve the table cache before any timed run

    def run_once() -> float:
        sess = Session(device="v5e")    # fresh memo: collection really runs
        t0 = time.perf_counter()
        sess.analyze(specs)
        return time.perf_counter() - t0

    run_once()  # warm the interpreter/allocator paths
    on_times, off_times = [], []
    for _ in range(5):
        telemetry.set_enabled(True)
        on_times.append(run_once())
        with telemetry.disabled():
            off_times.append(run_once())
    telemetry.set_enabled(True)
    on_s, off_s = min(on_times), min(off_times)
    overhead_pct = (on_s - off_s) / off_s * 100.0

    img = make_image("solid", 1 << 14)
    shares, consistent, hot_bins = {}, True, 0
    for variant in ("hist", "hist2"):
        spec = WorkloadSpec.from_histogram(
            np.asarray(img), label=f"obs-{variant}", variant=variant)
        hm = heatmap_for_spec(spec)
        shares[variant] = hm.top_bin_share
        consistent &= bitwise_equal(hm.counters, session().collect(spec))
        consistent &= int(hm.hits.sum()) == (1 << 14) * img.shape[1]
        if variant == "hist":
            hot_bins = int(hm.hot_mask.sum())

    global LAST_OBS
    LAST_OBS = {
        "overhead_pct": float(overhead_pct),
        "consistent": bool(consistent),
        "localized": shares["hist2"] < shares["hist"],
        "hot_bins": hot_bins,
    }
    emit("heatmap_overhead_16pt", on_s * 1e6,
         f"overhead_pct={overhead_pct:.2f};"
         f"telemetry_off_ms={off_s * 1e3:.1f};"
         f"telemetry_on_ms={on_s * 1e3:.1f};"
         f"hist_share={shares['hist']:.4f};"
         f"hist2_share={shares['hist2']:.4f};"
         f"hot_bins={hot_bins};consistent={int(consistent)}")


def kernel_walltime() -> None:
    img = jnp.asarray(make_image("uniform", 1 << 16))
    us = _timeit(lambda: hist_ops.histogram(img).block_until_ready())
    emit("kernel_walltime_histogram_64kpx", us,
         f"{(1 << 16) * 4 / (us / 1e6) / 1e6:.1f}Mupd/s(interpret)")
    ids = jnp.asarray(np.random.default_rng(0).integers(0, 128, 1 << 14),
                      jnp.int32)
    vals = jnp.ones((1 << 14, 64), jnp.float32)
    us = _timeit(lambda: scat_ops.scatter_add(
        vals, ids, num_segments=128).block_until_ready())
    emit("kernel_walltime_scatter_add_16k", us,
         f"{(1 << 14) / (us / 1e6) / 1e6:.2f}Mrow/s(interpret)")


def roofline_table() -> None:
    pat = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun",
                       "*.json")
    files = sorted(glob.glob(pat))
    n_ok = n_skip = n_err = 0
    for f in files:
        r = json.load(open(f))
        if r["status"] == "ok":
            n_ok += 1
            t = r["roofline"]
            emit(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}",
                 r.get("compile_seconds", 0) * 1e6,
                 f"dominant={t['dominant']};useful={t['useful_ratio']:.3f};"
                 f"roofline={t['roofline_fraction']:.4f};"
                 f"compute_ms={t['compute_s'] * 1e3:.2f};"
                 f"memory_ms={t['memory_s'] * 1e3:.2f};"
                 f"collective_ms={t['collective_s'] * 1e3:.2f}")
        elif r["status"] == "skipped":
            n_skip += 1
        else:
            n_err += 1
    emit("roofline_summary", 0.0,
         f"ok={n_ok};skipped={n_skip};errors={n_err}")


ALL = [fig1_service_time_table, fig3_utilization_sweep, fig4_popc_vs_fao,
       fig5_reorder_speedup, sec5_model_vs_measured, lint_static_vs_trace,
       moe_dispatch_profile, sweep_grid_parallel, profile_batch_vs_loop,
       collect_batch_vs_loop, advise_search, service_load, heatmap_overhead,
       kernel_walltime, roofline_table]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--min-batch-speedup", type=float, default=None,
                    help="perf canary: exit 1 if profile_batch_vs_loop "
                         "measures less than this batch-vs-loop speedup "
                         "(requires the benchmark to have run)")
    ap.add_argument("--min-collect-speedup", type=float, default=None,
                    help="perf canary: exit 1 if collect_batch_vs_loop "
                         "measures less than this batch-vs-scalar "
                         "collection speedup, or its warm merged re-sweep "
                         "collected anything")
    ap.add_argument("--service-gate", action="store_true",
                    help="CI gate: exit 1 unless service_load answered "
                         "every request with 200 (warm hits collecting "
                         "nothing, warm p99 under 500ms), tripped the "
                         "primary breaker under injected faults, and "
                         "recovered once the faults cleared")
    ap.add_argument("--advise-gate", action="store_true",
                    help="CI gate: exit 1 unless advise_search scored its "
                         "32-candidate frontier via one batch evaluation "
                         "(no scalar profiling) and the warm re-run "
                         "collected nothing")
    ap.add_argument("--obs-gate", action="store_true",
                    help="CI gate: exit 1 unless heatmap_overhead "
                         "measured < 3%% telemetry overhead, heat-map "
                         "counters bit-matched the provider, hot bins "
                         "surfaced on the contended input, and hist2's "
                         "top-bin share came out below hist's")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for fn in ALL:
        if args.only and args.only not in fn.__name__:
            continue
        fn()
    if args.min_batch_speedup is not None:
        import sys
        if LAST_BATCH_SPEEDUP is None:
            print("error: --min-batch-speedup set but profile_batch_vs_loop "
                  "did not run", file=sys.stderr)
            sys.exit(2)
        if LAST_BATCH_SPEEDUP < args.min_batch_speedup:
            print(f"error: batch path speedup {LAST_BATCH_SPEEDUP:.2f}x "
                  f"below the {args.min_batch_speedup:.2f}x canary "
                  f"threshold", file=sys.stderr)
            sys.exit(1)
        if LAST_WARM_COLLECTED:
            print(f"error: warm-cache re-sweep collected "
                  f"{LAST_WARM_COLLECTED} point(s), expected 0 — the "
                  f"persistent sweep cache is not being hit",
                  file=sys.stderr)
            sys.exit(1)
    if args.min_collect_speedup is not None:
        import sys
        if LAST_COLLECT_SPEEDUP is None:
            print("error: --min-collect-speedup set but "
                  "collect_batch_vs_loop did not run", file=sys.stderr)
            sys.exit(2)
        if LAST_COLLECT_SPEEDUP < args.min_collect_speedup:
            print(f"error: collect_batch speedup "
                  f"{LAST_COLLECT_SPEEDUP:.2f}x below the "
                  f"{args.min_collect_speedup:.2f}x canary threshold",
                  file=sys.stderr)
            sys.exit(1)
        if LAST_COLLECT_WARM:
            print(f"error: warm merged re-sweep collected "
                  f"{LAST_COLLECT_WARM} point(s), expected 0 — shard "
                  f"results are not merging through the persistent cache",
                  file=sys.stderr)
            sys.exit(1)
    if args.service_gate:
        import sys
        if LAST_SERVICE is None:
            print("error: --service-gate set but service_load did not run",
                  file=sys.stderr)
            sys.exit(2)
        s = LAST_SERVICE
        problems = []
        if s["not_200"]:
            problems.append(f"{s['not_200']} non-200 response(s), "
                            f"expected none")
        if s["warm_batches"]:
            problems.append(f"warm burst issued {s['warm_batches']} "
                            f"provider batch(es), expected 0 (memo miss)")
        if s["warm_p99_ms"] >= 500.0:
            problems.append(f"warm p99 {s['warm_p99_ms']:.0f}ms over the "
                            f"500ms bound")
        if not s["tripped"]:
            problems.append("primary breaker never opened under "
                            "fault_rate=1.0")
        if not s["degraded_under_faults"]:
            problems.append("no degraded responses while faults were "
                            "injected — the fallback chain did not engage")
        if not s["recovered"]:
            problems.append("no non-degraded response after faults "
                            "cleared — breaker never re-closed")
        if problems:
            print("error: service_load gate failed: "
                  + "; ".join(problems), file=sys.stderr)
            sys.exit(1)
    if args.obs_gate:
        import sys
        if LAST_OBS is None:
            print("error: --obs-gate set but heatmap_overhead did not run",
                  file=sys.stderr)
            sys.exit(2)
        o = LAST_OBS
        problems = []
        if o["overhead_pct"] >= 3.0:
            problems.append(f"telemetry overhead {o['overhead_pct']:.2f}% "
                            f"at or over the 3% bound")
        if not o["consistent"]:
            problems.append("heat-map counters diverged from the "
                            "provider's collect() (bit-consistency "
                            "broken)")
        if o["hot_bins"] < 1:
            problems.append("no hot bins surfaced on the contended "
                            "solid histogram")
        if not o["localized"]:
            problems.append("hist2 top-bin share not strictly below "
                            "hist — the §5 localization signal is gone")
        if problems:
            print("error: heatmap_overhead gate failed: "
                  + "; ".join(problems), file=sys.stderr)
            sys.exit(1)
    if args.advise_gate:
        import sys
        if LAST_ADVISE is None:
            print("error: --advise-gate set but advise_search did not run",
                  file=sys.stderr)
            sys.exit(2)
        a = LAST_ADVISE
        problems = []
        if a["candidates"] != 32:
            problems.append(f"enumerated {a['candidates']} candidates, "
                            f"expected 32")
        if a["batch_evals"] != a["frontiers"]:
            problems.append(f"{a['batch_evals']} batch evaluations for "
                            f"{a['frontiers']} frontier(s) — must be one "
                            f"per frontier")
        if a["scalar_evals"]:
            problems.append(f"{a['scalar_evals']} per-candidate scalar "
                            f"profile_counters call(s), expected 0")
        if a["warm_collected"]:
            problems.append(f"warm re-advise collected "
                            f"{a['warm_collected']} point(s), expected 0")
        if problems:
            print("error: advise_search gate failed: "
                  + "; ".join(problems), file=sys.stderr)
            sys.exit(1)


if __name__ == "__main__":
    main()
