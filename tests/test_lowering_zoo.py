"""Reduced ``build_lowered`` smoke over the full config zoo.

Every registered architecture must lower end to end on the audit's
reduced smoke geometry — the same path ``repro audit --reduced`` and the
lint/audit CI gates depend on.  One applicable step per config keeps the
sweep sub-minute while still exercising every architecture module,
``shape_tuned_config`` and the pre-SPMD compat mesh.
"""

from __future__ import annotations

import dataclasses

import pytest

from repro.audit.zoo import AUDIT_SHAPES, _REDUCED_GEOM
from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.lowering import (build_lowered, pre_optimization_hlo,
                                   shape_tuned_config)
from repro.launch.mesh import compat_make_mesh


def _reduced_shape(step: str):
    shape = SHAPES[AUDIT_SHAPES[step]]
    gb, sl = _REDUCED_GEOM[step]
    return dataclasses.replace(shape, global_batch=gb, seq_len=sl)


def _first_applicable(cfg):
    """(step, shape) for the first audit step this config supports."""
    for step in AUDIT_SHAPES:
        shape = _reduced_shape(step)
        ok, _why = shape_applicable(cfg, shape)
        if ok:
            return step, shape
    return None, None


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_reduced_lowering_smoke(arch):
    cfg = get_config(arch).reduced()
    step, shape = _first_applicable(cfg)
    assert step is not None, f"{arch}: no applicable audit step"
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    cfg_t, loss_chunk, train_kw = shape_tuned_config(cfg, shape, "base")
    lowered = build_lowered(cfg_t, shape, mesh, loss_chunk=loss_chunk,
                            train_kw=train_kw)
    text = pre_optimization_hlo(lowered)
    assert "HloModule" in text
    # pre-SPMD lowering carries the *global* shapes: a real module body,
    # not a stub
    assert text.count("\n") > 20, f"{arch}/{step}: suspiciously small HLO"
