import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

try:
    import hypothesis  # noqa: F401
except ImportError:
    # Optional dev dependency (requirements-dev.txt); fall back to the
    # deterministic stub so the property-test modules still collect + run.
    import _hypothesis_stub

    _hypothesis_stub.install()
