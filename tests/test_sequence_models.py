"""RWKV6 and Mamba2 math: chunked == scan == stepwise decode (hypothesis)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.models import mamba2, rwkv6


def _rwkv_cfg():
    return rwkv6.RWKVConfig(d_model=64, head_dim=16, decay_lora=8,
                            mix_lora=4, d_ff=128, dtype="float32")


@settings(max_examples=10, deadline=None)
@given(t=st.integers(4, 48), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 1000))
def test_rwkv_chunked_equals_scan(t, chunk, seed):
    cfg = _rwkv_cfg()
    p = rwkv6.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, t, 64),
                          jnp.float32)
    y_scan = rwkv6.time_mix(p, x, cfg, impl="scan")
    y_chunk = rwkv6.time_mix(p, x, cfg, impl="chunked", chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_scan), np.asarray(y_chunk),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_decode_equals_scan():
    cfg = _rwkv_cfg()
    p = rwkv6.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 64), jnp.float32)
    y = rwkv6.time_mix(p, x, cfg, impl="scan")
    st_ = rwkv6.init_state(cfg, 2)
    st_ = {"s": st_["s"], "last": st_["last"].astype(jnp.float32)}
    outs = []
    for t in range(12):
        o, st_ = rwkv6.time_mix_decode(p, x[:, t:t + 1], st_, cfg)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.concatenate(outs, 1)),
        rtol=1e-3, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(t=st.integers(4, 40), chunk=st.sampled_from([4, 8]),
       seed=st.integers(0, 1000))
def test_mamba2_decode_equals_chunked(t, chunk, seed):
    cfg = mamba2.Mamba2Config(d_model=32, state_dim=8, head_dim=8, expand=2,
                              chunk=chunk, dtype="float32")
    p = mamba2.init(jax.random.PRNGKey(seed), cfg)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, t, 32),
                          jnp.float32) * 0.5
    y = mamba2.apply(p, x, cfg)
    st_ = mamba2.init_state(cfg, 1)
    st_ = {"h": st_["h"], "conv": st_["conv"].astype(jnp.float32)}
    outs = []
    for i in range(t):
        o, st_ = mamba2.decode_step(p, x[:, i:i + 1], st_, cfg)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(jnp.concatenate(outs, 1)),
        rtol=2e-3, atol=2e-3)


def test_blockwise_attention_equals_dense():
    from repro.models import attention
    cfg = attention.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2,
                               head_dim=16, dtype="float32")
    p = attention.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64), jnp.float32)
    dense, _ = attention.attend(p, x, cfg)
    block, _ = attention.attend(p, x, cfg, kv_block=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                               rtol=1e-4, atol=1e-4)


def test_blockwise_attention_with_window_and_softcap():
    from repro.models import attention
    cfg = attention.AttnConfig(d_model=64, num_heads=4, num_kv_heads=4,
                               head_dim=16, window=24, logit_softcap=20.0,
                               dtype="float32")
    p = attention.init(jax.random.PRNGKey(2), cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 64, 64), jnp.float32)
    dense, _ = attention.attend(p, x, cfg)
    block, _ = attention.attend(p, x, cfg, kv_block=16)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(block),
                               rtol=1e-4, atol=1e-4)
