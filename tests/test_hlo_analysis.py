"""HLO cost analyzer: trip-count awareness, dot FLOPs, collective parsing."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hlo


def test_scan_trip_count_flops():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)

    def f(x, w):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        return jax.lax.scan(body, x, w)[0]

    c = jax.jit(f).lower(x, w).compile()
    mc = hlo.analyze_module(c.as_text(), 1)
    expect = 2 * 128 ** 3 * 7
    assert 1.0 <= mc.flops / expect < 1.25
    assert mc.unresolved_loops == 0


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(x):
        def outer(h, _):
            def inner(h2, _):
                return jnp.tanh(h2 @ h2), None
            h, _ = jax.lax.scan(inner, h, None, length=3)
            return h, None
        return jax.lax.scan(outer, x, None, length=5)[0]

    c = jax.jit(f).lower(x).compile()
    mc = hlo.analyze_module(c.as_text(), 1)
    expect = 2 * 64 ** 3 * 15
    assert 0.9 <= mc.flops / expect < 1.3, mc.flops / expect


def test_shape_bytes():
    assert hlo.shape_bytes("f32[2,3]") == 24
    assert hlo.shape_bytes("bf16[10]{0}") == 20
    assert hlo.shape_bytes("(f32[2], s32[4])") == 24
    assert hlo.shape_bytes("pred[]") == 1
    assert hlo.shape_bytes("token[]") == 0


def test_shape_bytes_tuples_with_layouts():
    # tuple elements carrying layout annotations (real dump syntax)
    assert hlo.shape_bytes("(f32[8,128]{1,0}, s32[])") == 8 * 128 * 4 + 4
    # one level of tuple nesting
    assert hlo.shape_bytes("((f32[8,128]{1,0}, s32[]), f32[4]{0})") \
        == 8 * 128 * 4 + 4 + 16
    # bounded dynamic dimensions count their bound
    assert hlo.shape_bytes("s32[<=16]") == 64
    assert hlo.shape_bytes("s32[<=16]{0}") == 64


def test_instr_re_tuple_results():
    m = hlo._INSTR_RE.match(
        "  while.1 = (f32[8,128]{1,0}, s32[]) while(tuple.0), "
        "condition=cond, body=body")
    assert m is not None
    name, shape, opcode = m.group(1), m.group(2), m.group(3)
    assert (name, shape, opcode) \
        == ("while.1", "(f32[8,128]{1,0}, s32[])", "while")
    m = hlo._INSTR_RE.match(
        "  t = ((f32[8,128]{1,0}, s32[]), f32[4]{0}) tuple(a, b, c)")
    assert m is not None and m.group(3) == "tuple"
    m = hlo._INSTR_RE.match("  d = s32[<=16]{0} add(a, b)")
    assert m is not None and m.group(2) == "s32[<=16]{0}"


def _golden(name):
    import gzip
    import pathlib
    path = pathlib.Path(__file__).parent / "data" / name
    return gzip.decompress(path.read_bytes()).decode()


def test_parse_golden_granite_decode():
    """Real pre-optimization dump: bare computation headers, tuple-shaped
    while carries, no layout-free signatures."""
    text = _golden("granite_moe_1b_a400m__decode.hlo.gz")
    comps = hlo.parse_computations(text)
    assert len(comps) > 20
    entry = hlo.find_entry(text)
    assert entry is not None
    # tuple-result instructions must be walked, not skipped
    tuple_instrs = [i for c in comps.values() for i in c
                    if i.result.startswith("(")]
    assert tuple_instrs
    mc = hlo.analyze_module(text, 1)
    assert mc.unresolved_loops == 0


def test_parse_golden_whisper_train():
    text = _golden("whisper_small__train.hlo.gz")
    comps = hlo.parse_computations(text)
    assert len(comps) > 50
    assert hlo.find_entry(text) is not None
    n_instr = sum(len(c) for c in comps.values())
    assert n_instr > 2000


def test_ring_wire_model():
    rw = hlo.CollectiveOp.ring_wire_bytes
    assert rw("all-gather", 100, 4) == 300
    assert rw("all-reduce", 100, 4) == 150
    assert rw("reduce-scatter", 100, 4) == 75
    assert rw("collective-permute", 100, 4) == 100
    assert rw("all-reduce", 100, 1) == 0


def test_collectives_detected_in_sharded_program():
    if len(jax.devices()) < 1:
        return
    # single-device: jit a psum via shard_map over a 1-axis mesh still emits
    # an all-reduce in the unoptimized case only; instead parse a canned line
    text = """
ENTRY %main (p0: f32[8,8]) -> f32[8,8] {
  %p0 = f32[8,8]{1,0} parameter(0)
  ROOT %ar = f32[8,8]{1,0} all-reduce(%p0), replica_groups=[2,4]<=[8], to_apply=%add
}
"""
    mc = hlo.analyze_module(text, 8)
    assert len(mc.collectives) == 1
    op = mc.collectives[0]
    assert op.opcode == "all-reduce"
    assert op.operand_bytes == 256
    assert op.group_size == 4
    np.testing.assert_allclose(op.wire_bytes, 2 * 256 * 3 / 4)


def test_memory_analysis_dict_tolerant():
    c = jax.jit(lambda x: x + 1).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)).compile()
    d = hlo.memory_analysis_dict(c)
    assert isinstance(d, dict)
