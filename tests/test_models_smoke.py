"""Per-arch smoke tests (assignment requirement): reduced same-family
config, one forward + one train step on CPU, output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models.registry import build_model, make_batch
from repro.optim import adamw
from repro.train import step as train_mod

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 32)
    if cfg.family == "audio":
        logits, _ = model.forward(params, batch["tokens"], batch["frames"])
    else:
        logits, _ = model.forward(params, batch["tokens"],
                                  image_embeds=batch.get("image_embeds"))
    assert logits.shape == (2, 32, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    tcfg = train_mod.TrainConfig(accum_steps=2)
    ocfg = adamw.AdamWConfig(warmup_steps=1, total_steps=10)
    step = jax.jit(train_mod.make_train_step(model, tcfg, ocfg))
    state = train_mod.init_state(model, jax.random.PRNGKey(0))
    batch = make_batch(cfg, 4, 32)
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["xent"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        state["params"], new_state["params"])
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ["qwen2-72b", "gemma2-27b", "rwkv6-7b"])
def test_full_config_param_count_sane(arch):
    """Full configs only via analytics (no allocation)."""
    cfg = get_config(arch)
    n = cfg.param_count()
    expected = {"qwen2-72b": 72e9, "gemma2-27b": 27e9, "rwkv6-7b": 7e9}[arch]
    assert 0.5 * expected < n < 1.7 * expected, n
