"""CLI smoke + equivalence tests: every subcommand, every format.

Most tests drive ``repro.cli.main(argv)`` in-process (fast, debuggable);
one subprocess test proves the ``python -m repro`` entry point itself
(module ``__main__`` wiring, import order) stays launchable.
"""

import csv as csv_mod
import io
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.analysis import Session, WorkloadSpec, get_device
from repro.cli import build_parser, main
from repro.core.profiler import CacheModel

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(autouse=True)
def _isolate_artifacts(tmp_path, monkeypatch):
    """Default results/cli artifacts land in a tmpdir, not the repo."""
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "results"))
    yield


def run_cli(argv, capsys):
    rc = main(argv)
    out = capsys.readouterr().out
    return rc, out


# -- devices ------------------------------------------------------------------


def test_devices_text(capsys):
    rc, out = run_cli(["devices"], capsys)
    assert rc == 0
    assert "v5e" in out and "v5p" in out
    assert "registered device(s)" in out


def test_devices_json(capsys):
    rc, out = run_cli(["devices", "--format", "json"], capsys)
    assert rc == 0
    rows = json.loads(out)
    assert {r["name"] for r in rows} >= {"v5e", "v5p"}
    assert {"description", "cores", "clock_ghz", "table_cached"} \
        <= set(rows[0])


def test_python_m_repro_subprocess():
    """The real entry point: ``python -m repro`` must stay launchable."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro", "devices"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=120)
    assert proc.returncode == 0, proc.stderr
    assert "v5e" in proc.stdout


# -- profile ------------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["text", "json", "csv"])
def test_profile_indices_formats(capsys, fmt):
    rc, out = run_cli([
        "profile", "--workload", "indices", "--size", "2^14",
        "--dist", "solid", "--waves-per-tile", "32", "--format", fmt], capsys)
    assert rc == 0
    if fmt == "json":
        payload = json.loads(out)
        assert payload["points"][0]["bottleneck"] == "scatter"
    elif fmt == "csv":
        rows = list(csv_mod.DictReader(io.StringIO(out)))
        assert rows[0]["bottleneck"] == "scatter"
    else:
        assert "scatter" in out


def test_profile_histogram_variant(capsys):
    rc, out = run_cli([
        "profile", "--workload", "histogram", "--pixels", "2^12",
        "--dist", "solid", "--variant", "hist2", "--format", "json"], capsys)
    assert rc == 0
    assert "hist2" in json.loads(out)["points"][0]["label"]


def test_profile_scatter(capsys):
    rc, out = run_cli([
        "profile", "--workload", "scatter", "--size", "2^13",
        "--num-segments", "64", "--format", "json"], capsys)
    assert rc == 0
    assert json.loads(out)["points"][0]["e"] > 1.0


def test_profile_output_file(capsys, tmp_path):
    out_file = tmp_path / "report.json"
    rc, out = run_cli([
        "profile", "--size", "2^12", "--format", "json",
        "--output", str(out_file)], capsys)
    assert rc == 0
    assert json.loads(out_file.read_text()) == json.loads(out)


def test_profile_rejects_multi_values(capsys):
    rc = main(["profile", "--size", "4096"])
    assert rc == 0
    # nargs is single-valued on profile: a second value is an argparse error
    with pytest.raises(SystemExit):
        build_parser().parse_args(["profile", "--size", "4096", "8192"])
    capsys.readouterr()


def test_unknown_device_is_a_clean_error(capsys):
    rc = main(["profile", "--size", "2^12", "--device", "h100"])
    err = capsys.readouterr().err
    assert rc == 2
    assert "h100" in err and "v5e" in err


# -- sweep --------------------------------------------------------------------


def test_sweep_grid_concurrent_roundtrip(capsys):
    """Acceptance: >=8-point grid, concurrent, csv and json round-trip."""
    argv = ["sweep", "--workload", "indices", "--size", "2^13", "2^14",
            "--dist", "uniform", "--waves-per-tile", "4", "8", "16", "32",
            "--jobs", "4", "--no-artifact"]
    rc, out = run_cli(argv + ["--format", "csv"], capsys)
    assert rc == 0
    rows = list(csv_mod.DictReader(io.StringIO(out)))
    assert len(rows) == 8                    # 2 sizes x 4 occupancies
    assert {"label", "bottleneck", "U_scatter", "e"} <= set(rows[0])
    assert all(float(r["U_scatter"]) >= 0 for r in rows)

    rc, out = run_cli(argv + ["--format", "json"], capsys)
    assert rc == 0
    payload = json.loads(out)
    assert len(payload["points"]) == 8
    assert [p["label"] for p in payload["points"]] == \
        [r["label"] for r in rows]           # same order both formats


def test_sweep_matches_session_api(capsys, tmp_path):
    """CLI sweep numbers are bit-identical to the Session API's."""
    rc, out = run_cli([
        "sweep", "--size", "2^14", "--dist", "uniform", "--seed", "3",
        "--waves-per-tile", "4", "8", "--format", "json", "--no-artifact"],
        capsys)
    assert rc == 0
    cli_points = json.loads(out)["points"]

    rng = np.random.default_rng(3)
    idx = rng.integers(0, 256, 1 << 14)
    specs = WorkloadSpec.from_indices(
        idx, 256, label="uniform-16384").grid(waves_per_tile=[4, 8])
    api = Session("v5e").sweep(specs)
    for got, prof in zip(cli_points, api.profiles):
        assert got["label"] == prof.label
        assert got["scatter_model_U"] == prof.scatter_utilization  # bit-equal
        assert got["e"] == prof.e


def test_sweep_text_format_and_artifact(capsys, tmp_path):
    out_file = tmp_path / "sweep.txt"
    rc, out = run_cli([
        "sweep", "--size", "2^13", "--waves-per-tile", "4", "8",
        "--output", str(out_file)], capsys)
    assert rc == 0
    assert "sweep on v5e (2 points)" in out
    assert out_file.read_text() == out


def test_sweep_multi_device_csv(capsys):
    rc, out = run_cli([
        "sweep", "--size", "2^13", "--waves-per-tile", "4", "8",
        "--devices", "v5e", "v5p", "--format", "csv", "--no-artifact"],
        capsys)
    assert rc == 0
    rows = list(csv_mod.DictReader(io.StringIO(out)))
    assert len(rows) == 4
    assert [r["device"] for r in rows] == ["v5e", "v5e", "v5p", "v5p"]


def test_sweep_user_label_stays_unique_per_size(capsys):
    """--label + multi-value sizes must not collapse rows to one name."""
    rc, out = run_cli([
        "sweep", "--size", "2^13", "2^14", "--label", "foo",
        "--format", "csv", "--no-artifact"], capsys)
    assert rc == 0
    labels = [r["label"] for r in csv_mod.DictReader(io.StringIO(out))]
    assert labels == ["foo-8192", "foo-16384"]
    # single point: the label is used verbatim
    rc, out = run_cli([
        "profile", "--size", "2^13", "--label", "foo", "--format", "json"],
        capsys)
    assert json.loads(out)["points"][0]["label"] == "foo"


def test_sweep_warm_cache_skips_collection(capsys, tmp_path, monkeypatch):
    """Acceptance: a repeated CLI sweep does zero counter collection.

    Each ``main()`` call builds a fresh Session (empty in-process memo),
    so the second run exercises the persistent results/cache/ path the
    way a new process would.
    """
    from repro.analysis.providers.trace import TraceProvider

    calls = []
    orig_collect = TraceProvider.collect
    orig_batch = TraceProvider.collect_batch

    def counting(self, spec, device):
        calls.append(spec.label)
        return orig_collect(self, spec, device)

    def counting_batch(self, specs, device, **kw):
        calls.extend(s.label for s in specs)
        return orig_batch(self, specs, device, **kw)

    monkeypatch.setattr(TraceProvider, "collect", counting)
    monkeypatch.setattr(TraceProvider, "collect_batch", counting_batch)
    argv = ["sweep", "--size", "2^13", "--waves-per-tile", "4", "8",
            "--format", "csv", "--no-artifact"]
    rc, out1 = run_cli(argv, capsys)
    assert rc == 0
    assert len(calls) == 2
    assert (tmp_path / "results" / "cache").exists()   # REPRO_RESULTS root
    rc, out2 = run_cli(argv, capsys)
    assert rc == 0
    assert len(calls) == 2                  # warm re-sweep: zero collection
    assert out2 == out1                     # and a bit-identical report
    # --no-cache opts out: the same sweep collects again
    rc, out3 = run_cli(argv + ["--no-cache"], capsys)
    assert rc == 0
    assert len(calls) == 4
    assert out3 == out1


def test_sweep_default_artifact_under_results(capsys, tmp_path):
    rc, _ = run_cli(["sweep", "--size", "2^12", "--format", "csv"], capsys)
    assert rc == 0
    artifact = tmp_path / "results" / "cli" / "sweep-v5e.csv"
    assert artifact.exists()
    assert list(csv_mod.DictReader(io.StringIO(artifact.read_text())))


# -- validate -----------------------------------------------------------------


@pytest.mark.parametrize("fmt", ["text", "json"])
def test_validate_trace_vs_kernel(capsys, fmt):
    rc, out = run_cli([
        "validate", "--workload", "histogram", "--pixels", "2^12",
        "--dist", "solid", "--format", fmt], capsys)
    assert rc == 0
    if fmt == "json":
        payload = json.loads(out)
        assert payload["reference"] == "trace"
        kernel = [c for c in payload["comparisons"]
                  if c["provider"] == "kernel"][0]
        assert kernel["rel_err"]["e"] == 0.0     # paper §5: exact match
    else:
        assert "max relative error: 0.00%" in out


def test_validate_hlo_workload_autoroutes(capsys, tmp_path):
    hlo = tmp_path / "mod.txt"
    hlo.write_text(
        "HloModule m\nENTRY e {\n  p = f32[128,128]{1,0} parameter(0)\n  "
        "ROOT a = f32[128,128]{1,0} add(p, p)\n}\n")
    rc, out = run_cli([
        "profile", "--workload", "hlo", "--hlo-file", str(hlo),
        "--format", "json"], capsys)
    assert rc == 0
    point = json.loads(out)["points"][0]
    assert point["bottleneck"] in ("hbm", "mxu", "none")


# -- compare ------------------------------------------------------------------


def _compare_argv(fmt):
    return ["compare", "--device", "v5e", "--kind", "solid",
            "--pixels", "2^12", "2^14", "--format", fmt, "--no-artifact"]


@pytest.mark.parametrize("fmt", ["text", "json", "csv"])
def test_compare_formats(capsys, fmt):
    rc, out = run_cli(_compare_argv(fmt), capsys)
    assert rc == 0
    if fmt == "json":
        payload = json.loads(out)
        assert {"device", "points", "size_shifts", "verdict"} == set(payload)
        assert len(payload["points"]) == 2
    elif fmt == "csv":
        rows = list(csv_mod.DictReader(io.StringIO(out)))
        assert len(rows) == 2
        assert {"kind", "pixels", "hist_U", "hist2_U", "speedup",
                "shift"} <= set(rows[0])
    else:
        assert "verdict:" in out and "hist2" in out


def test_compare_bit_identical_to_session_api(capsys):
    """Acceptance: compare == the Session API run of the same specs."""
    rc, out = run_cli(_compare_argv("json"), capsys)
    assert rc == 0
    points = json.loads(out)["points"]

    device = get_device("v5e").with_(cache=CacheModel(
        llc_bytes=1 << 21, miss_latency_cycles=800, hide_concurrency=48))
    sess = Session(device)
    from repro.data.images import make_image
    for point in points:
        px = int(point["pixels"])
        img = make_image("solid", px, seed=0)
        pair = [WorkloadSpec.from_histogram(
                    img, label=f"solid/{px}px/{v}", variant=v,
                    waves_per_tile=8)
                for v in ("hist", "hist2")]
        result = sess.sweep(pair)
        h, h2 = result.profiles
        assert point["hist_U"] == h.scatter_utilization          # bit-equal
        assert point["hist2_U"] == h2.scatter_utilization
        assert point["speedup"] == float(result.speedup_vs_first[1])
        assert point["hist_bottleneck"] == h.bottleneck


def test_compare_solid_speedup_exceeds_uniform(capsys):
    rc, out = run_cli([
        "compare", "--kind", "solid", "uniform", "--pixels", "2^14",
        "--format", "json", "--no-artifact"], capsys)
    assert rc == 0
    points = json.loads(out)["points"]
    by_kind = {p["kind"]: p for p in points}
    # reordering pays where contention is: solid >> uniform
    assert by_kind["solid"]["speedup"] > by_kind["uniform"]["speedup"]


# -- help text ----------------------------------------------------------------


def test_help_lists_all_subcommands(capsys):
    with pytest.raises(SystemExit):
        main(["--help"])
    out = capsys.readouterr().out
    for cmd in ("devices", "profile", "sweep", "advise", "validate",
                "compare"):
        assert cmd in out


@pytest.mark.parametrize(
    "cmd", ["devices", "profile", "sweep", "advise", "validate", "compare"])
def test_subcommand_help(capsys, cmd):
    with pytest.raises(SystemExit):
        main([cmd, "--help"])
    out = capsys.readouterr().out
    assert "--format" in out


# -- advise -------------------------------------------------------------------


ADVISE_ARGV = ["advise", "--workload", "indices", "--size", "2^12",
               "--dist", "solid", "--waves-per-tile", "8", "--top-k", "3"]


def test_advise_text(capsys):
    rc, out = run_cli(ADVISE_ARGV + ["--no-artifact", "--no-cache"], capsys)
    assert rc == 0
    assert "== advisor:" in out
    assert "rank  1" in out
    assert "baseline: bottleneck=" in out


def test_advise_json_matches_session(capsys):
    rc, out = run_cli(ADVISE_ARGV + [
        "--format", "json", "--no-artifact", "--no-cache"], capsys)
    assert rc == 0
    payload = json.loads(out)
    idx = np.zeros(1 << 12, np.int64)
    spec = WorkloadSpec.from_indices(idx, 256, label="solid-4096",
                                     waves_per_tile=8)
    api = Session("v5e").advise(spec, top_k=3)
    got = payload["candidates"]
    want = api.to_rows()
    assert [r["label"] for r in got] == [r["label"] for r in want]
    assert [r["predicted_speedup"] for r in got] \
        == [r["predicted_speedup"] for r in want]       # bit-equal


def test_advise_csv_and_artifact(capsys, tmp_path):
    rc, out = run_cli(ADVISE_ARGV + ["--format", "csv"], capsys)
    assert rc == 0
    rows = list(csv_mod.DictReader(io.StringIO(out)))
    assert len(rows) == 3
    assert rows[0]["rank"] == "1"
    artifact = tmp_path / "results" / "cli" / "advise-v5e.csv"
    assert artifact.exists()
    # capsys normalizes the csv writer's \r\n: compare parsed rows
    assert list(csv_mod.DictReader(io.StringIO(artifact.read_text()))) \
        == rows


def test_advise_warm_cache_skips_collection(capsys, tmp_path):
    from repro.analysis.providers.trace import TraceProvider

    calls = []
    orig = TraceProvider.collect
    orig_batch = TraceProvider.collect_batch

    def counting(self, spec, device):
        calls.append(spec.label)
        return orig(self, spec, device)

    def counting_batch(self, specs, device, **kw):
        calls.extend(s.label for s in specs)
        return orig_batch(self, specs, device, **kw)

    try:
        TraceProvider.collect = counting
        TraceProvider.collect_batch = counting_batch
        argv = ADVISE_ARGV + ["--format", "json", "--no-artifact"]
        rc, out1 = run_cli(argv, capsys)
        assert rc == 0
        assert calls
        n_cold = len(calls)
        rc, out2 = run_cli(argv, capsys)
        assert rc == 0
        assert len(calls) == n_cold     # warm re-advise: zero collection
        cold, warm = json.loads(out1), json.loads(out2)
        # collection stats legitimately differ (that is the point);
        # the ranking and every prediction must be bit-identical
        assert warm["candidates"] == cold["candidates"]
        assert warm["baseline"] == cold["baseline"]
        assert warm["stats"]["collected"] == 0
        assert warm["stats"]["disk_hits"] > 0
    finally:
        TraceProvider.collect = orig
        TraceProvider.collect_batch = orig_batch


def test_advise_rejects_multi_point(capsys):
    # advise is single-point: --size is not multi-valued, argparse rejects
    with pytest.raises(SystemExit) as exc:
        main(["advise", "--size", "2^12", "2^13", "--no-artifact"])
    assert exc.value.code == 2


# -- format hardening + cache footer (satellite) ------------------------------


@pytest.mark.parametrize("cmd,argv", [
    ("devices", ["devices"]),
    ("validate", ["validate", "--workload", "histogram",
                  "--pixels", "2^10"]),
])
def test_text_json_only_commands_reject_csv_up_front(capsys, cmd, argv):
    """argparse ``choices`` rejects csv before any work happens."""
    with pytest.raises(SystemExit) as exc:
        main(argv + ["--format", "csv"])
    assert exc.value.code == 2
    err = capsys.readouterr().err
    assert "--format" in err and "csv" in err


def test_sweep_text_cache_footer(capsys):
    argv = ["sweep", "--size", "2^12", "--waves-per-tile", "4", "8",
            "--no-artifact"]
    rc, out = run_cli(argv, capsys)
    assert rc == 0
    assert "cache: 2 collected, 0 memo hits, 0 disk hits" in out
    # warm run: both points served from the persistent cache
    rc, out = run_cli(argv, capsys)
    assert rc == 0
    assert "cache: 0 collected, 0 memo hits, 2 disk hits" in out
    # json/csv reports stay parseable: no footer
    rc, out = run_cli(argv + ["--format", "json"], capsys)
    assert rc == 0
    json.loads(out)
    rc, out = run_cli(argv + ["--format", "csv"], capsys)
    assert rc == 0
    assert "cache:" not in out
