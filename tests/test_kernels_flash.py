"""Flash-attention Pallas kernel vs jnp oracle: shape/dtype/block sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops, ref


@pytest.mark.parametrize("h,t,d", [(2, 64, 32), (4, 128, 64), (1, 256, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_ref(h, t, d, causal):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(kq, (h, t, d), jnp.float32)
    k = jax.random.normal(kk, (h, t, d), jnp.float32)
    v = jax.random.normal(kv, (h, t, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=32, bkv=32)
    expect = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("bq,bkv", [(16, 64), (64, 16), (32, 32)])
def test_block_shape_invariance(bq, bkv):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(kq, (2, 64, 32), jnp.float32)
    k = jax.random.normal(kk, (2, 64, 32), jnp.float32)
    v = jax.random.normal(kv, (2, 64, 32), jnp.float32)
    a = ops.flash_attention(q, k, v, bq=bq, bkv=bkv)
    b = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_bf16_and_batched():
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(kq, (2, 2, 64, 32), jnp.bfloat16)
    k = jax.random.normal(kk, (2, 2, 64, 32), jnp.bfloat16)
    v = jax.random.normal(kv, (2, 2, 64, 32), jnp.bfloat16)
    out = ops.flash_attention(q, k, v, bq=32, bkv=32)
    expect = jax.vmap(lambda a, b, c: ref.attention_ref(a, b, c))(q, k, v)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(expect, np.float32),
        rtol=3e-2, atol=3e-2)
