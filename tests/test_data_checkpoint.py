"""Data-pipeline determinism + checkpoint store semantics."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import store
from repro.data.pipeline import DataConfig, SyntheticLM


def _pipe(gb=8, seq=32, seed=7):
    return SyntheticLM(DataConfig(vocab_size=1000, seq_len=seq,
                                  global_batch=gb, seed=seed))


def test_restart_replay_exact():
    """The fault-tolerance property: batches at step s are identical across
    'restarts' (fresh pipeline objects)."""
    a, b = _pipe(), _pipe()
    for step in (0, 3, 17):
        np.testing.assert_array_equal(a.global_batch_at(step),
                                      b.global_batch_at(step))


@settings(max_examples=20, deadline=None)
@given(step=st.integers(0, 50), num_shards=st.sampled_from([1, 2, 4, 8]))
def test_shards_partition_global_batch(step, num_shards):
    p = _pipe()
    g = p.global_batch_at(step)
    parts = [p.shard_batch_at(step, s, num_shards) for s in range(num_shards)]
    np.testing.assert_array_equal(np.concatenate(parts), g)


def test_distinct_steps_distinct_data():
    p = _pipe()
    assert not np.array_equal(p.global_batch_at(0), p.global_batch_at(1))


def test_zipf_skew():
    p = _pipe(gb=32, seq=256)
    toks = p.global_batch_at(0).ravel()
    counts = np.bincount(toks, minlength=1000)
    # heavy head: the top token should be much more frequent than median
    assert counts.max() > 20 * max(np.median(counts), 1)


# -- checkpoint --------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((2,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 5, t)
    restored, step = store.restore(str(tmp_path), t)
    assert step == 5
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), t, restored)


def test_latest_pointer_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4):
        store.save(str(tmp_path), s, t)
    assert store.latest_step(str(tmp_path)) == 4
    store.gc(str(tmp_path), keep=2)
    dirs = sorted(os.listdir(str(tmp_path)))
    assert "step_3" in dirs and "step_4" in dirs and "step_1" not in dirs


def test_torn_write_never_visible(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 1, t)
    # simulate a crashed writer: stray tmp dir must not be visible
    os.makedirs(os.path.join(str(tmp_path), ".tmp_step_9"))
    assert store.latest_step(str(tmp_path)) == 1
    _, step = store.restore(str(tmp_path), t)
    assert step == 1


def test_async_checkpointer(tmp_path):
    t = _tree()
    ck = store.AsyncCheckpointer(str(tmp_path), keep=2)
    for s in (10, 20, 30):
        ck.submit(s, t)
    ck.close()
    assert store.latest_step(str(tmp_path)) == 30
    restored, _ = store.restore(str(tmp_path), t)
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), t, restored)


def test_restore_with_shardings(tmp_path):
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    store.save(str(tmp_path), 2, t)
    from repro.launch import mesh as mesh_mod
    mesh = mesh_mod.compat_make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = store.restore(str(tmp_path), t, shardings=sh)
    assert restored["a"].sharding == NamedSharding(mesh, P())
