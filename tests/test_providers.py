"""The provider layer: registry, the four backends, Session.validate.

The load-bearing claims: (a) TraceProvider and InstrumentedKernelProvider
agree *bit-for-bit* on the serialization counters (the instrumentation
docstring's promise, now enforced at the acquisition API), and (b)
``Session.validate`` reports zero relative error on the paper's histogram
case study — the §5 model-vs-measured validation.
"""

import numpy as np
import pytest

from repro.analysis import (
    CounterSet,
    Session,
    WorkloadSpec,
    get_device,
    get_provider,
    register_provider,
)
from repro.analysis import device as device_mod
from repro.analysis.providers import PROVIDERS
from repro.core import counters, profiler

jnp = pytest.importorskip("jax.numpy")


@pytest.fixture
def sess(tmp_path):
    device_mod._TABLE_MEMO.clear()
    return Session("v5e", cache_dir=tmp_path)


def _uniform_indices(num_waves=8, num_bins=256, seed=0):
    # length a multiple of the scatter kernel tile (2048) so the trace
    # and kernel providers see identical wave counts
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_bins, num_waves * 1024)


def _image(kind="uniform", n=2048):
    from repro.data.images import make_image
    return jnp.asarray(make_image(kind, n))


# -- registry -----------------------------------------------------------------


def test_registry_contains_the_four_shipped_providers():
    assert {"trace", "kernel", "hlo", "microbench"} <= set(PROVIDERS)


def test_get_provider_by_name_and_passthrough():
    p = get_provider("trace")
    assert p.name == "trace"
    assert get_provider(p) is p


def test_get_provider_unknown_lists_registry():
    with pytest.raises(KeyError, match="trace"):
        get_provider("nvml")


def test_get_provider_rejects_non_provider():
    with pytest.raises(TypeError):
        get_provider(42)


def test_register_custom_provider(sess):
    class Fixed:
        name = "fixed"

        def collect(self, spec, device):
            return CounterSet(label=spec.label, source=self.name,
                              num_cores=1, O=np.array([8.0]),
                              N_f=np.array([4.0]), num_waves=4,
                              waves_per_tile=4)

    register_provider(Fixed())
    try:
        cset = sess.collect(WorkloadSpec.from_indices(
            _uniform_indices(), 256, label="x"), provider="fixed")
        assert cset.source == "fixed" and cset.e == 2.0
    finally:
        del PROVIDERS["fixed"]


# -- trace vs kernel equivalence (the instrumentation promise) ----------------


def test_indices_providers_agree_bit_for_bit(sess):
    spec = WorkloadSpec.from_indices(_uniform_indices(), 256, label="idx",
                                     waves_per_tile=4)
    ct = sess.collect(spec, provider="trace")
    ck = sess.collect(spec, provider="kernel")
    assert ct.source == "trace" and ck.source == "kernel"
    np.testing.assert_array_equal(ct.O, ck.O)
    np.testing.assert_array_equal(ct.N, ck.N)
    assert ct.e == ck.e
    assert ct.num_waves == ck.num_waves


@pytest.mark.parametrize("kind,variant,pixels", [
    ("uniform", "hist", 2048),
    ("solid", "hist", 2048),
    ("solid", "hist2", 3000),   # padding + channel-rotation path
])
def test_histogram_providers_agree_bit_for_bit(sess, kind, variant, pixels):
    spec = WorkloadSpec.from_histogram(
        _image(kind, pixels), label=f"{kind}/{variant}", variant=variant,
        force_fao=True)
    ct = sess.collect(spec, provider="trace")
    ck = sess.collect(spec, provider="kernel")
    np.testing.assert_array_equal(ct.O, ck.O)
    np.testing.assert_array_equal(ct.N, ck.N)
    assert ct.e == ck.e


def test_kernel_provider_rejects_non_tile_multiple_indices(sess):
    """Sentinel-padded waves would be counted: refuse, don't diverge."""
    spec = WorkloadSpec.from_indices(
        np.random.default_rng(0).integers(0, 256, 1000), 256, label="odd")
    assert sess.collect(spec, provider="trace").total_jobs == 1  # trace ok
    with pytest.raises(ValueError, match="multiple of the scatter tile"):
        sess.collect(spec, provider="kernel")


def test_kernel_provider_rejects_bare_trace(sess):
    tr = counters.trace_from_indices(_uniform_indices(2), 256)
    spec = WorkloadSpec.from_trace(tr, label="pre-recorded")
    with pytest.raises(ValueError, match="runnable"):
        sess.collect(spec, provider="kernel")


def test_trace_provider_synthesizes_without_kernel_run(sess, monkeypatch):
    """The 'trace' path must not launch Pallas for a histogram spec."""
    from repro.kernels.histogram import ops as hist_ops

    def boom(*a, **k):
        raise AssertionError("trace provider launched the kernel")

    monkeypatch.setattr(hist_ops, "histogram_instrumented", boom)
    spec = WorkloadSpec.from_histogram(_image(), label="synth")
    cset = sess.collect(spec, provider="trace")
    assert cset.total_jobs > 0


# -- end-to-end sessions ------------------------------------------------------


def test_session_kernel_provider_classify_end_to_end(tmp_path):
    """ISSUE acceptance: kernel-provider classify on the histogram case."""
    device_mod._TABLE_MEMO.clear()
    sess = Session(device="v5e", provider="kernel", cache_dir=tmp_path)
    spec = WorkloadSpec.from_histogram(_image("solid", 1 << 15),
                                       label="solid 32Kpx",
                                       force_fao=True, waves_per_tile=32)
    verdict = sess.classify(spec)
    assert verdict.bottleneck == "scatter"
    assert sess.last.profiles[0].params["source"] == "kernel"


def test_validate_histogram_zero_rel_err(sess):
    """ISSUE acceptance: trace-vs-kernel e relative error == 0 (paper §5)."""
    spec = WorkloadSpec.from_histogram(_image("solid", 1 << 14),
                                       label="solid 16Kpx", force_fao=True,
                                       waves_per_tile=32)
    report = sess.validate(spec, providers=("trace", "kernel"))
    assert report.reference == "trace"
    assert report.rel_err("kernel", "e") == 0.0
    assert report.max_rel_err == 0.0
    text = report.render()
    assert "validation" in text and "kernel" in text
    payload = report.to_dict()
    assert payload["comparisons"][1]["provider"] == "kernel"
    with pytest.raises(ValueError):
        report.render("csv")


def test_validate_json_stays_valid_with_zero_reference(sess):
    """An HLO reference has N=O=0; inf rel-errs must not poison the JSON."""
    import jax
    import json

    f = jax.jit(lambda a: (a * a).sum())
    a = jnp.ones((64, 64), jnp.float32)
    hlo_spec = WorkloadSpec.from_compiled(f.lower(a).compile(), label="step")

    class HloThenTrace:
        """Adapter: one spec per provider, exercising a 0-counter reference."""
        def __init__(self, name, inner_spec):
            self.name, self._spec = name, inner_spec

        def collect(self, spec, device):
            return get_provider(self.name).collect(self._spec, device)

    trace_spec = WorkloadSpec.from_indices(_uniform_indices(2), 256,
                                           label="step")
    report = sess.validate(trace_spec, providers=(
        HloThenTrace("hlo", hlo_spec), HloThenTrace("trace", trace_spec)))
    assert report.rel_err("trace", "N") == float("inf")
    payload = json.loads(report.render("json"))   # must parse strictly
    assert payload["comparisons"][1]["rel_err"]["N"] is None


def test_validate_needs_two_providers(sess):
    spec = WorkloadSpec.from_indices(_uniform_indices(), 256, label="x")
    with pytest.raises(ValueError, match="two providers"):
        sess.validate(spec, providers=("trace",))


# -- microbench provider ------------------------------------------------------


def test_microbench_provider_fills_wall_time(sess):
    spec = WorkloadSpec.from_indices(_uniform_indices(), 256, label="mb",
                                     waves_per_tile=4)
    cset = sess.collect(spec, provider="microbench")
    assert cset.source == "microbench"
    assert cset.wall_time_s is not None and cset.wall_time_s > 0
    # counters themselves match the trace path (only the clock is added)
    ct = sess.collect(spec, provider="trace")
    assert cset.e == ct.e and cset.total_jobs == ct.total_jobs
    prof = Session("v5e", provider="microbench",
                   table=sess.table).profile(spec)
    assert prof.params["wall_time_s"] == cset.wall_time_s


# -- hlo provider -------------------------------------------------------------


def test_hlo_provider_from_compiled(sess):
    import jax

    f = jax.jit(lambda a, b: (a @ b).sum())
    a = jnp.ones((128, 128), jnp.float32)
    compiled = f.lower(a, a).compile()
    spec = WorkloadSpec.from_compiled(compiled, label="matmul")
    cset = sess.collect(spec, provider="hlo")
    assert cset.source == "hlo"
    assert cset.flops >= 2 * 128 ** 3   # one 128^3 matmul at least
    assert cset.bytes_read > 0
    assert cset.total_jobs == 0         # no scatter visibility from HLO
    prof = profiler.profile_counters(cset, sess.table)
    assert prof.per_core == []
    assert prof.bottleneck in ("hbm", "mxu")


def test_hlo_counter_set_gets_no_cache_exposure(sess):
    """The LLC-exposure heuristic reads launch geometry HLO doesn't have."""
    big = CounterSet(label="step", source="hlo", num_cores=1,
                     bytes_read=64 * 1024 ** 2)   # >> llc_bytes
    prof = profiler.profile_counters(big, sess.table)
    chip = get_device("v5e").chip
    ideal = big.bytes_read / (chip.hbm_bw / chip.clock_hz)
    assert prof.unit("hbm").busy_cycles == ideal   # no exposure term


def test_hlo_profiles_have_structural_unit_set(sess):
    """Mixed sweeps (some points with collectives, some without) must not
    crash: the unit list is a function of the source kind, not values."""
    with_ici = CounterSet(label="a", source="hlo", num_cores=1,
                          bytes_read=1024.0, flops=1024.0, ici_bytes=512.0)
    without = CounterSet(label="b", source="hlo", num_cores=1,
                         bytes_read=1024.0, flops=1024.0)
    profs = [profiler.profile_counters(c, sess.table)
             for c in (with_ici, without)]
    assert [u.name for u in profs[0].units] == \
        [u.name for u in profs[1].units]
    for order in (profs, profs[::-1]):
        sweep = profiler.utilization_sweep(order)
        assert sweep["ici"].shape == (2,)


def test_hlo_provider_from_text(sess):
    import jax

    f = jax.jit(lambda a, b: (a @ b).sum())
    a = jnp.ones((64, 64), jnp.float32)
    text = f.lower(a, a).compile().as_text()
    spec = WorkloadSpec.from_compiled(hlo_text=text, label="matmul-text")
    cset = sess.collect(spec, provider="hlo")
    assert cset.flops >= 2 * 64 ** 3
    assert cset.bytes_read > 0


def test_hlo_provider_honors_roofline_overrides(sess):
    import jax

    f = jax.jit(lambda a: (a * a).sum())
    a = jnp.ones((64, 64), jnp.float32)
    compiled = f.lower(a).compile()
    spec = WorkloadSpec.from_compiled(compiled, label="override",
                                      bytes_read=1e9, flops=5e12)
    cset = sess.collect(spec, provider="hlo")
    assert cset.bytes_read == 1e9 and cset.flops == 5e12


def test_hlo_provider_requires_compiled_source(sess):
    spec = WorkloadSpec.from_indices(_uniform_indices(2), 256, label="x")
    with pytest.raises(ValueError, match="compiled"):
        sess.collect(spec, provider="hlo")
    with pytest.raises(ValueError, match="hlo"):
        spec.with_(indices=None,
                   hlo_text="HloModule m").resolve_trace()


def test_ops_collect_counters_hooks_directly():
    """The per-family low-level hooks work outside a Session/provider."""
    from repro.kernels.histogram import ops as hist_ops
    from repro.kernels.scatter_add import ops as scat_ops

    cset = hist_ops.collect_counters(_image("solid"), label="hook-h",
                                     force_fao=True)
    assert cset.source == "kernel" and cset.total_jobs > 0
    assert cset.bytes_read == 2048 * 4          # image_bytes default
    ids = _uniform_indices(2)
    cset2 = scat_ops.collect_counters(
        ids, np.ones((ids.size, 1), np.float32), 256, label="hook-s")
    assert cset2.source == "kernel" and cset2.e >= 1.0
    assert cset2.bytes_read == ids.size * 4


def test_scatter_add_providers_agree_bit_for_bit(sess):
    ids = _uniform_indices(num_waves=4, num_bins=128, seed=3)
    vals = np.ones((ids.size, 1), np.float32)
    spec = WorkloadSpec.from_scatter_add(ids, vals, 128, label="scat",
                                         waves_per_tile=2)
    ct = sess.collect(spec, provider="trace")
    ck = sess.collect(spec, provider="kernel")
    np.testing.assert_array_equal(ct.O, ck.O)
    np.testing.assert_array_equal(ct.N, ck.N)
    assert ct.e == ck.e


def test_weighted_histogram_maps_to_cas_class(sess):
    spec = WorkloadSpec.from_histogram(_image(), label="w", weighted=True)
    for provider in ("trace", "kernel"):
        cset = sess.collect(spec, provider=provider)
        assert np.sum(cset.N_c) == cset.total_jobs   # all CAS-class
        assert np.sum(cset.N_f) == np.sum(cset.N_p) == 0


def test_unweighted_unforced_histogram_maps_to_popc_class(sess):
    spec = WorkloadSpec.from_histogram(_image(), label="p", force_fao=False)
    cset = sess.collect(spec, provider="trace")
    assert np.sum(cset.N_p) == cset.total_jobs


def test_unknown_kernel_op_raises(sess):
    from repro.analysis import KernelSource
    spec = WorkloadSpec(label="bad", kernel=KernelSource(op="fft"))
    for provider in ("trace", "kernel"):
        with pytest.raises(ValueError, match="unknown kernel op"):
            sess.collect(spec, provider=provider)
    with pytest.raises(ValueError, match="unknown kernel op"):
        spec.resolve_trace()


def test_spec_rejects_compiled_plus_trace_source():
    tr = counters.trace_from_indices(_uniform_indices(2), 256)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSpec(label="both", trace=tr, hlo_text="HloModule m")


def test_validate_accepts_provider_instances(sess):
    from repro.analysis import InstrumentedKernelProvider, TraceProvider
    spec = WorkloadSpec.from_indices(_uniform_indices(), 256, label="inst",
                                     waves_per_tile=4)
    report = sess.validate(
        spec, providers=(TraceProvider(), InstrumentedKernelProvider()))
    assert report.max_rel_err == 0.0


def test_session_profile_params_record_source(sess):
    spec = WorkloadSpec.from_indices(_uniform_indices(2), 256, label="src")
    prof = sess.profile(spec)
    assert prof.params["source"] == "trace"
    assert prof.params["wall_time_s"] is None


def test_microbench_provider_on_histogram_spec(sess):
    spec = WorkloadSpec.from_histogram(_image(), label="mb-hist",
                                       force_fao=True)
    cset = sess.collect(spec, provider="microbench")
    assert cset.wall_time_s is not None and cset.wall_time_s > 0
    assert cset.e == sess.collect(spec, provider="trace").e


# -- CounterSet ---------------------------------------------------------------


def test_counter_set_empty_defaults():
    cset = CounterSet(label="empty", num_cores=2)
    assert cset.total_jobs == 0 and cset.total_O == 0
    assert cset.e == 1.0
    assert cset.O.shape == (2,)


def test_geometry_helpers_match_wave_trace_methods():
    tr = counters.trace_from_indices(_uniform_indices(6), 256,
                                     waves_per_tile=2, pipeline_depth=3)
    for n_max in (4, 64):
        assert tr.occupancy(n_max) == counters.geometry_occupancy(
            tr.num_waves, tr.waves_per_tile, tr.pipeline_depth, n_max)
        assert tr.true_n(n_max) == counters.geometry_true_n(
            tr.num_waves, tr.waves_per_tile, tr.pipeline_depth, n_max)


def test_counter_set_from_trace_matches_basic_counters():
    tr = counters.trace_from_indices(_uniform_indices(4), 256, num_cores=4,
                                     waves_per_tile=2)
    cset = CounterSet.from_trace(tr, label="t", num_cores=4)
    basic = counters.collect_basic_counters(
        tr, num_cores=4, T_cycles_per_core=np.ones(4))
    for core, bc in enumerate(basic):
        assert cset.O[core] == bc.O
        assert cset.N_f[core] == bc.N_f
        assert cset.N_c[core] == bc.N_c
        assert cset.N_p[core] == bc.N_p
    got = cset.to_basic_counters(np.ones(4), 64)
    assert [b.occupancy for b in got] == [b.occupancy for b in basic]
    assert [b.n_true for b in got] == [b.n_true for b in basic]


def test_profile_counters_matches_legacy_trace_path(sess):
    """The legacy entry must be a pure delegation (same numbers out)."""
    tr = counters.trace_from_indices(_uniform_indices(), 256, num_cores=8,
                                     waves_per_tile=4)
    legacy = profiler.profile_scatter_workload(
        tr, sess.table, label="x", bytes_read=1 << 20, num_cores=8,
        overhead_cycles=500.0)
    cset = CounterSet.from_trace(tr, label="x", num_cores=8,
                                 bytes_read=float(1 << 20),
                                 overhead_cycles=500.0)
    new = profiler.profile_counters(cset, sess.table)
    np.testing.assert_array_equal(legacy.T_cycles, new.T_cycles)
    assert legacy.scatter_utilization == new.scatter_utilization
    assert [u.utilization for u in legacy.units] == \
        [u.utilization for u in new.units]
