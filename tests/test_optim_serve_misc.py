"""Coverage: AdamW math, schedules, serve prefill/generate, MoE capacity
semantics, timing-model properties, and the embedding-gradient scatter
profile (the paper's model watching a real training-data distribution)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import microbench, profiler, timing
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.kernels.scatter_add import ops as scat_ops
from repro.models import moe
from repro.optim import adamw


# -- AdamW -------------------------------------------------------------------


def test_adamw_converges_on_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                            weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([5.0, -3.0], jnp.float32)}
    state = adamw.init(params)
    for _ in range(150):
        grads = {"w": 2.0 * state["master"]["w"]}  # d/dw of w^2
        params, state, m = adamw.update(grads, state, cfg,
                                        params_dtype=jnp.float32)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_adamw_clipping_and_metrics():
    cfg = adamw.AdamWConfig(clip_norm=1.0)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw.init(params)
    grads = {"w": jnp.full((4,), 100.0, jnp.float32)}
    new_params, state, m = adamw.update(grads, state, cfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)
    assert new_params["w"].dtype == jnp.bfloat16
    # weight decay skipped for 1-D leaves (norms/bias convention)
    assert int(state["count"]) == 1


def test_schedule_warmup_and_cosine():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, 5)) == pytest.approx(0.5)
    assert float(adamw.schedule(cfg, 10)) == pytest.approx(1.0, abs=1e-2)
    assert float(adamw.schedule(cfg, 110)) == pytest.approx(0.1, abs=1e-3)


# -- serve prefill -----------------------------------------------------------


def test_prefill_then_decode_continues_correctly():
    from repro.configs import get_config
    from repro.models.registry import build_model, make_batch
    from repro.serve import step as serve_mod

    cfg = get_config("qwen2-72b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 12)
    scfg = serve_mod.ServeConfig(max_len=32)
    prefill = serve_mod.make_prefill(model, scfg)
    logits, cache = prefill(params, batch["tokens"])
    fwd, _ = model.forward(params, batch["tokens"])
    np.testing.assert_allclose(np.asarray(logits), np.asarray(fwd),
                               rtol=1e-4, atol=1e-4)
    assert logits.shape == (2, 12, cfg.padded_vocab)


# -- MoE capacity semantics ---------------------------------------------------


def test_moe_capacity_drops_overflow_rows():
    """GShard capacity semantics at the mechanism level: a collapsed
    dispatch stream keeps exactly `capacity` rows per expert."""
    cfg = moe.MoEConfig(d_model=16, d_expert=8, num_experts=4, top_k=1,
                        capacity_factor=0.5, dtype="float32")
    p = moe.init(jax.random.PRNGKey(0), cfg)
    xs = jax.random.normal(jax.random.PRNGKey(1), (32, 16), jnp.float32)
    ids = jnp.zeros((32,), jnp.int32)         # everyone wants expert 0
    rows = moe._expert_ffn_grouped(p, xs, ids, cfg.num_experts, 4, cfg,
                                   None)
    nonzero_rows = int((np.abs(np.asarray(rows)) > 1e-9).any(axis=1).sum())
    assert nonzero_rows == 4                  # capacity enforced
    # first-come-first-served within the sorted stream
    assert (np.abs(np.asarray(rows[:4])) > 1e-9).any()
    np.testing.assert_allclose(np.asarray(rows[4:]), 0.0)


def test_moe_no_drops_with_generous_capacity():
    cfg = moe.MoEConfig(d_model=16, d_expert=8, num_experts=4, top_k=2,
                        capacity_factor=8.0, dtype="float32")
    p = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16), jnp.float32)
    out, _, _ = moe.apply_local(p, x, cfg)
    assert int((np.abs(np.asarray(out)) > 1e-9).any(axis=1).sum()) == 64


# -- timing model properties ---------------------------------------------------


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 64), e=st.integers(1, 32), c=st.integers(0, 64))
def test_timing_monotone_in_c(n, e, c):
    c = min(c, n)
    t0 = float(timing.total_time_cycles(n, e, 0))
    t1 = float(timing.total_time_cycles(n, e, c))
    assert t1 >= t0  # CAS-class jobs never cheaper than FAO


@settings(max_examples=100, deadline=None)
@given(n=st.integers(1, 63), e=st.integers(1, 32))
def test_timing_total_time_monotone_in_n(n, e):
    assert timing.total_time_cycles(n + 1, e, 0) > \
        timing.total_time_cycles(n, e, 0)


# -- embedding-gradient scatter profile (DESIGN §3.1 item 3) ------------------


def test_embedding_grad_scatter_profile_zipf_vs_uniform():
    """Token-frequency skew is the LM-training analogue of the paper's
    monochrome image: a Zipfian batch must show a higher serialization
    degree on the embedding-grad scatter than a uniform batch."""
    table = microbench.build_table()
    zipf = SyntheticLM(DataConfig(vocab_size=4096, seq_len=2048,
                                  global_batch=8, zipf_alpha=1.2))
    uni = SyntheticLM(DataConfig(vocab_size=4096, seq_len=2048,
                                 global_batch=8, zipf_alpha=0.0))
    profs = {}
    for name, pipe in (("zipf", zipf), ("uniform", uni)):
        toks = pipe.global_batch_at(0).reshape(-1)
        _, c = scat_ops.instrumented_scatter_add(
            toks.astype(np.int32), np.ones((toks.size, 1), np.float32),
            4096)
        tr = c["trace"]
        tr.waves_per_tile = 32
        profs[name] = profiler.profile_scatter_workload(
            tr, table, label=name, bytes_read=float(toks.size * 4),
            overhead_cycles=500.0)
    e_zipf = profs["zipf"].per_core[0].e
    e_uni = profs["uniform"].per_core[0].e
    assert e_zipf > 1.5 * e_uni, (e_zipf, e_uni)
    assert profs["zipf"].scatter_utilization > \
        profs["uniform"].scatter_utilization
