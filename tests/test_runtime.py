"""Fault-tolerance runtime: heartbeats, failure/restart, elastic, stragglers."""

import numpy as np

from repro.runtime import fault_tolerance as ft
from repro.runtime import stragglers


def test_coordinator_detects_missed_beats():
    c = ft.Coordinator(num_hosts=3, timeout_s=1.0)
    for h in range(3):
        c.beat(h, now=100.0)
    assert c.healthy(now=100.5)
    c.beat(0, now=102.0)
    c.beat(1, now=102.0)
    assert c.dead_hosts(now=102.5) == [2]


def test_failure_injection_and_restart():
    calls = {"train": 0, "restore": 0, "save": []}

    def train_one(step):
        calls["train"] += 1
        return {"xent": 1.0 / (step + 1)}

    def save(step):
        calls["save"].append(step)

    def restore():
        calls["restore"] += 1
        return calls["save"][-1] if calls["save"] else 0

    coord = ft.Coordinator(num_hosts=2)
    inj = ft.FailureInjector({7: 1})
    out = ft.run_with_restarts(
        num_steps=12, train_one_step=train_one, save_every=5,
        save_fn=save, restore_fn=restore, coordinator=coord, injector=inj)
    assert out["restarts"] == 1
    assert calls["restore"] == 1
    # steps 5..6 replayed after restore-from-5
    assert calls["train"] == 12 + 2
    assert [h["step"] for h in out["history"]][-1] == 11


def test_restart_budget_enforced():
    coord = ft.Coordinator(num_hosts=1)
    inj = ft.FailureInjector({i: 0 for i in range(10)})
    try:
        ft.run_with_restarts(
            num_steps=5, train_one_step=lambda s: {},
            save_every=100, save_fn=lambda s: None, restore_fn=lambda: 0,
            coordinator=coord, injector=inj, max_restarts=2)
        raise AssertionError("expected restart budget error")
    except RuntimeError:
        pass


def test_plan_remesh_shrink():
    plan = ft.plan_remesh((2, 16, 16), ("pod", "data", "model"), 300)
    assert plan.action == "shrink"
    assert plan.new_shape == (1, 16, 16)
    plan2 = ft.plan_remesh((2, 16, 16), ("pod", "data", "model"), 512)
    assert not plan2.changed


def test_straggler_detection():
    times = {0: [1.0] * 20, 1: [1.02] * 20, 2: [1.5] * 20, 3: [0.98] * 20}
    reports = stragglers.detect(times)
    flagged = [r.host_id for r in reports if r.is_straggler]
    assert flagged == [2]
    slow = [r for r in reports if r.host_id == 2][0]
    np.testing.assert_allclose(slow.barrier_utilization, 1.0)
    assert "2" in stragglers.mitigation(reports)


def test_no_stragglers_on_uniform_fleet():
    times = {h: list(np.random.default_rng(h).normal(1.0, 0.01, 20))
             for h in range(8)}
    assert not [r for r in stragglers.detect(times) if r.is_straggler]
