"""ServiceTimeTable (de)serialization + the per-device .npz table cache."""

import dataclasses

import numpy as np
import pytest

from repro.analysis import device as device_mod
from repro.analysis.device import Device, get_device
from repro.core import microbench, qmodel, timing


@pytest.fixture(autouse=True)
def _clean_memo():
    """Each test sees a cold in-process memo (disk state is per-tmpdir)."""
    device_mod._TABLE_MEMO.clear()
    yield
    device_mod._TABLE_MEMO.clear()


def test_save_load_round_trip(tmp_path):
    tab = microbench.build_table()
    path = str(tmp_path / "t.npz")
    tab.save(path)
    back = qmodel.ServiceTimeTable.load(path)
    np.testing.assert_array_equal(back.n_grid, tab.n_grid)
    np.testing.assert_array_equal(back.e_grid, tab.e_grid)
    np.testing.assert_array_equal(back.cfrac_grid, tab.cfrac_grid)
    np.testing.assert_array_equal(back.T, tab.T)
    np.testing.assert_array_equal(back.popc_T, tab.popc_T)
    assert back.clock_hz == tab.clock_hz
    # meta survives the round trip (mode + calibration constants)
    assert back.meta["mode"] == "analytic"
    assert back.meta["params"]["n_max"] == timing.V5E_SCATTER.n_max
    # interpolated lookups are identical
    q = [(1, 1, 0), (17.5, 8.3, 4.2), (64, 32, 64)]
    for n, e, c in q:
        np.testing.assert_allclose(back.service_time(n, e, c),
                                   tab.service_time(n, e, c))


def test_save_load_without_popc(tmp_path):
    tab = microbench.build_table()
    tab2 = qmodel.ServiceTimeTable(
        n_grid=tab.n_grid, e_grid=tab.e_grid, cfrac_grid=tab.cfrac_grid,
        T=tab.T, popc_T=None)
    path = str(tmp_path / "nopopc.npz")
    tab2.save(path)
    back = qmodel.ServiceTimeTable.load(path)
    assert back.popc_T is None
    with pytest.raises(ValueError):
        back.popc_service_time(4, 2)


def test_save_is_compressed_and_loads_legacy_uncompressed(tmp_path):
    """``save`` writes compressed .npz; ``load`` reads both formats.

    Existing uncompressed artifacts under results/tables/ (written before
    the savez_compressed switch) must keep loading bit-for-bit.
    """
    import json
    import zipfile

    tab = microbench.build_table()
    new_path = tmp_path / "compressed.npz"
    tab.save(str(new_path))
    with zipfile.ZipFile(new_path) as z:
        assert all(i.compress_type == zipfile.ZIP_DEFLATED
                   for i in z.infolist())

    # a legacy artifact: the exact uncompressed layout save() used to emit
    legacy_path = tmp_path / "legacy.npz"
    np.savez(
        str(legacy_path),
        n_grid=tab.n_grid, e_grid=tab.e_grid, cfrac_grid=tab.cfrac_grid,
        T=tab.T, popc_T=tab.popc_T, clock_hz=np.float64(tab.clock_hz),
        meta=np.str_(json.dumps(tab.meta, default=float)))
    with zipfile.ZipFile(legacy_path) as z:
        assert all(i.compress_type == zipfile.ZIP_STORED
                   for i in z.infolist())

    for path in (new_path, legacy_path):
        back = qmodel.ServiceTimeTable.load(str(path))
        np.testing.assert_array_equal(back.T, tab.T)
        np.testing.assert_array_equal(back.popc_T, tab.popc_T)
        assert back.clock_hz == tab.clock_hz
        np.testing.assert_allclose(back.service_time(13.5, 7.2, 3.3),
                                   tab.service_time(13.5, 7.2, 3.3))
    # compression must actually pay on the regular grid
    assert new_path.stat().st_size < legacy_path.stat().st_size / 2


def test_device_table_builds_then_loads_from_disk(tmp_path, monkeypatch):
    dev = get_device("v5e")
    calls = {"n": 0}
    real_build = microbench.build_table

    def counting_build(*a, **kw):
        calls["n"] += 1
        return real_build(*a, **kw)

    monkeypatch.setattr(microbench, "build_table", counting_build)
    t1 = dev.table(cache_dir=tmp_path)
    assert calls["n"] == 1
    assert dev.table_path(tmp_path).exists()
    assert t1.meta["device"] == "v5e"

    # cold memo: second resolution must hit the .npz, not rebuild
    device_mod._TABLE_MEMO.clear()
    t2 = dev.table(cache_dir=tmp_path)
    assert calls["n"] == 1
    np.testing.assert_array_equal(t1.T, t2.T)

    # warm memo: no disk access path needed either
    t3 = dev.table(cache_dir=tmp_path)
    assert t3 is t2


def test_device_table_refresh_rebuilds(tmp_path, monkeypatch):
    dev = get_device("v5e")
    calls = {"n": 0}
    real_build = microbench.build_table

    def counting_build(*a, **kw):
        calls["n"] += 1
        return real_build(*a, **kw)

    monkeypatch.setattr(microbench, "build_table", counting_build)
    dev.table(cache_dir=tmp_path)
    dev.table(cache_dir=tmp_path, refresh=True)
    assert calls["n"] == 2


def test_device_table_corrupt_cache_falls_back_to_build(tmp_path):
    dev = get_device("v5e")
    path = dev.table_path(tmp_path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_bytes(b"not an npz")
    tab = dev.table(cache_dir=tmp_path)
    assert tab.T.shape[0] == timing.V5E_SCATTER.n_max + 1


def test_table_key_tracks_calibration():
    base = get_device("v5e")
    tweaked = base.with_(scatter=dataclasses.replace(
        base.scatter, cas_base=base.scatter.cas_base + 1.0))
    assert base.table_key() != tweaked.table_key()
    # different devices never collide either
    assert get_device("v5p").table_key() != base.table_key()


def test_devices_share_table_across_sessions(tmp_path, monkeypatch):
    """The acceptance path: two Sessions, one build."""
    from repro.analysis import Session

    calls = {"n": 0}
    real_build = microbench.build_table

    def counting_build(*a, **kw):
        calls["n"] += 1
        return real_build(*a, **kw)

    monkeypatch.setattr(microbench, "build_table", counting_build)
    s1 = Session("v5e", cache_dir=tmp_path)
    device_mod._TABLE_MEMO.clear()   # simulate a fresh process
    s2 = Session("v5e", cache_dir=tmp_path)
    assert calls["n"] == 1
    np.testing.assert_array_equal(s1.table.T, s2.table.T)
