"""Columnar ``collect_batch`` + sharded sweep executor (the PR 8 surface).

The load-bearing claims: (a) every shipped provider's ``collect_batch``
rows are bit-for-bit equal to its scalar ``collect`` (modeled fields only
for the measuring microbench backend), (b) ``Session``'s batch cache
resolution makes O(groups) provider calls cold and zero warm, (c) a
sharded sweep merging through the persistent ``SweepCache`` — including
two writers racing on the *same* slice, in threads and in subprocesses —
reassembles bit-identically to a single-process sweep, and (d) the cache
CLI + argparse validation reject bad shard/jobs arguments up front.
"""

import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.analysis import (
    CounterSet,
    Session,
    SweepCache,
    WorkloadSpec,
    register_provider,
)
from repro.analysis import device as device_mod
from repro.analysis.providers import (
    PROVIDERS,
    collect_batch_fallback,
    get_provider,
    provider_collect_batch,
)
from repro.cli import main
from repro.core import counters

jnp = pytest.importorskip("jax.numpy")

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(autouse=True)
def _isolate_results(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "results"))
    yield


@pytest.fixture
def sess(tmp_path):
    device_mod._TABLE_MEMO.clear()
    return Session("v5e", cache_dir=tmp_path)


def _indices(n=4 * 1024, num_bins=256, seed=0):
    return np.random.default_rng(seed).integers(0, num_bins, n)


def _grid(points=8, n=2048, seed=0):
    """A grid of *distinct-content* specs (nothing memoizes away)."""
    rng = np.random.default_rng(seed)
    return [WorkloadSpec.from_indices(rng.integers(0, 256, n), 256,
                                      label=f"pt{i}", waves_per_tile=4)
            for i in range(points)]


def run_cli(argv, capsys):
    rc = main(argv)
    return rc, capsys.readouterr().out


# -- the batched degree kernel ------------------------------------------------


def test_degrees_batch_axis_matches_per_row_and_wave_degree():
    rng = np.random.default_rng(1)
    idx = rng.integers(0, 64, size=(5, 7, counters.LANES))
    batch = counters._degrees_full_waves(idx, counters.COMMIT_GROUP)
    assert batch.shape == (5, 7)
    for p in range(5):
        row = counters._degrees_full_waves(idx[p], counters.COMMIT_GROUP)
        np.testing.assert_array_equal(batch[p], row)
        for w in range(7):
            assert batch[p, w] == counters.wave_degree(idx[p, w])


def test_degrees_independent_of_chunking():
    rng = np.random.default_rng(2)
    idx = rng.integers(0, 8, size=(100, counters.LANES))
    a = counters._degrees_full_waves(idx, counters.COMMIT_GROUP, chunk=7)
    b = counters._degrees_full_waves(idx, counters.COMMIT_GROUP, chunk=4096)
    np.testing.assert_array_equal(a, b)


# -- per-provider batch-vs-scalar bitwise equality ----------------------------


def test_trace_collect_batch_bitwise_equal_scalar(sess):
    p = get_provider("trace")
    specs = [
        WorkloadSpec.from_indices(_indices(4096, seed=1), 256, label="a",
                                  waves_per_tile=4),
        WorkloadSpec.from_indices(_indices(3000, seed=2), 256, label="b",
                                  waves_per_tile=2),   # partial trailing wave
        WorkloadSpec.from_indices(_indices(4096, seed=3), 256, label="c",
                                  waves_per_tile=8, pipeline_depth=4),
        WorkloadSpec.from_indices(np.zeros(2048, np.int64), 16, label="d"),
    ]
    frame = p.collect_batch(specs, sess.device)
    assert len(frame) == len(specs)
    for i, spec in enumerate(specs):
        scalar = p.collect(spec, sess.device)
        assert counters.bitwise_equal(frame.row(i), scalar), spec.label
    assert frame.labels == ["a", "b", "c", "d"]


def test_trace_collect_batch_kernel_source_specs(sess):
    """Kernel-backed specs batch through the synthesized committed stream."""
    from repro.data.images import make_image

    p = get_provider("trace")
    specs = [
        WorkloadSpec.from_histogram(jnp.asarray(make_image("uniform", 2048)),
                                    label="hist", force_fao=True),
        WorkloadSpec.from_histogram(jnp.asarray(make_image("solid", 2048)),
                                    label="hist2", variant="hist2",
                                    force_fao=True),
        WorkloadSpec.from_scatter_add(
            _indices(2048, 128, seed=4).astype(np.int32),
            np.ones((2048, 1), np.float32), 128, label="scat"),
        WorkloadSpec.from_indices(_indices(2048, seed=5), 256, label="idx"),
    ]
    frame = p.collect_batch(specs, sess.device)
    for i, spec in enumerate(specs):
        assert counters.bitwise_equal(frame.row(i),
                                      p.collect(spec, sess.device)), \
            spec.label


def test_kernel_provider_batch_matches_scalar(sess):
    p = get_provider("kernel")
    specs = [WorkloadSpec.from_indices(_indices(2048, seed=s), 256,
                                       label=f"k{s}", waves_per_tile=2)
             for s in (1, 2)]
    frame = p.collect_batch(specs, sess.device)
    for i, spec in enumerate(specs):
        assert counters.bitwise_equal(frame.row(i),
                                      p.collect(spec, sess.device))


def test_hlo_provider_batch_matches_scalar(sess):
    import jax

    f = jax.jit(lambda a: (a @ a).sum())
    a = jnp.ones((64, 64), jnp.float32)
    text = f.lower(a).compile().as_text()
    p = get_provider("hlo")
    specs = [WorkloadSpec.from_compiled(hlo_text=text, label="m1"),
             WorkloadSpec.from_compiled(hlo_text=text, label="m2",
                                        bytes_read=1e9)]
    frame = p.collect_batch(specs, sess.device)
    for i, spec in enumerate(specs):
        assert counters.bitwise_equal(frame.row(i),
                                      p.collect(spec, sess.device))


def test_microbench_batch_fills_wall_time_and_matches_modeled(sess):
    p = get_provider("microbench")
    specs = [WorkloadSpec.from_indices(_indices(2048, seed=s), 256,
                                       label=f"mb{s}", waves_per_tile=4)
             for s in (1, 2)]
    frame = p.collect_batch(specs, sess.device)
    for i, spec in enumerate(specs):
        row = frame.row(i)
        assert row.wall_time_s is not None and row.wall_time_s > 0
        assert row.meta.get("busy_cycles_measured")
        # the clock can never repeat; every modeled field must
        scalar = p.collect(spec, sess.device)
        assert counters.bitwise_equal(row, scalar,
                                      ignore=("wall_time_s", "meta"))


def test_countersets_from_traces_multicore_bitwise():
    """The stacked per-core aggregation vs scalar from_trace, cores > 1."""
    traces, refs = [], []
    for seed, cores in ((1, 4), (2, 4), (3, 4)):
        tr = counters.trace_from_indices(
            _indices(8 * 1024, seed=seed), 256, num_cores=cores,
            waves_per_tile=2)
        traces.append(tr)
        refs.append(CounterSet.from_trace(tr, label=f"t{seed}",
                                          num_cores=cores, bytes_read=4.0))
    got = counters.countersets_from_traces(
        traces, labels=["t1", "t2", "t3"], num_cores=4, bytes_read=4.0)
    for g, r in zip(got, refs):
        assert counters.bitwise_equal(g, r)


# -- dispatch helpers ---------------------------------------------------------


class _Counting:
    """Collect-only provider (no collect_batch): the fallback contract."""

    name = "counting-batch-test"

    def __init__(self):
        self.calls = []

    def collect(self, spec, device):
        self.calls.append(spec.label)
        return CounterSet(label=spec.label, source=self.name, num_cores=1,
                          O=np.array([8.0]), N_f=np.array([4.0]),
                          num_waves=4, waves_per_tile=4)


def test_collect_batch_fallback_loops_scalar_collect(sess):
    prov = _Counting()
    specs = [WorkloadSpec.from_indices(_indices(2048, seed=s), 256,
                                       label=f"s{s}") for s in range(3)]
    frame = collect_batch_fallback(prov, specs, sess.device)
    assert prov.calls == ["s0", "s1", "s2"]
    assert frame.labels == ["s0", "s1", "s2"]
    with pytest.raises(ValueError, match="at least one spec"):
        collect_batch_fallback(prov, [], sess.device)


def test_provider_collect_batch_dispatches_by_capability(sess):
    spec = WorkloadSpec.from_indices(_indices(2048), 256, label="x")
    prov = _Counting()
    frame = provider_collect_batch(prov, [spec], sess.device)
    assert prov.calls == ["x"]          # no collect_batch -> fallback loop
    trace = get_provider("trace")
    frame2 = provider_collect_batch(trace, [spec], sess.device)
    assert counters.bitwise_equal(frame2.row(0),
                                  trace.collect(spec, sess.device))
    assert len(frame) == len(frame2) == 1


# -- Session batch resolution + stats -----------------------------------------


def test_cold_sweep_one_batch_call_warm_sweep_zero(tmp_path):
    cache = tmp_path / "cache"
    specs = _grid(6)
    cold = Session("v5e", persistent_cache=str(cache))
    cold.sweep(specs)
    assert cold.stats == {"collected": 6, "memo_hits": 0, "disk_hits": 0,
                          "batch_calls": 1}
    warm = Session("v5e", persistent_cache=str(cache))
    warm.sweep(specs)
    assert warm.stats == {"collected": 0, "memo_hits": 0, "disk_hits": 6,
                          "batch_calls": 0}


def test_mixed_num_cores_sweep_one_batch_per_group(tmp_path):
    specs = [WorkloadSpec.from_indices(_indices(2048, seed=s), 256,
                                       label=f"c{cores}-{s}",
                                       num_cores=cores, waves_per_tile=2)
             for cores in (1, 2) for s in range(3)]
    sess = Session("v5e")
    result = sess.sweep(specs)
    assert sess.stats["batch_calls"] == 2       # one per num_cores group
    assert sess.stats["collected"] == 6
    assert len(result) == 6
    # row order matches input order despite the regrouping
    assert [p.label for p in result.profiles] == [s.label for s in specs]
    for spec, prof in zip(specs, result.profiles):
        direct = Session("v5e").profile(spec)
        assert prof.scatter_utilization == direct.scatter_utilization


def test_validate_reports_batch_bitwise_equal(sess):
    spec = WorkloadSpec.from_indices(_indices(2048), 256, label="v",
                                     waves_per_tile=2)
    report = sess.validate(spec, providers=("trace", "kernel"))
    assert all(c.batch_bitwise_equal is True for c in report.comparisons)
    text = report.render("text")
    assert "batch collection bit-identical: trace, kernel" in text
    assert "MISMATCH" not in text


def test_validate_collect_only_provider_has_no_batch_verdict(sess):
    register_provider(_Counting())
    try:
        spec = WorkloadSpec.from_indices(_indices(2048), 256, label="v")
        report = sess.validate(
            spec, providers=("trace", "counting-batch-test"))
        by_name = {c.provider: c for c in report.comparisons}
        assert by_name["trace"].batch_bitwise_equal is True
        assert by_name["counting-batch-test"].batch_bitwise_equal is None
        assert ("batch collection bit-identical: trace"
                in report.render("text"))
    finally:
        del PROVIDERS["counting-batch-test"]


# -- sharded sweeps -----------------------------------------------------------


def test_sweep_shard_validation():
    sess = Session("v5e")
    specs = _grid(4)
    with pytest.raises(ValueError, match="shards must be >= 1"):
        sess.sweep(specs, shards=0)
    with pytest.raises(ValueError, match="shard_index"):
        sess.sweep(specs, shards=2, shard_index=2)
    with pytest.raises(ValueError, match="owns no points"):
        sess.sweep(specs, shards=8, shard_index=5)


def test_two_shard_merge_bit_identical_to_single_sweep(tmp_path):
    specs = _grid(9)
    direct = Session("v5e").sweep(specs)
    cache = tmp_path / "cache"
    for i in range(2):
        shard_sess = Session("v5e", persistent_cache=str(cache))
        result = shard_sess.sweep(specs, shards=2, shard_index=i)
        assert [p.label for p in result.profiles] \
            == [s.label for s in specs[i::2]]
    merge_sess = Session("v5e", persistent_cache=str(cache))
    merged = merge_sess.sweep(specs)
    assert merge_sess.stats["collected"] == 0
    assert merge_sess.stats["disk_hits"] == 9
    assert merged.render("json") == direct.render("json")
    for a, b in zip(merged.profiles, direct.profiles):
        assert a.scatter_utilization == b.scatter_utilization
        np.testing.assert_array_equal(a.T_cycles, b.T_cycles)


def test_concurrent_same_slice_writers_threads(tmp_path):
    """Two SweepCache instances racing on the SAME grid slice.

    Atomic tmp+rename writes mean the last writer wins per entry and no
    reader ever sees a torn file: afterwards the cache is complete,
    every entry loads, and a warm merge is bit-identical to a direct
    sweep.
    """
    specs = _grid(8)
    root = tmp_path / "cache"
    errors = []

    def racer():
        try:
            Session("v5e",
                    persistent_cache=SweepCache(root)).sweep(specs)
        except Exception as exc:          # pragma: no cover - failure path
            errors.append(exc)

    threads = [threading.Thread(target=racer) for _ in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    cache = SweepCache(root)
    assert cache.stats()["entries"] == 8
    loaded = [cset for _, cset in cache.iter_entries()]
    assert len(loaded) == 8 and all(c is not None for c in loaded)
    warm = Session("v5e", persistent_cache=SweepCache(root))
    merged = warm.sweep(specs)
    assert warm.stats["collected"] == 0
    assert merged.render("json") == Session("v5e").sweep(specs).render("json")


def test_concurrent_shard_subprocesses_merge_bit_identical(tmp_path):
    """Two ``python -m repro sweep`` processes racing on the same shard,
    sharing one REPRO_RESULTS cache; then --merge == --no-cache."""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"),
               REPRO_RESULTS=str(tmp_path / "results"))
    argv = [sys.executable, "-m", "repro", "sweep", "--size", "2^13",
            "--waves-per-tile", "4", "8", "--format", "csv",
            "--no-artifact"]
    procs = [subprocess.Popen(
        argv + ["--shards", "2", "--shard-index", "0"],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        cwd=REPO, env=env) for _ in range(2)]
    for p in procs:
        out, err = p.communicate(timeout=240)
        assert p.returncode == 0, err
    run = lambda extra: subprocess.run(  # noqa: E731
        argv + extra, capture_output=True, text=True, cwd=REPO, env=env,
        timeout=240)
    second = run(["--shards", "2", "--shard-index", "1"])
    assert second.returncode == 0, second.stderr
    merged = run(["--merge"])
    direct = run(["--no-cache"])
    assert merged.returncode == 0 and direct.returncode == 0
    assert merged.stdout == direct.stdout
    text = subprocess.run(
        argv[:-3] + ["--format", "text", "--no-artifact", "--merge"],
        capture_output=True, text=True, cwd=REPO, env=env, timeout=240)
    assert text.returncode == 0, text.stderr
    assert "cache: 0 collected" in text.stdout


# -- SweepCache maintenance ---------------------------------------------------


def _fill_cache(root, n=4):
    cache = SweepCache(root)
    for i in range(n):
        cset = CounterSet(label=f"e{i}", source="trace", num_cores=1,
                          O=np.array([float(i)]), N_f=np.array([1.0]),
                          num_waves=2)
        cache.put(cache.key("trace", f"fp{i}", "tbl"), cset)
    return cache


def test_sweep_cache_stats_and_prune(tmp_path):
    cache = _fill_cache(tmp_path / "c", n=4)
    stats = cache.stats()
    assert stats["entries"] == 4 and stats["bytes"] > 0
    assert stats["by_provider"]["trace"]["entries"] == 4
    removed, freed = cache.prune(max_bytes=0)
    assert removed == 4 and freed == stats["bytes"]
    assert cache.stats()["entries"] == 0
    with pytest.raises(ValueError):
        cache.prune(max_bytes=-1)


def test_sweep_cache_prune_evicts_oldest_first(tmp_path):
    cache = _fill_cache(tmp_path / "c", n=3)
    entries = sorted((p for p, _ in cache.iter_entries()),
                     key=lambda p: p.stat().st_mtime)
    # age the first entry far into the past; keep the rest fresh
    old = entries[0]
    os.utime(old, (1, 1))
    total = cache.stats()["bytes"]
    removed, _ = cache.prune(max_bytes=total - 1)   # must evict exactly one
    assert removed == 1
    assert not old.exists()
    assert cache.stats()["entries"] == 2


# -- CLI: cache subcommand + argument validation ------------------------------


def test_cli_cache_stats_text_and_json(capsys, tmp_path):
    rc, _ = run_cli(["sweep", "--size", "2^13", "--waves-per-tile", "4",
                     "8", "--format", "csv", "--no-artifact"], capsys)
    assert rc == 0
    rc, out = run_cli(["cache", "stats"], capsys)
    assert rc == 0
    assert "cache root:" in out and "2 entries" in out
    assert "trace" in out
    rc, out = run_cli(["cache", "stats", "--format", "json"], capsys)
    assert rc == 0
    import json
    stats = json.loads(out)
    assert stats["entries"] == 2
    assert stats["by_provider"]["trace"]["entries"] == 2


def test_cli_cache_prune_and_clear(capsys):
    rc, _ = run_cli(["sweep", "--size", "2^13", "--waves-per-tile", "4",
                     "8", "--format", "csv", "--no-artifact"], capsys)
    assert rc == 0
    rc, out = run_cli(["cache", "prune", "--max-bytes", "0"], capsys)
    assert rc == 0 and "pruned 2 entries" in out
    rc, out = run_cli(["cache", "clear"], capsys)
    assert rc == 0 and "removed 0 cache entries" in out


@pytest.mark.parametrize("argv", [
    ["sweep", "--size", "2^13", "--shards", "0"],
    ["sweep", "--size", "2^13", "--shards", "-2"],
    ["sweep", "--size", "2^13", "--shard-index", "-1"],
    ["sweep", "--size", "2^13", "--shards", "2", "--shard-index", "2"],
    ["sweep", "--size", "2^13", "--jobs", "0"],
    ["sweep", "--size", "2^13", "--merge", "--no-cache"],
    ["sweep", "--size", "2^13", "--merge", "--shards", "2"],
    ["sweep", "--size", "2^13", "--merge", "--shards", "2",
     "--shard-index", "1"],
    ["advise", "--size", "2^13", "--jobs", "0"],
    ["cache", "prune"],                      # prune needs --max-bytes
    ["cache", "prune", "--max-bytes", "-5"],
])
def test_cli_rejects_bad_arguments_up_front(argv):
    with pytest.raises(SystemExit) as exc:
        main(argv)
    assert exc.value.code == 2


def test_cli_shard_index_alone_defaults_shards_error():
    # --shard-index without --shards (shards=1) is out of range for i>=1
    with pytest.raises(SystemExit) as exc:
        main(["sweep", "--size", "2^13", "--shard-index", "1"])
    assert exc.value.code == 2
