"""Minimal deterministic stand-in for ``hypothesis`` when it is missing.

The seed suite property-tests the queuing model with hypothesis, but the
container image does not ship it (it is an optional dev dependency — see
requirements-dev.txt).  Rather than skipping seven test modules, this stub
implements the exact subset the suite uses — ``given``, ``settings``, and
the ``integers`` / ``floats`` / ``lists`` / ``sampled_from`` strategies —
running each property on deterministic examples: the all-low corner, the
all-high corner, then seeded-random draws.  With real hypothesis installed
the stub is never imported and full shrinking/coverage applies.

Installed by ``conftest.py`` via ``sys.modules`` before test collection.
"""

from __future__ import annotations

import sys
import types
import zlib

import numpy as np

# Cap stub example counts: the corners catch the boundary bugs and the
# random draws are smoke, so re-running 200 examples buys little here.
MAX_STUB_EXAMPLES = 25
_ATTR = "_stub_max_examples"


class _Strategy:
    def __init__(self, sample, lo, hi):
        self.sample = sample    # fn(rng) -> value
        self.lo = lo            # fn() -> boundary-low value
        self.hi = hi            # fn() -> boundary-high value


def integers(min_value=0, max_value=1 << 16):
    return _Strategy(
        sample=lambda rng: int(rng.integers(min_value, max_value + 1)),
        lo=lambda: int(min_value),
        hi=lambda: int(max_value))


def floats(min_value=0.0, max_value=1.0, **_kw):
    return _Strategy(
        sample=lambda rng: float(rng.uniform(min_value, max_value)),
        lo=lambda: float(min_value),
        hi=lambda: float(max_value))


def sampled_from(elements):
    elements = list(elements)
    return _Strategy(
        sample=lambda rng: elements[int(rng.integers(len(elements)))],
        lo=lambda: elements[0],
        hi=lambda: elements[-1])


def booleans():
    return sampled_from([False, True])


def just(value):
    return _Strategy(sample=lambda rng: value,
                     lo=lambda: value, hi=lambda: value)


def lists(elements, min_size=0, max_size=None):
    if max_size is None:
        max_size = min_size + 10

    def sample(rng):
        size = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(size)]

    return _Strategy(
        sample=sample,
        lo=lambda: [elements.lo() for _ in range(min_size)],
        hi=lambda: [elements.hi() for _ in range(max_size)])


def given(*s_args, **s_kwargs):
    def deco(fn):
        # functools.wraps would copy __wrapped__, making pytest introspect
        # the original signature and demand fixtures for strategy params —
        # copy the identity attributes by hand instead.
        def wrapper():
            max_ex = getattr(wrapper, _ATTR, getattr(fn, _ATTR, 10))
            n = max(2, min(int(max_ex), MAX_STUB_EXAMPLES))
            seed = zlib.crc32(
                f"{fn.__module__}.{fn.__qualname__}".encode()) & 0xFFFFFFFF
            rng = np.random.default_rng(seed)
            for i in range(n):
                if i == 0:
                    args = [s.lo() for s in s_args]
                    kwargs = {k: s.lo() for k, s in s_kwargs.items()}
                elif i == 1:
                    args = [s.hi() for s in s_args]
                    kwargs = {k: s.hi() for k, s in s_kwargs.items()}
                else:
                    args = [s.sample(rng) for s in s_args]
                    kwargs = {k: s.sample(rng) for k, s in s_kwargs.items()}
                try:
                    fn(*args, **kwargs)
                except Exception:
                    print(f"falsifying example ({fn.__qualname__}): "
                          f"args={args} kwargs={kwargs}", file=sys.stderr)
                    raise
        for attr in ("__name__", "__qualname__", "__module__", "__doc__"):
            setattr(wrapper, attr, getattr(fn, attr))
        if hasattr(fn, _ATTR):
            setattr(wrapper, _ATTR, getattr(fn, _ATTR))
        return wrapper
    return deco


def settings(**kwargs):
    max_examples = kwargs.get("max_examples", 10)

    def deco(fn):
        setattr(fn, _ATTR, max_examples)
        return fn
    return deco


def install() -> None:
    """Register the stub as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "lists", "sampled_from",
                 "booleans", "just"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    mod.__stub__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
