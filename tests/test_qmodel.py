"""Queuing model: paper Eqs. 1-3, Tables 1-2, operational laws."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import microbench, qmodel, timing

TABLE = microbench.build_table()


def test_table_shape_and_boundary():
    assert TABLE.n_grid[0] == 0 and np.allclose(TABLE.T[0], 0.0)
    assert TABLE.e_grid[0] == 1 and TABLE.e_grid[-1] == 32
    assert TABLE.T.shape == (65, 32, 17)


def test_paper_fig1_shape_load_pipelining():
    """S decreases with n (pipelining amortizes fill latency)."""
    s1 = TABLE.service_time(1, 8, 0)
    s16 = TABLE.service_time(16, 8, 0)
    s64 = TABLE.service_time(64, 8, 0)
    assert s1 > s16 > s64


def test_paper_fig1_shape_conflict_serialization():
    """S increases with serialization degree e."""
    lo = TABLE.service_time(32, 1, 0)
    hi = TABLE.service_time(32, 32, 0)
    assert hi > lo
    # >10x dynamic range across the table (paper §1)
    smin = TABLE.service_time(64, 1, 0)
    smax = TABLE.service_time(1, 32, 1)
    assert smax / smin > 10


def test_cas_class_costs_more_and_popc_less():
    fao = TABLE.service_time(16, 8, 0)
    cas = TABLE.service_time(16, 8, 16)
    popc = TABLE.popc_service_time(16, 8)
    assert cas > fao > popc


def test_exact_on_lattice_points():
    for n, e, c in [(1, 1, 0), (16, 8, 8), (64, 32, 64), (32, 17, 16)]:
        expect = timing.total_time_cycles(n, e, c)
        got = TABLE.total_time(n, e, c)
        np.testing.assert_allclose(got, expect, rtol=1e-12)


@settings(max_examples=200, deadline=None)
@given(n=st.floats(0.0, 64.0), e=st.floats(1.0, 32.0),
       cfrac=st.floats(0.0, 1.0))
def test_interpolation_bounded_by_neighbors(n, e, cfrac):
    """Interpolated T lies within the hull of its 8 lattice neighbors."""
    c = cfrac * n
    got = float(TABLE.total_time(n, e, c))
    n0, n1 = np.floor(n), min(np.ceil(n), 64)
    e0, e1 = np.floor(e), min(np.ceil(e), 32)
    corners = []
    for nn in {n0, n1}:
        for ee in {e0, e1}:
            for cf in (np.floor(cfrac * 16) / 16, min(np.ceil(cfrac * 16) / 16, 1.0)):
                corners.append(float(TABLE.total_time(nn, ee, cf * nn)))
    assert min(corners) - 1e-6 <= got <= max(corners) + 1e-6


@settings(max_examples=100, deadline=None)
@given(n=st.floats(0.5, 64.0), e=st.floats(1.0, 32.0))
def test_service_time_is_T_over_n(n, e):
    t = float(TABLE.total_time(n, e, 0.0))
    s = float(TABLE.service_time(n, e, 0.0))
    np.testing.assert_allclose(s, t / n, rtol=1e-9)


def test_operational_laws():
    assert qmodel.throughput(100, 50) == 2.0
    assert qmodel.utilization_law(2.0, 0.25) == 0.5
    assert qmodel.littles_law_queue(2.0, 3.0) == 6.0
    assert qmodel.flow_balanced(10, 10)
    assert not qmodel.flow_balanced(10, 9)


def test_derive_core_utilization_table2():
    counters = [qmodel.BasicCounters(
        O=320.0, N_f=90.0, N_c=10.0, T_cycles=10000.0, occupancy=0.5,
        core_id=i) for i in range(2)]
    rows = qmodel.derive_core_utilization(counters, TABLE)
    for r in rows:
        assert r.N == 100
        np.testing.assert_allclose(r.n_hat, 32.0)      # o * n_max
        np.testing.assert_allclose(r.e, 3.2)           # O / sum N
        np.testing.assert_allclose(r.c, 32.0 * 0.1)    # n * Nc/N
        assert 0 < r.U < 1
        np.testing.assert_allclose(r.B_cycles, r.N * r.S_cycles)
        np.testing.assert_allclose(r.U, r.B_cycles / r.T_cycles)


def test_true_n_replaces_occupancy_estimate():
    c = [qmodel.BasicCounters(O=100, N_f=100, N_c=0, T_cycles=1e4,
                              occupancy=1.0, n_true=4.0)]
    est = qmodel.derive_core_utilization(c, TABLE, use_true_n=False)[0]
    tru = qmodel.derive_core_utilization(c, TABLE, use_true_n=True)[0]
    assert est.n_hat == 64.0 and tru.n_hat == 4.0
    # the paper's >100% artifact: overestimated n -> underestimated S ->
    # with low true concurrency the busy time is larger
    assert tru.B_cycles > est.B_cycles


def test_save_load_roundtrip(tmp_path):
    p = str(tmp_path / "table.npz")
    TABLE.save(p)
    t2 = qmodel.ServiceTimeTable.load(p)
    np.testing.assert_allclose(t2.T, TABLE.T)
    np.testing.assert_allclose(
        t2.service_time(13.5, 7.2, 3.3), TABLE.service_time(13.5, 7.2, 3.3))
