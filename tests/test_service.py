"""The profiling service: job schema, daemon contract, chaos acceptance.

The load-bearing claims: (a) ``parse_job`` rejects malformed payloads up
front with typed errors (the HTTP 400 surface) and expands valid ones to
the same specs the CLI would build, (b) the daemon answers every request
with a result, an explicitly-degraded result naming its fallback
provider, or a typed 4xx/5xx JSON error — never a bare 500 and never a
hang — shedding overload as 429 + Retry-After, (c) a warm resubmission
of an entire mixed burst performs zero provider collections, even with a
concurrently SIGKILLed writer sharing the cache (the chaos acceptance
test), and (d) the ``SweepCache`` quarantines corrupt entries and
survives its root being deleted out from under a running session.
"""

import json
import os
import shutil
import signal
import subprocess
import sys
import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import pytest

from repro.analysis import SweepCache, WorkloadSpec, get_provider
from repro.analysis.sweep_cache import save_counter_set
from repro.cli import main as cli_main
from repro.service import (
    JobError,
    ProfilingService,
    ServiceClient,
    ServiceConfig,
    ServiceError,
    parse_job,
)
from repro.service.server import make_http_server

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.fixture(autouse=True)
def _isolate_results(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "results"))
    yield


def _payload(kind="profile", **workload):
    workload.setdefault("workload", "indices")
    workload.setdefault("size", 1024)
    return {"kind": kind, "workload": workload}


@pytest.fixture
def service():
    svc = ProfilingService(ServiceConfig(
        workers=2, queue_depth=8, timeout_s=30.0,
        retries=1, backoff_base_s=0.001)).start()
    yield svc
    svc.stop()


# -- job schema ---------------------------------------------------------------


BAD_JOBS = [
    ([1, 2], "must be a JSON object"),
    ({"kind": "profile"}, "needs a 'workload' object"),
    ({"kind": "melt", "workload": {}}, "kind must be one of"),
    ({"kind": "profile", "workload": {}, "extra": 1}, "unknown job key"),
    ({"kind": "profile", "device": "", "workload": {}},
     "device must be a non-empty string"),
    ({"kind": "profile", "workload": {}, "timeout_s": 0}, "timeout_s"),
    ({"kind": "profile", "workload": {}, "timeout_s": 1e9},
     "timeout_s must be <="),
    ({"kind": "profile", "workload": {"bogus": 1}}, "unknown workload key"),
    ({"kind": "profile", "workload": {"size": 0}}, "size must be >= 1"),
    ({"kind": "profile", "workload": {"size": "big"}},
     "size must be a finite number"),
    ({"kind": "profile", "workload": {"size": []}},
     "size must not be an empty list"),
    ({"kind": "profile", "workload": {"size": 2.5}},
     "size must be an integer"),
    ({"kind": "profile", "workload": {"dist": "zipf"}}, "unknown dist"),
    ({"kind": "profile", "workload": {"variant": "hist9"}},
     "unknown variant"),
    ({"kind": "profile", "workload": {"workload": "fft"}},
     "unknown workload family"),
    ({"kind": "profile", "workload": {"workload": "hlo"}},
     "invalid workload"),
    ({"kind": "profile", "workload": {"size": [1024, 2048]}},
     "exactly one workload point"),
    ({"kind": "advise", "workload": {"waves_per_tile": [2, 4]}},
     "exactly one workload point"),
    ({"kind": "profile", "workload": {}, "options": {"depth": 2}},
     "unknown option"),
    ({"kind": "advise", "workload": {}, "options": {"depth": 0}},
     "depth must be >= 1"),
    ({"kind": "sweep", "workload": {}, "options": {"parallel": 0}},
     "parallel must be >= 1"),
    ({"kind": "validate", "workload": {},
      "options": {"providers": ["trace"]}}, "list of >= 2"),
]


@pytest.mark.parametrize("payload,match", BAD_JOBS,
                         ids=[m[:28] for _, m in BAD_JOBS])
def test_parse_job_rejects(payload, match):
    with pytest.raises(JobError, match=match.replace("(", "\\(")):
        parse_job(payload)


def test_parse_job_expands_the_cli_grid():
    job = parse_job({"kind": "sweep",
                     "workload": {"workload": "indices",
                                  "size": [1024, 2048], "dist": "solid",
                                  "waves_per_tile": [2, 4, 8]}})
    assert len(job.specs) == 6
    assert job.timeout_s == 30.0          # the default rides along
    assert sorted({s.waves_per_tile for s in job.specs}) == [2, 4, 8]
    # content matches what the CLI's builder makes for the same flags
    assert job.specs[0].label.startswith("solid-")


def test_parse_job_sweep_cap_is_enforced_before_synthesis():
    with pytest.raises(JobError, match="over the\nservice cap"
                       .replace("\n", " ")):
        parse_job({"kind": "sweep",
                   "workload": {"size": [1024] * 3,
                                "waves_per_tile": list(range(2, 6)),
                                "pipeline_depth": [1, 2]}},
                  max_points=10)


def test_parse_job_fills_kind_defaults():
    job = parse_job({"kind": "advise", "workload": {"size": 512}})
    assert job.options == {"depth": 2, "beam_width": 8, "top_k": 5,
                           "validate_top": 0}
    job = parse_job({"kind": "validate", "workload": {"size": 512}})
    assert job.options["providers"] == ["trace", "kernel"]


# -- the daemon contract ------------------------------------------------------


def test_profile_sweep_validate_roundtrip(service):
    st, body = service.handle(_payload("profile", dist="solid"))
    assert st == 200 and body["ok"] and not body["degraded"]
    assert body["result"]["points"][0]["bottleneck"]

    st, body = service.handle(_payload("sweep", waves_per_tile=[2, 4, 8]))
    assert st == 200 and len(body["result"]["points"]) == 3

    st, body = service.handle(
        {"kind": "validate", "workload": {"size": 512},
         "options": {"providers": ["trace", "trace"]}})
    assert st == 200
    assert body["result"]["comparisons"][1]["rel_err"]["e"] == 0.0


def test_advise_roundtrip(service):
    st, body = service.handle(
        {"kind": "advise", "workload": {"size": 1024, "dist": "solid"},
         "options": {"depth": 1, "beam_width": 2, "top_k": 2}})
    assert st == 200 and body["ok"]
    assert body["result"]["candidates"]


def test_warm_resubmission_collects_nothing(service):
    payload = _payload("sweep", dist="solid", waves_per_tile=[2, 4])
    st, _ = service.handle(payload)
    assert st == 200
    before = service.session("v5e").stats_snapshot()
    st, _ = service.handle(payload)
    assert st == 200
    after = service.session("v5e").stats_snapshot()
    assert after["batch_calls"] == before["batch_calls"]
    assert after["collected"] == before["collected"]


def test_malformed_payloads_are_400_never_500(service):
    for payload in (None, [], {"kind": "melt", "workload": {}},
                    {"kind": "profile", "workload": {"size": -1}}):
        st, body = service.handle(payload)
        assert st == 400 and not body["ok"]
        assert body["error_kind"] == "invalid-job"
    assert service.counters["invalid"] == 4


def test_degraded_responses_name_their_fallback(tmp_path):
    svc = ProfilingService(ServiceConfig(
        workers=2, fault_rate=1.0, retries=1,
        backoff_base_s=0.001)).start()
    try:
        st, body = svc.handle(_payload("profile"))
        assert st == 200 and body["ok"]
        assert body["degraded"] and body["fallback_providers"] == ["trace"]
        # the per-point meta stamp survives into the report payload
        meta = body["result"]["meta"]
        assert all(m["degraded"] and m["fallback_provider"] == "trace"
                   for m in meta.values())
        assert svc.counters["degraded"] == 1
    finally:
        svc.stop()


class _GatedProvider:
    """Blocks every collect on an event (queue-shedding fodder)."""

    name = "trace"

    def __init__(self, gate, entered=None):
        self.gate = gate
        self.entered = entered or threading.Event()
        self.inner = get_provider("trace")

    def collect(self, spec, device):
        self.entered.set()
        assert self.gate.wait(30)
        return self.inner.collect(spec, device)


def test_queue_full_sheds_with_429_and_retry_after():
    gate = threading.Event()
    entered = threading.Event()
    svc = ProfilingService(ServiceConfig(
        workers=1, queue_depth=1, timeout_s=30.0,
        call_timeout_s=60.0, provider=_GatedProvider(gate, entered),
        fallbacks=())).start()
    results = []

    def submit(seed):
        results.append(svc.handle(_payload("profile", seed=seed)))

    try:
        t1 = threading.Thread(target=submit, args=(1,))
        t1.start()
        # the worker signals from inside collect, so job 1 is provably
        # off the queue (polling qsize here races: it reads 0 before
        # the submitter thread has even enqueued the ticket)
        assert entered.wait(10)
        t2 = threading.Thread(target=submit, args=(2,))
        t2.start()
        # job 2 now fills the single queue slot behind the blocked worker
        deadline = time.monotonic() + 10
        while svc._queue.qsize() < 1 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert svc._queue.qsize() == 1
        st, body = svc.handle(_payload("profile", seed=3))  # queue full
        assert st == 429
        assert body["error_kind"] == "overloaded"
        assert body["retry_after_s"] > 0
        gate.set()
        t1.join(30)
        t2.join(30)
        assert [st for st, _ in results] == [200, 200]
        assert svc.counters["shed"] == 1
    finally:
        gate.set()
        svc.stop()


def test_unstarted_service_refuses_cleanly(service):
    svc = ProfilingService(ServiceConfig(workers=1))
    st, body = svc.handle(_payload())
    assert st == 503 and "not started" in body["error"]


def test_service_config_validates():
    with pytest.raises(ValueError):
        ServiceConfig(workers=0)
    with pytest.raises(ValueError):
        ServiceConfig(queue_depth=0)
    with pytest.raises(ValueError):
        ServiceConfig(timeout_s=50.0, max_timeout_s=10.0)
    with pytest.raises(ValueError):
        ServiceConfig(retries=-1)


# -- HTTP + client ------------------------------------------------------------


@pytest.fixture
def http_service(service):
    server = make_http_server(service, port=0)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    yield service, server.server_address[1]
    server.shutdown()
    server.server_close()


def test_http_endpoints(http_service):
    _, port = http_service
    client = ServiceClient("127.0.0.1", port, timeout_s=30)
    assert client.health() == {"ok": True}
    assert "workload_defaults" in client.schema()
    body = client.submit(_payload("profile", dist="solid"))
    assert body["ok"] and body["result"]["points"]
    status = client.status()
    assert status["counters"]["completed"] >= 1
    assert "trace" in status["breakers"]
    assert status["sessions"]["v5e"]["collected"] >= 1


def test_http_error_statuses(http_service):
    _, port = http_service
    client = ServiceClient("127.0.0.1", port, timeout_s=30)
    with pytest.raises(ServiceError) as ei:
        client.submit({"kind": "melt", "workload": {}})
    assert ei.value.status == 400
    assert ei.value.body["error_kind"] == "invalid-job"
    with pytest.raises(ServiceError) as ei:
        client._request("/nope")
    assert ei.value.status == 404
    # a connection refusal is a typed error too, not a raw socket trace
    dead = ServiceClient("127.0.0.1", 1, timeout_s=2)
    with pytest.raises(ServiceError) as ei:
        dead.health()
    assert ei.value.status is None


def test_http_rejects_unreadable_json(http_service):
    import urllib.error
    import urllib.request
    _, port = http_service
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}/v1/jobs", data=b"{not json",
        headers={"Content-Type": "application/json"})
    with pytest.raises(urllib.error.HTTPError) as ei:
        urllib.request.urlopen(req, timeout=10)
    assert ei.value.code == 400


def test_client_validates_and_retries_on_busy(monkeypatch):
    with pytest.raises(ValueError):
        ServiceClient(port=0)
    with pytest.raises(ValueError):
        ServiceClient(timeout_s=0)
    slept = []
    client = ServiceClient(port=8642, sleep=slept.append)
    calls = []

    def fake_request(path, payload=None):
        calls.append(path)
        if len(calls) < 3:
            raise ServiceError("busy", status=429,
                               body={"retry_after_s": 0.25})
        return {"ok": True}

    monkeypatch.setattr(client, "_request", fake_request)
    assert client.submit({"kind": "profile"}, retries_on_busy=3)["ok"]
    assert slept == [0.25, 0.25]          # Retry-After honored
    calls.clear()
    with pytest.raises(ServiceError):
        client.submit({"kind": "profile"}, retries_on_busy=1)
    assert len(calls) == 2                # bounded retries
    with pytest.raises(ValueError):
        client.submit({}, retries_on_busy=-1)


# -- chaos acceptance ---------------------------------------------------------


def _mixed_burst(n, rng):
    """n distinct-content jobs mixing every kind (advise kept cheap)."""
    jobs = []
    for i in range(n):
        size = int(rng.choice([512, 1024, 2048]))
        seed = int(rng.integers(0, 40))
        dist = str(rng.choice(["solid", "uniform"]))
        workload = {"workload": "indices", "size": size, "seed": seed,
                    "dist": dist}
        roll = i % 10
        if roll < 6:
            jobs.append({"kind": "profile", "workload": workload})
        elif roll < 8:
            jobs.append({"kind": "sweep",
                         "workload": {**workload,
                                      "waves_per_tile": [2, 4]}})
        elif roll < 9:
            jobs.append({"kind": "validate", "workload": workload,
                         "options": {"providers": ["trace", "trace"]}})
        else:
            jobs.append({"kind": "advise", "workload": workload,
                         "options": {"depth": 1, "beam_width": 2,
                                     "top_k": 1}})
    return jobs


def test_chaos_acceptance(tmp_path):
    """The PR's acceptance bar: a 200-job mixed burst against a daemon
    with 20% injected faults, with a concurrently SIGKILLed writer
    sharing the cache — every response is 200-with-result or explicitly
    degraded (naming its fallback), the cache holds zero corrupt
    entries, and a warm resubmission of the entire burst performs zero
    provider collections."""
    # retries=0 so an injected fault degrades immediately (with retries
    # a 20% per-call rate is almost always absorbed before the fallback,
    # and the burst would assert on a near-zero degradation count)
    svc = ProfilingService(ServiceConfig(
        workers=4, queue_depth=256, timeout_s=60.0, max_timeout_s=120.0,
        retries=0, breaker_threshold=10 ** 6,
        fault_rate=0.2, corrupt_rate=0.05, fault_seed=42)).start()
    jobs = _mixed_burst(200, np.random.default_rng(0))

    # the doomed writer: a sharded CLI sweep into the same cache root,
    # SIGKILLed mid-run — its half-written tmp files must never surface
    # as cache entries (atomic tmp+rename)
    env = {**os.environ,
           "REPRO_RESULTS": os.environ["REPRO_RESULTS"],
           "PYTHONPATH": os.path.join(REPO, "src")}
    victim = subprocess.Popen(
        [sys.executable, "-m", "repro", "sweep", "--workload", "indices",
         "--size", "2^14", "2^15", "--dist", "uniform",
         "--waves-per-tile", "2", "3", "4", "5", "6", "7",
         "--jobs", "1", "--no-artifact"],
        env=env, cwd=REPO, stdout=subprocess.DEVNULL,
        stderr=subprocess.DEVNULL)

    try:
        with ThreadPoolExecutor(max_workers=8) as pool:
            futures = [pool.submit(svc.handle, j) for j in jobs]
            time.sleep(0.4)               # let the victim get mid-sweep
            if victim.poll() is None:
                victim.send_signal(signal.SIGKILL)
            results = [f.result(timeout=120) for f in futures]
        victim.wait(30)

        # contract: every response is a 200 result; degraded ones name
        # their fallback; nothing is a 5xx and nothing hung
        assert [st for st, _ in results] == [200] * len(jobs)
        degraded = [b for _, b in results if b["degraded"]]
        assert degraded, "20% fault injection produced no degradations"
        assert all(b["fallback_providers"] for b in degraded)
        assert svc.counters["failed"] == 0
        assert svc.fault.stats_snapshot()["faults"] > 0

        # zero corrupt cache entries, even with the SIGKILLed writer
        entries = list(svc.cache.iter_entries())
        assert all(cset is not None for _, cset in entries)
        assert svc.cache.stats()["quarantined"] == 0

        # warm resubmission: the whole burst again, zero collections
        before = svc.session("v5e").stats_snapshot()
        with ThreadPoolExecutor(max_workers=8) as pool:
            warm = [f.result(timeout=120) for f in
                    [pool.submit(svc.handle, j) for j in jobs
                     if j["kind"] in ("profile", "sweep")]]
        assert all(st == 200 for st, _ in warm)
        after = svc.session("v5e").stats_snapshot()
        assert after["batch_calls"] == before["batch_calls"]
        assert after["collected"] == before["collected"]
    finally:
        if victim.poll() is None:
            victim.kill()
        svc.stop()


# -- SweepCache robustness (quarantine + vanished root) -----------------------


def _fill_cache(cache, n=3):
    spec = WorkloadSpec.from_indices(
        np.random.default_rng(0).integers(0, 256, 512), 256,
        label="seed", waves_per_tile=2)
    cset = get_provider("trace").collect(
        spec, __import__("repro.analysis",
                         fromlist=["get_device"]).get_device("v5e"))
    keys = [f"{i:032x}" for i in range(n)]
    for k in keys:
        cache.put(k, cset)
    return keys


def test_corrupt_entry_quarantined_then_pruned(tmp_path):
    cache = SweepCache()
    keys = _fill_cache(cache, 2)
    cache.path(keys[0]).write_bytes(b"not an npz at all")
    assert cache.get(keys[0]) is None     # miss, not a crash
    assert not cache.path(keys[0]).exists()   # moved aside
    stats = cache.stats()
    assert stats["quarantined"] == 1 and stats["entries"] == 1
    assert cache.get_many(keys) and keys[0] not in cache.get_many(keys)
    # a later write under the same key is a fresh, readable entry
    _fill_cache(cache, 1)
    assert cache.get(keys[0]) is not None
    removed, freed = cache.prune()
    assert removed == 1 and freed > 0     # the quarantined file
    assert cache.stats()["quarantined"] == 0


def test_prune_clears_orphaned_tmp_files(tmp_path):
    cache = SweepCache()
    _fill_cache(cache, 1)
    (cache.root / "deadbeef.tmp").write_bytes(b"half-written")
    removed, _ = cache.prune()
    assert removed == 1
    assert not list(cache.root.glob("*.tmp"))
    assert len(cache) == 1                # the live entry survives


def test_cache_root_deleted_out_from_under_running_session(tmp_path):
    cache = SweepCache()
    _fill_cache(cache, 3)
    assert len(cache) == 3
    shutil.rmtree(cache.root)
    # every maintenance surface reads the vanished root as empty
    assert cache.stats()["entries"] == 0
    assert cache.stats()["quarantined"] == 0
    assert cache.prune(0) == (0, 0)
    assert cache.clear() == 0
    assert len(cache) == 0
    assert cache.get("0" * 32) is None
    assert list(cache.iter_entries()) == []


def test_concurrent_clear_mid_iteration(tmp_path):
    """A clear() racing an iter_entries()/stats() scan from another
    thread: the scan may see fewer entries, never an exception."""
    cache = SweepCache()
    _fill_cache(cache, 40)
    it = cache.iter_entries()
    first = next(it)
    assert first[1] is not None
    cleared = {}

    def clear():
        cleared["n"] = cache.clear()

    t = threading.Thread(target=clear)
    t.start()
    survivors = [e for e in it]           # must not raise mid-race
    t.join()
    assert cleared["n"] <= 40
    assert len(survivors) <= 39
    assert cache.stats()["entries"] == 0


def test_cache_cli_reports_quarantined(tmp_path, capsys):
    cache = SweepCache()
    keys = _fill_cache(cache, 2)
    cache.path(keys[0]).write_bytes(b"garbage")
    assert cache.get(keys[0]) is None     # quarantines
    rc = cli_main(["cache", "stats"])
    out = capsys.readouterr().out
    assert rc == 0 and "1 quarantined corrupt file(s)" in out
    rc = cli_main(["cache", "stats", "--format", "json"])
    assert json.loads(capsys.readouterr().out)["quarantined"] == 1
    rc = cli_main(["cache", "prune", "--max-bytes", "10^9"])
    assert rc == 0 and "pruned 1" in capsys.readouterr().out
    rc = cli_main(["cache", "stats", "--format", "json"])
    assert json.loads(capsys.readouterr().out)["quarantined"] == 0


# -- serve/client argparse rejection matrix -----------------------------------


SERVE_REJECTS = [
    ["serve", "--port", "99999"],
    ["serve", "--port", "-1"],
    ["serve", "--workers", "0"],
    ["serve", "--queue-depth", "0"],
    ["serve", "--timeout", "0"],
    ["serve", "--timeout", "nan"],
    ["serve", "--timeout", "50", "--max-timeout", "10"],
    ["serve", "--call-timeout", "500", "--max-timeout", "300"],
    ["serve", "--retries", "-1"],
    ["serve", "--backoff-base", "0"],
    ["serve", "--breaker-threshold", "0"],
    ["serve", "--breaker-cooldown", "-1"],
    ["serve", "--fault-rate", "1.5"],
    ["serve", "--fault-rate", "-0.1"],
    ["serve", "--corrupt-rate", "2"],
    ["serve", "--latency-s", "0"],
    ["serve", "--fault-seed", "-1"],
    ["serve", "--max-points", "0"],
    ["client", "health", "--port", "0"],
    ["client", "health", "--port", "70000"],
    ["client", "submit"],
    ["client", "submit", "--job", "{}", "--job-file", "x.json"],
    ["client", "health", "--job", "{}"],
    ["client", "submit", "--job", "{}", "--retries-on-busy", "-1"],
    ["client", "status", "--timeout", "0"],
]


@pytest.mark.parametrize("argv", SERVE_REJECTS,
                         ids=[" ".join(a[1:])[:40] for a in SERVE_REJECTS])
def test_serve_client_flag_rejection_matrix(argv):
    with pytest.raises(SystemExit) as ei:
        cli_main(argv)
    assert ei.value.code == 2             # argparse rejection, no work done
