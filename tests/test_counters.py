"""Wave-trace counters: degree definition, occupancy, Table-1 aggregation."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import counters, timing


def test_wave_degree_extremes():
    solid = np.zeros(1024, np.int64)
    assert counters.wave_degree(solid) == 32.0          # full serialization
    distinct = np.arange(1024)
    assert counters.wave_degree(distinct) == 1.0        # conflict-free


def test_wave_degree_reorder_effect():
    """4 distinct bins per 32-lane group -> degree 8 (paper Listing 2)."""
    idx = np.tile(np.repeat(np.arange(4), 8), 32)
    assert counters.wave_degree(idx) == 8.0


def test_wave_degree_padding_adds_no_conflicts():
    idx = np.zeros(40, np.int64)  # pads to 64 with unique sentinels
    d = counters.wave_degree(idx, lanes=64, group=32)
    assert d == (32 + 8) / 2  # group1 fully solid, group2 8 solid + 24 pads


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(0, 255), min_size=32, max_size=512))
def test_wave_degree_bounds(ids):
    d = counters.wave_degree(np.asarray(ids))
    assert 1.0 <= d <= 32.0


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 20), st.integers(0, 255))
def test_solid_stream_always_degree_32(num_waves, color):
    idx = np.full(num_waves * 1024, color)
    tr = counters.trace_from_indices(idx, 256, num_cores=4)
    assert np.allclose(tr.degree, 32.0)


def test_trace_core_assignment_round_robin():
    idx = np.arange(8 * 1024)
    tr = counters.trace_from_indices(idx, 1 << 14, num_cores=4,
                                     waves_per_tile=2)
    assert tr.num_waves == 8
    np.testing.assert_array_equal(tr.core, [0, 0, 1, 1, 2, 2, 3, 3])


def test_occupancy_and_true_n():
    idx = np.zeros(64 * 1024, np.int64)
    tr = counters.trace_from_indices(idx, 256, num_cores=1, waves_per_tile=8)
    o = tr.occupancy(64)
    assert o == 16 / 64   # 8 waves x depth 2
    n_true = tr.true_n(64)
    assert 0 < n_true <= 16


def test_collect_basic_counters_conservation():
    rng = np.random.default_rng(0)
    idx = rng.integers(0, 256, 16 * 1024)
    tr = counters.trace_from_indices(idx, 256, num_cores=4)
    basic = counters.collect_basic_counters(tr, num_cores=4)
    assert sum(b.N_f for b in basic) == tr.num_waves
    total_o = sum(b.O for b in basic)
    np.testing.assert_allclose(total_o, tr.degree.sum())
    e = total_o / tr.num_waves
    assert 1.0 <= e <= 32.0


def test_job_classes_respected():
    idx = np.zeros(2048, np.int64)
    tr = counters.trace_from_indices(idx, 16, job_class=timing.CAS)
    basic = counters.collect_basic_counters(tr, num_cores=1)
    assert basic[0].N_c == tr.num_waves and basic[0].N_f == 0
