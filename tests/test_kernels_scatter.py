"""Scatter-add / bincount Pallas kernels vs jnp oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import microbench
from repro.kernels.scatter_add import ops, ref


@pytest.mark.parametrize("n,d,s", [(1000, 8, 64), (4096, 64, 128),
                                   (5000, 16, 128), (2048, 128, 32)])
def test_scatter_add_matches_ref(n, d, s):
    rng = np.random.default_rng(0)
    vals = rng.standard_normal((n, d)).astype(np.float32)
    ids = rng.integers(0, s, n).astype(np.int32)
    out = ops.scatter_add(jnp.asarray(vals), jnp.asarray(ids), num_segments=s)
    expect = ref.scatter_add_ref(jnp.asarray(vals), jnp.asarray(ids), s)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_scatter_add_dtypes(dtype):
    rng = np.random.default_rng(1)
    vals = rng.standard_normal((2048, 8)).astype(dtype)
    ids = rng.integers(0, 64, 2048).astype(np.int32)
    out = ops.scatter_add(jnp.asarray(vals), jnp.asarray(ids),
                          num_segments=64)
    expect = ref.scatter_add_ref(jnp.asarray(vals.astype(np.float32)),
                                 jnp.asarray(ids), 64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-3, atol=2e-3)


def test_blocked_segment_axis_vocab_scale():
    """Embedding-grad case: segments >> one VMEM block."""
    rng = np.random.default_rng(2)
    vals = rng.standard_normal((3000, 8)).astype(np.float32)
    ids = rng.integers(0, 16384, 3000).astype(np.int32)
    out = ops.scatter_add(jnp.asarray(vals), jnp.asarray(ids),
                          num_segments=16384, seg_block=4096)
    expect = ref.scatter_add_ref(jnp.asarray(vals), jnp.asarray(ids), 16384)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3000), st.integers(2, 200), st.integers(0, 2**31 - 1))
def test_bincount_property(n, s, seed):
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, s, n).astype(np.int32)
    out = np.asarray(ops.bincount(jnp.asarray(ids), num_segments=s))
    np.testing.assert_array_equal(out, np.bincount(ids, minlength=s))


def test_instrumented_counters_match_designed_pattern():
    """Tool-1 validation loop: designed (n, e) recovered from the kernel."""
    table = microbench.build_table(mode="kernel", kernel_validation_points=6)
    for rec in table.meta["kernel_validation"]:
        assert rec["e_rel_err"] < 0.05, rec


def test_instrumented_totals():
    rng = np.random.default_rng(3)
    ids = rng.integers(0, 128, 4096).astype(np.int32)
    vals = np.ones((4096, 4), np.float32)
    out, c = ops.instrumented_scatter_add(ids, vals, 128)
    assert c["N"] == 4096 / 1024  # 4 waves of 1024 lanes
    assert c["O"] >= c["N"]          # degree >= 1 per wave
    np.testing.assert_allclose(np.asarray(out).sum(), 4096 * 4, rtol=1e-6)
