"""Regenerate the golden pre-optimization HLO dumps used by the tests.

The goldens are real ``repro.audit.zoo`` lowerings of two reduced
configs (the same smoke geometry ``audit --reduced`` uses), gzipped to
keep the repo small:

    granite_moe_1b_a400m__decode.hlo.gz   MoE decode: dispatch scatter,
                                          expert-count histogram, argsort
                                          routing, KV-cache DUS writes
    whisper_small__train.hlo.gz           encoder-decoder train: heavy
                                          DUS traffic, tuple-shaped
                                          while carries

Run from the repo root after an intentional lowering change:

    PYTHONPATH=src python tests/data/regen_hlo_goldens.py
"""
import gzip
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[2] / "src"))

from repro.audit import zoo  # noqa: E402

HERE = pathlib.Path(__file__).resolve().parent
GOLDENS = {
    "granite_moe_1b_a400m__decode.hlo.gz": ("granite-moe-1b-a400m",
                                            "decode"),
    "whisper_small__train.hlo.gz": ("whisper-small", "train"),
}


def main() -> int:
    for fname, (arch, step) in GOLDENS.items():
        text = zoo.lower_config_steps(arch, steps=[step],
                                      reduced=True)[step]
        path = HERE / fname
        # mtime=0 keeps the archive byte-stable across regenerations
        with gzip.GzipFile(path, "wb", mtime=0) as fh:
            fh.write(text.encode())
        print(f"wrote {path} ({path.stat().st_size} bytes, "
              f"{len(text)} chars)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
