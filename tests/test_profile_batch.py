"""Columnar batch profiler: CounterFrame, profile_batch, sweep cache.

The acceptance contract of PR 4: for a >= 64-point grid, the batch path
(``CounterFrame`` + ``profiler.profile_batch``) must agree with the
scalar per-point path (``profiler.profile_counters``) point for point —
U, n-hat, e within rtol 1e-9 (they are in fact bit-identical), and
``classify``/``detect_shifts`` outputs identical — and the persistent
sweep cache must let a fresh Session re-sweep without collecting a
single counter.
"""

import dataclasses

import numpy as np
import pytest

from repro.analysis import Session, WorkloadSpec
from repro.analysis import device as device_mod
from repro.analysis.sweep_cache import SweepCache, save_counter_set
from repro.core import bottleneck, profiler, timing
from repro.core.counters import CounterFrame, CounterSet
from repro.core.profiler import CacheModel


@pytest.fixture
def sess(tmp_path):
    device_mod._TABLE_MEMO.clear()
    return Session("v5e", cache_dir=tmp_path)


def _grid_specs(n_points=64, stream=1 << 14, seed=0):
    rng = np.random.default_rng(seed)
    base = WorkloadSpec.from_indices(
        rng.integers(0, 256, stream), 256, label="grid")
    specs = base.grid(waves_per_tile=[1, 2, 4, 8, 16, 32, 64, 128],
                      pipeline_depth=[1, 2, 4, 8],
                      overhead_cycles=[500.0, 2000.0])
    assert len(specs) >= n_points
    return specs[:n_points]


def _scalar_profiles(sess, csets, **kw):
    dev = sess.device
    return [profiler.profile_counters(
        c, sess.table, params=dev.scatter, chip=dev.chip, cache=dev.cache,
        **kw) for c in csets]


def _assert_equivalent(scalar, batch, rtol=1e-9):
    assert len(scalar) == len(batch)
    for a, b in zip(scalar, batch):
        assert a.label == b.label
        np.testing.assert_allclose(b.scatter_utilization,
                                   a.scatter_utilization, rtol=rtol)
        np.testing.assert_allclose(b.e, a.e, rtol=rtol)
        np.testing.assert_allclose(b.n_hat, a.n_hat, rtol=rtol)
        np.testing.assert_allclose(b.T_cycles, a.T_cycles, rtol=rtol)
        assert len(a.per_core) == len(b.per_core)
        for ca, cb in zip(a.per_core, b.per_core):
            for f in ("N", "n_hat", "e", "c", "S_cycles", "B_cycles",
                      "T_cycles", "U"):
                np.testing.assert_allclose(getattr(cb, f), getattr(ca, f),
                                           rtol=rtol, err_msg=f)
        assert [u.name for u in a.units] == [u.name for u in b.units]
        for ua, ub in zip(a.units, b.units):
            np.testing.assert_allclose(ub.utilization, ua.utilization,
                                       rtol=rtol)
        assert a.bottleneck == b.bottleneck
        assert bottleneck.classify(a) == bottleneck.classify(b)
        assert a.params == b.params


# -- the acceptance grid ------------------------------------------------------


def test_batch_equals_scalar_on_64_point_grid(sess):
    specs = _grid_specs()
    csets = [sess.collect(s) for s in specs]
    scalar = _scalar_profiles(sess, csets)
    batch = profiler.profile_batch(
        CounterFrame.from_sets(csets), sess.table,
        params=sess.device.scatter, chip=sess.device.chip,
        cache=sess.device.cache)
    _assert_equivalent(scalar, batch)
    # shift events from both paths are identical, tolerance included
    assert bottleneck.detect_shifts(scalar) == bottleneck.detect_shifts(batch)
    assert (bottleneck.detect_shifts(scalar, tol=0.0)
            == bottleneck.detect_shifts(batch, tol=0.0))


def test_batch_equals_scalar_use_true_n(sess):
    specs = _grid_specs(n_points=16)
    csets = [sess.collect(s) for s in specs]
    scalar = _scalar_profiles(sess, csets, use_true_n=True)
    batch = profiler.profile_batch(
        CounterFrame.from_sets(csets), sess.table,
        params=sess.device.scatter, chip=sess.device.chip,
        cache=sess.device.cache, use_true_n=True)
    _assert_equivalent(scalar, batch)


def test_batch_handles_mixed_job_classes_and_empty_points(sess):
    """POPC/CAS rows and counter-less (HLO-style) rows in one frame."""
    rng = np.random.default_rng(1)
    specs = [
        WorkloadSpec.from_indices(np.zeros(1 << 13, np.int64), 256,
                                  label="popc", job_class=timing.POPC,
                                  waves_per_tile=8),
        WorkloadSpec.from_indices(rng.integers(0, 8, 1 << 13), 256,
                                  label="cas", job_class=timing.CAS,
                                  waves_per_tile=8),
        WorkloadSpec.from_indices(rng.integers(0, 256, 1 << 13), 256,
                                  label="fao", waves_per_tile=32),
    ]
    csets = [sess.collect(s) for s in specs]
    csets.append(CounterSet(label="hlo-only", source="hlo", num_cores=8,
                            bytes_read=4e6, flops=2e10))
    scalar = _scalar_profiles(sess, csets)
    batch = profiler.profile_batch(
        CounterFrame.from_sets(csets), sess.table,
        params=sess.device.scatter, chip=sess.device.chip,
        cache=sess.device.cache)
    _assert_equivalent(scalar, batch)
    assert batch[-1].per_core == []             # counter-less point


def test_batch_empty_frame_list():
    assert profiler.profile_batch.__name__  # import sanity
    with pytest.raises(ValueError, match="at least one"):
        CounterFrame.from_sets([])


def test_session_sweep_equals_scalar_loop_with_shifts(tmp_path):
    """End-to-end Session.sweep (batch) vs scalar loop, across a real
    bottleneck shift (the PR-1 scatter->hbm sweep)."""
    device_mod._TABLE_MEMO.clear()
    dev = device_mod.get_device("v5e").with_(cache=CacheModel(
        llc_bytes=1 << 20, miss_latency_cycles=2000, hide_concurrency=64.0))
    sess = Session(dev, cache_dir=tmp_path)
    rng = np.random.default_rng(0)
    specs = [
        WorkloadSpec.from_indices(
            rng.integers(0, 256, (1 << p) * 1024), 256,
            label=f"2^{p + 10}", waves_per_tile=2,
            bytes_read=float((1 << p) * 1024 * 4))
        for p in range(2, 11)]
    result = sess.sweep(specs)
    csets = [sess.collect(s) for s in specs]
    scalar = _scalar_profiles(sess, csets)
    _assert_equivalent(scalar, result.profiles)
    assert bottleneck.detect_shifts(scalar, tol=sess.shift_tol) \
        == result.shifts
    assert any(s.unit_after == "hbm" for s in result.shifts)


def test_session_profile_single_point_matches_scalar(sess):
    spec = WorkloadSpec.from_indices(np.zeros(1 << 14, np.int64), 256,
                                     label="solid", waves_per_tile=32)
    prof = sess.profile(spec)
    [scalar] = _scalar_profiles(sess, [sess.collect(spec)])
    _assert_equivalent([scalar], [prof])


def test_session_groups_mixed_core_counts(sess):
    """A sweep mixing num_cores still profiles (grouped frames)."""
    rng = np.random.default_rng(2)
    idx = rng.integers(0, 256, 1 << 13)
    specs = [
        WorkloadSpec.from_indices(idx, 256, label="8core", num_cores=8,
                                  waves_per_tile=8),
        WorkloadSpec.from_indices(idx, 256, label="2core", num_cores=2,
                                  waves_per_tile=8),
        WorkloadSpec.from_indices(idx, 256, label="8core-b", num_cores=8,
                                  waves_per_tile=16),
    ]
    result = sess.sweep(specs)
    assert [p.label for p in result.profiles] == ["8core", "2core", "8core-b"]
    assert [len(p.per_core) for p in result.profiles] == [8, 2, 8]
    csets = [sess.collect(s) for s in specs]
    _assert_equivalent(_scalar_profiles(sess, csets), result.profiles)


# -- CounterFrame -------------------------------------------------------------


def test_counter_frame_row_round_trip(sess):
    specs = _grid_specs(n_points=4)
    csets = [sess.collect(s) for s in specs]
    frame = CounterFrame.from_sets(csets)
    assert len(frame) == 4 and frame.num_points == 4
    for i, cs in enumerate(csets):
        back = frame.row(i)
        assert back.label == cs.label and back.source == cs.source
        assert back.num_cores == cs.num_cores
        for f in ("O", "N_f", "N_c", "N_p"):
            np.testing.assert_array_equal(getattr(back, f), getattr(cs, f))
        for f in ("lanes_active", "num_waves", "waves_per_tile",
                  "pipeline_depth", "bytes_read", "flops", "ici_bytes",
                  "overhead_cycles", "wall_time_s", "meta"):
            assert getattr(back, f) == getattr(cs, f)


def test_counter_frame_rejects_ragged_cores():
    a = CounterSet(label="a", num_cores=8)
    b = CounterSet(label="b", num_cores=2)
    with pytest.raises(ValueError, match="share num_cores"):
        CounterFrame.from_sets([a, b])


def test_counter_frame_derived_columns_match_sets(sess):
    specs = _grid_specs(n_points=8)
    csets = [sess.collect(s) for s in specs]
    frame = CounterFrame.from_sets(csets)
    n_max = sess.device.scatter.n_max
    for i, cs in enumerate(csets):
        assert float(frame.total_jobs[i]) == cs.total_jobs
        assert float(frame.total_O[i]) == cs.total_O
        np.testing.assert_allclose(float(frame.e[i]), cs.e, rtol=1e-12)
        assert float(frame.occupancy(n_max)[i]) == cs.occupancy(n_max)
        assert float(frame.true_n(n_max)[i]) == cs.true_n(n_max)


# -- persistent sweep cache ---------------------------------------------------


def test_sweep_cache_round_trip(tmp_path, sess):
    cache = SweepCache(tmp_path / "cache")
    cset = sess.collect(WorkloadSpec.from_indices(
        np.zeros(1 << 13, np.int64), 256, label="solid", waves_per_tile=8))
    key = cache.key("trace", "fp", sess.device.table_key())
    assert cache.get(key) is None
    cache.put(key, cset)
    back = cache.get(key)
    assert back is not None
    assert back.label == cset.label and back.source == cset.source
    for f in ("O", "N_f", "N_c", "N_p"):
        np.testing.assert_array_equal(getattr(back, f), getattr(cset, f))
    assert back.wall_time_s is None             # None survives, not 0.0
    assert back.meta == cset.meta
    assert len(cache) == 1
    assert cache.clear() == 1 and len(cache) == 0


def test_sweep_cache_wall_time_round_trip(tmp_path):
    cache = SweepCache(tmp_path)
    cset = CounterSet(label="timed", num_cores=2, wall_time_s=1.25,
                      meta={"k": "v"})
    cache.put("k1", cset)
    back = cache.get("k1")
    assert back.wall_time_s == 1.25 and back.meta == {"k": "v"}


def test_sweep_cache_corrupt_entry_is_a_miss(tmp_path):
    cache = SweepCache(tmp_path)
    cache.put("bad", CounterSet(label="x", num_cores=1))
    cache.path("bad").write_bytes(b"not an npz")
    assert cache.get("bad") is None


def test_warm_session_skips_collection(tmp_path, sess):
    """A fresh Session over a populated cache collects nothing and
    reproduces the cold sweep bit for bit."""
    root = tmp_path / "cache"
    specs = _grid_specs(n_points=8)
    cold = Session("v5e", table=sess.table, persistent_cache=root)
    r_cold = cold.sweep(specs, parallel=2)
    assert cold.stats["collected"] == len(specs)
    warm = Session("v5e", table=sess.table, persistent_cache=root)
    r_warm = warm.sweep(specs, parallel=2)
    assert warm.stats["collected"] == 0
    assert warm.stats["disk_hits"] == len(specs)
    for a, b in zip(r_cold.profiles, r_warm.profiles):
        assert a.label == b.label
        assert a.scatter_utilization == b.scatter_utilization
        np.testing.assert_array_equal(a.T_cycles, b.T_cycles)
    assert r_cold.shifts == r_warm.shifts
    assert [v.bottleneck for v in r_cold.verdicts] \
        == [v.bottleneck for v in r_warm.verdicts]


def test_cache_key_tracks_provider_fingerprint_and_device(tmp_path):
    cache = SweepCache(tmp_path)
    base = cache.key("trace", "fp1", "v5e-key")
    assert cache.key("kernel", "fp1", "v5e-key") != base
    assert cache.key("trace", "fp2", "v5e-key") != base
    assert cache.key("trace", "fp1", "v5p-key") != base
    assert cache.key("trace", "fp1", "v5e-key") == base


def test_cache_key_tracks_collection_implementation(tmp_path, monkeypatch):
    """Changing the counter-producing code must invalidate old entries:
    the key folds in a digest of the collection source files."""
    from repro.analysis import sweep_cache as sc
    cache = SweepCache(tmp_path)
    digest = sc._collection_code_digest()
    assert digest and digest == sc._collection_code_digest()  # stable
    base = cache.key("trace", "fp1", "v5e-key")
    monkeypatch.setattr(sc, "_collection_code_digest", lambda: "deadbeef")
    assert cache.key("trace", "fp1", "v5e-key") != base


def test_unfingerprintable_specs_bypass_cache(tmp_path, sess):
    from repro.core import counters as counters_mod
    tr = counters_mod.trace_from_indices(np.zeros(2048, np.int64), 256)
    spec = WorkloadSpec(label="opaque", run=lambda: tr)
    s = Session("v5e", table=sess.table, persistent_cache=tmp_path / "c")
    s.profile(spec)
    s.profile(spec)
    assert s.stats["collected"] == 2            # collected twice, never cached
    assert len(s.sweep_cache) == 0


def test_save_counter_set_atomic_leaves_no_tmp(tmp_path):
    path = tmp_path / "entry.npz"
    save_counter_set(CounterSet(label="a", num_cores=4), path)
    assert path.exists()
    assert list(tmp_path.glob("*.tmp")) == []


def test_session_rejects_nothing_and_keeps_memo_priority(tmp_path, sess):
    """Memo hits never touch the disk cache (stats prove the order)."""
    spec = WorkloadSpec.from_indices(np.zeros(1 << 12, np.int64), 256,
                                     label="m", waves_per_tile=4)
    s = Session("v5e", table=sess.table, persistent_cache=tmp_path / "c")
    s.profile(spec)
    s.profile(spec.with_(label="m2"))
    assert s.stats == {"collected": 1, "memo_hits": 1, "disk_hits": 0,
                       "batch_calls": 1}


def test_single_pass_profile_counters_matches_dataclass_fields(sess):
    """Satellite: the de-duplicated profile_counters still reports a
    consistent U = B / T against the modeled window."""
    cset = sess.collect(WorkloadSpec.from_indices(
        np.zeros(1 << 14, np.int64), 256, label="solid", waves_per_tile=32))
    prof = profiler.profile_counters(cset, sess.table,
                                     params=sess.device.scatter,
                                     chip=sess.device.chip,
                                     cache=sess.device.cache)
    for i, row in enumerate(prof.per_core):
        assert row.T_cycles == float(prof.T_cycles[i])
        np.testing.assert_allclose(row.U, row.B_cycles / row.T_cycles,
                                   rtol=1e-12)
        assert dataclasses.asdict(row)  # rows stay plain dataclasses
