"""Decode ≡ forward (teacher forcing) for every family, incl. stacked
shared-attn caches (zamba2), cross-attn image K/V (vlm), enc-dec cross
(whisper), ring-buffer sliding-window caches."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import attention
from repro.models.registry import build_model, make_batch

ALL_ARCHS = sorted(ARCHS)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)
    kw = {}
    if cfg.family == "audio":
        kw = {"frames": batch["frames"]}
        fwd, _ = model.forward(params, batch["tokens"], batch["frames"])
    elif cfg.family == "vlm":
        kw = {"image_embeds": batch["image_embeds"]}
        fwd, _ = model.forward(params, batch["tokens"],
                               image_embeds=batch["image_embeds"])
    else:
        fwd, _ = model.forward(params, batch["tokens"])
    cache = model.init_cache(params, 2, 64, **kw)
    errs = []
    for t in range(8):
        logits, cache = model.decode_step(
            params, batch["tokens"][:, t:t + 1], cache,
            pos=jnp.asarray(t, jnp.int32))
        errs.append(float(jnp.abs(logits[:, 0] - fwd[:, t]).max()))
    assert max(errs) < 2e-2, errs


def test_ring_buffer_window_cache():
    """Sliding-window decode with buffer < sequence equals full-buffer
    decode restricted to the window."""
    cfg = attention.AttnConfig(d_model=64, num_heads=4, num_kv_heads=2,
                               head_dim=16, window=8, dtype="float32")
    params = attention.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 64), jnp.float32)

    def run(buf_len):
        cache = attention.init_cache(cfg, 1, buf_len, jnp.float32)
        cache = {"k": cache["k"][:, :, :buf_len], "v": cache["v"][:, :, :buf_len]}
        outs = []
        for t in range(24):
            c = dict(cache, pos=jnp.asarray(t, jnp.int32))
            o, nc = attention.attend(params, x[:, t:t + 1], cfg,
                                     positions=jnp.asarray([t]), cache=c)
            cache = {"k": nc["k"], "v": nc["v"]}
            outs.append(o)
        return jnp.concatenate(outs, axis=1)

    full = run(24)   # big buffer, window mask applies
    ring = run(8)    # ring buffer sized to the window
    np.testing.assert_allclose(np.asarray(full), np.asarray(ring),
                               rtol=1e-5, atol=1e-5)
