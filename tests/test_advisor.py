"""Advisor subsystem tests: transforms, search invariants, report, hints.

Covers the PR's acceptance criteria directly:

  * every frontier is scored by a single ``CounterFrame``/``profile_batch``
    evaluation (counted by wrapping the profiler entry points),
  * a warm re-advise against the persistent sweep cache collects nothing,
  * the advisor rediscovers ``hist2``'s channel rotation from the plain
    ``hist`` workload with an in-band predicted speedup, and the top
    candidate's kernel-provider validation matches bit for bit,

plus the ``speedup_estimate`` property suite (identity, after-window
monotonicity) and advisor determinism.
"""

import csv as csv_mod
import io
import json

import numpy as np
import pytest

from repro.advisor import (
    AdvisorSearch,
    CasToFao,
    ChannelRotation,
    LaneInterleave,
    Replicate,
    SetPipelineDepth,
    SetWavesPerTile,
    TransformCost,
    default_catalog,
)
from repro.analysis import Session, WorkloadSpec
from repro.core import bottleneck, profiler, timing
from repro.core.profiler import UnitUtilization, WorkloadProfile
from repro.data.images import make_image


@pytest.fixture(scope="module")
def sess():
    return Session("v5e")


def _solid_idx(n=1 << 12):
    return np.zeros(n, np.int64)


def _clustered_idx(n=1 << 12, bins=64):
    return np.repeat(np.arange(bins, dtype=np.int64), n // bins)


def _prof(label, T):
    """Minimal profile with a given per-core window (for speedup props)."""
    T = np.asarray(T, np.float64)
    return WorkloadProfile(
        label=label, per_core=[],
        units=[UnitUtilization("scatter", float(T.max()) / 2, float(T.max()))],
        T_cycles=T)


# -- speedup_estimate properties ---------------------------------------------


def test_speedup_identity_transform_is_one(sess):
    """A transform that changes nothing predicts exactly 1.0."""
    spec = WorkloadSpec.from_indices(_solid_idx(), 256, label="s",
                                     waves_per_tile=8)
    prof = sess.profile(spec)
    assert bottleneck.speedup_estimate(prof, prof) == 1.0


def test_speedup_monotone_in_after_window():
    """Growing the after-window can only lower the predicted speedup."""
    before = _prof("before", [1000.0, 900.0])
    windows = [200.0, 500.0, 1000.0, 2000.0, 8000.0]
    speedups = [bottleneck.speedup_estimate(before, _prof("after", [w]))
                for w in windows]
    assert all(a >= b for a, b in zip(speedups, speedups[1:]))
    # and it crosses parity exactly at equal windows
    assert bottleneck.speedup_estimate(before, _prof("eq", [1000.0])) == 1.0


# -- transforms ---------------------------------------------------------------


def test_rotation_legality_and_apply():
    img = make_image("solid", 1 << 10)
    spec = WorkloadSpec.from_histogram(img, label="h", variant="hist")
    t = ChannelRotation()
    assert t.legal(spec)
    out = t.apply(spec)
    assert out.kernel.params["variant"] == "hist2"
    assert out.label == "h+rotate-channels"
    assert not t.legal(out)              # can't rotate twice
    assert spec.kernel.params["variant"] == "hist"   # original untouched


def test_replicate_apply_splits_bins():
    spec = WorkloadSpec.from_indices(_solid_idx(64), 16, label="s")
    t = Replicate(4)
    assert t.legal(spec)
    out = t.apply(spec)
    assert out.num_bins == 64
    idx = np.asarray(out.indices)
    # all-zero stream becomes round-robin replicas 0..3
    assert set(idx.tolist()) == {0, 1, 2, 3}
    cost = t.cost(spec)
    assert cost.scratch_bytes == 16 * 3 * 4
    assert cost.reduce_flops == 64
    with pytest.raises(ValueError):
        Replicate(1)


def test_cas_to_fao_legality_and_apply():
    spec = WorkloadSpec.from_indices(_solid_idx(64), 16, label="c",
                                     job_class=timing.CAS)
    t = CasToFao()
    assert t.legal(spec)
    assert t.apply(spec).job_class == timing.FAO
    assert not t.legal(spec.with_(job_class=timing.FAO))
    weighted = WorkloadSpec.from_histogram(
        make_image("solid", 1 << 8), label="w", weighted=True)
    assert t.legal(weighted)
    out = t.apply(weighted)
    assert out.kernel.params["weighted"] is False
    assert out.kernel.params["force_fao"] is True


def test_geometry_effective_default_is_not_a_candidate():
    spec = WorkloadSpec.from_indices(_solid_idx(64), 16, label="g",
                                     waves_per_tile=8)
    assert not SetWavesPerTile(8).legal(spec)
    assert SetWavesPerTile(32).legal(spec)
    # pipeline_depth None resolves to 2 everywhere: depth=2 is a no-op
    assert spec.pipeline_depth is None
    assert not SetPipelineDepth(2).legal(spec)
    assert SetPipelineDepth(4).legal(spec)
    # unset waves_per_tile resolves per source family: indices -> 1,
    # histogram kernels -> the kernel's own tiling — re-stating the
    # resolved default must not become a (no-op) candidate
    unset = WorkloadSpec.from_indices(_solid_idx(64), 16, label="u")
    assert not SetWavesPerTile(1).legal(unset)
    assert SetWavesPerTile(8).legal(unset)
    from repro.kernels.histogram import ops as hist_ops
    img = make_image("solid", 1 << 10)
    hist = WorkloadSpec.from_histogram(img, label="h", variant="hist")
    default = hist_ops.default_waves_per_tile(img)
    assert not SetWavesPerTile(default).legal(hist)
    assert SetWavesPerTile(default * 2).legal(hist)


def test_interleave_spreads_clusters():
    spec = WorkloadSpec.from_indices(_clustered_idx(), 64, label="cl")
    t = LaneInterleave()
    assert t.legal(spec)
    out = t.apply(spec)
    idx = np.asarray(out.indices)
    assert sorted(idx.tolist()) == sorted(_clustered_idx().tolist())
    # first commit group now holds distant elements, not one run
    assert len(set(idx[:32].tolist())) > 1


def test_cost_merge_sums_and_joins():
    merged = TransformCost.merge([
        TransformCost(scratch_bytes=8, reduce_flops=2, note="a"),
        TransformCost(scratch_bytes=4, note="b"),
        TransformCost(),
    ])
    assert merged.scratch_bytes == 12
    assert merged.reduce_flops == 2
    assert merged.note == "a; b"


# -- search invariants --------------------------------------------------------


def test_one_batch_eval_per_frontier_no_scalar_profiling(monkeypatch):
    """Acceptance: every frontier is one profile_batch, zero scalar calls."""
    calls = {"batch": 0, "scalar": 0}
    orig_batch = profiler.profile_batch

    def counting_batch(*a, **kw):
        calls["batch"] += 1
        return orig_batch(*a, **kw)

    def forbidden(*a, **kw):
        calls["scalar"] += 1
        raise AssertionError("advisor must never scalar-profile")

    monkeypatch.setattr(profiler, "profile_batch", counting_batch)
    monkeypatch.setattr(profiler, "profile_counters", forbidden)
    sess = Session("v5e")
    spec = WorkloadSpec.from_indices(_solid_idx(), 256, label="s",
                                     waves_per_tile=8)
    report = sess.advise(spec, depth=2, beam_width=4)
    assert calls["scalar"] == 0
    assert report.stats["frontiers"] == 2
    assert calls["batch"] == report.stats["frontiers"]
    assert report.stats["batch_evals"] == report.stats["frontiers"]


def test_warm_rerun_with_sweep_cache_collects_nothing(tmp_path):
    """Acceptance: persistent-cache re-advise does zero counter collection."""
    spec = WorkloadSpec.from_indices(_clustered_idx(), 64, label="cl",
                                     waves_per_tile=8)
    cold = Session("v5e", persistent_cache=str(tmp_path))
    r1 = cold.advise(spec, depth=2, beam_width=4)
    assert cold.stats["collected"] > 0
    warm = Session("v5e", persistent_cache=str(tmp_path))
    r2 = warm.advise(spec, depth=2, beam_width=4)
    assert warm.stats["collected"] == 0
    assert warm.stats["disk_hits"] > 0
    # and the served-from-disk ranking is bit-identical
    assert [(c.label, c.speedup) for c in r2.candidates] \
        == [(c.label, c.speedup) for c in r1.candidates]


def test_advisor_deterministic_ranking():
    """Same spec + seed -> identical ranking from independent sessions."""
    rng1 = np.random.default_rng(7)
    rng2 = np.random.default_rng(7)
    reports = []
    for rng in (rng1, rng2):
        idx = rng.integers(0, 64, 1 << 12)
        spec = WorkloadSpec.from_indices(np.sort(idx), 64, label="det",
                                         waves_per_tile=8)
        reports.append(Session("v5e").advise(spec, depth=2, beam_width=4))
    a, b = reports
    assert [c.label for c in a.candidates] == [c.label for c in b.candidates]
    assert [c.speedup for c in a.candidates] \
        == [c.speedup for c in b.candidates]


def test_family_once_and_dedup():
    """No composition reuses a family; no-op rewrites dedup away."""
    spec = WorkloadSpec.from_indices(_solid_idx(), 256, label="s",
                                     waves_per_tile=8)
    report = Session("v5e").advise(spec, depth=3, beam_width=8)
    for c in report.candidates:
        fams = c.families
        assert len(fams) == len(set(fams))
        # interleaving an all-equal stream is a no-op: deduped against
        # the baseline fingerprint, so it must not appear alone
        assert c.names != ("interleave-lanes",)


def test_no_legal_transform_reports_baseline_only(sess):
    spec = WorkloadSpec.from_indices(_solid_idx(64), 16, label="tiny")
    report = sess.advise(spec, catalog=[ChannelRotation()])
    assert report.candidates == []
    assert report.best is None
    assert report.stats["frontiers"] == 0
    assert "0 candidates" in report.render("text")
    assert json.loads(report.render("json"))["candidates"] == []


# -- the §5 rediscovery (example's acceptance, test-sized) --------------------


def test_advisor_rediscovers_hist2(sess):
    """From plain hist, the top-ranked fix is the rotation family, its
    predicted speedup is in the paper's up-to-30% band, and the kernel
    provider confirms the modeled counters bit for bit."""
    img = make_image("solid", 1 << 14)
    spec = WorkloadSpec.from_histogram(
        img, label="solid-16K", variant="hist", waves_per_tile=8,
        overhead_cycles=2500.0)
    report = sess.advise(spec, depth=2, top_k=5, validate_top=1)
    top = report.best
    assert "rotation" in top.families
    assert 1.0 < top.speedup <= 1.30
    assert top.validation is not None
    assert top.validation.rel_err("kernel", "e") == 0.0
    assert top.validation.max_rel_err == 0.0
    # the validation line must be rendered
    assert "validated (kernel vs trace)" in report.render("text")


# -- report rendering ---------------------------------------------------------


def test_report_csv_ragged_roundtrip(sess):
    """Candidates carry different param_* columns: the shared union-header
    helper must round-trip them with empty holes (satellite bugfix)."""
    spec = WorkloadSpec.from_indices(_clustered_idx(), 64, label="cl",
                                     waves_per_tile=8)
    report = sess.advise(spec, depth=2, beam_width=8, top_k=10)
    rows = list(csv_mod.DictReader(io.StringIO(report.render("csv"))))
    assert len(rows) == len(report.top(10))
    cols = set(rows[0])
    assert {"rank", "transforms", "predicted_speedup",
            "predicted_bottleneck", "scratch_bytes", "cost_note"} <= cols
    # at least one ragged param column, blank where not applicable
    param_cols = [c for c in cols if c.startswith("param_")]
    assert param_cols
    assert any(r[c] == "" for r in rows for c in param_cols)


def test_report_json_schema(sess):
    spec = WorkloadSpec.from_indices(_solid_idx(), 256, label="s",
                                     waves_per_tile=8)
    payload = json.loads(sess.advise(spec, depth=1).render("json"))
    assert set(payload) == {"device", "baseline", "candidates", "stats"}
    assert payload["baseline"]["bottleneck"]
    assert payload["baseline"]["hint"] is not None
    assert {"rank", "label", "transforms", "families", "predicted_speedup",
            "predicted_bottleneck", "shifts_bottleneck"} \
        <= set(payload["candidates"][0])
    assert payload["stats"]["batch_evals"] == payload["stats"]["frontiers"]


def test_report_unknown_format_raises(sess):
    spec = WorkloadSpec.from_indices(_solid_idx(64), 16, label="x")
    report = sess.advise(spec, depth=1)
    with pytest.raises(ValueError, match="unknown report format"):
        report.render("yaml")


def test_candidate_cost_uses_pre_transform_spec(sess):
    """Replicate's annotations describe the bins it multiplies: the report
    must carry cost(pre-apply spec), not cost of the rewritten spec."""
    spec = WorkloadSpec.from_indices(_clustered_idx(), 64, label="cl",
                                     waves_per_tile=8)
    report = sess.advise(spec, catalog=[Replicate(8)], depth=1)
    (cand,) = report.candidates
    want = Replicate(8).cost(spec)
    assert cand.cost.scratch_bytes == want.scratch_bytes == 64 * 7 * 4
    assert cand.cost.reduce_flops == want.reduce_flops == 64 * 8


def test_report_U_is_the_bottleneck_units(sess):
    """The utilization printed next to a bottleneck name must belong to
    that unit — an hbm-bound row must not show the scatter model's U."""
    spec = WorkloadSpec.from_indices(
        _clustered_idx(), 64, label="membound", waves_per_tile=8,
        job_class=timing.CAS, bytes_read=1e9)
    report = sess.advise(spec, depth=1, top_k=5)
    assert report.baseline_verdict.bottleneck == "hbm"
    payload = json.loads(report.render("json"))
    assert payload["baseline"]["utilization"] \
        == report.baseline_verdict.utilization
    for row, cand in zip(report.to_rows(), report.top()):
        assert row["predicted_U"] == \
            cand.profile.unit(row["predicted_bottleneck"]).utilization
        assert row["predicted_scatter_U"] == cand.profile.scatter_utilization


# -- structured classify hints (satellite) ------------------------------------


def test_classify_attaches_structured_hint(sess):
    spec = WorkloadSpec.from_indices(_solid_idx(1 << 14), 256, label="hot",
                                     waves_per_tile=32)
    v = sess.classify(spec)
    assert v.hint is not None
    assert v.hint.unit == v.bottleneck
    if v.saturated:
        assert v.hint.action == "reduce_contention"
        assert v.hint.family == "rotation"
    assert ":" in v.hint.compact() and "@" in v.hint.compact()


def test_hint_rendered_in_session_reports(sess):
    spec = WorkloadSpec.from_indices(_solid_idx(1 << 14), 256, label="hot",
                                     waves_per_tile=32)
    sess.profile(spec)
    payload = json.loads(sess.report("json"))
    hint = payload["points"][0]["hint"]
    assert isinstance(hint, dict)
    assert set(hint) == {"unit", "action", "family"}
    text = sess.report("text")
    assert f"[{hint['action']}:{hint['family']}@{hint['unit']}]" in text
    rows = list(csv_mod.DictReader(io.StringIO(sess.report("csv"))))
    assert rows[0]["hint"] == \
        f"{hint['action']}:{hint['family']}@{hint['unit']}"
