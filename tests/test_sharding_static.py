"""Static dry-run preconditions: every parameter/cache leaf of every FULL
config must divide over its assigned mesh axes on both production meshes.
Pure metadata (eval_shape) — no device allocation, fast."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.models.registry import build_model
from repro.parallel import sharding as shd

MESH_SIZES = {"single": {"data": 16, "model": 16},
              "pod2": {"pod": 2, "data": 16, "model": 16}}


def _axis_size(axes, sizes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    n = 1
    for a in axes:
        n *= sizes.get(a, 1)
    return n


@pytest.mark.parametrize("mesh_name", ["single", "pod2"])
@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_divide(arch, mesh_name):
    sizes = MESH_SIZES[mesh_name]
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(shapes, cfg)

    bad = []

    def check(path, leaf, spec):
        assert isinstance(spec, P)
        for dim, axes in zip(leaf.shape, tuple(spec)):
            n = _axis_size(axes, sizes)
            if n > 1 and dim % n:
                bad.append((jax.tree_util.keystr(path), leaf.shape, spec))

    jax.tree_util.tree_map_with_path(
        check, shapes, specs,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))
    assert not bad, bad[:10]


@pytest.mark.parametrize("arch", ["qwen3-moe-235b-a22b", "command-r-plus-104b"])
def test_big_params_are_actually_sharded(arch):
    """The >=64-expert MoE must EP-shard experts; huge dense weights must be
    2-D sharded (memory feasibility at 16 GiB/chip)."""
    sizes = MESH_SIZES["single"]
    cfg = get_config(arch)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    specs = shd.param_pspecs(shapes, cfg)
    leaves = jax.tree_util.tree_leaves_with_path(shapes)
    spec_leaves = {jax.tree_util.keystr(p): s for p, s in
                   jax.tree_util.tree_leaves_with_path(
                       specs, is_leaf=lambda x: isinstance(x, P))}
    worst = 0
    for path, leaf in leaves:
        key = jax.tree_util.keystr(path)
        spec = spec_leaves[key]
        shards = 1
        for axes in tuple(spec):
            shards *= _axis_size(axes, sizes)
        per_dev = leaf.size * leaf.dtype.itemsize / shards
        worst = max(worst, per_dev)
        assert per_dev < 4e9, (key, leaf.shape, spec, per_dev)
    assert worst > 0
