"""The repro.analysis session API: Device registry, WorkloadSpec, Session."""

import dataclasses
import json

import numpy as np
import pytest

from repro.analysis import (
    Device,
    Session,
    WorkloadSpec,
    get_device,
)
from repro.analysis import device as device_mod
from repro.core import counters
from repro.core.profiler import CacheModel


@pytest.fixture
def sess(tmp_path):
    device_mod._TABLE_MEMO.clear()
    return Session("v5e", cache_dir=tmp_path)


def _solid(num_waves=64):
    return np.zeros(num_waves * 1024, np.int64)


def _uniform(num_waves=64, num_bins=256, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_bins, num_waves * 1024)


# -- Device registry ----------------------------------------------------------


def test_get_device_known_and_passthrough():
    dev = get_device("v5e")
    assert dev.name == "v5e"
    assert get_device(dev) is dev


def test_get_device_unknown_lists_registry():
    with pytest.raises(KeyError, match="v5e"):
        get_device("h100")


def test_device_variant_with_():
    dev = get_device("v5e").with_(cache=CacheModel(llc_bytes=1))
    assert dev.cache.llc_bytes == 1
    assert get_device("v5e").cache.llc_bytes != 1  # registry untouched


def test_device_is_frozen():
    with pytest.raises(dataclasses.FrozenInstanceError):
        get_device("v5e").num_cores = 4


# -- WorkloadSpec -------------------------------------------------------------


def test_spec_requires_exactly_one_source():
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSpec(label="none")
    tr = counters.trace_from_indices(_solid(2), 256)
    with pytest.raises(ValueError, match="exactly one"):
        WorkloadSpec(label="both", trace=tr, indices=_solid(2))


def test_spec_is_frozen_and_with_derives():
    spec = WorkloadSpec.from_indices(_solid(4), 256, label="a",
                                     waves_per_tile=8)
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.label = "b"
    spec2 = spec.with_(label="b", waves_per_tile=16)
    assert (spec.label, spec.waves_per_tile) == ("a", 8)
    assert (spec2.label, spec2.waves_per_tile) == ("b", 16)


def test_spec_resolve_trace_applies_geometry_without_mutation():
    tr = counters.trace_from_indices(_solid(8), 256, waves_per_tile=4)
    spec = WorkloadSpec.from_trace(tr, label="g", waves_per_tile=32,
                                   pipeline_depth=4)
    resolved = spec.resolve_trace()
    assert (resolved.waves_per_tile, resolved.pipeline_depth) == (32, 4)
    assert (tr.waves_per_tile, tr.pipeline_depth) == (4, 2)  # source intact
    np.testing.assert_array_equal(resolved.degree, tr.degree)


def test_spec_from_indices_defaults_bytes_read():
    spec = WorkloadSpec.from_indices(_solid(4), 256, label="b")
    assert spec.bytes_read == 4 * 1024 * 4


# -- Session ------------------------------------------------------------------


def test_session_profile_solid_vs_uniform(sess):
    solid = sess.profile(WorkloadSpec.from_indices(
        _solid(), 256, label="solid", waves_per_tile=32))
    uniform = sess.profile(WorkloadSpec.from_indices(
        _uniform(), 256, label="uniform", waves_per_tile=32))
    assert solid.per_core[0].e > uniform.per_core[0].e
    assert solid.scatter_utilization > uniform.scatter_utilization


def test_session_uses_device_bundle(tmp_path):
    device_mod._TABLE_MEMO.clear()
    dev = get_device("v5e").with_(num_cores=2)
    sess = Session(dev, cache_dir=tmp_path)
    prof = sess.profile(WorkloadSpec.from_indices(
        _solid(), 256, label="2core", waves_per_tile=32, num_cores=2))
    assert len(prof.per_core) == 2


def test_session_classify_and_speedup(sess):
    verdict = sess.classify(WorkloadSpec.from_indices(
        _solid(), 256, label="solid", waves_per_tile=32))
    assert verdict.bottleneck == "scatter"
    sp = sess.speedup(
        WorkloadSpec.from_indices(_solid(), 256, label="before",
                                  waves_per_tile=32),
        WorkloadSpec.from_indices(_uniform(), 256, label="after",
                                  waves_per_tile=32))
    assert sp > 1.0  # de-conflicted stream must be faster


def test_session_sweep_detects_shift(tmp_path):
    """Growing working set + tiny LLC + low concurrency: scatter -> hbm."""
    device_mod._TABLE_MEMO.clear()
    dev = get_device("v5e").with_(cache=CacheModel(
        llc_bytes=1 << 20, miss_latency_cycles=2000, hide_concurrency=64.0))
    sess = Session(dev, cache_dir=tmp_path)
    specs = [
        WorkloadSpec.from_indices(
            _uniform(num_waves=1 << p0, seed=p0), 256,
            label=f"2^{p0 + 10}", waves_per_tile=2,
            bytes_read=float((1 << p0) * 1024 * 4))
        for p0 in range(2, 11)]
    result = sess.sweep(specs)
    assert len(result) == 9
    assert len(result.verdicts) == 9
    assert result.bottlenecks[0] == "scatter"
    assert any(s.unit_after == "hbm" for s in result.shifts), \
        result.bottlenecks
    # sweep utilization arrays are aligned with the points
    assert result.utilization["hbm"].shape == (9,)


def test_sweep_requires_specs(sess):
    with pytest.raises(ValueError):
        sess.sweep([])


# -- reporting ----------------------------------------------------------------


def test_report_before_profile_raises(tmp_path):
    device_mod._TABLE_MEMO.clear()
    with pytest.raises(RuntimeError):
        Session("v5e", cache_dir=tmp_path).report()


def test_report_formats(sess):
    specs = [WorkloadSpec.from_indices(_solid(), 256, label="solid",
                                       waves_per_tile=32),
             WorkloadSpec.from_indices(_uniform(), 256, label="uniform",
                                       waves_per_tile=32)]
    sess.sweep(specs)

    text = sess.report()
    assert "solid" in text and "uniform" in text and "v5e" in text

    payload = json.loads(sess.report("json"))
    assert payload["device"] == "v5e"
    assert [p["label"] for p in payload["points"]] == ["solid", "uniform"]
    assert {"bottleneck", "U_scatter", "U_hbm",
            "speedup_vs_first"} <= set(payload["points"][0])

    lines = sess.report("csv").strip().splitlines()
    assert len(lines) == 3  # header + 2 points
    assert lines[0].startswith("label,")

    with pytest.raises(ValueError):
        sess.report("yaml")


def test_speedup_records_both_profiles(sess):
    """report() after speedup() must show the pair, not a stale result."""
    before = WorkloadSpec.from_indices(_solid(), 256, label="before",
                                       waves_per_tile=32)
    after = WorkloadSpec.from_indices(_uniform(), 256, label="after",
                                      waves_per_tile=32)
    sess.profile(WorkloadSpec.from_indices(_solid(4), 256, label="stale"))
    sp = sess.speedup(before, after)
    assert sp > 1.0
    assert len(sess.last) == 2
    text = sess.report()
    assert "before" in text and "after" in text and "stale" not in text
    assert float(sess.last.speedup_vs_first[1]) == sp


def test_single_point_report_has_no_sweep_lines(sess):
    sess.profile(WorkloadSpec.from_indices(_solid(), 256, label="one",
                                           waves_per_tile=32))
    text = sess.report()
    assert "one" in text
    assert "no bottleneck shifts" not in text
    assert "profile" in text and "sweep" not in text


def test_to_rows_aggregates_all_cores():
    """e/n_hat must reflect every core, not per_core[0] (satellite fix)."""
    import repro.core.profiler as prof_mod
    from repro.core import qmodel

    def core(i, e, n_hat, n_jobs=4):
        return qmodel.CoreUtilization(core_id=i, N=n_jobs, n_hat=n_hat, e=e,
                                      c=0.0, S_cycles=1.0, B_cycles=4.0,
                                      T_cycles=10.0, U=0.4)

    p = prof_mod.WorkloadProfile(
        label="multi",
        per_core=[core(0, 2.0, 8.0, n_jobs=12), core(1, 4.0, 16.0, n_jobs=4)],
        units=[prof_mod.UnitUtilization("scatter", 4.0, 10.0)],
        T_cycles=np.array([10.0, 10.0]))
    from repro.analysis.session import SweepResult
    from repro.core import bottleneck as bn
    result = SweepResult(
        device=get_device("v5e"), specs=[], profiles=[p],
        verdicts=[bn.classify(p)], shifts=[],
        utilization={"scatter": np.array([0.4])},
        speedup_vs_first=np.array([1.0]))
    row = result.to_rows()[0]
    # job-weighted mean (12*2 + 4*4)/16, matching e = O/N — neither
    # per_core[0] nor the unweighted core mean
    assert row["e"] == 2.5
    assert row["n_hat"] == 16.0  # max(8, 16), not per_core[0]


def test_render_csv_roundtrips_to_rows(sess):
    import csv as csv_mod
    import io

    sess.sweep([
        WorkloadSpec.from_indices(_solid(), 256, label="solid",
                                  waves_per_tile=32),
        WorkloadSpec.from_indices(_uniform(), 256, label="uniform",
                                  waves_per_tile=32)])
    rows = sess.last.to_rows()
    parsed = list(csv_mod.DictReader(io.StringIO(sess.report("csv"))))
    assert len(parsed) == len(rows)
    for got, want in zip(parsed, rows):
        assert set(got) == set(want)
        assert got["label"] == want["label"]
        assert got["bottleneck"] == want["bottleneck"]
        assert float(got["e"]) == pytest.approx(want["e"])
        assert float(got["n_hat"]) == pytest.approx(want["n_hat"])
        assert float(got["U_scatter"]) == pytest.approx(want["U_scatter"])


def test_render_json_schema_is_stable(sess):
    sess.sweep([WorkloadSpec.from_indices(_solid(), 256, label="s",
                                          waves_per_tile=32)])
    payload = json.loads(sess.report("json"))
    assert set(payload) == {"device", "points", "shifts"}
    assert set(payload["points"][0]) == {
        "label", "bottleneck", "saturated", "comment", "hint",
        "scatter_model_U", "speedup_vs_first", "e", "n_hat", "U_scatter",
        "U_hbm", "U_mxu", "U_ici"}


def test_render_unknown_fmt_raises(sess):
    sess.profile(WorkloadSpec.from_indices(_solid(4), 256, label="x"))
    with pytest.raises(ValueError, match="unknown report format"):
        sess.last.render("yaml")


# -- grid-sweep engine --------------------------------------------------------


def test_spec_grid_cartesian_labels():
    spec = WorkloadSpec.from_indices(_solid(4), 256, label="base")
    grid = spec.grid(waves_per_tile=[4, 8], pipeline_depth=[2, 4])
    assert len(grid) == 4
    assert grid[0].label == "base[waves_per_tile=4,pipeline_depth=2]"
    assert grid[-1].label == "base[waves_per_tile=8,pipeline_depth=4]"
    assert (grid[-1].waves_per_tile, grid[-1].pipeline_depth) == (8, 4)
    assert spec.waves_per_tile is None  # base untouched


def test_spec_grid_unknown_axis_raises():
    spec = WorkloadSpec.from_indices(_solid(4), 256, label="base")
    with pytest.raises(ValueError, match="not a WorkloadSpec field"):
        spec.grid(wpt=[4, 8])


def test_spec_fingerprint_content_keyed():
    a = WorkloadSpec.from_indices(_solid(4), 256, label="a",
                                  waves_per_tile=8)
    b = WorkloadSpec.from_indices(_solid(4), 256, label="b",
                                  waves_per_tile=8)
    c = WorkloadSpec.from_indices(_solid(4), 256, label="a",
                                  waves_per_tile=16)
    d = WorkloadSpec.from_indices(_uniform(4), 256, label="a",
                                  waves_per_tile=8)
    assert a.fingerprint() == b.fingerprint()      # label-independent
    assert a.fingerprint() != c.fingerprint()      # geometry matters
    assert a.fingerprint() != d.fingerprint()      # content matters
    assert WorkloadSpec(label="r", run=lambda: None).fingerprint() is None


def test_sweep_parallel_matches_serial(sess):
    specs = WorkloadSpec.from_indices(
        _uniform(), 256, label="u").grid(waves_per_tile=[2, 4, 8, 16, 32],
                                         pipeline_depth=[2, 4])
    serial = Session("v5e", table=sess.table).sweep(specs)
    parallel = Session("v5e", table=sess.table).sweep(specs, parallel=8)
    assert len(parallel) == 10
    assert [p.label for p in parallel.profiles] == \
        [p.label for p in serial.profiles]          # order preserved
    np.testing.assert_array_equal(parallel.speedup_vs_first,
                                  serial.speedup_vs_first)
    for a, b in zip(serial.profiles, parallel.profiles):
        assert a.scatter_utilization == b.scatter_utilization
        np.testing.assert_array_equal(a.T_cycles, b.T_cycles)


def test_sweep_memoizes_by_content(sess):
    """Repeated points are collected once and served relabeled."""
    calls = []
    inner = sess.provider

    class Counting:
        name = "counting"

        def collect(self, spec, device):
            calls.append(spec.label)
            return inner.collect(spec, device)

    sess.provider = Counting()
    spec = WorkloadSpec.from_indices(_uniform(), 256, label="a",
                                     waves_per_tile=8)
    sess.sweep([spec, spec.with_(label="b")])
    assert calls == ["a"]                       # second point: cache hit
    assert [p.label for p in sess.last.profiles] == ["a", "b"]
    sess.sweep([spec.with_(label="c")])
    assert calls == ["a"]                       # re-run: still cached
    sess.sweep([spec.with_(waves_per_tile=16, label="d")])
    assert calls == ["a", "d"]                  # new content: collected


def test_sweep_grid_per_device(tmp_path):
    from repro.analysis import sweep_grid
    device_mod._TABLE_MEMO.clear()
    base = WorkloadSpec.from_indices(_uniform(), 256, label="u")
    results = sweep_grid(base, {"waves_per_tile": [4, 32]},
                         devices=("v5e", "v5p"), parallel=2,
                         cache_dir=tmp_path)
    assert list(results) == ["v5e", "v5p"]
    for res in results.values():
        assert len(res) == 2
        assert res.profiles[0].label == "u[waves_per_tile=4]"


def test_render_csv_ragged_union_columns():
    """Rows with later-only U_* columns must render, empty-filled (fix)."""
    import csv as csv_mod
    import io

    import repro.core.profiler as prof_mod
    from repro.analysis.session import SweepResult
    from repro.core import bottleneck as bn

    def prof(label, units):
        return prof_mod.WorkloadProfile(
            label=label, per_core=[],
            units=[prof_mod.UnitUtilization(n, b, 1000.0)
                   for n, b in units.items()],
            T_cycles=np.array([1000.0]))

    profiles = [prof("a", {"scatter": 500.0}),
                prof("b", {"scatter": 100.0, "ici": 700.0})]
    result = SweepResult(
        device=get_device("v5e"), specs=[], profiles=profiles,
        verdicts=[bn.classify(p) for p in profiles], shifts=[],
        utilization={}, speedup_vs_first=np.array([1.0, 1.0]))
    text = result.render("csv")
    rows = list(csv_mod.DictReader(io.StringIO(text)))
    assert "U_ici" in rows[0]
    assert rows[0]["U_ici"] == ""           # missing cell: empty, not crash
    assert float(rows[1]["U_ici"]) == 0.7


# -- deprecation shims --------------------------------------------------------


def test_old_core_imports_still_resolve():
    from repro.core import (  # noqa: F401
        CacheModel,
        ServiceTimeTable,
        WaveTrace,
        build_table,
        classify,
        detect_shifts,
        profile_scatter_workload,
        trace_from_indices,
    )


def test_core_namespace_forwards_session_with_warning():
    import repro.core as core
    with pytest.warns(DeprecationWarning, match="repro.analysis"):
        assert core.Session is Session
    with pytest.warns(DeprecationWarning):
        assert core.Device is Device
    with pytest.raises(AttributeError):
        core.not_a_real_name


# -- HLO provider meta surfaces in reports (unresolved loops, collectives) ----

_META_HLO = """\
HloModule meta_demo

cond {
  p = (s32[], s32[]) parameter(0)
  i = s32[] get-tuple-element(p), index=0
  n = s32[] get-tuple-element(p), index=1
  ROOT lt = pred[] compare(i, n), direction=LT
}

body {
  p = (s32[], s32[]) parameter(0)
  i = s32[] get-tuple-element(p), index=0
  n = s32[] get-tuple-element(p), index=1
  one = s32[] constant(1)
  i2 = s32[] add(i, one)
  ROOT t = (s32[], s32[]) tuple(i2, n)
}

ENTRY main {
  a = s32[] parameter(0)
  n = s32[] parameter(1)
  x = f32[8,8]{1,0} parameter(2)
  ar = f32[8,8]{1,0} all-reduce(x), replica_groups=[2,4]<=[8], to_apply=body
  t0 = (s32[], s32[]) tuple(a, n)
  ROOT w = (s32[], s32[]) while(t0), condition=cond, body=body
}
"""


def test_report_surfaces_hlo_meta_footers(tmp_path):
    """A dynamically-bounded while + an all-reduce: the provider's meta
    (unresolved_loops, collectives) must reach the text footer and the
    json payload of Session.report."""
    sess = Session("v5e", provider="hlo", cache_dir=tmp_path)
    spec = WorkloadSpec.from_compiled(hlo_text=_META_HLO, label="meta-demo",
                                      num_devices=8)
    sess.profile(spec)
    text = sess.report("text")
    assert "hlo meta [meta-demo]:" in text
    assert "unresolved loop trip count" in text
    assert "lower bounds" in text
    assert "collective op(s)" in text

    payload = json.loads(sess.report("json"))
    meta = payload["meta"]["meta-demo"]
    assert meta["unresolved_loops"] >= 1
    assert "all-reduce" in meta["collectives"]


def test_report_no_meta_footer_for_trace_sources(sess):
    spec = WorkloadSpec.from_indices(_uniform(), 256, label="plain")
    sess.profile(spec)
    assert "hlo meta" not in sess.report("text")
    assert "meta" not in json.loads(sess.report("json"))
