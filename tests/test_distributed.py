"""Distributed-semantics tests: run in a subprocess with 8 virtual devices
(XLA device count is locked at first jax init, so in-process is not an
option).  Each script asserts internally; the test checks exit status."""

import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(script: str, timeout=420):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + "\n" + r.stderr[-2000:]


def test_moe_ep_matches_local_oracle():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe
from repro.launch import mesh as mesh_mod
cfg = moe.MoEConfig(d_model=32, d_expert=16, num_experts=8, top_k=2,
                    capacity_factor=8.0, dtype="float32")
p = moe.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
ref, _, _ = moe.apply_local(p, x.reshape(-1, 32), cfg)
mesh = mesh_mod.compat_make_mesh((2, 2, 2), ("pod", "data", "model"))
with mesh:
    out, aux, disp = moe.apply_ep(p, x, cfg, mesh)
err = np.abs(np.asarray(out).reshape(-1, 32) - np.asarray(ref)).max()
assert err < 1e-4, err
g = jax.jit(jax.grad(lambda p, x: moe.apply_ep(p, x, cfg, mesh)[0].sum()))(p, x)
assert all(np.isfinite(np.asarray(l)).all() for l in jax.tree.leaves(g))
""")


def test_moe_tp_ragged_matches_local_oracle():
    _run("""
import jax, jax.numpy as jnp, numpy as np
from repro.models import moe
from repro.launch import mesh as mesh_mod
cfg = moe.MoEConfig(d_model=32, d_expert=16, num_experts=4, top_k=2,
                    capacity_factor=8.0, dtype="float32")
p = moe.init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
ref, _, _ = moe.apply_local(p, x.reshape(-1, 32), cfg)
mesh = mesh_mod.compat_make_mesh((2, 4), ("data", "model"))
with mesh:
    out, _, _ = moe.apply_sharded(p, x, cfg, mesh, data_axes=("data",))
err = np.abs(np.asarray(out).reshape(-1, 32) - np.asarray(ref)).max()
assert err < 1e-4, err
""")


def test_sharded_train_step_matches_single_device():
    """Same batch + params: sharded (2x4 mesh) loss == unsharded loss."""
    _run("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models.registry import build_model, make_batch
from repro.parallel import ctx as pctx, sharding as shd
from repro.launch import mesh as mesh_mod

cfg = get_config("qwen2-72b").reduced()
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))
batch = make_batch(cfg, 8, 32)
loss0, _ = jax.jit(lambda p, b: model.loss(p, b))(params, batch)

mesh = mesh_mod.compat_make_mesh((2, 4), ("data", "model"))
psh = shd.param_shardings(params, cfg, mesh)
params_s = jax.device_put(params, psh)
bsh = jax.tree.map(lambda x: NamedSharding(mesh, P(("data",))), batch)
batch_s = jax.device_put(batch, bsh)
with pctx.use_mesh(mesh, data_axes=("data",), tp_axis="model"):
    loss1, _ = jax.jit(lambda p, b: model.loss(p, b))(params_s, batch_s)
np.testing.assert_allclose(float(loss0), float(loss1), rtol=2e-2)
print("sharded loss matches:", float(loss0), float(loss1))
""")


def test_small_mesh_dryrun_lower_compile():
    """The dry-run machinery end-to-end on an in-test 4x2 mesh."""
    _run("""
import jax, jax.numpy as jnp
from repro.configs import get_config, SHAPES
from repro.core import roofline
from repro.launch import specs as specs_mod
from repro.models.registry import build_model
from repro.optim import adamw
from repro.parallel import ctx as pctx
from repro.launch import mesh as mesh_mod
from repro.train import step as train_mod
import dataclasses

cfg = get_config("granite-moe-1b-a400m").reduced()
cfg = dataclasses.replace(cfg, dtype="bfloat16")
model = build_model(cfg)
mesh = mesh_mod.compat_make_mesh((4, 2), ("data", "model"))
with pctx.use_mesh(mesh, data_axes=("data",), tp_axis="model"):
    tcfg = train_mod.TrainConfig(accum_steps=2)
    step = train_mod.make_train_step(model, tcfg, adamw.AdamWConfig())
    state_sds, state_sh = specs_mod.state_specs(model, mesh)
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    batch = {k: jax.ShapeDtypeStruct((8, 32), jnp.int32,
                                     sharding=NamedSharding(mesh, P(("data",))))
             for k in ("tokens", "labels")}
    lowered = jax.jit(step, in_shardings=(state_sh, jax.tree.map(
        lambda s: s.sharding, batch)), donate_argnums=(0,)).lower(state_sds, batch)
    compiled = lowered.compile()
terms = roofline.from_compiled(compiled, arch="granite-reduced",
                               shape="tiny", mesh_name="4x2", chips=8,
                               model_flops=1e9)
assert terms.hlo_flops > 0 and terms.compute_s > 0
print("dryrun small mesh ok:", terms.dominant)
""")
