"""Shift detection + speedup estimation (paper §4.1) on synthetic profiles."""

import numpy as np
import pytest

from repro.core import bottleneck, profiler
from repro.core.profiler import UnitUtilization, WorkloadProfile


def _prof(label, units, window=1000.0):
    """Profile whose dominant unit is fully controlled by ``units``."""
    return WorkloadProfile(
        label=label,
        per_core=[],
        units=[UnitUtilization(n, busy, window) for n, busy in units.items()],
        T_cycles=np.array([window]),
    )


def test_detect_shifts_empty_and_single():
    assert bottleneck.detect_shifts([]) == []
    assert bottleneck.detect_shifts([_prof("a", {"scatter": 900})]) == []


def test_detect_shifts_no_shift_sweep():
    profiles = [_prof(f"p{i}", {"scatter": 900 - i, "hbm": 100})
                for i in range(5)]
    assert bottleneck.detect_shifts(profiles) == []


def test_detect_shifts_single_shift():
    profiles = [
        _prof("small", {"scatter": 900, "hbm": 100}),
        _prof("large", {"scatter": 100, "hbm": 900}),
    ]
    [event] = bottleneck.detect_shifts(profiles)
    assert event.index == 1
    assert (event.unit_before, event.unit_after) == ("scatter", "hbm")
    assert (event.label_before, event.label_after) == ("small", "large")


def test_detect_shifts_multi_shift():
    profiles = [
        _prof("a", {"scatter": 900, "hbm": 100, "mxu": 50}),
        _prof("b", {"scatter": 100, "hbm": 900, "mxu": 50}),
        _prof("c", {"scatter": 100, "hbm": 100, "mxu": 950}),
        _prof("d", {"scatter": 100, "hbm": 100, "mxu": 950}),
    ]
    events = bottleneck.detect_shifts(profiles)
    assert [(e.index, e.unit_before, e.unit_after) for e in events] == [
        (1, "scatter", "hbm"), (2, "hbm", "mxu")]


def test_detect_shifts_near_tie_is_not_a_shift():
    """An argmax flip within the tie margin must not fire (satellite fix)."""
    profiles = [
        _prof("a", {"scatter": 500.0, "hbm": 495.0}),
        _prof("b", {"scatter": 495.0, "hbm": 500.0}),   # 1% lead: noise
        _prof("c", {"scatter": 500.0, "hbm": 496.0}),
    ]
    assert bottleneck.detect_shifts(profiles) == []


def test_detect_shifts_margin_crossing_fires_once():
    """Hysteresis: a genuine crossover emits one event, not a flicker."""
    profiles = [
        _prof("a", {"scatter": 600, "hbm": 300}),
        _prof("b", {"scatter": 500, "hbm": 502}),   # tie: held
        _prof("c", {"scatter": 502, "hbm": 500}),   # tie: held
        _prof("d", {"scatter": 300, "hbm": 600}),   # real lead: fires
    ]
    events = bottleneck.detect_shifts(profiles)
    assert [(e.index, e.unit_before, e.unit_after) for e in events] == [
        (3, "scatter", "hbm")]
    assert events[0].label_before == "c"


def test_detect_shifts_tol_is_configurable():
    profiles = [
        _prof("a", {"scatter": 500, "hbm": 450}),
        _prof("b", {"scatter": 450, "hbm": 500}),   # ~11% lead
    ]
    assert len(bottleneck.detect_shifts(profiles, tol=0.02)) == 1
    assert bottleneck.detect_shifts(profiles, tol=0.20) == []


def test_detect_shifts_heterogeneous_units_no_keyerror():
    """A held unit missing from a later profile counts as zero, not a crash."""
    profiles = [
        _prof("a", {"scatter": 900, "hbm": 100}),
        _prof("b", {"hbm": 900, "mxu": 100}),   # no scatter unit at all
    ]
    [event] = bottleneck.detect_shifts(profiles)
    assert (event.unit_before, event.unit_after) == ("scatter", "hbm")


def test_speedup_estimate_ratio():
    before = _prof("before", {"scatter": 900}, window=2000.0)
    after = _prof("after", {"scatter": 900}, window=500.0)
    assert bottleneck.speedup_estimate(before, after) == 4.0


def test_speedup_estimate_zero_over_zero_is_parity():
    """0/0 means nothing modeled either side: parity, not inf (satellite)."""
    a = _prof("a", {}, window=0.0)
    b = _prof("b", {}, window=0.0)
    assert bottleneck.speedup_estimate(a, b) == 1.0


def test_speedup_estimate_zero_after_window_raises():
    """A zero 'after' window must not silently report infinite speedup."""
    before = _prof("before", {"scatter": 900}, window=2000.0)
    degenerate = _prof("after", {}, window=0.0)
    with pytest.raises(ValueError, match="zero modeled window"):
        bottleneck.speedup_estimate(before, degenerate)


def test_speedup_estimate_zero_before_is_zero():
    before = _prof("before", {}, window=0.0)
    after = _prof("after", {"scatter": 900}, window=500.0)
    assert bottleneck.speedup_estimate(before, after) == 0.0


# -- utilization_sweep robustness (satellite fix) -----------------------------


def test_utilization_sweep_empty_returns_empty():
    assert profiler.utilization_sweep([]) == {}


def test_utilization_sweep_heterogeneous_units_union_fill():
    """Later-only units appear zero-filled; missing units read 0.0."""
    profiles = [
        _prof("a", {"scatter": 500, "hbm": 100}),
        _prof("b", {"hbm": 600, "ici": 300}),    # no scatter; new: ici
    ]
    out = profiler.utilization_sweep(profiles)
    assert set(out) == {"scatter", "hbm", "ici", "scatter_model"}
    np.testing.assert_allclose(out["scatter"], [0.5, 0.0])
    np.testing.assert_allclose(out["hbm"], [0.1, 0.6])
    np.testing.assert_allclose(out["ici"], [0.0, 0.3])
    assert out["hbm"].shape == (2,)


def test_classify_underutilized_comment():
    v = bottleneck.classify(_prof("idle", {"scatter": 100, "hbm": 50}))
    assert not v.saturated
    assert "no unit saturated" in v.comment


def test_classify_leading_unsaturated():
    v = bottleneck.classify(_prof("mid", {"scatter": 700, "hbm": 100}))
    assert v.bottleneck == "scatter" and not v.saturated
    assert "leading but unsaturated" in v.comment
