"""Shift detection + speedup estimation (paper §4.1) on synthetic profiles."""

import numpy as np

from repro.core import bottleneck
from repro.core.profiler import UnitUtilization, WorkloadProfile


def _prof(label, units, window=1000.0):
    """Profile whose dominant unit is fully controlled by ``units``."""
    return WorkloadProfile(
        label=label,
        per_core=[],
        units=[UnitUtilization(n, busy, window) for n, busy in units.items()],
        T_cycles=np.array([window]),
    )


def test_detect_shifts_empty_and_single():
    assert bottleneck.detect_shifts([]) == []
    assert bottleneck.detect_shifts([_prof("a", {"scatter": 900})]) == []


def test_detect_shifts_no_shift_sweep():
    profiles = [_prof(f"p{i}", {"scatter": 900 - i, "hbm": 100})
                for i in range(5)]
    assert bottleneck.detect_shifts(profiles) == []


def test_detect_shifts_single_shift():
    profiles = [
        _prof("small", {"scatter": 900, "hbm": 100}),
        _prof("large", {"scatter": 100, "hbm": 900}),
    ]
    [event] = bottleneck.detect_shifts(profiles)
    assert event.index == 1
    assert (event.unit_before, event.unit_after) == ("scatter", "hbm")
    assert (event.label_before, event.label_after) == ("small", "large")


def test_detect_shifts_multi_shift():
    profiles = [
        _prof("a", {"scatter": 900, "hbm": 100, "mxu": 50}),
        _prof("b", {"scatter": 100, "hbm": 900, "mxu": 50}),
        _prof("c", {"scatter": 100, "hbm": 100, "mxu": 950}),
        _prof("d", {"scatter": 100, "hbm": 100, "mxu": 950}),
    ]
    events = bottleneck.detect_shifts(profiles)
    assert [(e.index, e.unit_before, e.unit_after) for e in events] == [
        (1, "scatter", "hbm"), (2, "hbm", "mxu")]


def test_speedup_estimate_ratio():
    before = _prof("before", {"scatter": 900}, window=2000.0)
    after = _prof("after", {"scatter": 900}, window=500.0)
    assert bottleneck.speedup_estimate(before, after) == 4.0


def test_speedup_estimate_zero_window_guard():
    before = _prof("before", {"scatter": 900}, window=2000.0)
    degenerate = _prof("after", {}, window=0.0)
    assert bottleneck.speedup_estimate(before, degenerate) == float("inf")


def test_classify_underutilized_comment():
    v = bottleneck.classify(_prof("idle", {"scatter": 100, "hbm": 50}))
    assert not v.saturated
    assert "no unit saturated" in v.comment


def test_classify_leading_unsaturated():
    v = bottleneck.classify(_prof("mid", {"scatter": 700, "hbm": 100}))
    assert v.bottleneck == "scatter" and not v.saturated
    assert "leading but unsaturated" in v.comment
