"""End-to-end behaviour: train loop with failure injection + serving."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli


def test_train_loop_loss_decreases(tmp_path):
    out = train_cli.main([
        "--arch", "granite-moe-1b-a400m", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", str(tmp_path), "--save-every", "5"])
    hist = out["history"]
    assert hist[-1]["xent"] < hist[0]["xent"]
    assert out["restarts"] == 0


def test_train_loop_survives_failure(tmp_path):
    out = train_cli.main([
        "--arch", "zamba2-1.2b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "64", "--lr", "1e-3",
        "--ckpt-dir", str(tmp_path), "--save-every", "4",
        "--simulate-failure-at", "9"])
    assert out["restarts"] == 1
    hist = out["history"]
    # replayed steps appear twice; data determinism makes losses match
    steps = [h["step"] for h in hist]
    assert steps[-1] == 11
    replayed = [h for h in hist if h["step"] == 8]
    assert len(replayed) == 2
    np.testing.assert_allclose(replayed[0]["xent"], replayed[1]["xent"],
                               rtol=1e-4)


@pytest.mark.parametrize("arch", ["rwkv6-7b", "whisper-small"])
def test_serve_generates(arch):
    out = serve_cli.main(["--arch", arch, "--reduced", "--batch", "2",
                          "--prompt-len", "8", "--gen", "6"])
    assert out.shape == (2, 14)
