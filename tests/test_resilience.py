"""The resilience layer: retries, deadlines, breakers, degraded chains.

The load-bearing claims: (a) the retry schedule is deterministic under a
seed and every knob is validated up front, (b) a job deadline shorter
than one provider call fails fast as ``DeadlineExceeded`` — never a
hang, (c) the circuit breaker's half-open probe admits exactly one call
and re-opens on its failure, (d) ``ResilientProvider`` walks primary ->
fallbacks -> cached-stale, stamping every non-primary result
``meta["degraded"]``, and (e) degraded counters are memoized but never
written to the persistent cache.
"""

import threading

import numpy as np
import pytest

from repro.analysis import (
    FaultInjectionProvider,
    ResilientProvider,
    RetryPolicy,
    Session,
    SweepCache,
    WorkloadSpec,
    get_device,
)
from repro.analysis import device as device_mod
from repro.analysis.providers import InjectedFault, get_provider
from repro.analysis.resilience import (
    CircuitBreaker,
    CorruptCounterError,
    Deadline,
    DeadlineExceeded,
    ProviderCallTimeout,
    ResilienceExhausted,
    TransientProviderError,
    call_with_timeout,
    counter_set_error,
    current_deadline,
    is_degraded,
    mark_degraded,
    record_event,
    resilience_scope,
)


@pytest.fixture(autouse=True)
def _isolate_results(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "results"))
    yield


def _spec(n=1024, seed=0, label="pt", **kw):
    rng = np.random.default_rng(seed)
    return WorkloadSpec.from_indices(rng.integers(0, 256, n), 256,
                                     label=label, waves_per_tile=4, **kw)


DEVICE = get_device("v5e")
FAST = RetryPolicy(retries=2, backoff_base_s=0.001, jitter=0.0)


class FlakyProvider:
    """Fails the first ``fail_first`` collects, then delegates to trace."""

    def __init__(self, fail_first=0, exc=TransientProviderError,
                 name="trace"):
        self.inner = get_provider("trace")
        self.name = name
        self.fail_first = fail_first
        self.exc = exc
        self.calls = 0

    def collect(self, spec, device):
        self.calls += 1
        if self.calls <= self.fail_first:
            raise self.exc(f"flaky failure {self.calls}")
        return self.inner.collect(spec, device)


class BlockingProvider:
    """Sleeps ``delay_s`` per collect (timeout/deadline fodder)."""

    name = "trace"

    def __init__(self, delay_s):
        self.delay_s = delay_s
        self.inner = get_provider("trace")

    def collect(self, spec, device):
        import time
        time.sleep(self.delay_s)
        return self.inner.collect(spec, device)


# -- RetryPolicy --------------------------------------------------------------


def test_retry_policy_validates_up_front():
    with pytest.raises(ValueError):
        RetryPolicy(retries=-1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_base_s=-0.1)
    with pytest.raises(ValueError):
        RetryPolicy(backoff_factor=0.5)
    with pytest.raises(ValueError):
        RetryPolicy(jitter=1.5)


def test_retry_schedule_deterministic_and_bounded():
    p = RetryPolicy(retries=5, backoff_base_s=0.1, backoff_factor=2.0,
                    max_backoff_s=0.5, jitter=0.25)
    assert p.attempts == 6
    s1, s2 = p.schedule(seed=7), p.schedule(seed=7)
    assert s1 == s2                       # same seed, same schedule
    assert p.schedule(seed=8) != s1       # a different seed moves it
    assert len(s1) == 5
    # base grows 0.1, 0.2, 0.4 then clamps at 0.5; jitter adds <= 25%
    for k, d in enumerate(s1):
        base = min(0.1 * 2.0 ** k, 0.5)
        assert base <= d <= base * 1.25


def test_retry_schedule_no_jitter_is_exact():
    p = RetryPolicy(retries=3, backoff_base_s=0.5, backoff_factor=2.0,
                    max_backoff_s=10.0, jitter=0.0)
    assert p.schedule(seed=0) == [0.5, 1.0, 2.0]


def test_zero_retry_policy_single_attempt():
    p = RetryPolicy(retries=0)
    assert p.attempts == 1
    assert p.schedule() == []
    flaky = FlakyProvider(fail_first=1)
    rp = ResilientProvider(flaky, retry=p)
    with pytest.raises(ResilienceExhausted):
        rp.collect(_spec(), DEVICE)
    assert flaky.calls == 1               # no second attempt


# -- deadlines / timeouts -----------------------------------------------------


def test_call_with_timeout_paths():
    assert call_with_timeout(lambda: 42, None) == 42
    assert call_with_timeout(lambda: 42, 5.0) == 42
    with pytest.raises(ProviderCallTimeout):
        call_with_timeout(lambda: __import__("time").sleep(5), 0.05)
    with pytest.raises(ProviderCallTimeout):
        call_with_timeout(lambda: 42, 0.0)   # no budget left

    def boom():
        raise KeyError("inner")

    with pytest.raises(KeyError):
        call_with_timeout(boom, 5.0)


def test_deadline_and_scope():
    assert current_deadline() is None
    record_event({"kind": "noop"})        # no scope: silently dropped
    t = [0.0]
    with resilience_scope(2.0, clock=lambda: t[0]) as events:
        d = current_deadline()
        assert d is not None and not d.expired
        t[0] = 1.0
        assert d.remaining() == pytest.approx(1.0)
        record_event({"kind": "x"})
        t[0] = 3.0
        assert d.expired
    assert events == [{"kind": "x"}]
    assert current_deadline() is None
    with pytest.raises(ValueError):
        Deadline(0.0)


def test_deadline_shorter_than_one_call_fails_fast():
    """A 0.05s job deadline against a 5s provider call: the call is cut
    at the remaining budget and the job dies as DeadlineExceeded in
    ~deadline time, not provider time."""
    rp = ResilientProvider(BlockingProvider(5.0), retry=FAST,
                           call_timeout_s=30.0)
    import time
    start = time.monotonic()
    with resilience_scope(0.05) as events:
        with pytest.raises(DeadlineExceeded):
            rp.collect(_spec(), DEVICE)
    assert time.monotonic() - start < 2.0
    assert any(e["kind"] == "retry" for e in events)


# -- circuit breaker ----------------------------------------------------------


def test_breaker_validates_and_trips():
    with pytest.raises(ValueError):
        CircuitBreaker(failure_threshold=0)
    with pytest.raises(ValueError):
        CircuitBreaker(cooldown_s=-1)
    t = [0.0]
    br = CircuitBreaker(failure_threshold=2, cooldown_s=10.0,
                        clock=lambda: t[0])
    assert br.allow() and br.state == "closed"
    br.record_failure()
    assert br.allow()                     # one failure: still closed
    br.record_failure()
    assert br.state == "open" and not br.allow()
    snap = br.snapshot()
    assert snap["trips"] == 1
    assert snap["cooldown_remaining_s"] == pytest.approx(10.0)


def test_breaker_half_open_reprobe():
    t = [0.0]
    br = CircuitBreaker(failure_threshold=1, cooldown_s=10.0,
                        clock=lambda: t[0])
    br.record_failure()
    assert br.state == "open" and not br.allow()
    t[0] = 10.0
    assert br.allow()                     # the single half-open probe
    assert br.state == "half-open"
    assert not br.allow()                 # second caller is rejected
    br.record_failure()                   # probe failed: re-open
    assert br.state == "open"
    assert br.snapshot()["trips"] == 2
    assert not br.allow()                 # fresh cooldown from t=10
    t[0] = 20.0
    assert br.allow()
    br.record_success()                   # probe succeeded: re-close
    assert br.state == "closed" and br.allow()
    assert br.snapshot()["consecutive_failures"] == 0


def test_breaker_skips_dead_provider_without_paying_timeout():
    flaky = FlakyProvider(fail_first=10 ** 9)
    rp = ResilientProvider(flaky, retry=RetryPolicy(retries=0),
                           breaker_threshold=2,
                           breaker_cooldown_s=1000.0)
    for _ in range(2):
        with pytest.raises(ResilienceExhausted):
            rp.collect(_spec(), DEVICE)
    calls = flaky.calls
    with resilience_scope(30.0) as events:
        with pytest.raises(ResilienceExhausted):
            rp.collect(_spec(), DEVICE)
    assert flaky.calls == calls           # breaker open: not even called
    assert any(e["kind"] == "breaker-skip" for e in events)


# -- counter sanity -----------------------------------------------------------


def test_counter_set_error_catches_structural_garbage():
    good = get_provider("trace").collect(_spec(), DEVICE)
    assert counter_set_error(good) is None
    assert counter_set_error("nope")      # not a CounterSet
    import dataclasses
    nan = dataclasses.replace(
        good, O=np.full_like(np.asarray(good.O, float), np.nan))
    assert "non-finite" in counter_set_error(nan)
    neg = dataclasses.replace(good, N_f=-np.asarray(good.N_f, float))
    assert neg.N_f.min() <= 0  # sanity of the fixture itself
    assert counter_set_error(neg)
    short = dataclasses.replace(good, O=np.asarray(good.O)[:-1])
    assert "shape" in counter_set_error(short)
    bad_roof = dataclasses.replace(good, bytes_read=float("inf"))
    assert "non-finite" in counter_set_error(bad_roof)


def test_degraded_stamp_roundtrip():
    cset = get_provider("trace").collect(_spec(), DEVICE)
    assert not is_degraded(cset)
    marked = mark_degraded(cset, fallback="kernel", primary="trace")
    assert is_degraded(marked)
    assert marked.meta["fallback_provider"] == "kernel"
    assert not is_degraded(cset)          # original untouched


# -- the resilient chain ------------------------------------------------------


def test_transient_failure_retried_then_primary_result():
    flaky = FlakyProvider(fail_first=2)
    rp = ResilientProvider(flaky, retry=FAST)
    with resilience_scope(30.0) as events:
        cset = rp.collect(_spec(), DEVICE)
    assert flaky.calls == 3
    assert not is_degraded(cset)          # third attempt is the primary
    assert [e["kind"] for e in events] == ["retry", "retry"]


def test_permanent_failure_skips_retries_and_falls_back():
    flaky = FlakyProvider(fail_first=10 ** 9, exc=KeyError)
    rp = ResilientProvider(flaky, fallbacks=("trace",), retry=FAST)
    with resilience_scope(30.0) as events:
        cset = rp.collect(_spec(), DEVICE)
    assert flaky.calls == 1               # permanent: no retry
    assert is_degraded(cset)
    assert cset.meta["fallback_provider"] == "trace"
    kinds = [e["kind"] for e in events]
    assert kinds == ["permanent", "fallback"]


def test_corrupt_counters_detected_and_degraded():
    fault = FaultInjectionProvider("trace", corrupt_rate=1.0, seed=3)
    rp = ResilientProvider(fault, fallbacks=("trace",), retry=FAST)
    cset = rp.collect(_spec(), DEVICE)
    assert is_degraded(cset)
    assert np.all(np.isfinite(cset.O))    # the fallback's sane numbers
    assert fault.stats_snapshot()["corrupt"] == FAST.attempts


def test_exhausted_chain_reports_every_error():
    rp = ResilientProvider(FlakyProvider(fail_first=10 ** 9),
                           retry=RetryPolicy(retries=1,
                                             backoff_base_s=0.001))
    with pytest.raises(ResilienceExhausted) as ei:
        rp.collect(_spec(), DEVICE)
    assert len(ei.value.errors) == 2      # both attempts recorded
    assert all(name == "trace" for name, _ in ei.value.errors)


def test_stale_cache_is_the_last_resort():
    cache = SweepCache()
    spec = _spec(label="warm-me")
    cset = get_provider("trace").collect(spec, DEVICE)
    cache.put(cache.key("trace", spec.fingerprint(), DEVICE.table_key()),
              cset)
    rp = ResilientProvider(FlakyProvider(fail_first=10 ** 9),
                           retry=RetryPolicy(retries=0),
                           stale_cache=cache)
    with resilience_scope(30.0) as events:
        got = rp.collect(spec, DEVICE)
    assert is_degraded(got)
    assert got.meta["fallback_provider"] == "cached-stale"
    assert events[-1]["fallback"] == "cached-stale"
    np.testing.assert_array_equal(got.O, cset.O)


def test_deterministic_backoff_under_seeded_faults():
    """The sleeps a seeded ResilientProvider actually performs equal the
    policy's published schedule — the chaos tests' reproducibility
    contract."""
    policy = RetryPolicy(retries=3, backoff_base_s=0.01, jitter=0.25)
    for seed in (0, 11):
        slept = []
        fault = FaultInjectionProvider("trace", fault_rate=1.0, seed=1)
        rp = ResilientProvider(fault, retry=policy, seed=seed,
                               sleep=slept.append)
        with pytest.raises(ResilienceExhausted):
            rp.collect(_spec(), DEVICE)
        assert slept == policy.schedule(seed=seed)


def test_fault_provider_schedule_is_rate_independent():
    """Same seed, different enabled rates: the same calls are hit,
    because every call draws exactly three variates."""
    import random
    rng = random.Random(5)
    draws = [(rng.random(), rng.random(), rng.random())
             for _ in range(20)]
    fault_calls = {i for i, d in enumerate(draws) if d[0] < 0.3}
    fault = FaultInjectionProvider("trace", fault_rate=0.3, seed=5)
    spec = _spec()
    hit = set()
    for i in range(20):
        try:
            fault.collect(spec, DEVICE)
        except InjectedFault:
            hit.add(i)
    assert hit == fault_calls


def test_fault_provider_validates_and_reconfigures():
    fault = FaultInjectionProvider("trace", fault_rate=0.5)
    with pytest.raises(ValueError):
        fault.configure(fault_rate=1.5)
    with pytest.raises(ValueError):
        FaultInjectionProvider("trace", corrupt_rate=-0.1)
    fault.configure(fault_rate=0.0)
    fault.collect(_spec(), DEVICE)        # no injection at rate 0
    assert fault.stats_snapshot()["faults"] == 0


# -- session integration ------------------------------------------------------


@pytest.fixture
def sess_factory(tmp_path):
    device_mod._TABLE_MEMO.clear()

    def make(provider, **kw):
        return Session("v5e", provider=provider, cache_dir=tmp_path, **kw)
    return make


def test_degraded_results_memoized_but_never_on_disk(sess_factory):
    cache = SweepCache()
    fault = FaultInjectionProvider("trace", fault_rate=1.0, seed=0)
    rp = ResilientProvider(fault, fallbacks=("trace",), retry=FAST,
                           stale_cache=cache)
    sess = sess_factory(rp, persistent_cache=cache)
    specs = [_spec(seed=s, label=f"pt{s}") for s in range(4)]
    result = sess.sweep(specs, parallel=1)
    assert len(result) == 4
    assert all((p.params or {}).get("meta", {}).get("degraded")
               for p in result.profiles)
    assert len(cache) == 0                # nothing written to disk
    # warm resubmission: the memo serves every point, zero collections
    before = sess.stats_snapshot()
    sess.sweep(specs, parallel=1)
    after = sess.stats_snapshot()
    assert after["batch_calls"] == before["batch_calls"]
    assert after["collected"] == before["collected"]


def test_healthy_resilient_provider_shares_cache_with_plain_session(
        sess_factory):
    """ResilientProvider keeps the primary's name, so a spec warmed by a
    plain session is a disk hit for the resilient one (and vice versa)."""
    cache = SweepCache()
    plain = sess_factory("trace", persistent_cache=cache)
    spec = _spec(label="shared")
    plain.sweep([spec])
    assert len(cache) == 1
    rp = ResilientProvider("trace", retry=FAST, stale_cache=cache)
    resilient = sess_factory(rp, persistent_cache=cache)
    resilient.sweep([spec])
    assert resilient.stats_snapshot()["disk_hits"] == 1
    assert resilient.stats_snapshot()["collected"] == 0


def test_resilient_provider_dedups_fallbacks_and_labels_breakers():
    fault = FaultInjectionProvider("trace", fault_rate=1.0)
    rp = ResilientProvider(fault, fallbacks=("trace", "trace"))
    assert len(rp.fallbacks) == 1         # same instance listed once
    states = rp.breaker_states()
    assert set(states) == {"trace", "trace#2"}   # per-instance breakers


def test_breaker_isolation_between_primary_and_fallback():
    """Primary failures must never open the fallback's breaker, even
    when both carry the same provider name."""
    fault = FaultInjectionProvider("trace", fault_rate=1.0, seed=0)
    rp = ResilientProvider(fault, fallbacks=("trace",),
                           retry=RetryPolicy(retries=0,
                                             backoff_base_s=0.001),
                           breaker_threshold=2,
                           breaker_cooldown_s=1000.0)
    for i in range(4):
        cset = rp.collect(_spec(seed=i), DEVICE)
        assert is_degraded(cset)
    states = rp.breaker_states()
    assert states["trace"]["state"] == "open"       # primary tripped
    assert states["trace#2"]["state"] == "closed"   # fallback healthy


def test_thread_safety_of_resilient_collect():
    flaky = FlakyProvider(fail_first=0)
    rp = ResilientProvider(flaky, retry=FAST)
    results, errors = [], []

    def work(seed):
        try:
            results.append(rp.collect(_spec(seed=seed), DEVICE))
        except Exception as exc:  # noqa: BLE001 — collected for assert
            errors.append(exc)

    threads = [threading.Thread(target=work, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors and len(results) == 8
