"""Histogram Pallas kernel vs jnp oracle: shape/dtype sweeps + conflict
instrumentation fidelity (paper §4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import timing
from repro.kernels.histogram import ops, ref


@pytest.mark.parametrize("n_pixels", [256, 2048, 5000, 8192])
@pytest.mark.parametrize("variant", ["hist", "hist2"])
@pytest.mark.parametrize("dtype", [np.int32, np.uint8, np.int64])
def test_histogram_matches_ref(n_pixels, variant, dtype):
    rng = np.random.default_rng(0)
    img = rng.integers(0, 256, (n_pixels, 4)).astype(dtype)
    out = ops.histogram(jnp.asarray(img.astype(np.int32)), variant=variant)
    expect = ref.histogram_ref(jnp.asarray(img.astype(np.int32)))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    assert int(out.sum()) == n_pixels * 4


@pytest.mark.parametrize("variant", ["hist", "hist2"])
def test_histogram_weighted_matches_ref(variant):
    rng = np.random.default_rng(1)
    img = rng.integers(0, 256, (3000, 4)).astype(np.int32)
    w = rng.random(3000).astype(np.float32)
    out = ops.histogram_weighted(jnp.asarray(img), jnp.asarray(w),
                                 variant=variant)
    expect = ref.histogram_weighted_ref(jnp.asarray(img), jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2000), st.integers(0, 2**31 - 1))
def test_histogram_property_random_images(n_pixels, seed):
    rng = np.random.default_rng(seed)
    img = rng.integers(0, 256, (n_pixels, 4)).astype(np.int32)
    h1 = np.asarray(ops.histogram(jnp.asarray(img), variant="hist"))
    h2 = np.asarray(ops.histogram(jnp.asarray(img), variant="hist2"))
    expect = np.stack([np.bincount(img[:, c], minlength=256)
                       for c in range(4)])
    np.testing.assert_array_equal(h1, expect)
    np.testing.assert_array_equal(h2, expect)  # reorder preserves counts


def test_instrumented_degrees_solid_vs_reordered():
    """The paper's core observation: reordering cuts serialization ~4x."""
    solid = np.full((4096, 4), 9, np.int32)
    _, tr1 = ops.histogram_instrumented(jnp.asarray(solid), variant="hist")
    _, tr2 = ops.histogram_instrumented(jnp.asarray(solid), variant="hist2")
    assert tr1.degree.mean() == 32.0
    assert tr2.degree.mean() == 8.0


def test_instrumented_degrees_uniform():
    rng = np.random.default_rng(2)
    img = rng.integers(0, 256, (4096, 4)).astype(np.int32)
    _, tr = ops.histogram_instrumented(jnp.asarray(img), variant="hist")
    assert 1.0 <= tr.degree.mean() <= 4.0   # paper: e ~ 2-3 for uniform


def test_instruction_classes():
    img = np.zeros((2048, 4), np.int32)
    _, popc = ops.histogram_instrumented(jnp.asarray(img))
    _, fao = ops.histogram_instrumented(jnp.asarray(img), force_fao=True)
    _, cas = ops.histogram_instrumented(jnp.asarray(img), weighted=True)
    assert set(popc.job_class) == {timing.POPC}
    assert set(fao.job_class) == {timing.FAO}
    assert set(cas.job_class) == {timing.CAS}


def test_padding_correction():
    img = np.full((100, 4), 3, np.int32)   # far from tile multiple
    out = np.asarray(ops.histogram(jnp.asarray(img)))
    expect = np.asarray(ref.histogram_ref(jnp.asarray(img)))
    np.testing.assert_array_equal(out, expect)
