"""The observability layer: heat-map attribution + telemetry.

The load-bearing claims: (a) heat-map renderers round-trip — json and
csv parse back to exactly the per-bin attribution the ``Heatmap``
carries, (b) per-bin totals stay bit-consistent with the profile path —
the embedded ``CounterSet`` is bitwise-equal to the provider's
``collect`` and per-bin hits sum to the committed stream length, (c)
empty-stream and single-bin streams are well-defined, not crashes, (d)
the metrics registry enforces its label-cardinality bound even under
concurrent writers without losing counts, and (e) the service surfaces
it all: ``/metrics`` serves Prometheus-parseable text, ``/status``
carries ``SweepCache.stats()``, and every job answer carries a
propagated trace id plus span summaries.
"""

import csv
import io
import json
import re
import threading
import urllib.request

import numpy as np
import pytest

from repro.analysis import Session, WorkloadSpec
from repro.analysis.providers.trace import TraceProvider
from repro.core.counters import COMMIT_GROUP, LANES, bitwise_equal
from repro.data.images import make_image
from repro.obs import Heatmap, heatmap_for_spec, heatmap_from_stream
from repro.obs.telemetry import (OVERFLOW, MetricsRegistry, span,
                                 span_summaries, trace_scope)
from repro.service import ProfilingService, ServiceConfig
from repro.service.server import make_http_server


@pytest.fixture(autouse=True)
def _isolate_artifacts(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path / "results"))
    yield


def _session():
    return Session("v5e")


def _hist_spec(variant="hist", pixels=1 << 13):
    img = make_image("solid", pixels)
    return WorkloadSpec.from_histogram(
        img, label=f"solid-{variant}", variant=variant)


# -- attribution --------------------------------------------------------------


def test_heatmap_bit_consistent_with_counterset():
    """The tentpole invariant: same stream, same kernels, same counters."""
    prov = TraceProvider()
    for variant in ("hist", "hist2"):
        spec = _hist_spec(variant)
        hm = heatmap_for_spec(spec)
        cset = prov.collect(spec, None)
        assert bitwise_equal(hm.counters, cset)
        # hits sum to the committed stream length (pixels x channels)
        stream, _, _ = prov.committed_stream(spec)
        assert int(hm.hits.sum()) == stream.size
        assert hm.total_hits == stream.size
        # the wave series is the trace's degree array: one entry per
        # wave job, summing (per core) into the CounterSet's O
        assert hm.num_waves == cset.num_waves
        assert np.isclose(hm.wave_degree.sum(), cset.total_O)


def test_heatmap_localizes_hist_and_hist2_disperses():
    """Identical hit totals; strictly lower top-bin replay share for
    hist2 — the §5 story the heat map exists to show."""
    hist = heatmap_for_spec(_hist_spec("hist"))
    hist2 = heatmap_for_spec(_hist_spec("hist2"))
    assert np.array_equal(hist.bins, hist2.bins)
    assert np.array_equal(hist.hits, hist2.hits)
    assert hist.peak_degree == 32.0 and hist2.peak_degree == 8.0
    assert hist2.top_bin_share < hist.top_bin_share
    assert len(hist.hot_bins) >= 1
    assert list(hist.hot_bins) == list(hist2.hot_bins)


def test_heatmap_session_method_and_indices_source():
    idx = np.array([7] * LANES + [1, 2, 3], np.int64)
    spec = WorkloadSpec.from_indices(idx, 16, label="idx")
    hm = _session().heatmap(spec)
    assert isinstance(hm, Heatmap)
    assert hm.total_hits == idx.size
    assert hm.top_bin == 7
    # bin 7: one full wave of LANES hits, each commit group all-7s
    i7 = list(hm.bins).index(7)
    assert hm.hits[i7] == LANES
    assert hm.replays[i7] == LANES - LANES // COMMIT_GROUP
    assert hm.max_wave_degree[i7] == float(COMMIT_GROUP)


def test_heatmap_rejects_streamless_sources():
    tr = TraceProvider()._synthesize(_hist_spec())
    spec = WorkloadSpec(label="pre-recorded", trace=tr)
    with pytest.raises(ValueError, match="no committed index stream"):
        _session().heatmap(spec)


def test_heatmap_empty_stream():
    hm = heatmap_from_stream(np.empty(0, np.int64), label="empty")
    assert hm.total_hits == 0
    assert hm.bins.size == 0
    assert hm.top_bin is None
    assert hm.top_bin_share == 0.0
    assert hm.hot_bins.size == 0
    # all three renderers still produce output
    assert "empty" in hm.render("text")
    assert json.loads(hm.render("json"))["total_hits"] == 0
    assert hm.render("csv").startswith("bin,")


def test_heatmap_single_bin_stream():
    n = 4 * LANES
    hm = heatmap_from_stream(np.zeros(n, np.int64), label="one-bin")
    assert list(hm.bins) == [0]
    assert hm.hits[0] == n
    assert hm.replays[0] == n - n // COMMIT_GROUP
    assert hm.max_wave_degree[0] == float(COMMIT_GROUP)
    assert hm.top_bin == 0
    assert hm.top_bin_share == pytest.approx((COMMIT_GROUP - 1)
                                             / COMMIT_GROUP)
    assert list(hm.hot_bins) == [0]


def test_heatmap_negative_stream_rejected():
    with pytest.raises(ValueError, match="negative"):
        heatmap_from_stream(np.array([-1, 2]))


# -- renderers ----------------------------------------------------------------


def test_render_json_round_trip():
    hm = heatmap_for_spec(_hist_spec())
    body = json.loads(hm.render("json", top_k=64))
    assert body["label"] == hm.label
    assert body["total_hits"] == hm.total_hits
    assert body["hot_bins"] == [int(b) for b in hm.hot_bins]
    assert body["top_bin"] == hm.top_bin
    assert body["top_bin_share"] == pytest.approx(hm.top_bin_share)
    assert body["peak_wave"] == hm.peak_wave
    assert body["counters"]["total_O"] == hm.counters.total_O
    assert len(body["wave_degree"]) == hm.num_waves
    assert np.allclose(body["wave_degree"], hm.wave_degree)
    by_bin = {r["bin"]: r for r in body["bins"]}
    for i, b in enumerate(hm.bins):
        assert by_bin[int(b)]["hits"] == int(hm.hits[i])
        assert by_bin[int(b)]["replays"] == int(hm.replays[i])


def test_render_csv_round_trip():
    hm = heatmap_for_spec(_hist_spec())
    rows = list(csv.DictReader(io.StringIO(hm.render("csv"))))
    assert len(rows) == hm.bins.size
    for i, row in enumerate(sorted(rows, key=lambda r: int(r["bin"]))):
        assert int(row["bin"]) == int(hm.bins[i])
        assert int(row["hits"]) == int(hm.hits[i])
        assert int(row["replays"]) == int(hm.replays[i])
        assert float(row["max_wave_degree"]) == \
            pytest.approx(float(hm.max_wave_degree[i]))
        assert row["hot"] in ("0", "1")


def test_render_text_and_unknown_format():
    hm = heatmap_for_spec(_hist_spec())
    text = hm.render("text")
    assert "contention heat map" in text
    assert "top-bin share" in text
    assert "hot bins: 4" in text
    with pytest.raises(ValueError, match="unknown heat-map format"):
        hm.render("yaml")


# -- metrics registry ---------------------------------------------------------


def test_metrics_label_cardinality_bound_under_concurrency():
    reg = MetricsRegistry(max_series=8)
    ctr = reg.counter("test_total", "t", ("worker",))
    n_threads, per_thread = 16, 50

    def hammer(tid: int) -> None:
        for i in range(per_thread):
            ctr.inc(worker=f"w{tid}-{i}")   # every label value distinct

    threads = [threading.Thread(target=hammer, args=(t,))
               for t in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    series = ctr.series()
    assert len(series) <= 8 + 1            # bound + the overflow series
    assert (OVERFLOW,) in series
    # nothing is dropped: every increment landed somewhere
    total = sum(v[0] for v in series.values())
    assert total == n_threads * per_thread


def test_metrics_registry_types_and_render():
    reg = MetricsRegistry()
    c = reg.counter("jobs_total", "jobs", ("kind",))
    g = reg.gauge("depth", "queue depth")
    h = reg.histogram("lat_seconds", "latency", buckets=(0.1, 1.0))
    c.inc(kind="profile")
    c.inc(2, kind="sweep")
    g.set(3)
    h.observe(0.05)
    h.observe(5.0)
    text = reg.render()
    assert '# TYPE jobs_total counter' in text
    assert 'jobs_total{kind="profile"} 1' in text
    assert 'jobs_total{kind="sweep"} 2' in text
    assert "# TYPE depth gauge\ndepth 3" in text
    assert 'lat_seconds_bucket{le="0.1"} 1' in text
    assert 'lat_seconds_bucket{le="+Inf"} 2' in text
    assert 'lat_seconds_count 2' in text
    # prometheus text format: every non-comment line is `name{...} value`
    for line in text.strip().splitlines():
        if not line.startswith("#"):
            assert re.match(r'^[a-zA-Z_:][\w:]*(\{[^}]*\})? \S+$', line)
    # same name, different shape -> rejected
    with pytest.raises(ValueError, match="re-registered"):
        reg.counter("jobs_total", "jobs", ())
    with pytest.raises(ValueError, match="only go up"):
        c.inc(-1, kind="profile")
    with pytest.raises(ValueError, match="bad metric name"):
        reg.counter("bad name", "x")
    reg.reset()
    assert 'jobs_total{kind="profile"}' not in reg.render()


def test_spans_record_inside_scope_only():
    with span("orphan"):
        pass
    assert span_summaries() == []
    with trace_scope("tid123") as rec:
        with span("outer", label="x"):
            with span("inner"):
                pass
        assert rec["id"] == "tid123"
    names = [s["name"] for s in rec["spans"]]
    assert names == ["inner", "outer"]     # closed in completion order
    assert all(s["dur_ms"] >= 0 for s in rec["spans"])
    assert rec["spans"][1]["attrs"] == {"label": "x"}


# -- service surface ----------------------------------------------------------


@pytest.fixture
def service():
    svc = ProfilingService(ServiceConfig(
        workers=2, queue_depth=16, persistent_cache=True)).start()
    yield svc
    svc.stop()


def test_service_heatmap_kind_and_trace_ids(service):
    status, body = service.handle(
        {"kind": "heatmap",
         "workload": {"workload": "histogram", "pixels": 1 << 13,
                      "dist": "solid"},
         "options": {"top_k": 4, "hot_degree": 2.0}},
        trace_id="deadbeef01")
    assert status == 200, body
    assert body["trace_id"] == "deadbeef01"
    names = [s["name"] for s in body["spans"]]
    assert "service.dispatch" in names and "session.heatmap" in names
    result = body["result"]
    assert len(result["hot_bins"]) >= 1
    assert result["top_bin_share"] > 0
    # a heatmap job over a multi-point grid is a 400, like profile
    status, body = service.handle(
        {"kind": "heatmap",
         "workload": {"workload": "indices", "size": [1024, 2048]}})
    assert status == 400


def test_service_status_includes_cache_stats(service):
    service.handle({"kind": "profile",
                    "workload": {"workload": "indices", "size": 1024}})
    status = service.status()
    assert "cache" in status
    for key in ("entries", "bytes", "quarantined"):
        assert key in status["cache"]
    assert status["cache"]["entries"] >= 1


def test_metrics_endpoint_and_trace_header(service):
    server = make_http_server(service, port=0)
    t = threading.Thread(target=server.serve_forever, daemon=True)
    t.start()
    try:
        port = server.server_address[1]
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/v1/jobs",
            data=json.dumps(
                {"kind": "profile",
                 "workload": {"workload": "indices",
                              "size": 1024}}).encode(),
            headers={"X-Repro-Trace-Id": "my-trace-42"})
        with urllib.request.urlopen(req, timeout=30) as resp:
            assert resp.headers["X-Repro-Trace-Id"] == "my-trace-42"
            body = json.loads(resp.read())
        assert body["ok"] and body["trace_id"] == "my-trace-42"
        assert isinstance(body["spans"], list) and body["spans"]

        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=30) as resp:
            assert resp.headers["Content-Type"].startswith("text/plain")
            text = resp.read().decode()
        assert re.search(
            r'repro_service_jobs_total\{kind="profile",outcome="ok"\} \d+',
            text)
        assert "repro_circuit_breaker_open" in text
        assert "repro_service_queue_depth" in text
        assert "repro_session_calls_total" in text
    finally:
        server.shutdown()
        server.server_close()


def test_schema_lists_heatmap_kind(service):
    from repro.service.jobs import JOB_KINDS
    assert "heatmap" in JOB_KINDS


# -- CLI ----------------------------------------------------------------------


def test_cli_version(capsys):
    import repro
    from repro.cli.main import main
    with pytest.raises(SystemExit) as ei:
        main(["--version"])
    assert ei.value.code == 0
    assert repro.__version__ in capsys.readouterr().out


def test_cli_heatmap(capsys):
    from repro.cli.main import main
    rc = main(["heatmap", "--workload", "histogram", "--pixels", "2^13",
               "--dist", "solid", "--format", "json", "--no-artifact"])
    out = capsys.readouterr().out
    assert rc == 0
    body = json.loads(out)
    assert len(body["hot_bins"]) >= 1
    assert body["top_bin_share"] > 0


def test_cli_heatmap_writes_artifact(tmp_path, monkeypatch, capsys):
    from repro.cli.main import main
    monkeypatch.setenv("REPRO_RESULTS", str(tmp_path))
    rc = main(["heatmap", "--size", "2^12", "--dist", "solid",
               "--format", "csv"])
    capsys.readouterr()
    assert rc == 0
    arts = list(tmp_path.rglob("heatmap-*.csv"))
    assert len(arts) == 1
    rows = list(csv.DictReader(arts[0].open()))
    assert sum(int(r["hits"]) for r in rows) == 1 << 12
