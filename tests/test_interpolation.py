"""TableInterpolator vs trilinear: agreement, boundaries, edge grids.

The batch profiler's hot lookup (``qmodel.TableInterpolator``) must be a
drop-in for ``qmodel.trilinear`` — same clamping, same corner weights —
or the batch/scalar equivalence guarantee of ``profile_batch`` breaks.
These tests pin that contract on random queries, boundary clamping,
degenerate single-point axes, and the paper's ``T(0, ., .) = 0``
boundary (Eq. 1).
"""

import numpy as np
import pytest

from repro.core import microbench, qmodel

TABLE = microbench.build_table()
GRIDS3 = (TABLE.n_grid, TABLE.e_grid, TABLE.cfrac_grid)


def _rand_queries(n, lo, hi, seed):
    rng = np.random.default_rng(seed)
    return rng.uniform(lo, hi, n)


# -- agreement with trilinear -------------------------------------------------


def test_interpolator_matches_trilinear_random_3d():
    n = _rand_queries(4096, -8.0, 80.0, 0)       # deliberately out of range
    e = _rand_queries(4096, 0.0, 40.0, 1)
    cf = _rand_queries(4096, -0.3, 1.4, 2)
    ref = qmodel.trilinear(TABLE.T, GRIDS3, (n, e, cf))
    got = TABLE.interpolator()(n, e, cf)
    np.testing.assert_array_equal(got, ref)      # bit-identical, not approx


def test_interpolator_matches_trilinear_random_2d_popc():
    n = _rand_queries(2048, 0.0, 70.0, 3)
    e = _rand_queries(2048, 1.0, 35.0, 4)
    ref = qmodel.trilinear(TABLE.popc_T, (TABLE.n_grid, TABLE.e_grid), (n, e))
    got = TABLE.popc_interpolator()(n, e)
    np.testing.assert_array_equal(got, ref)


def test_interpolator_scalar_query_matches():
    for q in [(1.0, 1.0, 0.0), (13.7, 7.3, 0.42), (64.0, 32.0, 1.0)]:
        ref = float(qmodel.trilinear(TABLE.T, GRIDS3, q))
        got = float(TABLE.interpolator()(*q))
        assert got == ref


def test_service_time_batch_matches_scalar_loop():
    n = _rand_queries(512, 0.0, 70.0, 5)
    e = _rand_queries(512, 1.0, 35.0, 6)
    c = _rand_queries(512, 0.0, 1.0, 7) * n
    batch = TABLE.service_time_batch(n, e, c)
    loop = np.array([float(TABLE.service_time(ni, ei, ci))
                     for ni, ei, ci in zip(n, e, c)])
    np.testing.assert_array_equal(batch, loop)


def test_popc_service_time_batch_matches_scalar_loop():
    n = _rand_queries(256, 0.0, 70.0, 8)
    e = _rand_queries(256, 1.0, 35.0, 9)
    batch = TABLE.popc_service_time_batch(n, e)
    loop = np.array([float(TABLE.popc_service_time(ni, ei))
                     for ni, ei in zip(n, e)])
    np.testing.assert_array_equal(batch, loop)


def test_interpolators_are_cached_per_table():
    assert TABLE.interpolator() is TABLE.interpolator()
    assert TABLE.popc_interpolator() is TABLE.popc_interpolator()


def test_popc_interpolator_requires_popc_table():
    bare = qmodel.ServiceTimeTable(
        n_grid=TABLE.n_grid, e_grid=TABLE.e_grid,
        cfrac_grid=TABLE.cfrac_grid, T=TABLE.T, popc_T=None)
    with pytest.raises(ValueError, match="POPC"):
        bare.popc_interpolator()


# -- boundary clamping --------------------------------------------------------


def test_clamp_beyond_n_grid_end():
    """n > n_grid[-1] clamps to the table edge (saturated load)."""
    edge = float(TABLE.interpolator()(TABLE.n_grid[-1], 8.0, 0.5))
    beyond = float(TABLE.interpolator()(TABLE.n_grid[-1] + 50.0, 8.0, 0.5))
    assert beyond == edge
    # and matches trilinear's clamp bit for bit
    ref = float(qmodel.trilinear(
        TABLE.T, GRIDS3, (TABLE.n_grid[-1] + 50.0, 8.0, 0.5)))
    assert beyond == ref


def test_clamp_cfrac_at_0_and_1_and_beyond():
    it = TABLE.interpolator()
    at0 = float(it(16.0, 4.0, 0.0))
    below = float(it(16.0, 4.0, -0.7))
    assert below == at0
    at1 = float(it(16.0, 4.0, 1.0))
    above = float(it(16.0, 4.0, 1.7))
    assert above == at1
    # interior lattice values are hit exactly at the clamped edges
    np.testing.assert_allclose(at0, TABLE.T[16, 3, 0], rtol=1e-12)
    np.testing.assert_allclose(at1, TABLE.T[16, 3, -1], rtol=1e-12)


def test_clamp_e_below_and_above_grid():
    it = TABLE.interpolator()
    assert float(it(8.0, 0.0, 0.0)) == float(it(8.0, TABLE.e_grid[0], 0.0))
    assert float(it(8.0, 99.0, 0.0)) == float(it(8.0, TABLE.e_grid[-1], 0.0))


def test_zero_load_boundary_is_zero():
    """T(0, ., .) = 0 (paper Eq. 1) survives interpolation and S := 0."""
    e = _rand_queries(64, 1.0, 32.0, 10)
    cf = _rand_queries(64, 0.0, 1.0, 11)
    t0 = TABLE.interpolator()(np.zeros(64), e, cf)
    np.testing.assert_array_equal(t0, np.zeros(64))
    s0 = TABLE.service_time_batch(np.zeros(64), e, np.zeros(64))
    np.testing.assert_array_equal(s0, np.zeros(64))
    # negative n clamps to the n = 0 plane too
    assert float(TABLE.interpolator()(-3.0, 4.0, 0.5)) == 0.0


# -- degenerate grids ---------------------------------------------------------


def test_single_point_axis_matches_trilinear():
    """A length-1 axis interpolates to its only sample, like trilinear."""
    vals = np.array([[1.0, 2.0, 4.0]])          # axis 0 has one point
    grids = (np.array([5.0]), np.array([0.0, 1.0, 2.0]))
    it = qmodel.TableInterpolator(vals, grids)
    q0 = np.array([3.0, 5.0, 9.0])              # below / at / above the point
    q1 = np.array([0.5, 1.5, 5.0])
    ref = qmodel.trilinear(vals, grids, (q0, q1))
    got = it(q0, q1)
    np.testing.assert_array_equal(got, ref)
    np.testing.assert_allclose(got, [1.5, 3.0, 4.0], rtol=1e-12)


def test_all_single_point_axes():
    it = qmodel.TableInterpolator(np.array([[7.5]]),
                                  (np.array([2.0]), np.array([3.0])))
    assert float(it(0.0, 100.0)) == 7.5


def test_interpolator_rejects_mismatched_grids():
    with pytest.raises(ValueError, match="one grid per value axis"):
        qmodel.TableInterpolator(TABLE.T, (TABLE.n_grid, TABLE.e_grid))
    with pytest.raises(ValueError, match="does not match axis size"):
        qmodel.TableInterpolator(
            TABLE.T, (TABLE.n_grid, TABLE.e_grid, TABLE.e_grid))
    with pytest.raises(ValueError, match="query arrays"):
        TABLE.interpolator()(1.0, 2.0)


def test_exact_on_lattice_points_via_interpolator():
    it = TABLE.interpolator()
    for i, j, k in [(0, 0, 0), (16, 7, 8), (64, 31, 16), (33, 15, 3)]:
        got = float(it(TABLE.n_grid[i], TABLE.e_grid[j], TABLE.cfrac_grid[k]))
        np.testing.assert_allclose(got, TABLE.T[i, j, k], rtol=1e-12)
