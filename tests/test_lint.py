"""repro.lint — symbolic tracer, static derivation, KERN rules, CLI.

The headline guarantee under test: for affine kernels (hist/hist2) the
statically derived counters are **bit-for-bit** the trace provider's,
with zero kernel executions and the session's collection stats pinned
to zero.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from repro import audit as audit_mod
from repro import lint as lint_mod
from repro.analysis import Session, WorkloadSpec
from repro.analysis.providers.trace import TraceProvider
from repro.core import timing
from repro.data.images import make_image
from repro.kernels.histogram import ops as hist_ops
from repro.lint import registry as lint_registry_mod
from repro.lint import symbolic
from repro.lint.analysis import (DATA_DEPENDENT, STATIC, degree_stats,
                                 derive_counters, derive_stream,
                                 target_from_spec)
from repro.lint.tracing import analyze_callable

PROBE_PIXELS = lint_registry_mod.PROBE_PIXELS


@pytest.fixture(scope="module")
def sess():
    return Session("v5e")


def _probe_spec(variant, kind="solid", pixels=PROBE_PIXELS):
    img = make_image(kind, pixels, seed=0)
    return WorkloadSpec.from_histogram(
        img, label=f"{variant}-{kind}", variant=variant,
        waves_per_tile=8, overhead_cycles=2500.0)


# -- symbolic expressions ----------------------------------------------------


_I32 = np.dtype("int32")


def test_symbolic_affine_evaluation():
    # (iota(8) * 4 + pid) % 8 evaluated exactly
    iota = symbolic.Iota(shape=(8,), dtype=_I32, dim=0)
    four = symbolic.Const(shape=(), dtype=_I32, value=np.int32(4))
    eight = symbolic.Const(shape=(), dtype=_I32, value=np.int32(8))
    pid = symbolic.ProgramId(shape=(), dtype=_I32, axis=0)
    mul = symbolic.Elem(shape=(8,), dtype=_I32, op="mul",
                        args=(iota, four))
    add = symbolic.Elem(shape=(8,), dtype=_I32, op="add", args=(mul, pid))
    expr = symbolic.Elem(shape=(8,), dtype=_I32, op="rem",
                         args=(add, eight))
    got = symbolic.evaluate(expr, {("pid", 0): 3})
    np.testing.assert_array_equal(got, (np.arange(8) * 4 + 3) % 8)


def test_symbolic_trunc_division_matches_lax():
    # lax div/rem truncate toward zero; numpy floors — the evaluator
    # must follow lax
    num = symbolic.Const(shape=(3,), dtype=_I32,
                         value=np.array([-7, 7, -7], np.int32))
    den = symbolic.Const(shape=(3,), dtype=_I32,
                         value=np.array([2, -2, -2], np.int32))
    div = symbolic.Elem(shape=(3,), dtype=_I32, op="div", args=(num, den))
    rem = symbolic.Elem(shape=(3,), dtype=_I32, op="rem", args=(num, den))
    np.testing.assert_array_equal(symbolic.evaluate(div, {}), [-3, -3, 3])
    np.testing.assert_array_equal(symbolic.evaluate(rem, {}), [-1, 1, -1])


def test_symbolic_data_refs_and_program_axes():
    data = symbolic.Data(shape=(4,), dtype=_I32, ref=2, name="ref2")
    pid = symbolic.ProgramId(shape=(), dtype=_I32, axis=1)
    expr = symbolic.Elem(shape=(4,), dtype=_I32, op="add",
                         args=(data, pid))
    assert symbolic.data_refs(expr) == {2}
    assert symbolic.program_axes(expr) == {1}
    assert symbolic.data_refs(pid) == set()


# -- jaxpr tracing: structure ------------------------------------------------


def test_hist_kernel_model_structure():
    target = lint_registry_mod.build_target("hist")
    models = analyze_callable(target.fn, *target.args, name="hist")
    assert len(models) == 1
    m = models[0]
    assert m.grid == (PROBE_PIXELS // 2048,)   # one step per 2048-px tile
    site = m.sites[0]
    assert site.kind == "one_hot_popcount"
    assert site.rmw and site.num_bins == 1024 and site.row_elems == 1
    # the @pl.when(pid==0) zero-init is seen as an init guard on axis 0
    assert m.init_guards.get(site.ref) == {0}


def test_unguarded_accumulation_fires_kern003(sess):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        # rmw accumulate with NO pl.when(pid==0) zero-init, output block
        # independent of the grid axis: a cross-step race
        o_ref[...] += jnp.sum(x_ref[...], axis=0)

    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((256, 8), lambda i: (i, 0))],
            out_specs=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True,
        )(x)

    x = jax.ShapeDtypeStruct((1024, 8), jnp.float32)
    models = analyze_callable(launch, x, name="unguarded")
    target = lint_mod.LintTarget(
        label="unguarded", fn=launch, args=(x,), operands=(None,),
        spec=None, module=None, job_class=timing.FAO, waves_per_tile=8)
    findings = lint_mod.evaluate_target(target, sess, models=models)
    assert any(f.rule_id == "KERN003" and f.severity == "error"
               for f in findings), findings


def test_while_swap_fires_kern004(sess):
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def kernel(x_ref, o_ref):
        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        def body(i):
            o_ref[0] = x_ref[i]      # store inside a while body: retry shape
            return i + 1

        jax.lax.while_loop(lambda i: i < 4, body, 0)

    def launch(x):
        return pl.pallas_call(
            kernel,
            grid=(2,),
            in_specs=[pl.BlockSpec((8,), lambda i: (0,))],
            out_specs=pl.BlockSpec((8,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            interpret=True,
        )(x)

    x = jax.ShapeDtypeStruct((16,), jnp.float32)
    models = analyze_callable(launch, x, name="retry")
    assert models[0].while_has_swap
    target = lint_mod.LintTarget(
        label="retry", fn=launch, args=(x,), operands=(None,),
        spec=None, module=None, job_class=timing.FAO, waves_per_tile=8)
    findings = lint_mod.evaluate_target(target, sess, models=models)
    assert any(f.rule_id == "KERN004" for f in findings), findings


# -- static derivation: the bit-for-bit guarantee ----------------------------


@pytest.mark.parametrize("variant", ["hist", "hist2"])
def test_static_stream_equals_committed_stream(variant):
    spec = _probe_spec(variant, "solid")
    target = target_from_spec(spec)
    models = analyze_callable(target.fn, *target.args, name=variant)
    site = models[0].sites[0]
    deriv = derive_stream(models[0], site, target.operands)
    assert deriv.classification == STATIC, deriv.reasons
    img = spec.kernel.params["img"]
    # site.num_bins is the flattened output width (256 bins x 4 channels);
    # the ops-level synthesis takes the per-channel bin count
    assert site.num_bins == 256 * img.shape[-1]
    expected = hist_ops.committed_index_stream(
        img, num_bins=256, variant=variant)
    np.testing.assert_array_equal(deriv.stream, expected)


@pytest.mark.parametrize("variant", ["hist", "hist2"])
def test_uniform_probe_is_data_dependent(variant):
    # non-constant operand contents cannot be proved: the lint must
    # classify them for the dynamic path, never guess a stream
    spec = _probe_spec(variant, "uniform")
    target = target_from_spec(spec)
    models = analyze_callable(target.fn, *target.args, name=variant)
    deriv = derive_stream(models[0], models[0].sites[0], target.operands)
    assert deriv.classification == DATA_DEPENDENT
    assert deriv.stream is None


@pytest.mark.parametrize("variant", ["hist", "hist2"])
def test_derived_counters_bitwise_equal_trace_provider(variant):
    sess = Session("v5e")
    spec = _probe_spec(variant)
    derived, deriv = derive_counters(spec)
    assert derived is not None and deriv.is_static
    expected = TraceProvider().collect(spec, sess.device)
    for field in vars(expected):
        a, b = getattr(derived, field), getattr(expected, field)
        if isinstance(a, np.ndarray):
            assert a.dtype == b.dtype, field
            np.testing.assert_array_equal(a, b, err_msg=field)
        else:
            assert a == b, field
    # the whole derivation ran zero collections
    assert sess.stats == {"collected": 0, "memo_hits": 0, "disk_hits": 0,
                          "batch_calls": 0}


def test_degree_floor_separates_hist_from_hist2():
    stats = {}
    for variant in ("hist", "hist2"):
        target = target_from_spec(_probe_spec(variant))
        models = analyze_callable(target.fn, *target.args, name=variant)
        d = degree_stats(derive_stream(models[0], models[0].sites[0],
                                       target.operands))
        stats[variant] = d
    assert stats["hist"].mean_degree > stats["hist"].floor_degree
    assert stats["hist2"].mean_degree == pytest.approx(
        stats["hist2"].floor_degree)


# -- rule firing over the registry -------------------------------------------


def test_hist_fires_kern001_error(sess):
    rep = lint_mod.lint_kernel("hist", session=sess)
    f = next(f for f in rep.findings if f.rule_id == "KERN001")
    assert f.severity == "error" and not f.suppressed
    assert f.utilization is not None and f.contention > 1.0


def test_hist2_lints_clean(sess):
    rep = lint_mod.lint_kernel("hist2", session=sess)
    assert rep.active() == []


def test_flash_attention_lints_clean(sess):
    rep = lint_mod.lint_kernel("flash_attention", session=sess)
    assert rep.active() == []


def test_weighted_hist_fires_kern004(sess):
    rep = lint_mod.lint_kernel("hist_weighted", session=sess)
    ids = {f.rule_id for f in rep.active()}
    assert "KERN004" in ids and "KERN001" in ids


def test_scatter_add_kern002_suppressed_in_source(sess):
    # scatter_add/kernel.py carries `# repro: noqa KERN002`
    rep = lint_mod.lint_kernel("scatter_add", session=sess)
    k2 = [f for f in rep.findings if f.rule_id == "KERN002"]
    assert k2 and all(f.suppressed for f in k2)
    k5 = [f for f in rep.findings if f.rule_id == "KERN005"]
    assert k5 and not any(f.suppressed for f in k5)
    res = [r for r in rep.to_sarif()["runs"][0]["results"]
           if r["ruleId"] == "KERN002"]
    assert res[0]["suppressions"] == [{"kind": "inSource"}]


def test_data_dependent_kernels_emit_kern005_with_spec(sess):
    rep = lint_mod.lint_kernel("moe_dispatch", session=sess)
    f = next(f for f in rep.findings if f.rule_id == "KERN005")
    assert f.severity == "note"
    assert f.spec is not None        # carries the dynamic-audit workload
    assert f.site.classification == DATA_DEPENDENT


def test_session_lint_front_door(sess):
    rep = sess.lint(["hist2"])
    assert rep.active() == []
    rep = sess.lint(_probe_spec("hist"))   # a WorkloadSpec routes through
    assert any(f.rule_id == "KERN001" for f in rep.findings)


# -- unified audit/lint reporting --------------------------------------------


def test_sarif_catalog_spans_audit_and_kern_rules(sess):
    rep = lint_mod.lint_kernel("hist", session=sess)
    sarif = rep.to_sarif()
    ids = [d["id"] for d in sarif["runs"][0]["tool"]["driver"]["rules"]]
    for rid in ("ATOM001", "BANK001", "GEOM001", "AUDIT000",
                "KERN001", "KERN005"):
        assert rid in ids
    for r in sarif["runs"][0]["results"]:
        assert ids[r["ruleIndex"]] == r["ruleId"]


def test_merge_sarif_reindexes_by_rule_id(sess):
    lint_doc = lint_mod.lint_kernel("hist", session=sess).to_sarif()
    audit_doc = {"runs": [{"results": [
        {"ruleId": "ATOM001", "ruleIndex": 99, "level": "error",
         "message": {"text": "x"}}]}]}
    merged = audit_mod.merge_sarif([audit_doc, lint_doc])
    ids = [d["id"] for d in merged["runs"][0]["tool"]["driver"]["rules"]]
    results = merged["runs"][0]["results"]
    assert len(results) == 1 + len(lint_doc["runs"][0]["results"])
    for r in results:
        assert ids[r["ruleIndex"]] == r["ruleId"]
    json.dumps(merged)               # serializable end to end


def test_attach_advice_rotation_in_paper_band(sess):
    rep = lint_mod.lint_kernel("hist", session=sess)
    audit_mod.attach_advice(rep, sess)
    f = next(f for f in rep.findings if f.rule_id == "KERN001")
    assert f.advice is not None
    assert "rotation" in f.advice["families"]
    # the paper's headline: reordering buys up to ~30%
    assert 1.0 < f.advice["predicted_speedup"] <= 1.30
    assert f.advice["predicted_bottleneck"]
    res = next(r for r in rep.to_sarif()["runs"][0]["results"]
               if r["ruleId"] == "KERN001")
    assert res["properties"]["advise"]["families"] == f.advice["families"]


# -- CLI ---------------------------------------------------------------------


def test_cli_lint_gate(tmp_path, capsys):
    from repro.cli import main as cli_main
    rc = cli_main(["lint", "--kernel", "hist2", "--fail-on", "warning",
                   "--no-artifact"])
    assert rc == 0
    assert "no findings" in capsys.readouterr().out
    out_path = tmp_path / "lint.sarif"
    rc = cli_main(["lint", "--kernel", "hist", "--format", "sarif",
                   "--output", str(out_path), "--no-artifact"])
    assert rc == 1                   # KERN001 is an error at default gate
    doc = json.loads(out_path.read_text())
    assert any(r["ruleId"] == "KERN001"
               for r in doc["runs"][0]["results"])


def test_cli_lint_list(capsys):
    from repro.cli import main as cli_main
    assert cli_main(["lint", "--list"]) == 0
    out = capsys.readouterr().out.split()
    assert "hist" in out and "flash_attention" in out
