"""Tool 2 + the paper's §4 findings, reproduced end-to-end on the model:

  * utilization grows with image size (small images are overhead-bound),
  * solid images saturate the scatter unit; uniform stays below,
  * channel reordering (hist2) drops utilization and predicts speedup on
    solid images, slowdown-to-neutral on random ones,
  * the POPC class halves utilization vs forced-FAO (Ampere §4 finding),
  * the bottleneck shifts from scatter to memory as the working set spills
    the LLC with low concurrency (the paper's 2^20-pixel observation).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import bottleneck, microbench, profiler
from repro.data.images import make_image
from repro.kernels.histogram import ops

TABLE = microbench.build_table()


def _profile(kind, n_pixels, variant="hist", force_fao=True, cache=None,
             waves_per_tile=32, overhead=500.0):
    """waves_per_tile=32 is the 1024-thread-block analogue the paper uses
    for its saturation observations."""
    img = make_image(kind, n_pixels)
    _, trace = ops.histogram_instrumented(jnp.asarray(img), variant=variant,
                                          force_fao=force_fao)
    trace.waves_per_tile = waves_per_tile
    return profiler.profile_scatter_workload(
        trace, TABLE, label=f"{kind}-{variant}-{n_pixels}",
        bytes_read=ops.image_bytes(jnp.asarray(img)),
        overhead_cycles=overhead,
        cache=cache or profiler.CacheModel(),
    )


def test_utilization_grows_with_image_size():
    small = _profile("solid", 1 << 12)
    big = _profile("solid", 1 << 18)
    assert big.scatter_utilization > small.scatter_utilization


def test_solid_saturates_uniform_does_not():
    solid = _profile("solid", 1 << 18)
    uni = _profile("uniform", 1 << 18)
    assert solid.scatter_utilization > 0.9
    assert uni.scatter_utilization < solid.scatter_utilization
    assert solid.bottleneck == "scatter"


def test_reorder_reduces_utilization_and_predicts_speedup_on_solid():
    base = _profile("solid", 1 << 18, variant="hist")
    reord = _profile("solid", 1 << 18, variant="hist2")
    assert reord.scatter_utilization < base.scatter_utilization
    sp = bottleneck.speedup_estimate(base, reord)
    assert sp > 1.15    # paper: ~30% for large monochrome images


def test_reorder_neutral_on_uniform():
    base = _profile("uniform", 1 << 18, variant="hist")
    reord = _profile("uniform", 1 << 18, variant="hist2")
    sp = bottleneck.speedup_estimate(base, reord)
    assert 0.9 < sp < 1.1   # paper: random images see no atomic win


def test_popc_class_cuts_utilization():
    fao = _profile("solid", 1 << 18, force_fao=True)
    popc = _profile("solid", 1 << 18, force_fao=False)
    assert popc.scatter_utilization < 0.75 * fao.scatter_utilization


def test_bottleneck_shift_to_memory():
    """Sweep sizes with a small LLC + low concurrency: the dominant unit
    must shift from scatter to hbm at some size (paper Fig. 3, 2^20)."""
    cache = profiler.CacheModel(llc_bytes=1 << 20, miss_latency_cycles=2000,
                                hide_concurrency=64.0)
    profiles = [
        _profile("uniform", 1 << p, cache=cache, waves_per_tile=2)
        for p in range(12, 21)]
    shifts = bottleneck.detect_shifts(profiles)
    assert any(s.unit_after == "hbm" for s in shifts), \
        [p.bottleneck for p in profiles]


def test_classification_comments():
    v = bottleneck.classify(_profile("solid", 1 << 18))
    assert v.saturated and "saturated" in v.comment
    v2 = bottleneck.classify(_profile("solid", 1 << 10))
    assert not v2.saturated
