"""Cohere Command R+ 104B (hf:CohereForAI/c4ai-command-r-plus): GQA, no bias."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=33792, vocab_size=256000, qkv_bias=False, tie_embeddings=True,
    rope_theta=75e4,
)
