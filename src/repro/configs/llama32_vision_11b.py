"""Llama-3.2-Vision 11B (hf:meta-llama/Llama-3.2-11B-Vision): gated
cross-attention image layers every 5th layer; vision tower stubbed
(input_specs supplies precomputed patch embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=128256, tie_embeddings=False,
    cross_attn_every=5, image_tokens=1600, rope_theta=5e5,
)
