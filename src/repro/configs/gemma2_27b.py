"""Gemma-2 27B (arXiv:2408.00118): local+global alternating, logit softcaps."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16, head_dim=128,
    d_ff=36864, vocab_size=256000, tie_embeddings=True,
    attn_pattern="local_global", window=4096,
    attn_softcap=50.0, final_softcap=30.0, activation="gelu",
)
