"""Qwen3-MoE 235B-A22B family (hf:Qwen/Qwen3-30B-A3B scaled per assignment):
128 experts, top-8, GQA kv=4."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, d_expert=1536, num_experts=128, top_k=8,
    vocab_size=151936, qkv_bias=False, tie_embeddings=False,
    rope_theta=1e6,
)
