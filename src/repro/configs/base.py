"""Config system: one frozen dataclass covers all 10 assigned architectures.

Each ``configs/<arch>.py`` exports ``CONFIG`` (exact published dims) —
``CONFIG.reduced()`` gives the CPU smoke-test variant (same family/topology,
tiny dims).  ``SHAPES`` defines the assigned input-shape set and
``shape_for(cfg, name)`` resolves per-arch applicability (long_500k only
for sub-quadratic archs, decode only for archs with a decoder).
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense|moe|ssm|hybrid|audio|vlm
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0               # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = True
    rope_theta: float = 1e4
    attn_pattern: str = "full"      # full | local_global | none
    window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    norm: str = "rmsnorm"
    activation: str = "silu"
    # MoE
    num_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    moe_bf16_combine: bool = False
    # SSM / RWKV / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    rwkv: bool = False
    attn_every: int = 0             # zamba2: shared attn block cadence
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_frames: int = 1500
    # VLM
    cross_attn_every: int = 0
    image_tokens: int = 1024
    # numerics / impl knobs (hillclimb levers)
    dtype: str = "bfloat16"
    remat: str = "block"            # none | block
    attn_impl: str = "dense"        # dense | blockwise
    kv_block: int = 1024
    q_block: int = 0          # 0 = no q-chunking
    attn_tp_expand: bool = False   # Megatron GQA TP (expand kv heads)
    attn_bf16_score_grad: bool = False  # bf16 softmax-bwd boundary (P9)
    rwkv_impl: str = "chunked"
    ssm_chunk: int = 64
    scan_layers: bool = True
    collect_dispatch: bool = False  # emit MoE dispatch ids for profiling

    # -- derived -----------------------------------------------------------

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a 256 multiple so the embedding/logits shard
        over any TP degree (standard practice; labels never hit pads)."""
        return -(-self.vocab_size // 256) * 256

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM / linear-attn / windowed hybrid)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs are decoders or enc-dec

    def param_count(self) -> float:
        """Analytic parameter count (embedding included)."""
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        if self.rwkv:
            per = (4 * d * d + d * self.d_ff * 2 + d * d  # tm + cm
                   + d * 64 * 2 + d * 5 * 32 * 2)
            return L * per + emb
        if self.family in ("ssm", "hybrid") and not self.rwkv:
            d_inner = 2 * d
            per = d * (2 * d_inner + 2 * self.ssm_state
                       + d_inner // self.ssm_head_dim) + d_inner * d
            total = L * per
            if self.attn_every:
                q = self.num_heads * hd
                kv = self.num_kv_heads * hd
                shared = d * (q + 2 * kv) + q * d + 3 * d * self.d_ff
                total += shared  # shared block counted once
            return total + emb
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * (q + 2 * kv) + q * d
        if self.is_moe:
            ffn = (3 * d * self.d_expert * self.num_experts
                   + d * self.num_experts
                   + 3 * d * self.d_expert * self.num_shared_experts)
        else:
            ffn = 3 * d * self.d_ff
        total = L * (attn + ffn)
        if self.encoder_layers:
            total += self.encoder_layers * (attn + ffn)
            total += L * (attn)  # decoder cross-attn
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            total += n_cross * attn
        return total + emb

    def active_param_count(self) -> float:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, L = self.d_model, self.num_layers
        hd = self.resolved_head_dim
        q = self.num_heads * hd
        kv = self.num_kv_heads * hd
        attn = d * (q + 2 * kv) + q * d
        ffn = (3 * d * self.d_expert * (self.top_k + self.num_shared_experts)
               + d * self.num_experts)
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + ffn) + emb

    def reduced(self) -> "ModelConfig":
        """Tiny same-topology variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            num_layers=max(2, min(4, self.num_layers)),
            d_model=128,
            num_heads=4 if self.num_heads else 0,
            num_kv_heads=min(4, self.num_kv_heads) if self.num_kv_heads else 0,
            head_dim=32 if self.num_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512 if self.vocab_size else 0,
            num_experts=min(8, self.num_experts),
            moe_capacity_factor=8.0,
            top_k=min(2, self.top_k),
            d_expert=64 if self.d_expert else 0,
            num_shared_experts=min(1, self.num_shared_experts),
            ssm_state=min(16, self.ssm_state),
            ssm_head_dim=16 if self.ssm_state else 64,
            attn_every=min(2, self.attn_every),
            encoder_layers=min(2, self.encoder_layers),
            encoder_frames=64 if self.encoder_layers else 1500,
            cross_attn_every=min(2, self.cross_attn_every),
            image_tokens=16 if self.cross_attn_every else 1024,
            window=64,
            ssm_chunk=16,
            kv_block=64,
            dtype="float32",
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig
                     ) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) per the assignment's skip rules."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic"
    return True, ""
