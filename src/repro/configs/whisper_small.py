"""Whisper-small (arXiv:2212.04356): enc-dec; conv frontend stubbed
(input_specs supplies precomputed frame embeddings)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, encoder_layers=12, encoder_frames=1500,
    d_model=768, num_heads=12, num_kv_heads=12, head_dim=64,
    d_ff=3072, vocab_size=51865, tie_embeddings=True,
    norm="layernorm", activation="gelu", qkv_bias=True,
)
