"""Zamba2-1.2B (arXiv:2411.15242): Mamba2 backbone + shared attention block
every 6 layers (shared weights, per-invocation KV)."""
from repro.configs.base import ModelConfig

# The Mamba2 conv-state ring buffers dominate this config's scan as
# stride-aligned dynamic-update-slice writes, but each slot has exactly
# one producer per step (overwrite, no read-modify-write), so the bank
# hazard is benign here.
# repro: noqa BANK001

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=32000, tie_embeddings=True,
    ssm_state=64, ssm_head_dim=64, attn_every=6, window=4096,
)
