"""RWKV-6 'Finch' 7B (arXiv:2404.05892) — attention-free linear RNN."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm", rwkv=True,
    num_layers=32, d_model=4096, d_ff=14336, vocab_size=65536,
    attn_pattern="none", tie_embeddings=False,
)
