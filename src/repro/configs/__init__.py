"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``."""

from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    SHAPES,
    ModelConfig,
    ShapeConfig,
    shape_applicable,
)

ARCHS = {
    "rwkv6-7b": "rwkv6_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "command-r-plus-104b": "command_r_plus_104b",
    "gemma2-27b": "gemma2_27b",
    "qwen1.5-110b": "qwen15_110b",
    "qwen2-72b": "qwen2_72b",
    "whisper-small": "whisper_small",
    "zamba2-1.2b": "zamba2_1p2b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
}


def get_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.CONFIG
