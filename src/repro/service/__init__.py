"""Profiling-as-a-service: the paper's tool behind a localhost daemon.

``python -m repro serve`` turns the ``Session`` API into a long-running
HTTP service: ``WorkloadSpec`` JSON jobs (profile / sweep / advise /
validate) are queued onto a bounded worker pool that shares one
cross-request memo and persistent ``SweepCache`` per device — a hot spec
costs zero collection no matter which client asks.  Every provider call
runs through ``repro.analysis.resilience`` (deadlines, retries,
breakers, degraded fallbacks), so the daemon sheds load with 429s and
degrades with marked responses instead of hanging or five-hundreding.

    repro serve --port 8642 --workers 4
    repro client --port 8642 submit --kind profile \
        --workload indices --size 2^14 --dist solid

Python surface::

    from repro.service import ProfilingService, ServiceConfig, serve
    svc = ProfilingService(ServiceConfig(workers=4))
    svc.start()
    response = svc.submit({"kind": "profile",
                           "workload": {"workload": "indices"}})
"""

from repro.service.client import ServiceClient, ServiceError  # noqa: F401
from repro.service.jobs import (  # noqa: F401
    JOB_KINDS,
    Job,
    JobError,
    parse_job,
)
from repro.service.server import (  # noqa: F401
    ProfilingService,
    ServiceConfig,
    ServiceOverloaded,
    serve,
)
