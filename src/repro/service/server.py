"""The profiling daemon: bounded workers, shared caches, never a 500.

``ProfilingService`` owns one ``ResilientProvider`` stack (optionally
fault-wrapped for chaos runs) and one lazily-built ``Session`` per
device, all sharing the session memo and the persistent ``SweepCache`` —
so a spec profiled once is a zero-collection hit for every later job,
whichever client or kind asks.  Jobs flow::

    HTTP POST /v1/jobs -> parse_job (400 on malformed payloads)
                       -> bounded queue (429 + Retry-After when full)
                       -> worker thread under resilience_scope(timeout)
                       -> 200 {ok, result, degraded, fallback_providers}

The response contract is the whole point: a request is answered with its
result, an *explicitly degraded* result naming the fallback provider
that produced it, or a typed JSON error (400 / 429 / 503 / 504) — never
a bare 500 and never a hang, because every provider call underneath runs
through deadlines, per-call timeouts, retries, and circuit breakers.

``serve(config)`` is the blocking CLI entry point (``repro serve``);
``ProfilingService`` alone (``start``/``handle``/``stop``) is the
embeddable form the tests and benchmarks drive in-process.
"""

from __future__ import annotations

import dataclasses
import json
import queue
import re
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from repro.analysis.providers import FaultInjectionProvider, get_provider
from repro.analysis.resilience import (
    DeadlineExceeded,
    ResilienceExhausted,
    ResilientProvider,
    RetryPolicy,
    resilience_scope,
)
from repro.analysis.session import Session
from repro.analysis.sweep_cache import SweepCache
from repro.obs import telemetry as _telemetry
from repro.service.jobs import (JOB_KINDS, Job, JobError, describe_defaults,
                                parse_job)

_QUEUE_DEPTH = _telemetry.gauge(
    "repro_service_queue_depth", "Jobs waiting in the bounded queue")
_JOBS_TOTAL = _telemetry.counter(
    "repro_service_jobs_total", "Service jobs by kind and outcome",
    ("kind", "outcome"))
_JOB_SECONDS = _telemetry.histogram(
    "repro_service_job_seconds", "Job wall-clock by kind and outcome",
    ("kind", "outcome"))
_BREAKER_OPEN = _telemetry.gauge(
    "repro_circuit_breaker_open",
    "1 while the named provider's circuit breaker is open", ("provider",))

_TRACE_ID_RE = re.compile(r"[^A-Za-z0-9_-]")


class ServiceOverloaded(RuntimeError):
    """Queue full — shed the request (HTTP 429 + Retry-After)."""

    def __init__(self, message: str, retry_after_s: float = 1.0) -> None:
        super().__init__(message)
        self.retry_after_s = retry_after_s


@dataclasses.dataclass
class ServiceConfig:
    """Everything ``repro serve`` exposes as flags, as one record."""

    host: str = "127.0.0.1"
    port: int = 0                       # 0 = ephemeral (printed on start)
    workers: int = 4
    queue_depth: int = 32
    device: str = "v5e"
    provider: str = "trace"
    fallbacks: tuple = ("trace",)
    timeout_s: float = 30.0             # default + cap basis for job deadlines
    max_timeout_s: float = 300.0
    max_points: int = 4096              # sweep-size cap per job
    call_timeout_s: Optional[float] = 10.0
    retries: int = 2
    backoff_base_s: float = 0.05
    breaker_threshold: int = 5
    breaker_cooldown_s: float = 5.0
    persistent_cache: bool = True
    # chaos knobs (all off by default; the CI smoke test turns them on)
    fault_rate: float = 0.0
    latency_rate: float = 0.0
    latency_s: float = 0.05
    corrupt_rate: float = 0.0
    fault_seed: int = 0

    def __post_init__(self) -> None:
        if self.workers < 1:
            raise ValueError(f"workers must be >= 1, got {self.workers}")
        if self.queue_depth < 1:
            raise ValueError(
                f"queue_depth must be >= 1, got {self.queue_depth}")
        if self.timeout_s <= 0 or self.max_timeout_s < self.timeout_s:
            raise ValueError(
                f"need 0 < timeout_s <= max_timeout_s, got "
                f"{self.timeout_s} / {self.max_timeout_s}")
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")


class _Ticket:
    """One queued job + the event its submitter blocks on."""

    __slots__ = ("job", "done", "status", "body", "trace_id")

    def __init__(self, job: Job, trace_id: Optional[str] = None) -> None:
        self.job = job
        self.done = threading.Event()
        self.status: int = 503
        self.body: dict = {"ok": False, "error": "job was never run"}
        self.trace_id = trace_id or _telemetry.new_trace_id()


class ProfilingService:
    """The daemon behind ``repro serve`` (see module docstring)."""

    def __init__(self, config: Optional[ServiceConfig] = None) -> None:
        self.config = config or ServiceConfig()
        cfg = self.config
        base = get_provider(cfg.provider)
        self.fault: Optional[FaultInjectionProvider] = None
        primary = base
        if cfg.fault_rate or cfg.latency_rate or cfg.corrupt_rate:
            self.fault = FaultInjectionProvider(
                base, fault_rate=cfg.fault_rate,
                latency_rate=cfg.latency_rate, latency_s=cfg.latency_s,
                corrupt_rate=cfg.corrupt_rate, seed=cfg.fault_seed)
            primary = self.fault
        self.cache: Optional[SweepCache] = \
            SweepCache() if cfg.persistent_cache else None
        self.provider = ResilientProvider(
            primary,
            fallbacks=cfg.fallbacks,
            stale_cache=self.cache,
            retry=RetryPolicy(retries=cfg.retries,
                              backoff_base_s=cfg.backoff_base_s),
            call_timeout_s=cfg.call_timeout_s,
            breaker_threshold=cfg.breaker_threshold,
            breaker_cooldown_s=cfg.breaker_cooldown_s,
        )
        self._sessions: dict[str, Session] = {}
        self._sessions_lock = threading.Lock()
        self._advise_lock = threading.Lock()
        self._queue: queue.Queue = queue.Queue(maxsize=cfg.queue_depth)
        self._threads: list[threading.Thread] = []
        self._started = False
        self._started_at = time.monotonic()
        self._counters_lock = threading.Lock()
        self.counters = {"submitted": 0, "completed": 0, "degraded": 0,
                         "failed": 0, "shed": 0, "invalid": 0}

    # -- lifecycle --------------------------------------------------------

    def start(self) -> "ProfilingService":
        if self._started:
            return self
        self._started = True
        self._started_at = time.monotonic()
        for i in range(self.config.workers):
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"repro-service-worker-{i}")
            t.start()
            self._threads.append(t)
        return self

    def stop(self, timeout_s: float = 5.0) -> None:
        """Drain the pool: one sentinel per worker, then join."""
        if not self._started:
            return
        for _ in self._threads:
            self._queue.put(None)
        for t in self._threads:
            t.join(timeout_s)
        self._threads.clear()
        self._started = False

    def __enter__(self) -> "ProfilingService":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- the request path -------------------------------------------------

    @staticmethod
    def _kind_label(payload) -> str:
        """A *bounded* kind label for metrics (never raw client input)."""
        if isinstance(payload, dict) and payload.get("kind") in JOB_KINDS:
            return payload["kind"]
        return "unknown"

    def handle(self, payload,
               trace_id: Optional[str] = None) -> tuple[int, dict]:
        """(http_status, json_body) for one job payload — never raises.

        The single entry point both the HTTP handler and in-process
        callers use, so the never-500 contract is enforced in exactly
        one place.
        """
        try:
            return 200, self.submit(payload, trace_id=trace_id)
        except JobError as exc:
            self._count("invalid")
            _JOBS_TOTAL.inc(kind=self._kind_label(payload),
                            outcome="invalid")
            return 400, {"ok": False, "error": str(exc),
                         "error_kind": "invalid-job"}
        except ServiceOverloaded as exc:
            self._count("shed")
            _JOBS_TOTAL.inc(kind=self._kind_label(payload), outcome="shed")
            return 429, {"ok": False, "error": str(exc),
                         "error_kind": "overloaded",
                         "retry_after_s": exc.retry_after_s}
        except DeadlineExceeded as exc:
            self._count("failed")
            return 504, {"ok": False, "error": str(exc),
                         "error_kind": "deadline"}
        except ResilienceExhausted as exc:
            self._count("failed")
            return 503, {"ok": False, "error": str(exc),
                         "error_kind": "exhausted"}
        except Exception as exc:  # noqa: BLE001 — the never-500 contract
            self._count("failed")
            return 503, {"ok": False,
                         "error": f"{type(exc).__name__}: {exc}",
                         "error_kind": "internal"}

    def submit(self, payload, trace_id: Optional[str] = None) -> dict:
        """Parse, enqueue, and wait out one job; the success-path body.

        Raises ``JobError`` (malformed), ``ServiceOverloaded`` (queue
        full), ``DeadlineExceeded``/``ResilienceExhausted`` (the job ran
        and failed) — ``handle`` maps these to HTTP statuses.
        """
        if not self._started:
            raise RuntimeError("service not started — call start() first")
        cfg = self.config
        job = parse_job(payload, default_timeout_s=cfg.timeout_s,
                        max_timeout_s=cfg.max_timeout_s,
                        max_points=cfg.max_points)
        ticket = _Ticket(job, trace_id)
        try:
            self._queue.put_nowait(ticket)
        except queue.Full:
            raise ServiceOverloaded(
                f"queue full ({cfg.queue_depth} jobs pending) — retry "
                f"shortly", retry_after_s=min(job.timeout_s, 1.0)) from None
        self._count("submitted")
        _QUEUE_DEPTH.set(self._queue.qsize())
        # the worker enforces the deadline; the extra grace only covers
        # queue wait + scheduling, so a hung worker can never hang a client
        grace = job.timeout_s + cfg.timeout_s + 5.0
        if not ticket.done.wait(grace):
            raise DeadlineExceeded(
                f"job {job.label!r} did not complete within {grace:.1f}s "
                f"(queue wait + deadline grace)")
        if ticket.status != 200:
            exc_kind = ticket.body.get("error_kind")
            message = ticket.body.get("error", "job failed")
            if exc_kind == "deadline":
                raise DeadlineExceeded(message)
            raise ResilienceExhausted(message)
        return ticket.body

    # -- workers ----------------------------------------------------------

    def _worker_loop(self) -> None:
        while True:
            ticket = self._queue.get()
            if ticket is None:
                return
            _QUEUE_DEPTH.set(self._queue.qsize())
            try:
                with _telemetry.trace_scope(ticket.trace_id) as trace:
                    ticket.status, ticket.body = self._run_job(ticket.job)
                    ticket.body["trace_id"] = trace["id"]
                    if ticket.status == 200:
                        ticket.body["spans"] = trace["spans"]
            except Exception as exc:  # noqa: BLE001 — belt and braces
                ticket.status = 503
                ticket.body = {"ok": False,
                               "error": f"{type(exc).__name__}: {exc}",
                               "error_kind": "internal",
                               "trace_id": ticket.trace_id}
            finally:
                ticket.done.set()

    def _observe_job(self, job: Job, outcome: str, started: float) -> None:
        _JOBS_TOTAL.inc(kind=job.kind, outcome=outcome)
        _JOB_SECONDS.observe(time.monotonic() - started,
                             kind=job.kind, outcome=outcome)

    def _run_job(self, job: Job) -> tuple[int, dict]:
        started = time.monotonic()
        sess = self.session(job.device)
        try:
            with resilience_scope(job.timeout_s) as events:
                with _telemetry.span("service.dispatch", kind=job.kind,
                                     label=job.label):
                    result = self._dispatch(sess, job)
        except DeadlineExceeded as exc:
            # failure counters are handle()'s job (one count per request)
            self._observe_job(job, "deadline", started)
            return 504, {"ok": False, "error": str(exc),
                         "error_kind": "deadline"}
        except (ResilienceExhausted, JobError, ValueError, OSError) as exc:
            self._observe_job(job, "failed", started)
            return 503, {"ok": False,
                         "error": f"{type(exc).__name__}: {exc}",
                         "error_kind": "exhausted"}
        fallbacks = sorted({e["fallback"] for e in events
                            if e.get("kind") == "fallback"})
        degraded = bool(fallbacks)
        self._count("completed")
        if degraded:
            self._count("degraded")
        self._observe_job(job, "degraded" if degraded else "ok", started)
        return 200, {
            "ok": True,
            "kind": job.kind,
            "device": job.device,
            "degraded": degraded,
            "fallback_providers": fallbacks,
            "elapsed_s": round(time.monotonic() - started, 4),
            "result": result,
        }

    def _dispatch(self, sess: Session, job: Job) -> dict:
        """Run one parsed job through the session API; JSON-ready result."""
        if job.kind in ("profile", "sweep"):
            result = sess.analyze(job.specs,
                                  parallel=job.options.get("parallel"))
            return json.loads(result.render("json"))
        if job.kind == "advise":
            # the advisor mutates search state across many collect calls;
            # one at a time keeps its frontier bookkeeping single-threaded
            # (collection itself still shares the session memo + cache)
            with self._advise_lock:
                report = sess.advise(job.specs[0], **job.options)
            return json.loads(report.render("json"))
        if job.kind == "validate":
            report = sess.validate(job.specs[0],
                                   providers=job.options["providers"])
            return report.to_dict()
        if job.kind == "heatmap":
            kw = {k: job.options[k] for k in ("hot_degree",)
                  if k in job.options}
            hm = sess.heatmap(job.specs[0], **kw)
            return json.loads(hm.render(
                "json", top_k=job.options.get("top_k", 16)))
        raise JobError(f"unknown job kind {job.kind!r}")

    # -- shared state -----------------------------------------------------

    def session(self, device: str) -> Session:
        with self._sessions_lock:
            sess = self._sessions.get(device)
            if sess is None:
                sess = Session(
                    device, provider=self.provider,
                    persistent_cache=self.cache
                    if self.cache is not None else False)
                self._sessions[device] = sess
            return sess

    def _count(self, key: str) -> None:
        with self._counters_lock:
            self.counters[key] += 1

    def status(self) -> dict:
        with self._counters_lock:
            counters = dict(self.counters)
        body = {
            "ok": True,
            "uptime_s": round(time.monotonic() - self._started_at, 3),
            "workers": self.config.workers,
            "queue_depth": self.config.queue_depth,
            "queued": self._queue.qsize(),
            "provider": self.provider.name,
            "fallbacks": [p.name for p in self.provider.fallbacks],
            "counters": counters,
            "breakers": self.provider.breaker_states(),
            "sessions": {name: sess.stats_snapshot()
                         for name, sess in self._sessions.items()},
        }
        if self.cache is not None:
            body["cache_root"] = str(self.cache.root)
            body["cache"] = self.cache.stats()
        if self.fault is not None:
            body["fault_injection"] = self.fault.stats_snapshot()
        return body

    def refresh_metrics(self) -> None:
        """Push point-in-time gauges (queue, breakers) into the registry."""
        _QUEUE_DEPTH.set(self._queue.qsize())
        for name, snap in self.provider.breaker_states().items():
            _BREAKER_OPEN.set(1.0 if snap.get("state") == "open" else 0.0,
                              provider=name)

    def metrics_text(self) -> str:
        """The ``GET /metrics`` payload (Prometheus text exposition)."""
        self.refresh_metrics()
        return _telemetry.render()


# -- HTTP layer --------------------------------------------------------------


class _Handler(BaseHTTPRequestHandler):
    """Thin JSON shim over ``ProfilingService.handle``/``status``."""

    service: ProfilingService      # set by make_http_server
    protocol_version = "HTTP/1.1"

    def log_message(self, *args) -> None:   # quiet by default
        pass

    def _reply(self, status: int, body: dict,
               trace_id: Optional[str] = None) -> None:
        data = json.dumps(body).encode()
        self.send_response(status)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        if trace_id:
            self.send_header("X-Repro-Trace-Id", trace_id)
        if status == 429:
            self.send_header(
                "Retry-After",
                str(max(1, round(body.get("retry_after_s", 1.0)))))
        self.end_headers()
        self.wfile.write(data)

    def _reply_text(self, status: int, text: str,
                    content_type: str) -> None:
        data = text.encode()
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _request_trace_id(self) -> str:
        """Propagate the client's ``X-Repro-Trace-Id`` or mint one.

        The inbound value is sanitized and bounded so a hostile header
        can't smuggle bytes into responses or metrics.
        """
        raw = self.headers.get("X-Repro-Trace-Id", "")
        cleaned = _TRACE_ID_RE.sub("", raw)[:64]
        return cleaned or _telemetry.new_trace_id()

    def do_GET(self) -> None:               # noqa: N802 — http.server API
        if self.path == "/healthz":
            self._reply(200, {"ok": True})
        elif self.path == "/status":
            self._reply(200, self.service.status())
        elif self.path == "/metrics":
            self._reply_text(200, self.service.metrics_text(),
                             "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/schema":
            self._reply(200, {"ok": True, "kinds": list(JOB_KINDS),
                              "workload_defaults": describe_defaults()})
        else:
            self._reply(404, {"ok": False,
                              "error": f"no such endpoint {self.path!r}",
                              "error_kind": "not-found"})

    def do_POST(self) -> None:              # noqa: N802 — http.server API
        trace_id = self._request_trace_id()
        if self.path != "/v1/jobs":
            self._reply(404, {"ok": False,
                              "error": f"no such endpoint {self.path!r}",
                              "error_kind": "not-found"}, trace_id)
            return
        try:
            length = int(self.headers.get("Content-Length", 0))
            payload = json.loads(self.rfile.read(length) or b"null")
        except (ValueError, OSError) as exc:
            self._reply(400, {"ok": False,
                              "error": f"unreadable JSON body: {exc}",
                              "error_kind": "invalid-job",
                              "trace_id": trace_id}, trace_id)
            return
        status, body = self.service.handle(payload, trace_id=trace_id)
        body.setdefault("trace_id", trace_id)
        self._reply(status, body, body.get("trace_id", trace_id))


def make_http_server(service: ProfilingService,
                     host: Optional[str] = None,
                     port: Optional[int] = None) -> ThreadingHTTPServer:
    """Bind (but don't run) the HTTP front end; ``.server_address`` has
    the resolved ephemeral port when ``port=0``."""
    handler = type("BoundHandler", (_Handler,), {"service": service})
    server = ThreadingHTTPServer(
        (service.config.host if host is None else host,
         service.config.port if port is None else port), handler)
    server.daemon_threads = True
    return server


def serve(config: Optional[ServiceConfig] = None, *,
          port_file: Optional[str] = None,
          ready: Optional[threading.Event] = None) -> None:
    """Run the daemon until interrupted (the ``repro serve`` body).

    Prints one ``repro-serve: listening on http://host:port`` line (and
    optionally writes the bound port to ``port_file``) so scripts — and
    the CI smoke test — can target an ephemeral port.
    """
    service = ProfilingService(config).start()
    server = make_http_server(service)
    host, port = server.server_address[:2]
    if port_file:
        with open(port_file, "w") as fh:
            fh.write(str(port))
    print(f"repro-serve: listening on http://{host}:{port} "
          f"(workers={service.config.workers}, "
          f"queue={service.config.queue_depth}, "
          f"provider={service.provider.name})", flush=True)
    if ready is not None:
        ready.set()
    try:
        server.serve_forever(poll_interval=0.2)
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        service.stop()
