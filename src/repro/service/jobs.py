"""Job schema: JSON payloads -> validated ``WorkloadSpec`` jobs.

The service accepts the same declarative workload description the CLI
does (family + content knobs + launch-geometry axes), as JSON::

    {"kind": "sweep",
     "device": "v5e",
     "timeout_s": 20,
     "workload": {"workload": "indices", "size": 16384, "dist": "solid",
                  "waves_per_tile": [4, 8, 32]}}

Parsing is strict and *up front* — unknown keys, wrong types, empty
grids, and over-budget sweeps all raise ``JobError`` (HTTP 400) before
any session or device work starts, mirroring the CLI's argparse
rejection matrix.  Spec construction delegates to
``repro.cli.workloads.build_specs``, so a service job is bit-identical
to the same CLI invocation.
"""

from __future__ import annotations

import argparse
import dataclasses
import math

from repro.analysis.workload import WorkloadSpec

JOB_KINDS = ("profile", "sweep", "advise", "validate", "heatmap")

# one declarative workload surface, shared with the CLI: every key the
# ``repro.cli.workloads.build_specs`` namespace reads, with its default
WORKLOAD_DEFAULTS: dict = {
    "workload": "indices",
    "size": None,
    "pixels": None,
    "dist": "uniform",
    "variant": "hist",
    "num_bins": 256,
    "num_segments": 256,
    "seed": 0,
    "hlo_file": None,
    "num_devices": 1,
    "label": None,
    "waves_per_tile": None,
    "pipeline_depth": None,
    "num_cores": 8,
    "bytes_read": None,
    "flops": None,
    "overhead_cycles": 500.0,
}

class JobError(ValueError):
    """A malformed job payload (maps to HTTP 400)."""


@dataclasses.dataclass
class Job:
    """One validated unit of service work."""

    kind: str
    device: str
    specs: list[WorkloadSpec]
    timeout_s: float
    options: dict                  # kind-specific knobs (advise/validate)
    workload: dict                 # the raw (defaulted) workload payload

    @property
    def label(self) -> str:
        return self.specs[0].label if self.specs else "<empty>"


def _require(cond: bool, message: str) -> None:
    if not cond:
        raise JobError(message)


def _check_number(name: str, value, *, minimum=None,
                  integral: bool = False) -> None:
    _require(isinstance(value, (int, float))
             and not isinstance(value, bool)
             and math.isfinite(value),
             f"{name} must be a finite number, got {value!r}")
    if integral:
        _require(float(value) == int(value),
                 f"{name} must be an integer, got {value!r}")
    if minimum is not None:
        _require(value >= minimum,
                 f"{name} must be >= {minimum}, got {value!r}")


def _workload_namespace(workload: dict) -> argparse.Namespace:
    """The defaulted, type-checked namespace ``build_specs`` consumes."""
    _require(isinstance(workload, dict),
             f"'workload' must be an object, got {type(workload).__name__}")
    unknown = sorted(set(workload) - set(WORKLOAD_DEFAULTS))
    _require(not unknown,
             f"unknown workload key(s): {', '.join(unknown)} "
             f"(known: {', '.join(sorted(WORKLOAD_DEFAULTS))})")
    merged = {**WORKLOAD_DEFAULTS, **workload}
    for name in ("size", "pixels", "waves_per_tile", "pipeline_depth"):
        value = merged[name]
        if value is None:
            continue
        values = value if isinstance(value, list) else [value]
        _require(len(values) > 0, f"{name} must not be an empty list")
        for v in values:
            _check_number(name, v, minimum=1, integral=True)
        merged[name] = [int(v) for v in values] \
            if isinstance(value, list) else int(value)
    for name, minimum in (("num_bins", 1), ("num_segments", 1),
                          ("num_devices", 1), ("num_cores", 1),
                          ("seed", 0), ("overhead_cycles", 0.0)):
        _check_number(name, merged[name], minimum=minimum,
                      integral=name != "overhead_cycles")
    for name in ("bytes_read", "flops"):
        if merged[name] is not None:
            _check_number(name, merged[name], minimum=0.0)
    _require(merged["workload"] in ("indices", "histogram", "scatter",
                                    "hlo"),
             f"unknown workload family {merged['workload']!r}")
    _require(merged["dist"] in ("solid", "uniform"),
             f"unknown dist {merged['dist']!r}")
    _require(merged["variant"] in ("hist", "hist2"),
             f"unknown variant {merged['variant']!r}")
    return argparse.Namespace(**merged)


def build_workload_specs(workload: dict,
                         max_points: int = 4096) -> list[WorkloadSpec]:
    """Expand one workload payload to its full spec list (grid included)."""
    from repro.cli import workloads as wl  # lazy: keeps import cheap
    ns = _workload_namespace(workload)
    # cheap combinatorics check before any content is synthesized
    n_points = 1
    for name in ("size", "pixels", "waves_per_tile", "pipeline_depth"):
        value = getattr(ns, name)
        if isinstance(value, list):
            n_points *= len(value)
    _require(n_points <= max_points,
             f"workload grid expands to {n_points} points, over the "
             f"service cap of {max_points}")
    try:
        specs, axes = wl.build_specs(ns)
        specs = wl.expand_grid(specs, axes)
    except JobError:
        raise
    except (ValueError, OSError) as exc:
        raise JobError(f"invalid workload: {exc}") from exc
    _require(len(specs) >= 1, "workload expanded to zero points")
    return specs


def parse_job(payload, *, default_timeout_s: float = 30.0,
              max_timeout_s: float = 300.0,
              max_points: int = 4096) -> Job:
    """Validate one JSON job payload into a ``Job`` (or raise JobError)."""
    _require(isinstance(payload, dict),
             f"job payload must be a JSON object, got "
             f"{type(payload).__name__}")
    known = {"kind", "device", "workload", "timeout_s", "options"}
    unknown = sorted(set(payload) - known)
    _require(not unknown,
             f"unknown job key(s): {', '.join(unknown)} "
             f"(known: {', '.join(sorted(known))})")
    kind = payload.get("kind")
    _require(kind in JOB_KINDS,
             f"kind must be one of {', '.join(JOB_KINDS)}, got {kind!r}")
    device = payload.get("device", "v5e")
    _require(isinstance(device, str) and device,
             f"device must be a non-empty string, got {device!r}")
    timeout_s = payload.get("timeout_s", default_timeout_s)
    _check_number("timeout_s", timeout_s, minimum=0.001)
    _require(timeout_s <= max_timeout_s,
             f"timeout_s must be <= {max_timeout_s}, got {timeout_s}")
    options = payload.get("options", {})
    _require(isinstance(options, dict), "options must be an object")
    _require("workload" in payload, "job payload needs a 'workload' object")

    specs = build_workload_specs(payload["workload"],
                                 max_points=max_points)
    if kind in ("profile", "advise", "validate", "heatmap"):
        _require(len(specs) == 1,
                 f"{kind} takes exactly one workload point, got "
                 f"{len(specs)} — use kind 'sweep' for multi-value axes")
    options = _check_options(kind, options)
    return Job(kind=kind, device=device, specs=specs,
               timeout_s=float(timeout_s), options=options,
               workload=payload["workload"])


_OPTION_SCHEMA = {
    # kind -> option name -> (minimum, integral)
    "advise": {"depth": (1, True), "beam_width": (1, True),
               "top_k": (1, True), "validate_top": (0, True)},
    "sweep": {"parallel": (1, True)},
    "profile": {},
    "validate": {},   # 'providers' handled separately
    "heatmap": {"top_k": (1, True)},  # 'hot_degree' handled separately
}
_ADVISE_DEFAULTS = {"depth": 2, "beam_width": 8, "top_k": 5,
                    "validate_top": 0}


def _check_options(kind: str, options: dict) -> dict:
    schema = _OPTION_SCHEMA[kind]
    extra_keys = {"providers"} if kind == "validate" else set()
    if kind == "heatmap":
        extra_keys = {"hot_degree"}
    unknown = sorted(set(options) - set(schema) - extra_keys)
    _require(not unknown,
             f"unknown option(s) for kind {kind!r}: {', '.join(unknown)}")
    out = dict(_ADVISE_DEFAULTS) if kind == "advise" else {}
    for name, (minimum, integral) in schema.items():
        if name in options:
            _check_number(name, options[name], minimum=minimum,
                          integral=integral)
            out[name] = int(options[name]) if integral else options[name]
    if kind == "heatmap" and "hot_degree" in options:
        _check_number("hot_degree", options["hot_degree"], minimum=1.0)
        out["hot_degree"] = float(options["hot_degree"])
    if kind == "validate":
        providers = options.get("providers", ["trace", "kernel"])
        _require(isinstance(providers, list) and len(providers) >= 2
                 and all(isinstance(p, str) for p in providers),
                 "validate providers must be a list of >= 2 provider "
                 "names")
        out["providers"] = providers
    return out


def describe_defaults() -> dict:
    """The defaulted workload schema (the ``/schema`` endpoint payload)."""
    return dict(WORKLOAD_DEFAULTS)
