"""Minimal stdlib HTTP client for the profiling service.

``ServiceClient`` speaks the daemon's JSON contract over
``urllib.request`` (no third-party dependency): ``health``/``status``
GETs plus ``submit`` for jobs, with optional bounded retry on 429 that
honors the server's ``Retry-After``.  Every non-2xx response surfaces as
``ServiceError`` carrying the HTTP status and the decoded error body, so
callers (the ``repro client`` CLI, tests, the load benchmark) branch on
``exc.status`` instead of parsing strings.
"""

from __future__ import annotations

import json
import time
import urllib.error
import urllib.request
from typing import Optional


class ServiceError(RuntimeError):
    """A non-2xx service response (or no response at all)."""

    def __init__(self, message: str, status: Optional[int] = None,
                 body: Optional[dict] = None) -> None:
        super().__init__(message)
        self.status = status
        self.body = body or {}


class ServiceClient:
    """One service endpoint (host, port) as a Python object."""

    def __init__(self, host: str = "127.0.0.1", port: int = 8642, *,
                 timeout_s: float = 60.0,
                 sleep=time.sleep) -> None:
        if not 1 <= port <= 65535:
            raise ValueError(f"port must be in [1, 65535], got {port}")
        if timeout_s <= 0:
            raise ValueError(f"timeout_s must be > 0, got {timeout_s}")
        self.base_url = f"http://{host}:{port}"
        self.timeout_s = timeout_s
        self._sleep = sleep

    # -- transport --------------------------------------------------------

    def _request(self, path: str, payload: Optional[dict] = None) -> dict:
        url = self.base_url + path
        data = None
        headers = {"Accept": "application/json"}
        if payload is not None:
            data = json.dumps(payload).encode()
            headers["Content-Type"] = "application/json"
        req = urllib.request.Request(url, data=data, headers=headers)
        try:
            with urllib.request.urlopen(req, timeout=self.timeout_s) as r:
                return json.loads(r.read() or b"{}")
        except urllib.error.HTTPError as exc:
            try:
                body = json.loads(exc.read() or b"{}")
            except ValueError:
                body = {}
            retry_after = exc.headers.get("Retry-After")
            if retry_after is not None:
                body.setdefault("retry_after_s", float(retry_after))
            raise ServiceError(
                body.get("error", f"HTTP {exc.code} from {url}"),
                status=exc.code, body=body) from None
        except (urllib.error.URLError, ConnectionError, OSError) as exc:
            raise ServiceError(
                f"service unreachable at {url}: {exc}") from None

    # -- endpoints --------------------------------------------------------

    def health(self) -> dict:
        return self._request("/healthz")

    def status(self) -> dict:
        return self._request("/status")

    def schema(self) -> dict:
        return self._request("/schema")

    def submit(self, payload: dict, *, retries_on_busy: int = 0) -> dict:
        """POST one job; optionally retry 429s honoring Retry-After.

        Only overload (429) is retried — a 400 payload will not become
        valid and a 503/504 already exhausted the server's own retries.
        """
        if retries_on_busy < 0:
            raise ValueError(
                f"retries_on_busy must be >= 0, got {retries_on_busy}")
        for attempt in range(retries_on_busy + 1):
            try:
                return self._request("/v1/jobs", payload)
            except ServiceError as exc:
                if exc.status != 429 or attempt == retries_on_busy:
                    raise
                self._sleep(float(exc.body.get("retry_after_s", 1.0)))
        raise AssertionError("unreachable")
