"""serve subpackage."""
