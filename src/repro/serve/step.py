"""Serving steps: batched prefill and single-token decode.

``make_serve_step`` returns the jittable ``serve_step(params, cache,
tokens, pos)`` the decode_32k / long_500k dry-run cells lower: one new
token against a KV cache of ``seq_len`` (spec: decode shapes lower
``serve_step``, not ``train_step``).  Caches are donated by the launcher;
greedy/temperature sampling is provided for the runnable examples.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    temperature: float = 0.0         # 0 = greedy
    max_len: int = 32768


def make_decode_step(model):
    def serve_step(params, cache, tokens, pos):
        """tokens (B,1) int32; pos () int32 -> (next_tokens (B,1), logits,
        new_cache)."""
        logits, new_cache = model.decode_step(params, tokens, cache, pos=pos)
        next_tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        return next_tok, logits, new_cache

    return serve_step


def make_prefill(model, scfg: ServeConfig):
    """Prefill = forward over the prompt + cache construction.

    The transformer caches are built by running decode-free forward and
    then bulk-writing K/V; for simplicity and dry-run fidelity we lower
    the forward (logits) together with the cache init — the compiled
    artifact contains both phases.
    """

    def prefill_step(params, tokens, extras: Optional[dict] = None):
        extras = extras or {}
        if model.cfg.family == "audio":
            logits, _ = model.forward(params, tokens, extras["frames"])
            cache = model.init_cache(params, tokens.shape[0], scfg.max_len,
                                     frames=extras["frames"])
        elif model.cfg.family == "vlm":
            logits, _ = model.forward(
                params, tokens, image_embeds=extras["image_embeds"])
            cache = model.init_cache(params, tokens.shape[0], scfg.max_len,
                                     image_embeds=extras["image_embeds"])
        else:
            logits, _ = model.forward(params, tokens)
            cache = model.init_cache(params, tokens.shape[0], scfg.max_len)
        return logits, cache

    return prefill_step


def generate(model, params, prompt: jnp.ndarray, steps: int,
             scfg: ServeConfig, extras: Optional[dict] = None,
             rng=None) -> jnp.ndarray:
    """Greedy/temperature autoregressive generation (example driver)."""
    extras = extras or {}
    b, t0 = prompt.shape
    if model.cfg.family == "audio":
        cache = model.init_cache(params, b, scfg.max_len,
                                 frames=extras["frames"])
    elif model.cfg.family == "vlm":
        cache = model.init_cache(params, b, scfg.max_len,
                                 image_embeds=extras["image_embeds"])
    else:
        cache = model.init_cache(params, b, scfg.max_len)
    # teacher-force the prompt token by token (robust across families)
    tok = prompt[:, :1]
    out = [tok]
    decode = jax.jit(lambda p, c, t, pos: model.decode_step(p, t, c, pos=pos))
    for i in range(t0 + steps - 1):
        logits, cache = decode(params, cache, tok, jnp.asarray(i, jnp.int32))
        if i + 1 < t0:
            tok = prompt[:, i + 1:i + 2]
        else:
            if scfg.temperature > 0:
                rng, sub = jax.random.split(rng)
                tok = jax.random.categorical(
                    sub, logits[:, -1] / scfg.temperature)[:, None]
                tok = tok.astype(jnp.int32)
            else:
                tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
