"""Jit'd wrapper + batched convenience for the flash-attention kernel."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as fk


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bkv",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bkv: int = 128, interpret: bool = True):
    """(H, T, d) or batched (B, H, T, d) flash attention."""
    if q.ndim == 4:
        return jax.vmap(lambda a, b, c: fk.flash_attention_pallas(
            a, b, c, causal=causal, bq=bq, bkv=bkv,
            interpret=interpret))(q, k, v)
    return fk.flash_attention_pallas(q, k, v, causal=causal, bq=bq,
                                     bkv=bkv, interpret=interpret)
