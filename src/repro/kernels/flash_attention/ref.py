"""Pure-jnp oracle for the flash-attention kernel."""

from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True) -> jnp.ndarray:
    """q/k/v: (H, T, d) — single example, multi-head.  f32 math."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        t = q.shape[1]
        mask = jnp.tril(jnp.ones((t, t), bool))
        s = jnp.where(mask[None], s, -2.0e38)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32)).astype(q.dtype)
