"""Pallas TPU flash attention: online-softmax over KV blocks, q-tiled grid.

The perf-critical substrate kernel for the LM side of the framework
(DESIGN §3): scores never touch HBM — each (q-block, kv-block) tile lives
in VMEM, sized to the MXU (block dims multiples of 128 at production
shapes).  Grid = (heads, q_blocks); the kv loop runs inside the kernel as
a fori_loop over VMEM-resident K/V blocks so m/l/acc carries stay in
registers/VMEM (contrast with the jnp blockwise path in models/attention,
whose carries round-trip HBM — the §Perf P3 lesson).

Layout: q (H, T, d) blocked (1, BQ, d); k/v (H, T, d) blocked (1, T, d) —
whole-K/V per head resident (fits VMEM for T <= ~8k at d=128; longer
sequences compose with the model-level sequence sharding).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bkv: int,
                  causal: bool, scale: float):
    qi = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, d)
    t_kv = k_ref.shape[1]
    nkv = t_kv // bkv

    def body(j, carry):
        m, l, acc = carry
        k = jax.lax.dynamic_slice_in_dim(k_ref[0], j * bkv, bkv, 0)
        v = jax.lax.dynamic_slice_in_dim(v_ref[0], j * bkv, bkv, 0)
        s = jax.lax.dot_general(q, k.astype(jnp.float32),
                                (((1,), (1,)), ((), ())))  # (BQ, BKV)
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 0)
            k_pos = j * bkv + jax.lax.broadcasted_iota(
                jnp.int32, (bq, bkv), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=1)
        acc_new = acc * corr[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())))
        return m_new, l_new, acc_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    a0 = jnp.zeros((bq, q_ref.shape[2]), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nkv, body, (m0, l0, a0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, bq: int = 128, bkv: int = 128,
    interpret: bool = True) -> jnp.ndarray:
    """q/k/v (H, T, d) -> (H, T, d).  T % bq == 0 and T % bkv == 0."""
    h, t, d = q.shape
    assert t % bq == 0 and t % bkv == 0
    scale = d ** -0.5
    kernel = functools.partial(_flash_kernel, bq=bq, bkv=bkv, causal=causal,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(h, t // bq),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((1, t, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((1, t, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, t, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
