"""Pure-jnp oracles for the scatter-add / segment-sum / bincount kernels."""

from __future__ import annotations

import jax.numpy as jnp


def scatter_add_ref(values: jnp.ndarray, ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """(N, D) values summed into (num_segments, D) by ids (N,)."""
    out = jnp.zeros((num_segments, values.shape[-1]), jnp.float32)
    return out.at[ids].add(values.astype(jnp.float32))


def bincount_ref(ids: jnp.ndarray, num_segments: int) -> jnp.ndarray:
    """(num_segments,) int32 occurrence counts."""
    return jnp.bincount(ids, length=num_segments).astype(jnp.int32)
