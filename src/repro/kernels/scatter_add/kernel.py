"""Pallas TPU scatter-add (segment-sum) and bincount kernels.

These are the production faces of the paper's hot spot inside the
framework: MoE token->expert dispatch counting (bincount), expert-output
combine and embedding-gradient accumulation (scatter-add).  The GPU
implementations of all three are shared-memory-atomic loops — the programs
the paper's model exists to diagnose.

TPU adaptation: scatter-add becomes a one-hot matmul on the MXU
(``onehot(ids).T @ values``), with the destination accumulator resident in
VMEM across grid steps (constant output index_map).  Duplicate ids within
a commit wave serialize in the VPU/MXU commit path; the instrumented
variants measure that serialization degree in-kernel.

Blocking: a 2-D grid (segment-block j outer, token tile i inner) so the
segment axis can exceed VMEM (embedding-gradient case: vocab up to 256k):
each (j, i) step accumulates tile i's contribution to segment rows
[j*SB, (j+1)*SB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import instrumentation as instr

DEFAULT_TILE = 2048
DEFAULT_SEG_BLOCK = 4096

# The one-hot matmul's update rows are deliberately commit-group aligned
# (D a multiple of 32 keeps the MXU contraction dense); the bank-stride
# hazard the lint models is accepted here.
# repro: noqa KERN002


def _scatter_kernel(ids_ref, val_ref, out_ref, *, seg_block: int):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                      # (TILE,)
    vals = val_ref[...]                     # (TILE, D)
    local = ids - j * seg_block
    t = ids.shape[0]
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t, seg_block), 1))
    out_ref[...] += jax.lax.dot_general(
        onehot.astype(vals.dtype), vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def _bincount_kernel(ids_ref, out_ref, *, num_segments: int):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]
    t = ids.shape[0]
    onehot = (ids[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t, num_segments), 1))
    out_ref[...] += onehot.astype(jnp.int32).sum(axis=0)[None, :]


def _scatter_instrumented_kernel(ids_ref, val_ref, out_ref, deg_ref, *,
                                 seg_block: int):
    j = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]
    vals = val_ref[...]
    local = ids - j * seg_block
    t = ids.shape[0]
    onehot = (local[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t, seg_block), 1))
    out_ref[...] += jax.lax.dot_general(
        onehot.astype(vals.dtype), vals,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == 0)  # degree depends only on the id stream; count once
    def _trace():
        deg_ref[...] = instr.wave_degrees(ids)[None, :]


def scatter_add_pallas(
    values: jnp.ndarray,
    ids: jnp.ndarray,
    num_segments: int,
    *,
    tile: int = DEFAULT_TILE,
    seg_block: int = DEFAULT_SEG_BLOCK,
    instrumented: bool = False,
    interpret: bool = True,
):
    """values (N, D) f32/bf16, ids (N,) int32 in [0, num_segments)."""
    n, d = values.shape
    assert n % tile == 0, "pad in ops.py"
    assert num_segments % seg_block == 0 or num_segments < seg_block
    seg_block = min(seg_block, num_segments)
    num_seg_blocks = -(-num_segments // seg_block)
    grid = (num_seg_blocks, n // tile)

    ids_spec = pl.BlockSpec((tile,), lambda j, i: (i,))
    val_spec = pl.BlockSpec((tile, d), lambda j, i: (i, 0))
    out_spec = pl.BlockSpec((seg_block, d), lambda j, i: (j, 0))

    if instrumented:
        assert tile % instr.LANES == 0
        waves_per_tile = tile // instr.LANES
        kernel = functools.partial(_scatter_instrumented_kernel,
                                   seg_block=seg_block)
        out, deg = pl.pallas_call(
            kernel,
            grid=grid,
            in_specs=[ids_spec, val_spec],
            out_specs=[out_spec,
                       pl.BlockSpec((1, waves_per_tile), lambda j, i: (i, 0))],
            out_shape=[
                jax.ShapeDtypeStruct((num_seg_blocks * seg_block, d),
                                     jnp.float32),
                jax.ShapeDtypeStruct((n // tile, waves_per_tile),
                                     jnp.float32)],
            interpret=interpret,
        )(ids, values)
        return out[:num_segments], deg

    kernel = functools.partial(_scatter_kernel, seg_block=seg_block)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[ids_spec, val_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((num_seg_blocks * seg_block, d),
                                       jnp.float32),
        interpret=interpret,
    )(ids, values)
    return out[:num_segments]


def bincount_pallas(
    ids: jnp.ndarray,
    num_segments: int,
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
) -> jnp.ndarray:
    """(num_segments,) int32 counts; the MoE dispatch/POPC-class kernel."""
    n = ids.shape[0]
    assert n % tile == 0, "pad in ops.py"
    assert num_segments <= 8192, "use scatter_add blocking for larger"
    kernel = functools.partial(_bincount_kernel, num_segments=num_segments)
    out = pl.pallas_call(
        kernel,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1, num_segments), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, num_segments), jnp.int32),
        interpret=interpret,
    )(ids)
    return out[0]
