"""Jit'd public wrappers for scatter-add / bincount + instrumentation glue."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counters as counters_mod
from repro.core import timing
from repro.kernels import instrumentation as instr
from repro.kernels.scatter_add import kernel as sk


def _pad_n(ids: jnp.ndarray, values: jnp.ndarray, tile: int):
    n = ids.shape[0]
    pad = (-n) % tile
    if pad:
        ids = jnp.concatenate([ids, jnp.zeros((pad,), ids.dtype)])
        values = jnp.concatenate(
            [values, jnp.zeros((pad,) + values.shape[1:], values.dtype)])
    return ids, values, pad


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "tile", "seg_block", "interpret"))
def scatter_add(values: jnp.ndarray, ids: jnp.ndarray, *, num_segments: int,
                tile: int = sk.DEFAULT_TILE,
                seg_block: int = sk.DEFAULT_SEG_BLOCK,
                interpret: bool = True) -> jnp.ndarray:
    """Segment-sum: (N, D) values + (N,) ids -> (num_segments, D) f32.

    Padding rows carry zero values, so their (id 0) contribution is zero.
    """
    ids, values, _ = _pad_n(ids.astype(jnp.int32), values, tile)
    return sk.scatter_add_pallas(values, ids, num_segments, tile=tile,
                                 seg_block=seg_block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=(
    "num_segments", "tile", "interpret"))
def bincount(ids: jnp.ndarray, *, num_segments: int,
             tile: int = sk.DEFAULT_TILE,
             interpret: bool = True) -> jnp.ndarray:
    """(num_segments,) int32 counts (the MoE dispatch histogram)."""
    n = ids.shape[0]
    ids_p, _, pad = _pad_n(ids.astype(jnp.int32),
                           jnp.zeros((n, 1), jnp.float32), tile)
    out = sk.bincount_pallas(ids_p, num_segments, tile=tile,
                             interpret=interpret)
    if pad:  # padding ids are 0: remove their counts
        out = out.at[0].add(-pad)
    return out


def committed_id_stream(ids, num_segments: int, *,
                        tile: int = sk.DEFAULT_TILE) -> np.ndarray:
    """The flat id stream the instrumented kernel commits (numpy).

    Pads to a tile multiple with *unique out-of-range* sentinel ids: they
    match no segment block (contributing nothing) and add no artificial
    conflicts to the degree counters.  ``instrumented_scatter_add`` feeds
    this exact stream to the kernel, so trace-side synthesis and in-kernel
    instrumentation see identical commit groups.
    """
    ids = np.asarray(ids).astype(np.int32).reshape(-1)
    pad = (-ids.shape[0]) % tile
    if pad:
        seg_blocks = -(-num_segments // min(sk.DEFAULT_SEG_BLOCK, num_segments))
        base = seg_blocks * min(sk.DEFAULT_SEG_BLOCK, num_segments)
        sentinel = base + np.arange(pad, dtype=np.int32)
        ids = np.concatenate([ids, sentinel]).astype(np.int32)
    return ids


def default_waves_per_tile(tile: int = sk.DEFAULT_TILE) -> int:
    """The kernel's own tiling: waves issued per grid tile."""
    return tile // instr.LANES


def collect_counters(
    ids,
    values,
    num_segments: int,
    *,
    label: str = "",
    tile: int = sk.DEFAULT_TILE,
    num_cores: int = 8,
    job_class: int = timing.FAO,
    waves_per_tile: int | None = None,
    pipeline_depth: int = 2,
    bytes_read: float | None = None,
    flops: float = 0.0,
    overhead_cycles: float = 500.0,
) -> counters_mod.CounterSet:
    """Run the instrumented kernel and return its counters as a CounterSet.

    The provider hook: ``repro.analysis.providers.InstrumentedKernelProvider``
    calls this so every counter is read back from the interpret-mode
    Pallas launch, not synthesized.
    """
    _, counters = instrumented_scatter_add(
        ids, values, num_segments, tile=tile, num_cores=num_cores,
        job_class=job_class, waves_per_tile=waves_per_tile,
        pipeline_depth=pipeline_depth)
    if bytes_read is None:
        bytes_read = float(np.asarray(ids).size * 4)
    return counters_mod.CounterSet.from_trace(
        counters["trace"], label=label, num_cores=num_cores,
        bytes_read=bytes_read, flops=flops, overhead_cycles=overhead_cycles,
        source="kernel", meta={"op": "scatter_add"})


def instrumented_scatter_add(
    ids,
    values,
    num_segments: int,
    *,
    wave: int = instr.LANES,
    tile: int = sk.DEFAULT_TILE,
    num_cores: int = 8,
    job_class: int = timing.FAO,
    interpret: bool = True,
    waves_per_tile: int | None = None,
    pipeline_depth: int = 2,
):
    """Scatter-add + the paper-Table-1 counters its instrumentation emits.

    Returns (out, counters) where counters has the basic quantities
    ``N`` (wave jobs), ``O`` (serialization transactions), per-wave
    ``degree``, and a ready-to-profile ``trace``.

    ``waves_per_tile`` (default: the kernel tiling ``tile / LANES``) and
    ``pipeline_depth`` set the trace's launch geometry directly — no
    post-construction mutation needed.
    """
    del wave  # fixed at instr.LANES inside the kernel
    n = np.asarray(ids).reshape(-1).shape[0]
    ids = jnp.asarray(
        committed_id_stream(ids, num_segments, tile=tile))
    values = jnp.asarray(values, jnp.float32)
    if values.ndim == 1:
        values = values[:, None]
    pad = ids.shape[0] - n
    if pad:
        values = jnp.concatenate(
            [values, jnp.zeros((pad,) + values.shape[1:], values.dtype)])
    out, deg = sk.scatter_add_pallas(values, ids, num_segments, tile=tile,
                                     instrumented=True, interpret=interpret)
    deg = np.asarray(deg).reshape(-1)
    num_waves = deg.shape[0]
    if waves_per_tile is None:
        waves_per_tile = tile // instr.LANES
    tiles = np.arange(num_waves) // max(waves_per_tile, 1)
    trace = counters_mod.WaveTrace(
        degree=deg,
        job_class=np.full(num_waves, job_class, np.int32),
        core=(tiles % num_cores).astype(np.int32),
        lanes_active=np.full(num_waves, float(instr.LANES)),
        waves_per_tile=waves_per_tile,
        pipeline_depth=pipeline_depth,
    )
    counters = {
        "N": float(num_waves),
        "O": float(deg.sum()),
        "degree": deg,
        "trace": trace,
    }
    return out, counters
