from repro.kernels.scatter_add import ops, ref  # noqa: F401
