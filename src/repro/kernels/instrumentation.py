"""In-kernel conflict instrumentation shared by the Pallas scatter kernels.

This is the counter source the paper wishes hardware provided (§4: "No GPU
performance counter directly measures n and we recommend GPU manufacturers
add one").  The instrumented kernel variants compute, *inside the kernel
body* and from the same index stream the scatter path commits:

  * per-wave serialization degree (the replay-count analogue feeding the
    paper's ``O`` counter: ``e = O / N``),

matching ``repro.core.counters.wave_degree`` bit-for-bit (cross-validated
by tests).  Instrumentation mirrors NCU's replay counters: it adds
overhead when enabled and is compiled out of production kernels.
"""

from __future__ import annotations

import jax.numpy as jnp

LANES = 1024        # one wave = 8 x 128 VPU lane group
COMMIT_GROUP = 32   # lanes retiring together; conflicts serialize within


def wave_degrees(flat_idx: jnp.ndarray, lanes: int = LANES,
                 group: int = COMMIT_GROUP) -> jnp.ndarray:
    """Per-wave serialization degree of a flat index stream.

    ``flat_idx`` length must be a multiple of ``lanes``.  Returns
    ``(len // lanes,)`` float32 degrees: mean over commit groups of the max
    duplicate multiplicity within the group.  Static shapes only — safe
    inside a Pallas kernel body.
    """
    assert flat_idx.size % lanes == 0 and lanes % group == 0
    g = flat_idx.reshape(-1, group)
    eq = (g[:, :, None] == g[:, None, :]).astype(jnp.int32)
    mult = eq.sum(axis=2).max(axis=1)                    # (num_groups,)
    per_wave = mult.reshape(-1, lanes // group)
    return per_wave.astype(jnp.float32).mean(axis=1)     # (num_waves,)


def wave_active(flat_idx: jnp.ndarray, valid: jnp.ndarray,
                lanes: int = LANES) -> jnp.ndarray:
    """Active lanes per wave given a validity mask (padding lanes off)."""
    assert flat_idx.size % lanes == 0
    v = valid.reshape(-1, lanes).astype(jnp.float32)
    return v.sum(axis=1)
