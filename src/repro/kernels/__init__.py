"""Pallas kernels: histogram (paper case study), scatter_add (MoE
dispatch / embedding-grad), flash_attention (online-softmax, VMEM-tiled),
conflict instrumentation."""
