"""Pure-jnp oracle for the histogram kernels (paper §4 case study)."""

from __future__ import annotations

import jax.numpy as jnp


def histogram_ref(img: jnp.ndarray, num_bins: int = 256) -> jnp.ndarray:
    """Per-channel histogram of an image.

    img: (num_pixels, channels) integer channel values in [0, num_bins).
    returns: (channels, num_bins) int32 counts.
    """
    n, c = img.shape
    flat = img.astype(jnp.int32).T  # (C, N)
    onehot = flat[:, :, None] == jnp.arange(num_bins, dtype=jnp.int32)
    return onehot.sum(axis=1).astype(jnp.int32)


def histogram_weighted_ref(img: jnp.ndarray, weights: jnp.ndarray,
                           num_bins: int = 256) -> jnp.ndarray:
    """Weighted per-channel histogram (f32 accumulate — the CAS-class path).

    weights: (num_pixels,) float32, applied to every channel's bin update.
    returns: (channels, num_bins) float32 sums.
    """
    n, c = img.shape
    flat = img.astype(jnp.int32).T  # (C, N)
    onehot = (flat[:, :, None] == jnp.arange(num_bins, dtype=jnp.int32))
    return (onehot * weights[None, :, None]).sum(axis=1).astype(jnp.float32)
