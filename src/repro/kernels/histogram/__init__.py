from repro.kernels.histogram import ops, ref  # noqa: F401
