"""Pallas TPU histogram kernel — the paper's case-study kernel, TPU-native.

GPU original (paper Listings 1-2): each thread reads a pixel's channels and
``atomicAdd``s into a shared-memory sub-histogram; Listing 2 rotates the
channel processing order by thread id so same-color neighbours hit
different sub-histogram banks.

TPU adaptation: there is no atomic unit; the idiomatic TPU histogram keeps
the (channels x bins) accumulator resident in VMEM across the grid (output
block with a constant index_map) and commits each tile with a one-hot
reduction — the VPU serializes duplicate destinations in its commit path,
which is exactly the unit the queuing model prices.  Two variants:

  * ``hist``   — channels processed in natural order (Listing 1): a
    solid-color tile drives every lane of a wave into one bin.
  * ``hist2``  — channel order rotated per lane (Listing 2): a solid-color
    tile spreads each commit group over ``channels`` distinct bins,
    cutting the serialization degree by ~channels.

Both produce identical histograms (tests assert vs ``ref.py``); they
differ in the *conflict structure* of the committed index stream, which
the instrumented variants measure in-kernel (``instrumentation.py``).

Block layout: image tiles of ``tile`` pixels x C channels stream HBM->VMEM
via the grid; the (C, num_bins) accumulator stays in VMEM (constant
index_map) for the whole launch — the scratchpad residency pattern the
paper's kernels use shared memory for.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import instrumentation as instr

DEFAULT_TILE = 2048


def _issue_ordered_bins(tile: jnp.ndarray, num_bins: int, reorder: bool
                        ) -> jnp.ndarray:
    """Flat bin ids (T*C,) for a (T, C) tile, in commit/issue order.

    The GPU kernel's warp issues channel step s for all 32 of its pixels
    together (Listing 1's inner loop), so the committed stream is
    step-major within each 32-pixel group — that ordering is what the
    conflict structure (and our wave_degrees instrumentation) sees.  The
    histogram itself is order-invariant; we commit in the same order for
    fidelity.  ``reorder`` rotates the channel by lane id (Listing 2).
    """
    t, c = tile.shape
    g = instr.COMMIT_GROUP
    assert t % g == 0
    step = jax.lax.broadcasted_iota(jnp.int32, (t, c), 1)
    if reorder:
        lane = jax.lax.broadcasted_iota(jnp.int32, (t, c), 0)
        ch = (step + lane) % c
        # gather channel `ch[l, s]` of pixel l without dynamic gather
        # (TPU-friendly): sum of per-channel selects.
        vals = jnp.zeros((t, c), jnp.int32)
        for k in range(c):
            vals = jnp.where(ch == k, tile[:, k:k + 1].astype(jnp.int32), vals)
    else:
        ch = step
        vals = tile.astype(jnp.int32)
    bins = ch * num_bins + vals                      # (t, c) pixel-major
    bins = bins.reshape(t // g, g, c).transpose(0, 2, 1)  # step-major
    return bins.reshape(t * c)


def _hist_kernel(img_ref, out_ref, *, num_bins: int, reorder: bool):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = img_ref[...]
    t, c = tile.shape
    flat = _issue_ordered_bins(tile, num_bins, reorder)
    onehot = (flat[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t * c, c * num_bins), 1))
    counts = onehot.astype(jnp.int32).sum(axis=0)
    out_ref[...] += counts.reshape(c, num_bins)


def _hist_weighted_kernel(img_ref, w_ref, out_ref, *, num_bins: int,
                          reorder: bool):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = img_ref[...]
    t, c = tile.shape
    flat = _issue_ordered_bins(tile, num_bins, reorder)
    g = instr.COMMIT_GROUP
    w = jnp.broadcast_to(w_ref[...][:, None], (t, c))
    w = w.reshape(t // g, g, c).transpose(0, 2, 1).reshape(t * c)
    onehot = (flat[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t * c, c * num_bins), 1))
    sums = (onehot.astype(jnp.float32) * w[:, None]).sum(axis=0)
    out_ref[...] += sums.reshape(c, num_bins)


def _hist_instrumented_kernel(img_ref, out_ref, deg_ref, *, num_bins: int,
                              reorder: bool):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    tile = img_ref[...]
    t, c = tile.shape
    flat = _issue_ordered_bins(tile, num_bins, reorder)
    onehot = (flat[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (t * c, c * num_bins), 1))
    counts = onehot.astype(jnp.int32).sum(axis=0)
    out_ref[...] += counts.reshape(c, num_bins)
    deg_ref[...] = instr.wave_degrees(flat)[None, :]


def histogram_pallas(
    img: jnp.ndarray,
    *,
    num_bins: int = 256,
    reorder: bool = False,
    tile: int = DEFAULT_TILE,
    weights: jnp.ndarray | None = None,
    instrumented: bool = False,
    interpret: bool = True,
):
    """Launch the histogram kernel.  img: (N, C) ints, N % tile == 0.

    Returns (C, num_bins) counts — int32, or f32 when ``weights`` given.
    With ``instrumented=True`` additionally returns per-wave serialization
    degrees, shape (grid, waves_per_tile).
    """
    n, c = img.shape
    assert n % tile == 0, "pad in ops.py before calling"
    assert (tile * c) % instr.LANES == 0
    grid = n // tile
    waves_per_tile = (tile * c) // instr.LANES

    img_spec = pl.BlockSpec((tile, c), lambda i: (i, 0))
    out_spec = pl.BlockSpec((c, num_bins), lambda i: (0, 0))

    if weights is not None:
        kernel = functools.partial(_hist_weighted_kernel, num_bins=num_bins,
                                   reorder=reorder)
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[img_spec, pl.BlockSpec((tile,), lambda i: (i,))],
            out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((c, num_bins), jnp.float32),
            interpret=interpret,
        )(img, weights)

    if instrumented:
        kernel = functools.partial(_hist_instrumented_kernel,
                                   num_bins=num_bins, reorder=reorder)
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[img_spec],
            out_specs=[out_spec,
                       pl.BlockSpec((1, waves_per_tile), lambda i: (i, 0))],
            out_shape=[jax.ShapeDtypeStruct((c, num_bins), jnp.int32),
                       jax.ShapeDtypeStruct((grid, waves_per_tile),
                                            jnp.float32)],
            interpret=interpret,
        )(img)

    kernel = functools.partial(_hist_kernel, num_bins=num_bins,
                               reorder=reorder)
    return pl.pallas_call(
        kernel,
        grid=(grid,),
        in_specs=[img_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((c, num_bins), jnp.int32),
        interpret=interpret,
    )(img)
