"""Jit'd public wrappers for the histogram kernels + profiler glue.

Instruction-class mapping (paper §2 / §4):

  * unweighted, result-unread  -> POPC class (Ampere's ``ATOMS.POPC.INC``:
    the compiler's cheap population-count increment; our one-hot popcount
    reduction is literally that operation),
  * unweighted, ``force_fao``  -> FAO class (the paper forces ``ATOMS.ADD``
    back with a dummy read of the atomic's result),
  * weighted (f32 accumulate)  -> CAS class (FP atomics lower to
    compare-and-swap loops on the GPU; the read-modify-verify analogue).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import counters as counters_mod
from repro.core import timing
from repro.kernels import instrumentation as instr
from repro.kernels.histogram import kernel as hk


def _pad(img: jnp.ndarray, tile: int) -> tuple[jnp.ndarray, int]:
    n = img.shape[0]
    pad = (-n) % tile
    if pad:
        img = jnp.concatenate(
            [img, jnp.zeros((pad, img.shape[1]), img.dtype)], axis=0)
    return img, pad


@functools.partial(jax.jit, static_argnames=(
    "num_bins", "variant", "tile", "interpret"))
def histogram(img: jnp.ndarray, *, num_bins: int = 256,
              variant: str = "hist", tile: int = hk.DEFAULT_TILE,
              interpret: bool = True) -> jnp.ndarray:
    """(C, num_bins) int32 histogram; `variant` is 'hist' or 'hist2'."""
    reorder = {"hist": False, "hist2": True}[variant]
    padded, pad = _pad(img.astype(jnp.int32), tile)
    out = hk.histogram_pallas(padded, num_bins=num_bins, reorder=reorder,
                              tile=tile, interpret=interpret)
    if pad:  # padding pixels are zeros: remove their channel-0-value counts
        out = out.at[:, 0].add(-pad)
    return out


@functools.partial(jax.jit, static_argnames=(
    "num_bins", "variant", "tile", "interpret"))
def histogram_weighted(img: jnp.ndarray, weights: jnp.ndarray, *,
                       num_bins: int = 256, variant: str = "hist",
                       tile: int = hk.DEFAULT_TILE,
                       interpret: bool = True) -> jnp.ndarray:
    reorder = {"hist": False, "hist2": True}[variant]
    padded, pad = _pad(img.astype(jnp.int32), tile)
    w = jnp.concatenate([weights.astype(jnp.float32),
                         jnp.zeros((pad,), jnp.float32)]) if pad else weights
    return hk.histogram_pallas(padded, num_bins=num_bins, reorder=reorder,
                               tile=tile, weights=w.astype(jnp.float32),
                               interpret=interpret)


def histogram_instrumented(
    img: jnp.ndarray,
    *,
    num_bins: int = 256,
    variant: str = "hist",
    tile: int = hk.DEFAULT_TILE,
    force_fao: bool = False,
    weighted: bool = False,
    num_cores: int = 8,
    interpret: bool = True,
    waves_per_tile: Optional[int] = None,
    pipeline_depth: int = 2,
) -> tuple[jnp.ndarray, counters_mod.WaveTrace]:
    """Histogram + the wave trace its instrumentation emits.

    The committed-index stream is identical for the weighted variant, so
    the integer instrumented kernel supplies the trace in both cases; only
    the job class differs (CAS for weighted f32 accumulation).

    ``waves_per_tile``/``pipeline_depth`` describe the launch geometry the
    occupancy model sees; ``waves_per_tile`` defaults to the kernel's own
    tiling (``tile * channels / LANES``) and, when overridden, also governs
    the round-robin core assignment — it *is* the scheduled tile size.
    """
    reorder = {"hist": False, "hist2": True}[variant]
    padded, pad = _pad(img.astype(jnp.int32), tile)
    hist, degrees = hk.histogram_pallas(
        padded, num_bins=num_bins, reorder=reorder, tile=tile,
        instrumented=True, interpret=interpret)
    if pad:
        hist = hist.at[:, 0].add(-pad)
    deg = np.asarray(degrees).reshape(-1)
    num_waves = deg.shape[0]
    if waves_per_tile is None:
        waves_per_tile = default_waves_per_tile(img, tile)
    tiles = np.arange(num_waves) // max(waves_per_tile, 1)
    job_class = histogram_job_class(force_fao=force_fao, weighted=weighted)
    trace = counters_mod.WaveTrace(
        degree=deg,
        job_class=np.full(num_waves, job_class, np.int32),
        core=(tiles % num_cores).astype(np.int32),
        lanes_active=np.full(num_waves, float(instr.LANES)),
        waves_per_tile=waves_per_tile,
        pipeline_depth=pipeline_depth,
    )
    return hist, trace


def image_bytes(img: jnp.ndarray) -> float:
    """HBM read traffic of the launch: 1 byte/channel as in the paper."""
    return float(img.shape[0] * img.shape[1])


def histogram_job_class(*, force_fao: bool, weighted: bool) -> int:
    """Instruction-class mapping (module docstring): CAS > FAO > POPC."""
    if weighted:
        return timing.CAS
    if force_fao:
        return timing.FAO
    return timing.POPC


def default_waves_per_tile(img, tile: int = hk.DEFAULT_TILE) -> int:
    """The kernel's own tiling: waves issued per grid tile."""
    return (tile * np.shape(img)[1]) // instr.LANES


def committed_index_stream(img, *, num_bins: int = 256,
                           variant: str = "hist",
                           tile: int = hk.DEFAULT_TILE) -> np.ndarray:
    """The flat bin-index stream the kernel commits, synthesized in numpy.

    Mirrors ``kernel._issue_ordered_bins`` (zero-padding to a tile
    multiple, channel-offset bins, per-lane channel rotation for hist2,
    step-major ordering within each commit group) without running Pallas —
    the modeled counter source the instrumented kernel cross-validates.
    The per-commit-group transform never mixes rows across tiles, so it is
    applied to the whole padded image at once.
    """
    reorder = {"hist": False, "hist2": True}[variant]
    a = np.asarray(img).astype(np.int32)
    pad = (-a.shape[0]) % tile
    if pad:
        a = np.concatenate([a, np.zeros((pad, a.shape[1]), a.dtype)])
    t, c = a.shape
    g = instr.COMMIT_GROUP
    step = np.broadcast_to(np.arange(c, dtype=np.int32)[None, :], (t, c))
    if reorder:
        lane = ((np.arange(t, dtype=np.int32) % tile)[:, None]
                + np.zeros((1, c), np.int32))
        ch = (step + lane) % c
        vals = np.take_along_axis(a, ch, axis=1)
    else:
        ch = step
        vals = a
    bins = ch * num_bins + vals                           # (t, c) pixel-major
    bins = bins.reshape(t // g, g, c).transpose(0, 2, 1)  # step-major
    return bins.reshape(t * c)


def collect_counters(
    img,
    *,
    label: str = "",
    num_bins: int = 256,
    variant: str = "hist",
    tile: int = hk.DEFAULT_TILE,
    force_fao: bool = False,
    weighted: bool = False,
    num_cores: int = 8,
    waves_per_tile: Optional[int] = None,
    pipeline_depth: int = 2,
    bytes_read: Optional[float] = None,
    flops: float = 0.0,
    overhead_cycles: float = 500.0,
) -> counters_mod.CounterSet:
    """Run the instrumented kernel and return its counters as a CounterSet.

    The provider hook: ``repro.analysis.providers.InstrumentedKernelProvider``
    calls this so every counter (``O``, ``N``, active lanes) is read back
    from the interpret-mode Pallas launch, not synthesized.
    """
    img = jnp.asarray(img)
    _, trace = histogram_instrumented(
        img, num_bins=num_bins, variant=variant, tile=tile,
        force_fao=force_fao, weighted=weighted, num_cores=num_cores,
        waves_per_tile=waves_per_tile, pipeline_depth=pipeline_depth)
    return counters_mod.CounterSet.from_trace(
        trace, label=label, num_cores=num_cores,
        bytes_read=image_bytes(img) if bytes_read is None else bytes_read,
        flops=flops, overhead_cycles=overhead_cycles,
        source="kernel", meta={"op": "histogram", "variant": variant})
