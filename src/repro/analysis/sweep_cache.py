"""Persistent cross-process counter cache for sweeps.

``Session`` already memoizes collected ``CounterSet``s per process by
content fingerprint; this module extends that memo across processes so a
repeated CLI sweep (a new process every time) skips counter *collection*
entirely and goes straight to the batch model evaluation.  Entries are
one ``.npz`` per point under ``results/cache/`` (relocate with the
``REPRO_RESULTS`` environment variable; clear by deleting the directory
or via ``SweepCache.clear()``), keyed by

    provider name + ``WorkloadSpec.fingerprint()`` + ``Device.table_key()``
    + a content hash of the counter-producing source files

so a different counter source, workload content, launch geometry,
scatter-unit calibration, or collection *implementation* never collides
(a PR that changes counter synthesis invalidates old entries by
construction — stale numbers cannot survive a code change).  Specs whose content cannot be
hashed (``fingerprint() is None``: opaque ``run`` callables, compiled
artifacts) are never cached, mirroring the in-process memo.  Corrupt or
truncated entries read as misses and are overwritten on the next
collection — the cache is an accelerator, never a correctness input.
"""

from __future__ import annotations

import functools
import hashlib
import json
import os
import tempfile
from pathlib import Path
from typing import Optional, Union

import numpy as np

from repro.core.counters import CounterSet
from repro.obs import telemetry as _telemetry

_CACHE_LOOKUPS = _telemetry.counter(
    "repro_sweep_cache_lookups_total",
    "SweepCache.get outcomes (bulk reads route through get too)",
    ("result",))

CACHE_VERSION = 1


@functools.lru_cache(maxsize=1)
def _collection_code_digest() -> str:
    """Content hash of the counter-*producing* source files.

    The spec fingerprint and device key capture the inputs to
    ``collect``; this captures its implementation.  Folding it into
    every cache key means a PR that changes counter synthesis (a
    provider, the wave-degree math, a kernel's committed-stream mirror)
    automatically invalidates stale cross-process entries — nobody has
    to remember to bump ``CACHE_VERSION`` or clear ``results/cache/``.
    Over-inclusion only costs a cold re-collection, so the whole kernels
    package is hashed rather than chasing exact call graphs.
    """
    import repro.analysis.providers as providers_pkg
    import repro.core.counters as counters_mod
    import repro.kernels as kernels_pkg

    paths = [Path(counters_mod.__file__)]
    for pkg in (providers_pkg, kernels_pkg):
        root = Path(pkg.__file__).parent
        paths.extend(sorted(root.rglob("*.py")))
    h = hashlib.sha256()
    for p in paths:
        h.update(str(p.name).encode())
        h.update(p.read_bytes())
    return h.hexdigest()[:16]


def results_root() -> Path:
    """``results/`` at the repo root (``REPRO_RESULTS`` overrides).

    The single resolution rule for where results live — the CLI's
    artifact directory and this cache both resolve through here, so a
    cache written by one surface is always found by the other.
    """
    env = os.environ.get("REPRO_RESULTS")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "results"


def default_cache_root() -> Path:
    """``results/cache/`` under ``results_root()``."""
    return results_root() / "cache"


def save_counter_set(cset: CounterSet, path: Union[str, Path]) -> None:
    """Serialize one ``CounterSet`` to an ``.npz`` (atomic via tmp+rename)."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=path.parent, suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as fh:
            np.savez_compressed(
                fh,
                version=np.int64(CACHE_VERSION),
                label=np.str_(cset.label),
                source=np.str_(cset.source),
                num_cores=np.int64(cset.num_cores),
                O=cset.O, N_f=cset.N_f, N_c=cset.N_c, N_p=cset.N_p,
                lanes_active=np.float64(cset.lanes_active),
                num_waves=np.int64(cset.num_waves),
                waves_per_tile=np.int64(cset.waves_per_tile),
                pipeline_depth=np.int64(cset.pipeline_depth),
                bytes_read=np.float64(cset.bytes_read),
                flops=np.float64(cset.flops),
                ici_bytes=np.float64(cset.ici_bytes),
                overhead_cycles=np.float64(cset.overhead_cycles),
                has_wall_time=np.bool_(cset.wall_time_s is not None),
                wall_time_s=np.float64(cset.wall_time_s
                                       if cset.wall_time_s is not None
                                       else 0.0),
                meta=np.str_(json.dumps(cset.meta, default=str)),
            )
        os.replace(tmp, path)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def load_counter_set(path: Union[str, Path]) -> CounterSet:
    """Inverse of ``save_counter_set`` (raises on any malformed entry)."""
    z = np.load(path)
    if int(z["version"]) != CACHE_VERSION:
        raise ValueError(f"cache entry version {int(z['version'])} != "
                         f"{CACHE_VERSION}")
    return CounterSet(
        label=str(z["label"]),
        source=str(z["source"]),
        num_cores=int(z["num_cores"]),
        O=z["O"], N_f=z["N_f"], N_c=z["N_c"], N_p=z["N_p"],
        lanes_active=float(z["lanes_active"]),
        num_waves=int(z["num_waves"]),
        waves_per_tile=int(z["waves_per_tile"]),
        pipeline_depth=int(z["pipeline_depth"]),
        bytes_read=float(z["bytes_read"]),
        flops=float(z["flops"]),
        ici_bytes=float(z["ici_bytes"]),
        overhead_cycles=float(z["overhead_cycles"]),
        wall_time_s=float(z["wall_time_s"]) if bool(z["has_wall_time"])
        else None,
        meta=json.loads(str(z["meta"])),
    )


class SweepCache:
    """One-file-per-point on-disk counter cache (see module docstring)."""

    def __init__(self, root: Optional[Union[str, Path]] = None) -> None:
        self.root = Path(root) if root is not None else default_cache_root()

    def key(self, provider_name: str, fingerprint: str,
            table_key: str) -> str:
        payload = (f"v{CACHE_VERSION}|{_collection_code_digest()}|"
                   f"{provider_name}|{fingerprint}|{table_key}")
        return hashlib.sha256(payload.encode()).hexdigest()[:32]

    def path(self, key: str) -> Path:
        return self.root / f"{key}.npz"

    def _glob(self, pattern: str) -> list:
        """Directory listing that treats a vanished root as empty.

        A concurrent ``clear()``/``rm -rf results/cache`` (or a racing
        prune in another process) can delete the root between an
        ``exists()`` check and the scan; every maintenance surface
        resolves its file list through here so that race reads as an
        empty cache, never a crash.
        """
        try:
            return sorted(self.root.glob(pattern))
        except OSError:
            return []

    def _quarantine(self, path: Path) -> None:
        """Move a corrupt entry aside as ``<name>.npz.corrupt``.

        A corrupt entry left in place would be re-read (and re-fail) on
        every future lookup — a permanent per-request tax.  Renaming it
        turns the corruption into a one-time event: the key reads as a
        clean miss, the next collection overwrites it, and the evidence
        survives for ``cache stats`` (``quarantined``) until ``cache
        prune`` deletes it.  Rename races with other readers or a
        concurrent clear are benign (first mover wins).
        """
        try:
            path.rename(path.with_name(path.name + ".corrupt"))
        except OSError:
            pass

    def get(self, key: str) -> Optional[CounterSet]:
        """Cached CounterSet, or ``None`` (missing or unreadable = miss).

        An unreadable-but-present entry is quarantined (see
        ``_quarantine``) instead of being left to fail again forever.
        """
        path = self.path(key)
        try:
            hit = load_counter_set(path)
            _CACHE_LOOKUPS.inc(result="hit")
            return hit
        except FileNotFoundError:
            _CACHE_LOOKUPS.inc(result="miss")
            return None
        except Exception:
            if path.exists():
                self._quarantine(path)
                _CACHE_LOOKUPS.inc(result="quarantined")
            else:
                _CACHE_LOOKUPS.inc(result="miss")
            return None

    def put(self, key: str, cset: CounterSet) -> None:
        save_counter_set(cset, self.path(key))

    def get_many(self, keys) -> dict[str, CounterSet]:
        """Bulk read: ``{key: CounterSet}`` for the keys present.

        Misses (absent or unreadable entries) are simply omitted — the
        batch sweep executor treats anything not in the returned dict as
        a point to collect.
        """
        out: dict[str, CounterSet] = {}
        for key in keys:
            hit = self.get(key)
            if hit is not None:
                out[key] = hit
        return out

    def put_many(self, entries: dict) -> None:
        """Bulk write-back; each entry keeps the atomic tmp+rename write,
        so concurrent shards racing on the same keys stay safe."""
        for key, cset in entries.items():
            self.put(key, cset)

    def iter_entries(self):
        """Yield ``(path, CounterSet | None)`` per on-disk entry
        (``None`` marks a corrupt/unreadable one), in stable path order —
        the shard-merge and maintenance iteration surface."""
        for f in self._glob("*.npz"):
            try:
                yield f, load_counter_set(f)
            except FileNotFoundError:
                continue    # vanished mid-iteration (concurrent clear)
            except Exception:
                yield f, None

    def stats(self) -> dict:
        """Entry count, bytes on disk, and a per-provider breakdown.

        The provider is recovered from each entry's stored ``source``
        field (keys are opaque hashes); unreadable entries are counted
        under ``"<corrupt>"`` and quarantined ``*.npz.corrupt`` files
        under ``quarantined``, so the report never hides either.  Files
        vanishing mid-scan (a concurrent ``clear()``) are skipped, and a
        deleted cache root reads as an empty cache.
        """
        entries = 0
        total_bytes = 0
        by_provider: dict[str, dict] = {}
        for path, cset in self.iter_entries():
            try:
                size = path.stat().st_size
            except OSError:
                continue    # vanished between listing and stat
            entries += 1
            total_bytes += size
            source = cset.source if cset is not None else "<corrupt>"
            bucket = by_provider.setdefault(source, {"entries": 0, "bytes": 0})
            bucket["entries"] += 1
            bucket["bytes"] += size
        return {"root": str(self.root), "entries": entries,
                "bytes": total_bytes,
                "quarantined": len(self._glob("*.npz.corrupt")),
                "by_provider": dict(sorted(by_provider.items()))}

    def prune(self, max_bytes: Optional[int] = None) -> tuple[int, int]:
        """Delete quarantined/tmp litter, then LRU-evict to ``max_bytes``.

        Quarantined ``*.npz.corrupt`` entries and orphaned ``*.tmp``
        files (a writer SIGKILLed between ``mkstemp`` and the atomic
        rename) are always removed — they serve no lookup and only
        accumulate.  Then, when ``max_bytes`` is given, oldest-written
        live entries go first (every write refreshes mtime via the
        tmp+rename, so mtime is last-write recency).  Returns
        ``(entries_removed, bytes_freed)`` over both phases.  Races with
        concurrent writers are benign: a vanished file is skipped, and
        evicting an entry another process still wants only costs it a
        re-collection.
        """
        if max_bytes is not None and max_bytes < 0:
            raise ValueError(f"max_bytes must be >= 0, got {max_bytes}")
        removed = 0
        freed = 0
        for f in self._glob("*.npz.corrupt") + self._glob("*.tmp"):
            try:
                size = f.stat().st_size
                f.unlink()
            except OSError:
                continue
            removed += 1
            freed += size
        if max_bytes is None:
            return removed, freed
        files = []
        for f in self._glob("*.npz"):
            try:
                st = f.stat()
            except OSError:
                continue
            files.append((st.st_mtime, st.st_size, f))
        total = sum(size for _, size, _ in files)
        for _, size, f in sorted(files, key=lambda t: (t[0], t[2].name)):
            if total <= max_bytes:
                break
            try:
                f.unlink()
            except OSError:
                continue
            total -= size
            removed += 1
            freed += size
        return removed, freed

    def clear(self) -> int:
        """Delete every entry (live, quarantined, tmp); returns how many
        live entries were removed.  Safe against concurrent clears."""
        n = 0
        for f in self._glob("*.npz"):
            try:
                f.unlink()
            except OSError:
                continue
            n += 1
        for f in self._glob("*.npz.corrupt") + self._glob("*.tmp"):
            try:
                f.unlink()
            except OSError:
                pass
        return n

    def __len__(self) -> int:
        return len(self._glob("*.npz"))
