"""WorkloadSpec: an immutable description of one scatter-heavy launch.

The old call path required the caller to (a) run an instrumented kernel,
(b) mutate ``trace.waves_per_tile`` after the fact, and (c) thread 11
kwargs into ``profiler.profile_scatter_workload``.  A ``WorkloadSpec``
captures all of that declaratively: what runs (an index stream, an
existing wave trace, or an instrumented kernel launch), under which launch
geometry, and with which roofline-side inputs (bytes read, FLOPs,
overhead).  Specs are frozen — sweeps derive variants with ``with_()``
instead of mutating shared state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core import counters as counters_mod
from repro.core import timing


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One profileable launch: measurement source + geometry + roofline.

    Exactly one of ``trace`` / ``indices`` / ``run`` is the measurement
    source (checked at resolve time).  ``run`` is a zero-arg callable
    returning a ``WaveTrace`` — the hook for instrumented-kernel sources
    (see ``from_histogram`` / ``from_scatter_add``), kept lazy so building
    a sweep's spec list costs nothing until ``Session.profile`` runs it.
    """

    label: str
    # measurement source (one of):
    trace: Optional[counters_mod.WaveTrace] = None
    indices: Optional[np.ndarray] = None
    run: Optional[Any] = None          # () -> WaveTrace, lazy kernel source
    # index-stream interpretation (for the ``indices`` source):
    num_bins: int = 256
    job_class: int = timing.FAO
    # launch geometry:
    waves_per_tile: Optional[int] = None   # None: keep the source's own
    pipeline_depth: Optional[int] = None
    num_cores: int = 8
    # roofline-side inputs:
    bytes_read: float = 0.0
    flops: float = 0.0
    overhead_cycles: float = 500.0

    def __post_init__(self) -> None:
        sources = sum(s is not None
                      for s in (self.trace, self.indices, self.run))
        if sources != 1:
            raise ValueError(
                f"WorkloadSpec {self.label!r} needs exactly one measurement "
                f"source (trace | indices | run), got {sources}")

    # -- derivation -------------------------------------------------------

    def with_(self, **changes) -> "WorkloadSpec":
        """Frozen-friendly variant derivation (sweeps, relabeling)."""
        return dataclasses.replace(self, **changes)

    def resolve_trace(self) -> counters_mod.WaveTrace:
        """Materialize the wave trace with this spec's geometry applied.

        Never mutates the source trace: geometry overrides produce a
        copied-geometry view via ``WaveTrace.with_geometry``.
        """
        if self.trace is not None:
            tr = self.trace
        elif self.run is not None:
            tr = self.run()
        else:
            tr = counters_mod.trace_from_indices(
                np.asarray(self.indices), self.num_bins,
                num_cores=self.num_cores, job_class=self.job_class,
                waves_per_tile=self.waves_per_tile or 1,
                pipeline_depth=self.pipeline_depth or 2)
        if self.waves_per_tile is not None or self.pipeline_depth is not None:
            tr = tr.with_geometry(self.waves_per_tile, self.pipeline_depth)
        return tr

    # -- constructors -----------------------------------------------------

    @classmethod
    def from_trace(cls, trace: counters_mod.WaveTrace, *, label: str,
                   **kw) -> "WorkloadSpec":
        return cls(label=label, trace=trace, **kw)

    @classmethod
    def from_indices(cls, indices, num_bins: int, *, label: str,
                     **kw) -> "WorkloadSpec":
        """Synthetic/offline index stream (no kernel run needed)."""
        spec = cls(label=label, indices=np.asarray(indices),
                   num_bins=num_bins, **kw)
        if spec.bytes_read == 0.0:
            spec = spec.with_(bytes_read=float(np.asarray(indices).size * 4))
        return spec

    @classmethod
    def from_histogram(cls, img, *, label: str, variant: str = "hist",
                       force_fao: bool = True, weighted: bool = False,
                       num_bins: int = 256, **kw) -> "WorkloadSpec":
        """Instrumented Pallas histogram launch as the trace source.

        ``bytes_read`` defaults to the image's HBM traffic (1 byte per
        channel, as in the paper's case study).
        """
        from repro.kernels.histogram import ops as hist_ops  # lazy: pulls jax

        spec_kw = dict(kw)
        num_cores = spec_kw.get("num_cores", 8)
        # forward the launch geometry into the kernel wrapper so core
        # round-robin assignment matches the direct-call and indices paths
        wpt = spec_kw.get("waves_per_tile")
        depth = spec_kw.get("pipeline_depth") or 2

        def _run(img=img):
            _, tr = hist_ops.histogram_instrumented(
                img, variant=variant, force_fao=force_fao,
                weighted=weighted, num_bins=num_bins, num_cores=num_cores,
                waves_per_tile=wpt, pipeline_depth=depth)
            return tr

        spec_kw.setdefault("bytes_read", hist_ops.image_bytes(img))
        return cls(label=label, run=_run, **spec_kw)

    @classmethod
    def from_scatter_add(cls, ids, values, num_segments: int, *, label: str,
                         job_class: int = timing.FAO, **kw) -> "WorkloadSpec":
        """Instrumented Pallas scatter-add launch as the trace source."""
        from repro.kernels.scatter_add import ops as scat_ops  # lazy

        spec_kw = dict(kw)
        num_cores = spec_kw.get("num_cores", 8)
        wpt = spec_kw.get("waves_per_tile")
        depth = spec_kw.get("pipeline_depth") or 2

        def _run(ids=ids, values=values):
            _, c = scat_ops.instrumented_scatter_add(
                ids, values, num_segments, num_cores=num_cores,
                job_class=job_class, waves_per_tile=wpt,
                pipeline_depth=depth)
            return c["trace"]

        spec_kw.setdefault("bytes_read", float(np.asarray(ids).size * 4))
        return cls(label=label, run=_run, **spec_kw)
