"""WorkloadSpec: an immutable description of one scatter-heavy launch.

The old call path required the caller to (a) run an instrumented kernel,
(b) mutate ``trace.waves_per_tile`` after the fact, and (c) thread 11
kwargs into ``profiler.profile_scatter_workload``.  A ``WorkloadSpec``
captures all of that declaratively: what runs (an index stream, an
existing wave trace, a described kernel launch, or a compiled artifact),
under which launch geometry, and with which roofline-side inputs (bytes
read, FLOPs, overhead).  Specs are frozen — sweeps derive variants with
``with_()`` instead of mutating shared state.

A spec is deliberately *provider-agnostic*: it describes the workload,
not how its counters are acquired.  ``KernelSource`` keeps the kernel
launch as data (op name + arguments) rather than a baked closure, so the
``repro.analysis.providers`` backends can either synthesize the committed
index stream in numpy (``TraceProvider``) or actually run the
interpret-mode Pallas kernel (``InstrumentedKernelProvider``) from one
and the same spec — the model-vs-measured split the paper's validation
(§5) needs.
"""

from __future__ import annotations

import dataclasses
import hashlib
import itertools
from typing import Any, Optional

import numpy as np

from repro.core import counters as counters_mod
from repro.core import timing


@dataclasses.dataclass(frozen=True)
class KernelSource:
    """A described (not yet launched) instrumented-kernel source.

    ``op`` names the kernel family (``"histogram"`` | ``"scatter_add"``);
    ``params`` holds its source-specific arguments (image / ids / values /
    bins).  Launch geometry lives on the owning ``WorkloadSpec`` so
    ``with_()`` derivations apply to the launch too.
    """

    op: str
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    """One profileable launch: measurement source + geometry + roofline.

    Exactly one of ``trace`` / ``indices`` / ``run`` / ``kernel`` /
    ``compiled``-or-``hlo_text`` is the measurement source (checked at
    construction).  ``run`` is a zero-arg callable returning a
    ``WaveTrace`` — the escape hatch for custom instrumented sources,
    kept lazy so building a sweep's spec list costs nothing until a
    provider collects it.  ``kernel`` is the declarative form the shipped
    providers understand (see ``from_histogram`` / ``from_scatter_add``).
    ``compiled``/``hlo_text`` describe a compiled step for the HLO
    provider (no wave trace; roofline counters only).
    """

    label: str
    # measurement source (one of):
    trace: Optional[counters_mod.WaveTrace] = None
    indices: Optional[np.ndarray] = None
    run: Optional[Any] = None          # () -> WaveTrace, lazy custom source
    kernel: Optional[KernelSource] = None
    compiled: Optional[Any] = None     # jax compiled artifact (HLO provider)
    hlo_text: Optional[str] = None     # post-optimization HLO module text
    # index-stream interpretation (for the ``indices`` source):
    num_bins: int = 256
    job_class: int = timing.FAO
    # launch geometry:
    waves_per_tile: Optional[int] = None   # None: keep the source's own
    pipeline_depth: Optional[int] = None
    num_cores: int = 8
    num_devices: int = 1               # chips (HLO collective accounting)
    # roofline-side inputs:
    bytes_read: float = 0.0
    flops: float = 0.0
    overhead_cycles: float = 500.0

    def __post_init__(self) -> None:
        sources = sum(s is not None
                      for s in (self.trace, self.indices, self.run,
                                self.kernel))
        sources += self.compiled is not None or self.hlo_text is not None
        if sources != 1:
            raise ValueError(
                f"WorkloadSpec {self.label!r} needs exactly one measurement "
                f"source (trace | indices | run | kernel | compiled/hlo), "
                f"got {sources}")

    # -- derivation -------------------------------------------------------

    def with_(self, **changes) -> "WorkloadSpec":
        """Frozen-friendly variant derivation (sweeps, relabeling)."""
        return dataclasses.replace(self, **changes)

    def grid(self, **axes) -> list["WorkloadSpec"]:
        """Cartesian expansion of this spec over parameter axes.

        Each keyword names a spec field and supplies the values to sweep;
        the product is expanded in the given axis order (last axis fastest)
        and every point is relabeled ``label[k=v,...]`` so sweep reports
        and shift events stay self-describing::

            spec.grid(waves_per_tile=[4, 8, 32], pipeline_depth=[2, 4])
            # -> 6 specs, labels like "solid[waves_per_tile=4,pipeline_depth=2]"

        Pair with ``Session.sweep`` (or ``sweep_grid`` for a device axis).
        """
        for k in axes:
            if k not in {f.name for f in dataclasses.fields(self)}:
                raise ValueError(
                    f"grid axis {k!r} is not a WorkloadSpec field")
        keys = list(axes)
        out = []
        for combo in itertools.product(*(axes[k] for k in keys)):
            changes = dict(zip(keys, combo))
            suffix = ",".join(f"{k}={v}" for k, v in changes.items())
            out.append(self.with_(label=f"{self.label}[{suffix}]", **changes))
        return out

    def fingerprint(self) -> Optional[str]:
        """Content hash of everything a provider's ``collect`` reads.

        Keys the sweep engine's per-point memoization: two specs with the
        same fingerprint yield the same ``CounterSet`` from a (stateless)
        provider, so a repeated grid point or a re-run sweep is served
        from cache.  The label is deliberately *excluded* — it names the
        point but does not change the measurement (the cache relabels).
        Opaque sources (``run`` callables, ``compiled`` artifacts) are not
        hashable by content: returns ``None``, meaning "never memoize".
        """
        if self.run is not None or self.compiled is not None:
            return None
        h = hashlib.sha256()

        def put(*parts) -> None:
            for part in parts:
                if isinstance(part, np.ndarray):
                    arr = np.ascontiguousarray(part)
                    h.update(str(arr.dtype).encode())
                    h.update(str(arr.shape).encode())
                    h.update(arr.tobytes())
                else:
                    h.update(repr(part).encode())
                h.update(b"|")

        if self.trace is not None:
            put("trace", self.trace.degree, self.trace.job_class,
                self.trace.core, self.trace.lanes_active,
                self.trace.waves_per_tile, self.trace.pipeline_depth)
        elif self.indices is not None:
            put("indices", np.asarray(self.indices))
        elif self.kernel is not None:
            put("kernel", self.kernel.op)
            for k in sorted(self.kernel.params):
                v = self.kernel.params[k]
                v = np.asarray(v) if hasattr(v, "shape") else v
                put(k, v)
        elif self.hlo_text is not None:
            put("hlo", self.hlo_text)
        put(self.num_bins, self.job_class, self.waves_per_tile,
            self.pipeline_depth, self.num_cores, self.num_devices,
            self.bytes_read, self.flops, self.overhead_cycles)
        return h.hexdigest()

    def resolve_trace(self) -> counters_mod.WaveTrace:
        """Materialize the wave trace with this spec's geometry applied.

        Runs the kernel for ``kernel``/``run`` sources (the legacy
        acquisition path; ``TraceProvider`` synthesizes ``kernel`` sources
        without a launch instead).  Never mutates the source trace:
        geometry overrides produce a copied-geometry view via
        ``WaveTrace.with_geometry``.
        """
        if self.compiled is not None or self.hlo_text is not None:
            raise ValueError(
                f"WorkloadSpec {self.label!r} has no wave-trace source "
                f"(compiled/HLO specs carry roofline counters only — "
                f"collect them with the 'hlo' provider)")
        if self.trace is not None:
            tr = self.trace
        elif self.run is not None:
            tr = self.run()
        elif self.kernel is not None:
            tr = self.run_kernel()
        else:
            tr = counters_mod.trace_from_indices(
                np.asarray(self.indices), self.num_bins,
                num_cores=self.num_cores, job_class=self.job_class,
                waves_per_tile=self.waves_per_tile or 1,
                pipeline_depth=self.pipeline_depth or 2)
        if self.waves_per_tile is not None or self.pipeline_depth is not None:
            tr = tr.with_geometry(self.waves_per_tile, self.pipeline_depth)
        return tr

    def run_kernel(self) -> counters_mod.WaveTrace:
        """Launch the described instrumented kernel; return its trace."""
        if self.kernel is None:
            raise ValueError(f"WorkloadSpec {self.label!r} has no kernel "
                             f"source")
        p = self.kernel.params
        if self.kernel.op == "histogram":
            from repro.kernels.histogram import ops as hist_ops  # lazy: jax
            _, tr = hist_ops.histogram_instrumented(
                p["img"], variant=p["variant"], force_fao=p["force_fao"],
                weighted=p["weighted"], num_bins=p["num_bins"],
                num_cores=self.num_cores,
                waves_per_tile=self.waves_per_tile,
                pipeline_depth=self.pipeline_depth or 2)
            return tr
        if self.kernel.op == "scatter_add":
            from repro.kernels.scatter_add import ops as scat_ops  # lazy
            _, c = scat_ops.instrumented_scatter_add(
                p["ids"], p["values"], p["num_segments"],
                num_cores=self.num_cores, job_class=p["job_class"],
                waves_per_tile=self.waves_per_tile,
                pipeline_depth=self.pipeline_depth or 2)
            return c["trace"]
        raise ValueError(f"unknown kernel op {self.kernel.op!r}")

    # -- constructors -----------------------------------------------------

    @classmethod
    def from_trace(cls, trace: counters_mod.WaveTrace, *, label: str,
                   **kw) -> "WorkloadSpec":
        return cls(label=label, trace=trace, **kw)

    @classmethod
    def from_indices(cls, indices, num_bins: int, *, label: str,
                     **kw) -> "WorkloadSpec":
        """Synthetic/offline index stream (no kernel run needed)."""
        spec = cls(label=label, indices=np.asarray(indices),
                   num_bins=num_bins, **kw)
        if spec.bytes_read == 0.0:
            spec = spec.with_(bytes_read=float(np.asarray(indices).size * 4))
        return spec

    @classmethod
    def from_histogram(cls, img, *, label: str, variant: str = "hist",
                       force_fao: bool = True, weighted: bool = False,
                       num_bins: int = 256, **kw) -> "WorkloadSpec":
        """Instrumented Pallas histogram launch as the counter source.

        ``bytes_read`` defaults to the image's HBM traffic (1 byte per
        channel, as in the paper's case study).
        """
        spec_kw = dict(kw)
        if "bytes_read" not in spec_kw:
            from repro.kernels.histogram import ops as hist_ops  # lazy: jax
            spec_kw["bytes_read"] = hist_ops.image_bytes(img)
        return cls(label=label,
                   kernel=KernelSource(op="histogram", params={
                       "img": img, "variant": variant,
                       "force_fao": force_fao, "weighted": weighted,
                       "num_bins": num_bins}),
                   **spec_kw)

    @classmethod
    def from_scatter_add(cls, ids, values, num_segments: int, *, label: str,
                         job_class: int = timing.FAO, **kw) -> "WorkloadSpec":
        """Instrumented Pallas scatter-add launch as the counter source."""
        spec_kw = dict(kw)
        spec_kw.setdefault("bytes_read", float(np.asarray(ids).size * 4))
        return cls(label=label,
                   kernel=KernelSource(op="scatter_add", params={
                       "ids": ids, "values": values,
                       "num_segments": num_segments,
                       "job_class": job_class}),
                   **spec_kw)

    @classmethod
    def from_compiled(cls, compiled=None, *, label: str,
                      hlo_text: Optional[str] = None, num_devices: int = 1,
                      **kw) -> "WorkloadSpec":
        """Compiled-step source for the HLO provider (roofline counters).

        Pass a jax compiled artifact (``jit(f).lower(...).compile()``),
        a post-optimization HLO module text, or both (the artifact
        supplies flops/bytes via cost analysis; the text supplies the
        collective traffic).
        """
        return cls(label=label, compiled=compiled, hlo_text=hlo_text,
                   num_devices=num_devices, **kw)
