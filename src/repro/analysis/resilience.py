"""Fault-tolerant counter acquisition: retries, timeouts, breakers.

A long-running profiling service cannot assume a provider call returns,
returns quickly, or returns *sane numbers*: interpret-mode kernel runs
can hang, a racing cache writer can be killed mid-flight, and an
instrumented backend can hand back garbage.  This module is the one
place those failure modes are handled, so ``Session`` and the
``repro.service`` daemon never see them raw:

* ``RetryPolicy`` — bounded retries with exponential backoff + jitter,
  deterministic under a seed (``schedule()``) so tests can pin the exact
  delay sequence.
* ``Deadline`` / ``resilience_scope`` — a per-job time budget carried in
  a context variable; every provider call under the scope shrinks its
  own timeout to the remaining budget, so a job with a 2 s deadline
  never waits 30 s on a hung backend.
* ``CircuitBreaker`` — per-provider closed/open/half-open state: after
  ``failure_threshold`` consecutive failures the provider is skipped
  outright (no timeout paid per request) until ``cooldown_s`` elapses,
  then exactly one half-open probe decides re-close vs re-open.
* ``ResilientProvider`` — a ``CounterProvider`` wrapper running every
  ``collect`` through timeout + retry + breaker, then down a degraded
  fallback chain (e.g. kernel -> trace -> cached-stale).  Fallback
  results are stamped ``meta["degraded"]`` with the fallback provider's
  name, so a response built from them can honor the service's
  degraded-response contract; ``Session`` refuses to write degraded
  counters to the persistent cache (they are not the primary's numbers).

Nothing here imports jax; the layer is pure stdlib + numpy and safe to
use from any thread.
"""

from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import math
import random
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.counters import CounterSet
from repro.obs import telemetry as _telemetry

_CALL_SECONDS = _telemetry.histogram(
    "repro_provider_call_seconds", "Per-provider collect call latency",
    ("provider",))
_RETRIES = _telemetry.counter(
    "repro_provider_retries_total",
    "Transient provider errors that entered the retry path", ("provider",))
_BREAKER_TRANSITIONS = _telemetry.counter(
    "repro_breaker_transitions_total",
    "Circuit-breaker state transitions", ("provider", "to"))
_FALLBACKS = _telemetry.counter(
    "repro_provider_fallbacks_total",
    "Degraded collects served by a fallback source",
    ("provider", "fallback"))


# -- error taxonomy ----------------------------------------------------------


class TransientProviderError(RuntimeError):
    """A provider failure worth retrying (fault, timeout, corrupt read)."""


class ProviderCallTimeout(TransientProviderError):
    """One provider call exceeded its per-call timeout."""


class CorruptCounterError(TransientProviderError):
    """A provider returned a structurally invalid ``CounterSet``."""


class DeadlineExceeded(RuntimeError):
    """The enclosing job's time budget ran out before a result existed."""


class ResilienceExhausted(RuntimeError):
    """Every provider in the chain (and the stale cache) failed.

    ``errors`` carries the per-attempt ``(provider, exception)`` pairs so
    callers can report *why* the chain died, not just that it did.
    """

    def __init__(self, message: str, errors: Sequence[tuple] = ()) -> None:
        super().__init__(message)
        self.errors = list(errors)


# exception classes a retry may fix; anything else is treated as
# permanent for the current provider (straight to the next in the chain)
TRANSIENT_ERRORS = (TransientProviderError, TimeoutError, ConnectionError,
                    OSError)


# -- retry policy ------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded exponential backoff with jitter.

    ``retries`` is the number of *re*-tries: a call is attempted
    ``retries + 1`` times.  Delay before retry ``k`` (0-based) is
    ``min(backoff_base_s * backoff_factor**k, max_backoff_s)`` scaled by
    ``1 + jitter * u`` with ``u ~ U[0, 1)`` from the caller's rng — a
    seeded rng therefore yields a fully deterministic schedule
    (``schedule()``), which is how the edge-case tests pin it.
    """

    retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    max_backoff_s: float = 2.0
    jitter: float = 0.25

    def __post_init__(self) -> None:
        if self.retries < 0:
            raise ValueError(f"retries must be >= 0, got {self.retries}")
        if self.backoff_base_s < 0 or self.max_backoff_s < 0:
            raise ValueError("backoff bounds must be >= 0")
        if self.backoff_factor < 1.0:
            raise ValueError(
                f"backoff_factor must be >= 1, got {self.backoff_factor}")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError(f"jitter must be in [0, 1], got {self.jitter}")

    @property
    def attempts(self) -> int:
        return self.retries + 1

    def delay(self, attempt: int, rng: Optional[random.Random] = None,
              ) -> float:
        """Backoff before re-attempt ``attempt`` (0-based)."""
        base = min(self.backoff_base_s * self.backoff_factor ** attempt,
                   self.max_backoff_s)
        if self.jitter and rng is not None:
            return base * (1.0 + self.jitter * rng.random())
        return base

    def schedule(self, seed: int = 0) -> list[float]:
        """The full deterministic delay sequence for one call under
        ``seed`` — what a failing call would sleep between attempts."""
        rng = random.Random(seed)
        return [self.delay(k, rng) for k in range(self.retries)]


# -- deadlines (per-job time budgets) ----------------------------------------


class Deadline:
    """A monotonic time budget (``None`` seconds = unbounded)."""

    def __init__(self, seconds: Optional[float], *,
                 clock: Callable[[], float] = time.monotonic) -> None:
        if seconds is not None and seconds <= 0:
            raise ValueError(f"deadline must be > 0 seconds, got {seconds}")
        self._clock = clock
        self.seconds = seconds
        self._expires = None if seconds is None else clock() + seconds

    def remaining(self) -> float:
        if self._expires is None:
            return math.inf
        return self._expires - self._clock()

    @property
    def expired(self) -> bool:
        return self.remaining() <= 0


_DEADLINE: contextvars.ContextVar[Optional[Deadline]] = \
    contextvars.ContextVar("repro_resilience_deadline", default=None)
_EVENTS: contextvars.ContextVar[Optional[list]] = \
    contextvars.ContextVar("repro_resilience_events", default=None)


def current_deadline() -> Optional[Deadline]:
    """The enclosing ``resilience_scope``'s deadline, if any."""
    return _DEADLINE.get()


def record_event(event: dict) -> None:
    """Append a degradation/failure event to the enclosing scope.

    A no-op outside a scope, so ``ResilientProvider`` can always call it
    unconditionally.
    """
    events = _EVENTS.get()
    if events is not None:
        events.append(event)


@contextlib.contextmanager
def resilience_scope(timeout_s: Optional[float] = None, *,
                     clock: Callable[[], float] = time.monotonic):
    """Install a per-job deadline + event recorder for the current context.

    The service worker wraps each job in one of these; every
    ``ResilientProvider`` call underneath sees the deadline and records
    its degradations into the yielded list::

        with resilience_scope(job.timeout_s) as events:
            result = session.analyze(specs)
        degraded = [e for e in events if e.get("kind") == "fallback"]
    """
    deadline = Deadline(timeout_s, clock=clock) \
        if timeout_s is not None else None
    events: list = []
    tok_d = _DEADLINE.set(deadline)
    tok_e = _EVENTS.set(events)
    try:
        yield events
    finally:
        _DEADLINE.reset(tok_d)
        _EVENTS.reset(tok_e)


# -- per-call timeouts -------------------------------------------------------


def call_with_timeout(fn: Callable, timeout_s: Optional[float]):
    """Run ``fn()`` with a wall-clock bound; raise ``ProviderCallTimeout``.

    Python cannot preempt a running thread, so on timeout the worker
    thread is *abandoned* (daemonized — it cannot block interpreter
    exit) and its eventual result discarded.  That leaks at most one
    busy thread per hung call, which is the price of never hanging the
    caller; the circuit breaker keeps a repeatedly-hanging provider from
    piling these up.
    """
    if timeout_s is None or timeout_s == math.inf:
        return fn()
    if timeout_s <= 0:
        raise ProviderCallTimeout(
            f"no time budget left for the call ({timeout_s:.3g}s)")
    outcome: dict = {}
    done = threading.Event()

    def run() -> None:
        try:
            outcome["value"] = fn()
        except BaseException as exc:  # noqa: BLE001 — re-raised below
            outcome["error"] = exc
        finally:
            done.set()

    t = threading.Thread(target=run, daemon=True,
                         name="repro-resilience-call")
    t.start()
    if not done.wait(timeout_s):
        raise ProviderCallTimeout(
            f"provider call exceeded {timeout_s:.3g}s")
    if "error" in outcome:
        raise outcome["error"]
    return outcome["value"]


# -- circuit breaker ---------------------------------------------------------


class CircuitBreaker:
    """Per-provider closed / open / half-open failure gate.

    Closed: calls flow, consecutive failures are counted.  At
    ``failure_threshold`` the breaker opens: ``allow()`` rejects without
    paying the provider's timeout.  After ``cooldown_s`` the next
    ``allow()`` transitions to half-open and admits exactly one probe;
    the probe's outcome re-closes (success) or re-opens with a fresh
    cooldown (failure).  All transitions are lock-protected; ``clock``
    is injectable so tests drive time explicitly.
    """

    CLOSED, OPEN, HALF_OPEN = "closed", "open", "half-open"

    def __init__(self, failure_threshold: int = 5, cooldown_s: float = 30.0,
                 *, clock: Callable[[], float] = time.monotonic) -> None:
        if failure_threshold < 1:
            raise ValueError(
                f"failure_threshold must be >= 1, got {failure_threshold}")
        if cooldown_s < 0:
            raise ValueError(f"cooldown_s must be >= 0, got {cooldown_s}")
        self.failure_threshold = failure_threshold
        self.cooldown_s = cooldown_s
        self._clock = clock
        self._lock = threading.Lock()
        self._state = self.CLOSED
        self._consecutive_failures = 0
        self._opened_at: Optional[float] = None
        self._trips = 0

    def allow(self) -> bool:
        with self._lock:
            if self._state == self.CLOSED:
                return True
            if self._state == self.OPEN:
                if self._clock() - self._opened_at >= self.cooldown_s:
                    self._state = self.HALF_OPEN
                    return True          # the single half-open probe
                return False
            return False                 # half-open: probe already in flight

    def record_success(self) -> None:
        with self._lock:
            self._state = self.CLOSED
            self._consecutive_failures = 0
            self._opened_at = None

    def record_failure(self) -> None:
        with self._lock:
            self._consecutive_failures += 1
            if (self._state == self.HALF_OPEN
                    or self._consecutive_failures >= self.failure_threshold):
                self._state = self.OPEN
                self._opened_at = self._clock()
                self._trips += 1

    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def snapshot(self) -> dict:
        """The status-endpoint view of this breaker."""
        with self._lock:
            remaining = 0.0
            if self._state == self.OPEN:
                remaining = max(
                    0.0,
                    self.cooldown_s - (self._clock() - self._opened_at))
            return {
                "state": self._state,
                "consecutive_failures": self._consecutive_failures,
                "trips": self._trips,
                "cooldown_remaining_s": round(remaining, 3),
            }


# -- counter sanity ----------------------------------------------------------


def counter_set_error(cset) -> Optional[str]:
    """Why ``cset`` is not a sane ``CounterSet`` (``None`` when it is).

    The structural checks every downstream consumer silently assumes:
    per-core arrays of the declared core count, finite non-negative
    counters, finite roofline fields.  The resilience layer treats a
    violation as a transient failure (``CorruptCounterError``) so a
    corrupting backend is retried/failed over instead of poisoning the
    model evaluation or the persistent cache.
    """
    if not isinstance(cset, CounterSet):
        return f"expected a CounterSet, got {type(cset).__name__}"
    if cset.num_cores < 1:
        return f"num_cores must be >= 1, got {cset.num_cores}"
    for name in ("O", "N_f", "N_c", "N_p"):
        arr = getattr(cset, name)
        if not isinstance(arr, np.ndarray):
            return f"{name} is not an ndarray"
        if arr.shape != (cset.num_cores,):
            return (f"{name} has shape {arr.shape}, expected "
                    f"({cset.num_cores},)")
        if not np.all(np.isfinite(arr)):
            return f"{name} contains non-finite values"
        if np.any(arr < 0):
            return f"{name} contains negative counts"
    for name in ("lanes_active", "bytes_read", "flops", "ici_bytes",
                 "overhead_cycles"):
        v = getattr(cset, name)
        if not math.isfinite(v):
            return f"{name} is non-finite ({v!r})"
        if v < 0:
            return f"{name} is negative ({v!r})"
    if cset.num_waves < 0:
        return f"num_waves is negative ({cset.num_waves})"
    if cset.waves_per_tile < 1 or cset.pipeline_depth < 1:
        return (f"launch geometry out of range (waves_per_tile="
                f"{cset.waves_per_tile}, pipeline_depth="
                f"{cset.pipeline_depth})")
    if cset.wall_time_s is not None and not math.isfinite(cset.wall_time_s):
        return f"wall_time_s is non-finite ({cset.wall_time_s!r})"
    return None


def mark_degraded(cset: CounterSet, *, fallback: str,
                  primary: str) -> CounterSet:
    """Copy of ``cset`` stamped as a degraded (non-primary) result."""
    meta = {**cset.meta, "degraded": True, "fallback_provider": fallback,
            "primary_provider": primary}
    return dataclasses.replace(cset, meta=meta)


def is_degraded(cset: CounterSet) -> bool:
    return bool(cset.meta.get("degraded"))


# -- the resilient provider wrapper ------------------------------------------


class ResilientProvider:
    """A ``CounterProvider`` that survives its backends.

    ``collect`` runs the primary through per-call timeout + retry +
    breaker; on exhaustion it walks the ``fallbacks`` chain the same
    way, and as a last resort serves the primary's last known counters
    from ``stale_cache`` (the persistent ``SweepCache``).  Every
    non-primary result is stamped ``meta["degraded"]`` with the fallback
    provider's name (``"cached-stale"`` for the cache), and a matching
    event is recorded into the enclosing ``resilience_scope``.

    ``name`` mirrors the primary's so memo and cache keys are shared
    with a plain session — a spec warmed by a direct CLI sweep is a
    zero-collection hit for the service, and vice versa.  Degraded
    results never reach the disk cache (``Session`` checks
    ``is_degraded`` before write-back), so that transparency cannot
    cache another provider's numbers under the primary's key.

    ``collect_batch`` is deliberately *not* implemented: the service
    values per-point failure isolation over vectorization, so
    ``Session`` loops the resilient scalar path via its fallback.
    """

    def __init__(self, primary, *, fallbacks: Sequence = (),
                 stale_cache=None,
                 retry: RetryPolicy = RetryPolicy(),
                 call_timeout_s: Optional[float] = None,
                 breaker_threshold: int = 5,
                 breaker_cooldown_s: float = 30.0,
                 seed: int = 0,
                 clock: Callable[[], float] = time.monotonic,
                 sleep: Callable[[float], None] = time.sleep) -> None:
        from repro.analysis.providers.base import get_provider  # lazy: cycle
        self.primary = get_provider(primary)
        # identity (not name) dedup: a fault-wrapped primary shares its
        # inner provider's name, and that inner provider is still a
        # legitimate fallback
        chain = []
        for f in fallbacks:
            prov = get_provider(f)
            if prov is not self.primary and prov not in chain:
                chain.append(prov)
        self.fallbacks = chain
        self.stale_cache = stale_cache
        self.retry = retry
        self.call_timeout_s = call_timeout_s
        self.name = self.primary.name
        self._clock = clock
        self._sleep = sleep
        self._rng = random.Random(seed)
        self._rng_lock = threading.Lock()
        # one breaker per provider *instance*, not per name: a
        # fault-wrapped primary shares its inner provider's name, and the
        # primary's failures must never open the fallback's breaker
        self.breakers: dict[int, CircuitBreaker] = {}
        self._breaker_labels: dict[int, str] = {}
        for prov in [self.primary, *self.fallbacks]:
            label = prov.name
            taken = set(self._breaker_labels.values())
            k = 2
            while label in taken:
                label = f"{prov.name}#{k}"
                k += 1
            self.breakers[id(prov)] = CircuitBreaker(
                breaker_threshold, breaker_cooldown_s, clock=clock)
            self._breaker_labels[id(prov)] = label

    @staticmethod
    def _key(prov) -> str:
        return prov.name

    def breaker_states(self) -> dict:
        """Per-provider breaker snapshots (the /status payload).

        Keys are provider names, suffixed ``#2``... when two chain
        entries share one (a fault-wrapped primary and its raw inner
        provider as fallback).
        """
        return {self._breaker_labels[pid]: br.snapshot()
                for pid, br in self.breakers.items()}

    # -- the chain -------------------------------------------------------

    def collect(self, spec, device) -> CounterSet:
        deadline = current_deadline()
        errors: list[tuple[str, BaseException]] = []
        for pos, prov in enumerate([self.primary, *self.fallbacks]):
            if deadline is not None and deadline.expired:
                record_event({"kind": "deadline", "label": spec.label,
                              "provider": self._key(prov)})
                break
            cset = self._collect_one(prov, spec, device, deadline, errors)
            if cset is None:
                continue
            if pos > 0:
                cset = mark_degraded(cset, fallback=self._key(prov),
                                     primary=self.name)
                record_event({"kind": "fallback", "label": spec.label,
                              "provider": self.name,
                              "fallback": self._key(prov)})
                _FALLBACKS.inc(provider=self.name,
                               fallback=self._key(prov))
            return cset
        stale = self._collect_stale(spec, device)
        if stale is not None:
            record_event({"kind": "fallback", "label": spec.label,
                          "provider": self.name,
                          "fallback": "cached-stale"})
            _FALLBACKS.inc(provider=self.name, fallback="cached-stale")
            return stale
        detail = "; ".join(f"{name}: {type(exc).__name__}: {exc}"
                           for name, exc in errors) or "no provider admitted"
        if deadline is not None and deadline.expired:
            raise DeadlineExceeded(
                f"{spec.label!r}: job deadline exhausted before any "
                f"provider returned ({detail})")
        raise ResilienceExhausted(
            f"{spec.label!r}: every provider failed and no stale cache "
            f"entry exists ({detail})", errors)

    def _note_breaker(self, prov, before: str) -> None:
        """Count a breaker state transition (telemetry, no behaviour)."""
        after = self.breakers[id(prov)].state
        if after != before:
            _BREAKER_TRANSITIONS.inc(
                provider=self._breaker_labels[id(prov)], to=after)

    def _collect_one(self, prov, spec, device, deadline, errors):
        """Timeout + retry + breaker for one provider; None = move on."""
        br = self.breakers[id(prov)]
        for attempt in range(self.retry.attempts):
            if deadline is not None and deadline.expired:
                return None
            br_state = br.state
            admitted = br.allow()
            self._note_breaker(prov, br_state)  # open -> half-open probes
            if not admitted:
                record_event({"kind": "breaker-skip", "label": spec.label,
                              "provider": self._key(prov)})
                return None
            timeout = self.call_timeout_s
            if deadline is not None:
                remaining = deadline.remaining()
                timeout = remaining if timeout is None \
                    else min(timeout, remaining)
            t0 = time.perf_counter()
            try:
                cset = call_with_timeout(
                    lambda: prov.collect(spec, device), timeout)
                _CALL_SECONDS.observe(time.perf_counter() - t0,
                                      provider=self._key(prov))
                problem = counter_set_error(cset)
                if problem:
                    raise CorruptCounterError(
                        f"{self._key(prov)} returned corrupt counters "
                        f"for {spec.label!r}: {problem}")
                br_state = br.state
                br.record_success()
                self._note_breaker(prov, br_state)
                return cset
            except TRANSIENT_ERRORS as exc:
                br_state = br.state
                br.record_failure()
                self._note_breaker(prov, br_state)
                errors.append((self._key(prov), exc))
                _RETRIES.inc(provider=self._key(prov))
                record_event({"kind": "retry", "label": spec.label,
                              "provider": self._key(prov),
                              "attempt": attempt,
                              "error": f"{type(exc).__name__}: {exc}"})
                if attempt + 1 < self.retry.attempts:
                    delay = self._next_delay(attempt)
                    if deadline is not None:
                        delay = min(delay, max(deadline.remaining(), 0.0))
                    if delay > 0:
                        self._sleep(delay)
            except Exception as exc:  # permanent: straight to the next
                br_state = br.state
                br.record_failure()
                self._note_breaker(prov, br_state)
                errors.append((self._key(prov), exc))
                record_event({"kind": "permanent", "label": spec.label,
                              "provider": self._key(prov),
                              "error": f"{type(exc).__name__}: {exc}"})
                return None
        return None

    def _next_delay(self, attempt: int) -> float:
        with self._rng_lock:
            return self.retry.delay(attempt, self._rng)

    def _collect_stale(self, spec, device) -> Optional[CounterSet]:
        """Last-resort read of the primary's last known cached counters.

        Deliberately allowed even after the deadline: a cache read costs
        microseconds and a stale answer beats no answer — that is the
        CUTHERMO-style graceful-degradation contract.
        """
        if self.stale_cache is None:
            return None
        fp = spec.fingerprint()
        if fp is None:
            return None
        try:
            key = self.stale_cache.key(self.name, fp, device.table_key())
            hit = self.stale_cache.get(key)
        except Exception:
            return None
        if hit is None:
            return None
        hit = dataclasses.replace(hit, label=spec.label)
        return mark_degraded(hit, fallback="cached-stale",
                             primary=self.name)
