"""Public analysis API: Device registry + WorkloadSpec + Session.

The two paper tools in five lines:

    from repro.analysis import Session, WorkloadSpec
    sess = Session(device="v5e")            # Tool 1: cached S(n, e, c) table
    spec = WorkloadSpec.from_histogram(img, label="solid 256Kpx",
                                       waves_per_tile=32)
    print(sess.classify(spec).comment)      # Tool 2: utilization -> verdict

Older entry points (``repro.core.microbench.build_table`` +
``repro.core.profiler.profile_scatter_workload``) remain available but are
deprecated for direct use; new workloads should integrate here.
"""

from repro.analysis.device import (  # noqa: F401
    DEVICES,
    Device,
    default_cache_dir,
    get_device,
    register_device,
)
from repro.analysis.workload import WorkloadSpec  # noqa: F401
from repro.analysis.session import Session, SweepResult  # noqa: F401
