"""Public analysis API: Device + provider registries, WorkloadSpec, Session.

The two paper tools in five lines:

    from repro.analysis import Session, WorkloadSpec
    sess = Session(device="v5e")            # Tool 1: cached S(n, e, c) table
    spec = WorkloadSpec.from_histogram(img, label="solid 256Kpx",
                                       waves_per_tile=32)
    print(sess.classify(spec).comment)      # Tool 2: utilization -> verdict

Counter acquisition is pluggable: ``Session(provider="kernel")`` reads
counters back from the interpret-mode instrumented Pallas kernels instead
of synthesizing the trace, and ``sess.validate(spec)`` compares the two —
the paper's §5 model-vs-measured validation as one call.

Older entry points (``repro.core.microbench.build_table`` +
``repro.core.profiler.profile_scatter_workload``) remain available but are
deprecated for direct use; new workloads should integrate here.
"""

from repro.analysis.device import (  # noqa: F401
    DEVICES,
    Device,
    default_cache_dir,
    get_device,
    register_device,
)
from repro.analysis.providers import (  # noqa: F401
    PROVIDERS,
    CounterProvider,
    CounterSet,
    FaultInjectionProvider,
    HloProvider,
    InjectedFault,
    InstrumentedKernelProvider,
    MicrobenchProvider,
    TraceProvider,
    get_provider,
    register_provider,
)
from repro.analysis.resilience import (  # noqa: F401
    CircuitBreaker,
    CorruptCounterError,
    Deadline,
    DeadlineExceeded,
    ProviderCallTimeout,
    ResilienceExhausted,
    ResilientProvider,
    RetryPolicy,
    TransientProviderError,
    resilience_scope,
)
from repro.analysis.render import (  # noqa: F401
    rows_to_csv,
    union_fieldnames,
)
from repro.analysis.sweep_cache import (  # noqa: F401
    SweepCache,
    default_cache_root,
)
from repro.analysis.workload import KernelSource, WorkloadSpec  # noqa: F401
from repro.analysis.session import (  # noqa: F401
    ProviderComparison,
    Session,
    SweepResult,
    ValidationReport,
    sweep_grid,
)
from repro.core.counters import CounterFrame  # noqa: F401
from repro.core.profiler import profile_batch  # noqa: F401
