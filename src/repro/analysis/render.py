"""Shared report-rendering helpers for ragged row sets.

Sweep and advisor reports both emit "one flat record per point" csv, and
both can be *ragged*: a sweep point's ``U_*`` columns depend on its unit
set, and an advisor candidate's ``param_*`` columns depend on which
transforms it composes.  ``csv.DictWriter`` with fieldnames from the
first row raises ``ValueError`` on the first later-only column, so every
csv path must build its header as the union across ALL rows — this
module is that one rule, shared so the renderers can never drift apart.
"""

from __future__ import annotations

import csv
import io
from typing import Sequence


def union_fieldnames(rows: Sequence[dict]) -> list[str]:
    """Header union across ragged rows, in first-appearance order."""
    fieldnames: list[str] = []
    for row in rows:
        for k in row:
            if k not in fieldnames:
                fieldnames.append(k)
    return fieldnames


def rows_to_csv(rows: Sequence[dict]) -> str:
    """Render ragged dict rows as csv text (missing cells left empty).

    The union header means a row set where later rows introduce new
    columns (heterogeneous sweeps, advisor candidates with different
    transform parameters) round-trips through ``csv.DictReader`` with
    ``""`` in the holes instead of raising at write time.  Empty input
    renders as the empty string (no header to invent).
    """
    rows = list(rows)
    if not rows:
        return ""
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=union_fieldnames(rows), restval="")
    w.writeheader()
    w.writerows(rows)
    return buf.getvalue()
