"""Device registry: one bundle per modeled chip, one cached table per bundle.

The paper's Tool 1 output — the service-time table ``S(n, e, c)`` — is a
*per-device* artifact: "once per chip model" (§3.4).  Schweizer et al. and
Stevens & Klöckner both organize their atomic-cost models the same way: a
per-architecture parameter bundle plus a fitted table, looked up by device
name.  This module is that bundle for our reproduction:

    Device = ChipParams (throughput servers: MXU/HBM/ICI)
           + ScatterUnitParams (the load-dependent queue server)
           + CacheModel (LLC latency-exposure emulation)
           + lazily built, disk-cached ServiceTimeTable

Tables are cached as ``.npz`` under ``results/tables/`` keyed by device
name and a hash of the scatter-unit calibration, so a second ``Session``
(or a ``--only`` benchmark run, or a test import) never pays the full-grid
microbenchmark again.  Changing the calibration constants invalidates the
key and triggers a rebuild.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Optional, Union

from repro.core import microbench, qmodel, timing
from repro.core.profiler import CacheModel

# In-process memo so repeated Session construction in one process does not
# even touch the filesystem.  Keyed like the on-disk cache.
_TABLE_MEMO: dict[str, qmodel.ServiceTimeTable] = {}


def default_cache_dir() -> Path:
    """``results/tables/`` at the repo root (overridable per call).

    Resolved relative to this source tree so example scripts and tests
    share one cache regardless of their working directory; set the
    ``REPRO_TABLE_CACHE`` environment variable to relocate it (e.g. to a
    tmpdir in hermetic CI).
    """
    env = os.environ.get("REPRO_TABLE_CACHE")
    if env:
        return Path(env)
    return Path(__file__).resolve().parents[3] / "results" / "tables"


@dataclasses.dataclass(frozen=True)
class Device:
    """Immutable per-device parameter bundle (the registry entry)."""

    name: str
    chip: timing.ChipParams = timing.V5E
    scatter: timing.ScatterUnitParams = timing.V5E_SCATTER
    cache: CacheModel = CacheModel()
    num_cores: int = 8
    description: str = ""

    # -- table cache ------------------------------------------------------

    def table_key(self) -> str:
        """Cache key: device name + calibration hash + grid shape.

        Any change to the scatter-unit constants (the thing the table is
        built *from*) changes the key, so stale tables are never reused.
        """
        payload = json.dumps(dataclasses.asdict(self.scatter), sort_keys=True)
        digest = hashlib.sha256(payload.encode()).hexdigest()[:12]
        return (f"{self.name}-n{self.scatter.n_max}"
                f"-e{self.scatter.e_max}-{digest}")

    def table_path(self, cache_dir: Optional[Union[str, Path]] = None) -> Path:
        base = Path(cache_dir) if cache_dir is not None else default_cache_dir()
        return base / f"{self.table_key()}.npz"

    def table(self, cache_dir: Optional[Union[str, Path]] = None,
              refresh: bool = False) -> qmodel.ServiceTimeTable:
        """The device's service-time table, building it at most once.

        Resolution order: in-process memo -> ``.npz`` on disk -> full grid
        build (which is then written back to disk).  ``refresh=True``
        forces a rebuild and overwrites the cached file.
        """
        path = self.table_path(cache_dir)
        # memo key includes the resolved path: a caller asking for a
        # specific cache_dir must hit/populate THAT directory, not a table
        # memoized under a different one
        key = str(path)
        if not refresh and key in _TABLE_MEMO:
            return _TABLE_MEMO[key]
        if not refresh and path.exists():
            try:
                tab = qmodel.ServiceTimeTable.load(str(path))
            except Exception:
                tab = None  # corrupt/stale cache: fall through to rebuild
            if tab is not None:
                _TABLE_MEMO[key] = tab
                return tab
        tab = microbench.build_table(self.scatter)
        tab.meta["device"] = self.name
        path.parent.mkdir(parents=True, exist_ok=True)
        tab.save(str(path))
        _TABLE_MEMO[key] = tab
        return tab

    def describe(self, cache_dir: Optional[Union[str, Path]] = None) -> dict:
        """Flat summary record (the ``devices`` CLI listing / json row)."""
        return {
            "name": self.name,
            "description": self.description,
            "cores": self.num_cores,
            "clock_ghz": self.chip.clock_hz / 1e9,
            "hbm_gbps": self.chip.hbm_bw / 1e9,
            "table_cached": self.table_path(cache_dir).exists(),
        }

    # -- variants ---------------------------------------------------------

    def with_(self, **changes) -> "Device":
        """Derived device (e.g. a different CacheModel for case studies).

        A changed name keeps cache entries distinguishable in listings;
        the table cache itself is keyed by calibration, so variants that
        only change ``chip``/``cache``/``num_cores`` share the same table.
        """
        return dataclasses.replace(self, **changes)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

DEVICES: dict[str, Device] = {}


def register_device(device: Device) -> Device:
    DEVICES[device.name] = device
    return device


register_device(Device(
    name="v5e",
    description="TPU v5e (default calibration; paper's Titan-V analogue)",
))

# A bandwidth-rich sibling: same scatter-unit calibration scaled to a
# faster clock, ~3.4x HBM and ~2.3x peak FLOPs (public v5p specs).  Shows
# the bottleneck-shift machinery reacting to hardware balance: workloads
# that are HBM-bound on v5e stay scatter-bound longer here.
register_device(Device(
    name="v5p",
    chip=timing.ChipParams(peak_bf16_flops=459e12, hbm_bw=2765e9,
                           ici_bw_per_link=100e9, clock_hz=1.75e9,
                           vmem_bytes=128 * 1024 * 1024,
                           hbm_bytes=95 * 1024**3),
    scatter=dataclasses.replace(timing.V5E_SCATTER, clock_hz=1.75e9),
    description="TPU v5p (modeled: v5e scatter calibration at v5p clock)",
))


def get_device(name_or_device: Union[str, Device]) -> Device:
    """Look up a registry entry; a Device instance passes through."""
    if isinstance(name_or_device, Device):
        return name_or_device
    try:
        return DEVICES[name_or_device]
    except KeyError:
        known = ", ".join(sorted(DEVICES))
        raise KeyError(
            f"unknown device {name_or_device!r}; registered: {known}. "
            f"Use repro.analysis.register_device() for custom hardware."
        ) from None
