"""Session: one-call pipeline from workload spec to bottleneck verdict.

The paper promises a user can "immediately determine if shared-memory
atomic operations are a bottleneck".  A ``Session`` is that promise as an
API: it owns a ``Device`` (and therefore the cached service-time table)
plus a ``CounterProvider`` (how counters are acquired), and turns
``WorkloadSpec``s into profiles, sweeps, shift reports, and renderable
verdicts:

    sess = Session(device="v5e")              # counters via "trace"
    prof = sess.profile(spec)                 # one launch
    result = sess.sweep([spec_1, ..., spec_k])  # a parameter sweep
    print(sess.report())                      # text | json | csv

    Session(device="v5e", provider="kernel")  # counters from the
                                              # instrumented Pallas run

``validate`` is the paper's §5 as an API call: collect the same spec
through several providers (modeled vs measured) and report per-counter
relative errors and the utilization delta.
"""

from __future__ import annotations

import contextlib
import dataclasses
import io
import json
import threading
import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.analysis.device import Device, get_device
from repro.analysis.providers import (CounterProvider, get_provider,
                                      provider_collect_batch)
from repro.analysis.render import rows_to_csv
from repro.analysis.sweep_cache import SweepCache
from repro.analysis.workload import WorkloadSpec
from repro.core import bottleneck, profiler, qmodel
from repro.core import counters as counters_mod
from repro.core.counters import CounterFrame, CounterSet
from repro.obs import telemetry as _telemetry
from repro.obs.heatmap import DEFAULT_HOT_DEGREE, Heatmap, heatmap_for_spec

_SESSION_CALLS = _telemetry.counter(
    "repro_session_calls_total", "Session entry-point invocations",
    ("method",))
_SESSION_SECONDS = _telemetry.histogram(
    "repro_session_seconds", "Session entry-point latency", ("method",))
_SESSION_POINTS = _telemetry.counter(
    "repro_session_points_total", "Workload points analyzed")


@contextlib.contextmanager
def _observed(method: str, **attrs):
    """Count + time + span one Session entry point (telemetry-gated)."""
    _SESSION_CALLS.inc(method=method)
    t0 = time.perf_counter()
    with _telemetry.span(f"session.{method}", **attrs):
        yield
    _SESSION_SECONDS.observe(time.perf_counter() - t0, method=method)


@dataclasses.dataclass
class SweepResult:
    """Profiles + per-point verdicts + shift/speedup analysis for a sweep."""

    device: Device
    specs: list[WorkloadSpec]
    profiles: list[profiler.WorkloadProfile]
    verdicts: list[bottleneck.BottleneckVerdict]
    shifts: list[bottleneck.ShiftEvent]
    utilization: dict[str, np.ndarray]      # unit name -> per-point U
    speedup_vs_first: np.ndarray            # modeled T(first) / T(point)

    def __len__(self) -> int:
        return len(self.profiles)

    @property
    def bottlenecks(self) -> list[str]:
        return [p.bottleneck for p in self.profiles]

    # -- renderers --------------------------------------------------------

    def to_rows(self, structured_hints: bool = False) -> list[dict]:
        """One flat record per sweep point (the csv/json payload).

        ``e`` is the job-weighted mean across cores (matching the global
        ``e = O / N`` of ``CounterSet``/``validate``) and ``n_hat`` the
        max (the profile's peak concurrency estimate) — a multi-core
        profile must not be reported from core 0 alone.  The verdict's
        machine-usable ``hint`` rides along: compact ``action:family``
        form by default (csv/text cells), the full structured dict with
        ``structured_hints=True`` (the json payload).
        """
        rows = []
        for i, (p, v) in enumerate(zip(self.profiles, self.verdicts)):
            if v.hint is None:
                hint = None if structured_hints else ""
            elif structured_hints:
                hint = dataclasses.asdict(v.hint)
            else:
                hint = v.hint.compact()
            row = {
                "label": p.label,
                "bottleneck": v.bottleneck,
                "saturated": v.saturated,
                "comment": v.comment,
                "hint": hint,
                "scatter_model_U": p.scatter_utilization,
                "speedup_vs_first": float(self.speedup_vs_first[i]),
                "e": p.e,
                "n_hat": p.n_hat,
            }
            for u in p.units:
                row[f"U_{u.name}"] = u.utilization
            rows.append(row)
        return rows

    def _point_meta(self) -> dict[str, dict]:
        """Non-empty provider meta per point label (HLO provider fills
        ``unresolved_loops`` / ``collectives``; trace sources have none)."""
        out: dict[str, dict] = {}
        for p in self.profiles:
            meta = (p.params or {}).get("meta") or {}
            if meta:
                out[p.label] = meta
        return out

    def render(self, fmt: str = "text") -> str:
        if fmt == "json":
            payload = {
                "device": self.device.name,
                "points": self.to_rows(structured_hints=True),
                "shifts": [dataclasses.asdict(s) for s in self.shifts],
            }
            meta = self._point_meta()
            if meta:
                payload["meta"] = meta
            return json.dumps(payload, indent=2, default=str)
        if fmt == "csv":
            # Heterogeneous sweeps produce ragged rows (a point's U_*
            # columns depend on its unit set): the shared union-header
            # helper (also the advisor csv path) writes missing cells
            # empty instead of raising on later-only columns.
            return rows_to_csv(self.to_rows())
        if fmt == "text":
            buf = io.StringIO()
            multi = len(self.profiles) > 1
            head = "sweep" if multi else "profile"
            buf.write(f"== {head} on {self.device.name} "
                      f"({len(self.profiles)} point"
                      f"{'s' if multi else ''}) ==\n")
            for row in self.to_rows():
                units = "  ".join(
                    f"{k[2:]}={row[k]:6.2%}" for k in row if k.startswith("U_"))
                hint = f"  [{row['hint']}]" if row["hint"] else ""
                buf.write(f"{row['label']:>28}  {units}  "
                          f"-> {row['bottleneck']}"
                          f"{' (saturated)' if row['saturated'] else ''}"
                          f"{hint}\n")
            # shift lines are sweep properties: meaningless for one point
            if multi:
                if self.shifts:
                    for s in self.shifts:
                        buf.write(f"bottleneck shift at point {s.index}: "
                                  f"{s.unit_before} -> {s.unit_after} "
                                  f"({s.label_before} -> {s.label_after})\n")
                else:
                    buf.write("no bottleneck shifts in sweep\n")
            for label, meta in self._point_meta().items():
                parts = []
                if meta.get("unresolved_loops"):
                    parts.append(f"{meta['unresolved_loops']} unresolved "
                                 "loop trip count(s) — costs are lower "
                                 "bounds")
                coll = meta.get("collectives")
                if coll:
                    n = sum(int(d.get("count", 0)) for d in coll.values())
                    wire = sum(float(d.get("wire_bytes", 0.0))
                               for d in coll.values())
                    parts.append(f"{n} collective op(s), "
                                 f"{wire / 1e6:.1f} MB modeled wire traffic")
                if parts:
                    buf.write(f"hlo meta [{label}]: " + "; ".join(parts)
                              + "\n")
            return buf.getvalue()
        raise ValueError(f"unknown report format {fmt!r} "
                         "(expected 'text', 'json' or 'csv')")


@dataclasses.dataclass
class ProviderComparison:
    """One provider's counters + errors relative to the reference."""

    provider: str
    counters: dict               # N, O, e, n_hat, U
    rel_err: dict                # same keys, |x - ref| / |ref|
    utilization_delta: float     # U - U_ref (signed)
    wall_time_s: Optional[float] = None
    # collect_batch([spec]).row(0) exactly equals collect(spec)?  None
    # when the provider has no batch path (collect-only custom sources)
    batch_bitwise_equal: Optional[bool] = None


@dataclasses.dataclass
class ValidationReport:
    """Model-vs-measured counter comparison (paper §5 as an API call)."""

    device: str
    label: str
    reference: str                         # provider name errors are vs
    comparisons: list[ProviderComparison]

    @property
    def max_rel_err(self) -> float:
        return max((e for c in self.comparisons
                    for e in c.rel_err.values()), default=0.0)

    def rel_err(self, provider: str, counter: str) -> float:
        for c in self.comparisons:
            if c.provider == provider:
                return c.rel_err[counter]
        raise KeyError(provider)

    def to_dict(self) -> dict:
        def finite(v):
            # a zero reference with a nonzero counter yields rel_err=inf;
            # JSON has no Infinity, so emit null there
            if isinstance(v, float) and not np.isfinite(v):
                return None
            return v

        comparisons = []
        for c in self.comparisons:
            d = dataclasses.asdict(c)
            d["rel_err"] = {k: finite(v) for k, v in d["rel_err"].items()}
            comparisons.append(d)
        return {
            "device": self.device, "label": self.label,
            "reference": self.reference, "comparisons": comparisons,
        }

    def render(self, fmt: str = "text") -> str:
        if fmt == "json":
            return json.dumps(self.to_dict(), indent=2)
        if fmt != "text":
            raise ValueError(f"unknown report format {fmt!r} "
                             "(expected 'text' or 'json')")
        buf = io.StringIO()
        buf.write(f"== validation: {self.label} on {self.device} "
                  f"(reference: {self.reference}) ==\n")
        keys = list(self.comparisons[0].counters) if self.comparisons else []
        buf.write(f"{'provider':>12}  "
                  + "  ".join(f"{k:>12}" for k in keys) + "\n")
        for c in self.comparisons:
            buf.write(f"{c.provider:>12}  "
                      + "  ".join(f"{c.counters[k]:>12.4g}" for k in keys)
                      + "\n")
            if c.provider != self.reference:
                buf.write(f"{'rel err':>12}  "
                          + "  ".join(f"{c.rel_err[k]:>12.2%}" for k in keys)
                          + "\n")
        buf.write(f"max relative error: {self.max_rel_err:.2%}\n")
        checked = [c for c in self.comparisons
                   if c.batch_bitwise_equal is not None]
        if checked:
            bad = [c.provider for c in checked if not c.batch_bitwise_equal]
            if bad:
                buf.write("batch collection MISMATCH (collect_batch != "
                          "collect): " + ", ".join(bad) + "\n")
            else:
                buf.write("batch collection bit-identical: "
                          + ", ".join(c.provider for c in checked) + "\n")
        return buf.getvalue()


class Session:
    """The single public entry point for the paper's two tools.

    Tool 1 (the per-device table) runs implicitly — construction resolves
    the device's cached ``ServiceTimeTable``, building it only on first
    ever use.  Tool 2 is ``profile``/``sweep``, with counters acquired by
    ``provider`` (a registry name or a ``CounterProvider`` instance;
    default ``"trace"``, the modeled path).
    """

    def __init__(self, device: Union[str, Device] = "v5e", *,
                 table: Optional[qmodel.ServiceTimeTable] = None,
                 cache_dir=None, use_true_n: bool = False,
                 provider: Union[str, CounterProvider] = "trace",
                 shift_tol: float = bottleneck.SHIFT_TOL,
                 persistent_cache: Union[bool, str, SweepCache] = False,
                 ) -> None:
        self.device = get_device(device)
        self.provider = get_provider(provider)
        self.table = table if table is not None \
            else self.device.table(cache_dir)
        self.use_true_n = use_true_n
        self.shift_tol = shift_tol
        self._last: Optional[SweepResult] = None
        # per-point memo for sweeps: (provider, fingerprint) -> CounterSet
        self._collect_memo: dict[tuple[str, str], CounterSet] = {}
        self._memo_lock = threading.Lock()
        # cross-process counter cache (results/cache/): False = off,
        # True = default root, or a path / SweepCache instance.  The CLI
        # turns it on for sweeps; the Python API keeps it opt-in.
        if isinstance(persistent_cache, SweepCache):
            self.sweep_cache: Optional[SweepCache] = persistent_cache
        elif persistent_cache:
            self.sweep_cache = SweepCache(
                None if persistent_cache is True else persistent_cache)
        else:
            self.sweep_cache = None
        # collection accounting, consistent across the scalar, batch, and
        # persistent-cache paths: points actually collected, points served
        # from the in-process memo / the on-disk sweep cache, and how many
        # provider batch calls the collected points took (O(groups), not
        # O(points))
        self.stats = {"collected": 0, "memo_hits": 0, "disk_hits": 0,
                      "batch_calls": 0}

    # -- the pipeline -----------------------------------------------------

    def collect(self, spec: WorkloadSpec,
                provider: Union[str, CounterProvider, None] = None,
                ) -> CounterSet:
        """Acquire the spec's counters (this session's provider by default)."""
        prov = self.provider if provider is None else get_provider(provider)
        return prov.collect(spec, self.device)

    def profile(self, spec: WorkloadSpec) -> profiler.WorkloadProfile:
        """Run one spec through counters -> queue model -> utilization.

        A single point is just a one-row ``CounterFrame`` through the
        same columnar batch path sweeps use.
        """
        with _observed("profile", label=spec.label):
            self._last = self.analyze([spec])
        return self._last.profiles[0]

    def classify(self, spec: WorkloadSpec) -> bottleneck.BottleneckVerdict:
        """Spec straight to verdict (the paper's 'immediately determine')."""
        self.profile(spec)
        return self._last.verdicts[0]

    def sweep(self, specs: Sequence[WorkloadSpec], *,
              parallel: Optional[int] = None,
              shards: int = 1, shard_index: int = 0) -> SweepResult:
        """Profile every spec and analyze the sweep as a whole.

        Two phases.  *Collection* runs the batch path
        (``collect_cached_batch``): points are partitioned into
        in-process memo hits, bulk on-disk ``SweepCache`` reads (when
        ``persistent_cache`` is set), and one ``provider.collect_batch``
        call per remaining miss group — a warm sweep touches zero
        providers, a cold one makes O(groups) provider calls instead of
        O(points).  ``parallel`` threads the loop fallback of providers
        with no vectorized batch.  *Model evaluation*: all points go
        through ``profiler.profile_batch`` as one columnar
        ``CounterFrame`` pass — the whole §3 queue model in whole-array
        numpy ops, point-for-point identical to the per-point path.
        Result order always matches ``specs`` — neither phase reorders.

        ``shards``/``shard_index`` turn the call into one shard of a
        distributed sweep: the grid is deterministically strided as
        ``specs[shard_index::shards]`` (every process slices the same
        full grid the same way), each shard runs independently, and
        shards merge through the persistent ``SweepCache`` as the shared
        backing store — a follow-up full-grid sweep (or the CLI's
        ``--merge``) assembles the complete result from cache hits.
        """
        specs = list(specs)
        if not specs:
            raise ValueError("sweep() needs at least one WorkloadSpec")
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        if not 0 <= shard_index < shards:
            raise ValueError(f"shard_index must be in [0, {shards}), "
                             f"got {shard_index}")
        if shards > 1:
            specs = specs[shard_index::shards]
            if not specs:
                raise ValueError(
                    f"shard {shard_index}/{shards} owns no points — the "
                    f"grid is smaller than the shard count")
        with _observed("sweep", points=len(specs)):
            self._last = self.analyze(specs, parallel=parallel)
        return self._last

    def analyze(self, specs: Sequence[WorkloadSpec], *,
                parallel: Optional[int] = None) -> SweepResult:
        """``sweep``'s pipeline without touching session-wide report state.

        Collection and model evaluation exactly as ``sweep`` runs them
        (memo + persistent cache + batch providers, then one columnar
        ``profile_batch`` pass per core-count group), but the result is
        only *returned* — ``last``/``report()`` are untouched.  This is
        the entry point for concurrent callers sharing one session (the
        ``repro.service`` worker pool): the memo and stats are
        lock-protected, and with no ``_last`` mutation two jobs can run
        through the same session without racing each other's reports.
        """
        specs = list(specs)
        if not specs:
            raise ValueError("analyze() needs at least one WorkloadSpec")
        with _observed("analyze", points=len(specs)):
            _SESSION_POINTS.inc(len(specs))
            with _telemetry.span("session.collect", points=len(specs)):
                csets = self.collect_cached_batch(specs, parallel=parallel)
            with _telemetry.span("session.model", points=len(specs)):
                return self._as_result(specs, self._profile_batch(csets))

    def advise(self, spec: WorkloadSpec, *, catalog=None, depth: int = 2,
               beam_width: int = 8, top_k: int = 5, validate_top: int = 0,
               parallel: Optional[int] = None):
        """Search workload transforms around ``spec``; rank predicted fixes.

        The ``repro.advisor`` subsystem as a session call: enumerate
        legal transform compositions (channel rotation, bin replication,
        CAS→FAO substitution, launch geometry, lane interleave — or a
        custom ``catalog``), collect each candidate's counters through
        this session's provider + memo + persistent cache, score every
        frontier with one columnar ``profile_batch`` evaluation, and
        return the ranked ``AdvisorReport``.  ``validate_top`` re-checks
        that many top candidates through the ``kernel`` provider (paper
        §5's model-vs-measured).
        """
        from repro.advisor.search import AdvisorSearch  # lazy: layer above
        return AdvisorSearch(
            self, catalog=catalog, depth=depth, beam_width=beam_width,
        ).search(spec, top_k=top_k, validate_top=validate_top,
                 parallel=parallel)

    def audit(self, source, *, label: str = "module", rules=None,
              suppress: Sequence[str] = (), num_cores: int = 8):
        """Static contention lint of an HLO-bearing source.

        ``source`` may be HLO module text, a jax ``Lowered`` (audited at
        its pre-optimization HLO), a jax ``Compiled``, or a
        ``WorkloadSpec`` built with ``from_compiled``.  Scans for
        atomic-shaped sites (scatters, KV-cache writes, one-hot /
        sort-segment histograms), scores each matched rule with one
        columnar model pass, and returns an ``AuditReport`` — this
        session's trace/kernel providers are never invoked.
        """
        from repro.audit import audit_source  # lazy: layer above
        return audit_source(source, session=self, label=label,
                            rules=rules, suppress=suppress,
                            num_cores=num_cores)

    def lint(self, kernels: Optional[Sequence[str]] = None, *,
             suppress: Sequence[str] = (),
             num_cores: Optional[int] = None):
        """Symbolic jaxpr-level lint of registered Pallas kernels.

        One level below ``audit``: traces each kernel (or a
        ``WorkloadSpec`` passed in place of a name) to its jaxpr, walks
        it for scatter/accumulate sites, and — where the index stream
        is statically derivable — proves the exact degree distribution
        with zero kernel executions, scoring findings through the same
        columnar model pass the audit uses.  Returns an
        ``AuditReport`` carrying KERN001–KERN005 findings.
        """
        from repro.lint import lint_registry, lint_spec  # lazy layer
        if kernels is not None and not isinstance(kernels, (list, tuple)):
            return lint_spec(kernels, session=self, suppress=suppress,
                             num_cores=num_cores)
        return lint_registry(kernels, session=self, suppress=suppress,
                             num_cores=num_cores)

    def heatmap(self, spec: WorkloadSpec, *,
                hot_degree: float = DEFAULT_HOT_DEGREE) -> Heatmap:
        """Per-bin contention attribution for one workload point.

        Turns the trace provider's committed index stream into per-bin
        hit counts, serialized-replay counts, per-bin max wave degree,
        and the per-wave contention series (``repro.obs.heatmap``) —
        "the unit is saturated" becomes "these bins are, and the skew
        peaks at wave W".  The embedded ``CounterSet`` is bitwise-equal
        to what ``profile`` reports for the same spec; only ``kernel``
        and ``indices`` sources carry a stream to attribute.
        """
        with _observed("heatmap", label=spec.label):
            return heatmap_for_spec(spec, hot_degree=hot_degree)

    def speedup(self, before: WorkloadSpec, after: WorkloadSpec) -> float:
        """Predicted speedup of ``after`` over ``before``.

        Records both profiles as the session's last result, so a
        following ``report()`` shows the pair (not a stale earlier run).
        """
        result = self.sweep([before, after])
        return float(result.speedup_vs_first[1])

    def validate(self, spec: WorkloadSpec,
                 providers: Sequence[Union[str, CounterProvider]] = (
                     "trace", "kernel"),
                 *, check_batch: bool = True) -> ValidationReport:
        """Collect one spec through several providers and compare counters.

        The paper's §5 validation as a first-class call: the first
        provider is the reference (modeled), the rest are compared against
        it with per-counter relative errors (``N``, ``O``, ``e``,
        ``n_hat``) and the scatter-utilization delta.

        With ``check_batch`` (the default) every provider that implements
        ``collect_batch`` is additionally collected as a batch of one and
        compared bit-for-bit against its scalar ``collect`` — the batch
        path's acceptance invariant, reported per provider as
        ``batch_bitwise_equal`` (``None`` for collect-only providers).
        """
        provs = [get_provider(p) for p in providers]
        if len(provs) < 2:
            raise ValueError("validate() needs at least two providers")
        csets = [p.collect(spec, self.device) for p in provs]
        batch_equal: list[Optional[bool]] = []
        for p, cset in zip(provs, csets):
            if check_batch and hasattr(p, "collect_batch"):
                row = p.collect_batch([spec], self.device).row(0)
                # a provider that measures wall time (microbench) can
                # never repeat the clock bit-for-bit — the check covers
                # every modeled field, not the timing
                ignore = (("wall_time_s", "meta")
                          if cset.wall_time_s is not None else ())
                batch_equal.append(
                    counters_mod.bitwise_equal(cset, row, ignore=ignore))
            else:
                batch_equal.append(None)
        profiles = self._profile_batch(csets)

        def numbers(cset: CounterSet, prof) -> dict:
            return {
                "N": cset.total_jobs,
                "O": cset.total_O,
                "e": cset.e,
                "n_hat": prof.n_hat,
                "U": prof.scatter_utilization,
            }

        ref = numbers(csets[0], profiles[0])
        comparisons = []
        for prov, cset, prof, beq in zip(provs, csets, profiles,
                                         batch_equal):
            got = numbers(cset, prof)
            rel = {
                k: (abs(got[k] - ref[k]) / abs(ref[k]) if ref[k]
                    else (0.0 if got[k] == ref[k] else float("inf")))
                for k in ref
            }
            comparisons.append(ProviderComparison(
                provider=prov.name, counters=got, rel_err=rel,
                utilization_delta=got["U"] - ref["U"],
                wall_time_s=cset.wall_time_s,
                batch_bitwise_equal=beq))
        return ValidationReport(
            device=self.device.name, label=spec.label,
            reference=provs[0].name, comparisons=comparisons)

    # -- reporting --------------------------------------------------------

    @property
    def last(self) -> Optional[SweepResult]:
        return self._last

    def stats_snapshot(self) -> dict:
        """Point-in-time copy of the collection accounting, taken under
        the memo lock (the /status endpoint's consistent read)."""
        with self._memo_lock:
            return dict(self.stats)

    def report(self, fmt: str = "text") -> str:
        """Render the most recent profile()/sweep() result."""
        if self._last is None:
            raise RuntimeError("nothing profiled yet — call profile() or "
                               "sweep() before report()")
        return self._last.render(fmt)

    # -- building blocks for layered tools (the advisor) ------------------

    def collect_cached(self, spec: WorkloadSpec) -> CounterSet:
        """``collect`` behind this session's memo + persistent cache.

        The scalar face of ``collect_cached_batch`` (a batch of one):
        layered tools like the advisor call this so their counter
        acquisition shares the same in-process memo and on-disk
        ``SweepCache`` a ``sweep`` would use.
        """
        return self.collect_cached_batch([spec])[0]

    def collect_cached_batch(self, specs: Sequence[WorkloadSpec], *,
                             parallel: Optional[int] = None,
                             ) -> list[CounterSet]:
        """Batch cache resolution: memo -> bulk disk reads -> providers.

        The sweep engine's collection phase.  Per point, in order:

        1. in-process memo by ``(provider, fingerprint)`` — including
           duplicates *within this batch* (later occurrences of a
           fingerprint count as memo hits, exactly as the sequential
           scalar path would see them);
        2. bulk ``SweepCache.get_many`` for the remaining fingerprints
           (when ``persistent_cache`` is set);
        3. one ``provider.collect_batch`` per ``num_cores`` group of the
           still-missing specs (``CounterFrame`` rows are rectangular),
           with bulk write-back to the memo and the disk cache.

        Specs whose content cannot be hashed (``fingerprint() is None``)
        bypass the caches and are collected point by point.  Hits are
        *relabeled copies* — the fingerprint excludes the label, so
        cached counters may carry another point's name.  Output order
        matches ``specs``.
        """
        specs = list(specs)
        out: list = [None] * len(specs)
        pending: list[tuple[int, str]] = []   # cache-eligible memo misses
        first_of_fp: dict[str, int] = {}
        duplicates: list[tuple[int, int]] = []
        for i, spec in enumerate(specs):
            fp = spec.fingerprint()
            if fp is None:
                out[i] = self.collect(spec)
                with self._memo_lock:
                    self.stats["collected"] += 1
                continue
            with self._memo_lock:
                hit = self._collect_memo.get((self.provider.name, fp))
            if hit is not None:
                with self._memo_lock:
                    self.stats["memo_hits"] += 1
                out[i] = dataclasses.replace(hit, label=spec.label)
                continue
            if fp in first_of_fp:
                duplicates.append((i, first_of_fp[fp]))
                with self._memo_lock:
                    self.stats["memo_hits"] += 1
                continue
            first_of_fp[fp] = i
            pending.append((i, fp))
        # bulk disk reads for the memo misses
        misses: list[tuple[int, str, Optional[str]]] = []
        if pending and self.sweep_cache is not None:
            disk_keys = {
                i: self.sweep_cache.key(self.provider.name, fp,
                                        self.device.table_key())
                for i, fp in pending}
            found = self.sweep_cache.get_many(disk_keys.values())
            for i, fp in pending:
                hit = found.get(disk_keys[i])
                if hit is not None:
                    with self._memo_lock:
                        self.stats["disk_hits"] += 1
                        self._collect_memo[(self.provider.name, fp)] = hit
                    out[i] = dataclasses.replace(hit, label=specs[i].label)
                else:
                    misses.append((i, fp, disk_keys[i]))
        else:
            misses = [(i, fp, None) for i, fp in pending]
        # one provider batch per num_cores group (frames are rectangular)
        by_cores: dict[int, list] = {}
        for item in misses:
            by_cores.setdefault(specs[item[0]].num_cores, []).append(item)
        for items in by_cores.values():
            group = [specs[i] for i, _, _ in items]
            frame = provider_collect_batch(self.provider, group,
                                           self.device, parallel)
            with self._memo_lock:
                self.stats["collected"] += len(group)
                self.stats["batch_calls"] += 1
            write_back = {}
            for row, (i, fp, disk_key) in enumerate(items):
                cset = frame.row(row)
                with self._memo_lock:
                    self._collect_memo[(self.provider.name, fp)] = cset
                # degraded counters (a resilient provider's fallback or
                # stale result) stay out of the persistent cache: under
                # the primary's key they would masquerade as its numbers
                # for every future process.  The in-process memo keeps
                # them (warm resubmission still collects nothing), and
                # the meta stamp survives so reports stay honest.
                if disk_key is not None and not cset.meta.get("degraded"):
                    write_back[disk_key] = cset
                out[i] = dataclasses.replace(cset, label=specs[i].label)
            if write_back:
                self.sweep_cache.put_many(write_back)
        # duplicates resolve off their batch-mate's now-filled slot
        for i, j in duplicates:
            out[i] = dataclasses.replace(out[j], label=specs[i].label)
        return out

    def profile_sets(self, csets: Sequence[CounterSet],
                     ) -> list[profiler.WorkloadProfile]:
        """Columnar model evaluation of pre-collected CounterSets.

        One ``CounterFrame``/``profile_batch`` pass per ``num_cores``
        group (a single pass when all rows share a core count — the
        advisor's frontier invariant), in input order.
        """
        return self._profile_batch(list(csets))

    # -- internals --------------------------------------------------------

    def _profile_batch(self, csets: Sequence[CounterSet],
                       ) -> list[profiler.WorkloadProfile]:
        """Columnar model evaluation for many CounterSets at once.

        A ``CounterFrame`` is rectangular (points x cores), so a sweep
        mixing core counts is grouped by ``num_cores`` first — each group
        is one ``profile_batch`` pass, and results are reassembled in the
        original point order.
        """
        profiles: list = [None] * len(csets)
        by_cores: dict[int, list[int]] = {}
        for i, cs in enumerate(csets):
            by_cores.setdefault(cs.num_cores, []).append(i)
        for idxs in by_cores.values():
            frame = CounterFrame.from_sets([csets[i] for i in idxs])
            outs = profiler.profile_batch(
                frame, self.table,
                params=self.device.scatter,
                chip=self.device.chip,
                cache=self.device.cache,
                use_true_n=self.use_true_n,
            )
            for i, prof in zip(idxs, outs):
                profiles[i] = prof
        return profiles

    def _as_result(self, specs, profiles) -> SweepResult:
        verdicts = [bottleneck.classify(p) for p in profiles]
        shifts = bottleneck.detect_shifts(profiles, tol=self.shift_tol)
        utilization = profiler.utilization_sweep(profiles)
        speedups = np.array([
            bottleneck.speedup_estimate(profiles[0], p) for p in profiles])
        return SweepResult(
            device=self.device, specs=list(specs), profiles=list(profiles),
            verdicts=verdicts, shifts=shifts, utilization=utilization,
            speedup_vs_first=speedups)


def sweep_grid(base: WorkloadSpec, axes: Optional[dict] = None, *,
               devices: Sequence[Union[str, Device]] = ("v5e",),
               provider: Union[str, CounterProvider] = "trace",
               parallel: Optional[int] = None,
               shards: int = 1, shard_index: int = 0,
               **session_kw) -> dict[str, SweepResult]:
    """Expand a base spec over a parameter grid and sweep it per device.

    The grid engine's one-call form: ``axes`` are ``WorkloadSpec.grid``
    axes (spec fields -> value lists), ``devices`` is the outermost axis
    (each device is its own ``Session`` — a service-time table is a
    per-device artifact, so a device cannot be an in-spec axis).  Returns
    ``{device_name: SweepResult}`` in the given device order::

        results = sweep_grid(
            WorkloadSpec.from_indices(idx, 256, label="uniform"),
            {"waves_per_tile": [4, 8, 32], "pipeline_depth": [2, 4]},
            devices=("v5e", "v5p"), parallel=8)

    Extra keyword arguments are forwarded to each ``Session`` (e.g.
    ``cache_dir``, ``use_true_n``, ``shift_tol``).
    ``shards``/``shard_index`` stride the expanded grid the same way
    ``Session.sweep`` does — every device sweeps this shard's slice.
    """
    specs = base.grid(**axes) if axes else [base]
    out: dict[str, SweepResult] = {}
    for dev in devices:
        sess = Session(dev, provider=provider, **session_kw)
        out[sess.device.name] = sess.sweep(
            specs, parallel=parallel, shards=shards, shard_index=shard_index)
    return out
