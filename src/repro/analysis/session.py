"""Session: one-call pipeline from workload spec to bottleneck verdict.

The paper promises a user can "immediately determine if shared-memory
atomic operations are a bottleneck".  A ``Session`` is that promise as an
API: it owns a ``Device`` (and therefore the cached service-time table)
and turns ``WorkloadSpec``s into profiles, sweeps, shift reports, and
renderable verdicts:

    sess = Session(device="v5e")
    prof = sess.profile(spec)                 # one launch
    result = sess.sweep([spec_1, ..., spec_k])  # a parameter sweep
    print(sess.report())                      # text | json | csv
"""

from __future__ import annotations

import csv
import dataclasses
import io
import json
from typing import Optional, Sequence, Union

import numpy as np

from repro.analysis.device import Device, get_device
from repro.analysis.workload import WorkloadSpec
from repro.core import bottleneck, profiler, qmodel


@dataclasses.dataclass
class SweepResult:
    """Profiles + per-point verdicts + shift/speedup analysis for a sweep."""

    device: Device
    specs: list[WorkloadSpec]
    profiles: list[profiler.WorkloadProfile]
    verdicts: list[bottleneck.BottleneckVerdict]
    shifts: list[bottleneck.ShiftEvent]
    utilization: dict[str, np.ndarray]      # unit name -> per-point U
    speedup_vs_first: np.ndarray            # modeled T(first) / T(point)

    def __len__(self) -> int:
        return len(self.profiles)

    @property
    def bottlenecks(self) -> list[str]:
        return [p.bottleneck for p in self.profiles]

    # -- renderers --------------------------------------------------------

    def to_rows(self) -> list[dict]:
        """One flat record per sweep point (the csv/json payload)."""
        rows = []
        for i, (p, v) in enumerate(zip(self.profiles, self.verdicts)):
            row = {
                "label": p.label,
                "bottleneck": v.bottleneck,
                "saturated": v.saturated,
                "comment": v.comment,
                "scatter_model_U": p.scatter_utilization,
                "speedup_vs_first": float(self.speedup_vs_first[i]),
                "e": p.per_core[0].e if p.per_core else 0.0,
                "n_hat": p.per_core[0].n_hat if p.per_core else 0.0,
            }
            for u in p.units:
                row[f"U_{u.name}"] = u.utilization
            rows.append(row)
        return rows

    def render(self, fmt: str = "text") -> str:
        if fmt == "json":
            payload = {
                "device": self.device.name,
                "points": self.to_rows(),
                "shifts": [dataclasses.asdict(s) for s in self.shifts],
            }
            return json.dumps(payload, indent=2)
        if fmt == "csv":
            rows = self.to_rows()
            if not rows:
                return ""
            buf = io.StringIO()
            w = csv.DictWriter(buf, fieldnames=list(rows[0]))
            w.writeheader()
            w.writerows(rows)
            return buf.getvalue()
        if fmt == "text":
            buf = io.StringIO()
            buf.write(f"== sweep on {self.device.name} "
                      f"({len(self.profiles)} points) ==\n")
            for row in self.to_rows():
                units = "  ".join(
                    f"{k[2:]}={row[k]:6.2%}" for k in row if k.startswith("U_"))
                buf.write(f"{row['label']:>28}  {units}  "
                          f"-> {row['bottleneck']}"
                          f"{' (saturated)' if row['saturated'] else ''}\n")
            if self.shifts:
                for s in self.shifts:
                    buf.write(f"bottleneck shift at point {s.index}: "
                              f"{s.unit_before} -> {s.unit_after} "
                              f"({s.label_before} -> {s.label_after})\n")
            else:
                buf.write("no bottleneck shifts in sweep\n")
            return buf.getvalue()
        raise ValueError(f"unknown report format {fmt!r} "
                         "(expected 'text', 'json' or 'csv')")


class Session:
    """The single public entry point for the paper's two tools.

    Tool 1 (the per-device table) runs implicitly — construction resolves
    the device's cached ``ServiceTimeTable``, building it only on first
    ever use.  Tool 2 is ``profile``/``sweep``.
    """

    def __init__(self, device: Union[str, Device] = "v5e", *,
                 table: Optional[qmodel.ServiceTimeTable] = None,
                 cache_dir=None, use_true_n: bool = False) -> None:
        self.device = get_device(device)
        self.table = table if table is not None \
            else self.device.table(cache_dir)
        self.use_true_n = use_true_n
        self._last: Optional[SweepResult] = None

    # -- the pipeline -----------------------------------------------------

    def profile(self, spec: WorkloadSpec) -> profiler.WorkloadProfile:
        """Run one spec through counters -> queue model -> utilization."""
        prof = self._profile_only(spec)
        self._last = self._as_result([spec], [prof])
        return prof

    def classify(self, spec: WorkloadSpec) -> bottleneck.BottleneckVerdict:
        """Spec straight to verdict (the paper's 'immediately determine')."""
        self.profile(spec)
        return self._last.verdicts[0]

    def sweep(self, specs: Sequence[WorkloadSpec]) -> SweepResult:
        """Profile every spec and analyze the sweep as a whole."""
        specs = list(specs)
        if not specs:
            raise ValueError("sweep() needs at least one WorkloadSpec")
        profiles = [self._profile_only(s) for s in specs]
        self._last = self._as_result(specs, profiles)
        return self._last

    def speedup(self, before: WorkloadSpec, after: WorkloadSpec) -> float:
        """Predicted speedup of ``after`` over ``before``."""
        return bottleneck.speedup_estimate(self._profile_only(before),
                                           self._profile_only(after))

    # -- reporting --------------------------------------------------------

    @property
    def last(self) -> Optional[SweepResult]:
        return self._last

    def report(self, fmt: str = "text") -> str:
        """Render the most recent profile()/sweep() result."""
        if self._last is None:
            raise RuntimeError("nothing profiled yet — call profile() or "
                               "sweep() before report()")
        return self._last.render(fmt)

    # -- internals --------------------------------------------------------

    def _profile_only(self, spec: WorkloadSpec) -> profiler.WorkloadProfile:
        return profiler.profile_scatter_workload(
            spec.resolve_trace(), self.table,
            label=spec.label,
            bytes_read=spec.bytes_read,
            flops=spec.flops,
            num_cores=spec.num_cores,
            overhead_cycles=spec.overhead_cycles,
            params=self.device.scatter,
            chip=self.device.chip,
            cache=self.device.cache,
            use_true_n=self.use_true_n,
        )

    def _as_result(self, specs, profiles) -> SweepResult:
        verdicts = [bottleneck.classify(p) for p in profiles]
        shifts = bottleneck.detect_shifts(profiles)
        utilization = profiler.utilization_sweep(profiles)
        speedups = np.array([
            bottleneck.speedup_estimate(profiles[0], p) for p in profiles])
        return SweepResult(
            device=self.device, specs=list(specs), profiles=list(profiles),
            verdicts=verdicts, shifts=shifts, utilization=utilization,
            speedup_vs_first=speedups)
