"""Pluggable counter-acquisition backends for the analysis Session.

One acquisition API for modeled, measured, and HLO-derived counters::

    Session(device="v5e", provider="kernel").classify(spec)
    Session(device="v5e").validate(spec, providers=("trace", "kernel"))

See ``base`` for the ``CounterProvider`` protocol and registry, and the
sibling modules for the four shipped providers.
"""

from repro.analysis.providers.base import (  # noqa: F401
    PROVIDERS,
    CounterProvider,
    collect_batch_fallback,
    get_provider,
    provider_collect_batch,
    register_provider,
)
from repro.analysis.providers.fault import (  # noqa: F401
    FaultInjectionProvider,
    InjectedFault,
)
from repro.analysis.providers.hlo import HloProvider  # noqa: F401
from repro.analysis.providers.kernel import (  # noqa: F401
    InstrumentedKernelProvider,
)
from repro.analysis.providers.microbench import MicrobenchProvider  # noqa: F401
from repro.analysis.providers.trace import TraceProvider  # noqa: F401
from repro.core.counters import CounterSet  # noqa: F401
