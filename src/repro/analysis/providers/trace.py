"""TraceProvider: the modeled counter path (no Pallas launch).

This is the acquisition backend the pre-provider ``Session`` hardwired:
counters derived from a wave trace built on the host.  For ``indices``
and ``trace`` sources that is exactly the old behaviour; for ``kernel``
sources it *synthesizes the kernel's committed index stream in numpy*
(``committed_index_stream`` mirrors the in-kernel issue ordering bit for
bit) instead of launching the interpret-mode kernel — the "modeled"
column of the paper's §5 model-vs-measured validation, and orders of
magnitude faster than a Pallas interpret run.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.analysis.providers.base import register_provider
from repro.core import counters as counters_mod
from repro.core.counters import CounterFrame, CounterSet


class TraceProvider:
    """Counters from a host-synthesized wave trace (see module docstring)."""

    name = "trace"

    def collect(self, spec, device) -> CounterSet:
        del device  # trace synthesis is device-independent
        if spec.kernel is not None:
            tr = self._synthesize(spec)
        else:
            tr = spec.resolve_trace()
        return self._from_trace(tr, spec)

    def collect_batch(self, specs: Sequence, device, *,
                      parallel: Optional[int] = None) -> CounterFrame:
        """Vectorized batch collection: one frame row per spec.

        Every spec whose counters come from a committed index stream
        (``indices`` sources and ``kernel`` sources, whose streams the
        kernel ops synthesize in numpy) is routed through
        ``traces_from_index_batch`` and the stacked per-core aggregation
        of ``countersets_from_traces``, so the whole grid's wave degrees
        AND counter bundles come out of a few large numpy ops.
        Pre-recorded ``trace`` sources and opaque ``run`` callables keep
        the scalar path per point.  Rows are bit-for-bit equal to
        ``collect`` — neither the batch degree kernel nor the stacked
        aggregation ever mixes rows (asserted per provider by
        ``Session.validate`` and the ``collect_batch_vs_loop`` canary).
        """
        del parallel  # the vectorized path has no per-point loop to thread
        specs = list(specs)
        if not specs:
            raise ValueError("collect_batch needs at least one spec")
        csets: list = [None] * len(specs)
        planned: list[int] = []
        streams, classes, wpts, depths, cores = [], [], [], [], []
        for i, spec in enumerate(specs):
            if spec.kernel is not None:
                stream, job_class, wpt = self._stream_plan(spec)
            elif spec.indices is not None:
                stream = np.asarray(spec.indices).reshape(-1)
                job_class = spec.job_class
                wpt = spec.waves_per_tile or 1
            else:
                csets[i] = self.collect(spec, device)
                continue
            planned.append(i)
            streams.append(stream)
            classes.append(job_class)
            wpts.append(wpt)
            depths.append(spec.pipeline_depth or 2)
            cores.append(spec.num_cores)
        if planned:
            traces = counters_mod.traces_from_index_batch(
                streams, num_cores=cores, job_class=classes,
                waves_per_tile=wpts, pipeline_depth=depths)
            batch_sets = counters_mod.countersets_from_traces(
                traces,
                labels=[specs[i].label for i in planned],
                num_cores=cores,
                bytes_read=[specs[i].bytes_read for i in planned],
                flops=[specs[i].flops for i in planned],
                overhead_cycles=[specs[i].overhead_cycles for i in planned],
                source=self.name)
            for i, cs in zip(planned, batch_sets):
                csets[i] = cs
        return CounterFrame.from_sets(csets)

    def committed_stream(self, spec):
        """(stream, job_class, waves_per_tile) for attributable specs.

        The public stream-planning hook the observability layer rides
        (``repro.obs.heatmap``): the exact committed index stream,
        class, and geometry this provider feeds ``trace_from_indices``,
        so per-bin attribution stays bit-consistent with ``collect``.
        Sources that carry no index stream (pre-recorded ``trace``,
        opaque ``run``, ``hlo``) cannot be attributed per bin.
        """
        if spec.kernel is not None:
            return self._stream_plan(spec)
        if spec.indices is not None:
            return (np.asarray(spec.indices).reshape(-1),
                    spec.job_class, spec.waves_per_tile or 1)
        raise ValueError(
            f"spec {spec.label!r} has no committed index stream to "
            f"attribute (kernel/indices sources only)")

    def _from_trace(self, tr: counters_mod.WaveTrace, spec) -> CounterSet:
        """The one aggregation call both scalar and batch paths share."""
        return CounterSet.from_trace(
            tr, label=spec.label, num_cores=spec.num_cores,
            bytes_read=spec.bytes_read, flops=spec.flops,
            overhead_cycles=spec.overhead_cycles, source=self.name)

    def _stream_plan(self, spec):
        """(committed stream, job class, waves_per_tile) for a kernel spec.

        The kernel family's committed-stream mirror makes the degrees
        match the in-kernel instrumentation exactly (cross-validated by
        the provider-equivalence tests and ``Session.validate``).
        """
        p = spec.kernel.params
        if spec.kernel.op == "histogram":
            from repro.kernels.histogram import ops as hist_ops  # lazy: jax
            stream = hist_ops.committed_index_stream(
                p["img"], num_bins=p["num_bins"], variant=p["variant"])
            job_class = hist_ops.histogram_job_class(
                force_fao=p["force_fao"], weighted=p["weighted"])
            wpt = (spec.waves_per_tile
                   or hist_ops.default_waves_per_tile(p["img"]))
        elif spec.kernel.op == "scatter_add":
            from repro.kernels.scatter_add import ops as scat_ops  # lazy
            stream = scat_ops.committed_id_stream(
                p["ids"], p["num_segments"])
            job_class = p["job_class"]
            wpt = spec.waves_per_tile or scat_ops.default_waves_per_tile()
        else:
            raise ValueError(f"unknown kernel op {spec.kernel.op!r}")
        return stream, job_class, wpt

    def _synthesize(self, spec) -> counters_mod.WaveTrace:
        """Build the trace a kernel launch would emit, without launching."""
        stream, job_class, wpt = self._stream_plan(spec)
        # trace_from_indices' num_bins argument is unused (degrees come
        # from the raw index values); the spec default satisfies the
        # signature
        return counters_mod.trace_from_indices(
            stream, spec.num_bins, num_cores=spec.num_cores,
            job_class=job_class, waves_per_tile=wpt,
            pipeline_depth=spec.pipeline_depth or 2)


register_provider(TraceProvider())
