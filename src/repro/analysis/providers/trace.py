"""TraceProvider: the modeled counter path (no Pallas launch).

This is the acquisition backend the pre-provider ``Session`` hardwired:
counters derived from a wave trace built on the host.  For ``indices``
and ``trace`` sources that is exactly the old behaviour; for ``kernel``
sources it *synthesizes the kernel's committed index stream in numpy*
(``committed_index_stream`` mirrors the in-kernel issue ordering bit for
bit) instead of launching the interpret-mode kernel — the "modeled"
column of the paper's §5 model-vs-measured validation, and orders of
magnitude faster than a Pallas interpret run.
"""

from __future__ import annotations

from repro.analysis.providers.base import register_provider
from repro.core import counters as counters_mod
from repro.core.counters import CounterSet


class TraceProvider:
    """Counters from a host-synthesized wave trace (see module docstring)."""

    name = "trace"

    def collect(self, spec, device) -> CounterSet:
        del device  # trace synthesis is device-independent
        if spec.kernel is not None:
            tr = self._synthesize(spec)
        else:
            tr = spec.resolve_trace()
        return CounterSet.from_trace(
            tr, label=spec.label, num_cores=spec.num_cores,
            bytes_read=spec.bytes_read, flops=spec.flops,
            overhead_cycles=spec.overhead_cycles, source=self.name)

    def _synthesize(self, spec) -> counters_mod.WaveTrace:
        """Build the trace a kernel launch would emit, without launching.

        Uses the kernel family's committed-stream mirror so the degrees
        match the in-kernel instrumentation exactly (cross-validated by
        the provider-equivalence tests and ``Session.validate``).
        """
        p = spec.kernel.params
        if spec.kernel.op == "histogram":
            from repro.kernels.histogram import ops as hist_ops  # lazy: jax
            stream = hist_ops.committed_index_stream(
                p["img"], num_bins=p["num_bins"], variant=p["variant"])
            job_class = hist_ops.histogram_job_class(
                force_fao=p["force_fao"], weighted=p["weighted"])
            wpt = (spec.waves_per_tile
                   or hist_ops.default_waves_per_tile(p["img"]))
        elif spec.kernel.op == "scatter_add":
            from repro.kernels.scatter_add import ops as scat_ops  # lazy
            stream = scat_ops.committed_id_stream(
                p["ids"], p["num_segments"])
            job_class = p["job_class"]
            wpt = spec.waves_per_tile or scat_ops.default_waves_per_tile()
        else:
            raise ValueError(f"unknown kernel op {spec.kernel.op!r}")
        # trace_from_indices' num_bins argument is unused (degrees come
        # from the raw index values); the spec default satisfies the
        # signature
        return counters_mod.trace_from_indices(
            stream, spec.num_bins, num_cores=spec.num_cores,
            job_class=job_class, waves_per_tile=wpt,
            pipeline_depth=spec.pipeline_depth or 2)


register_provider(TraceProvider())
