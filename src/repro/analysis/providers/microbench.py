"""MicrobenchProvider: trace counters plus a measured-service-time clock.

The paper's validation compares the queue model's prediction against a
*timed* run.  On hardware this provider would wall-clock the launch; in
this CPU container wall-clocking an interpret-mode Pallas run would time
the Python interpreter (see ``core.timing``), so the calibrated timing
model prices the counted ``(n, e, c)`` directly — exactly what
``core.microbench`` does in ``analytic`` mode when building Tool 1's
table.  The point is the *shape*: downstream consumers get a
``wall_time_s`` that came from the measurement side, not from the
service-time table the model interpolates, so ``Session.validate`` has an
independent time axis to compare against.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.analysis.providers.base import register_provider
from repro.analysis.providers.trace import TraceProvider
from repro.core import timing
from repro.core.counters import CounterFrame, CounterSet


class MicrobenchProvider(TraceProvider):
    """Trace counters + timing-model wall time (measured-side stand-in)."""

    name = "microbench"

    def collect(self, spec, device) -> CounterSet:
        return self._attach_wall_time(super().collect(spec, device), device)

    def collect_batch(self, specs: Sequence, device, *,
                      parallel: Optional[int] = None) -> CounterFrame:
        """The inherited vectorized trace batch, plus the per-row wall
        time post-pass (which the plain trace batch would silently drop —
        this override is what keeps batch rows bit-identical to scalar
        ``collect``)."""
        frame = super().collect_batch(specs, device, parallel=parallel)
        return CounterFrame.from_sets(
            [self._attach_wall_time(frame.row(i), device)
             for i in range(len(frame))])

    def _attach_wall_time(self, cset: CounterSet, device) -> CounterSet:
        params = device.scatter
        n_hat = cset.occupancy(params.n_max) * params.n_max
        e = cset.e
        # Price each core's jobs in batches of n_hat through the timing
        # model: busy ~= N * T(n_hat, e, c, p) / n_hat (paper Eq. 3).
        busy = np.zeros(cset.num_cores)
        for core in range(cset.num_cores):
            n_jobs = float(cset.N[core])
            if n_jobs == 0 or n_hat <= 0:
                continue
            c_share = n_hat * (cset.N_c[core] / n_jobs)
            p_share = n_hat * (cset.N_p[core] / n_jobs)
            t_batch = float(timing.total_time_cycles(
                n_hat, e, c_share, p_share, params))
            busy[core] = n_jobs * t_batch / n_hat
        # source is already "microbench": the inherited collect stamps
        # self.name
        cset.wall_time_s = float(np.max(busy)) / params.clock_hz
        cset.meta["busy_cycles_measured"] = busy.tolist()
        return cset


register_provider(MicrobenchProvider())
