"""FaultInjectionProvider: deterministic chaos for the resilience layer.

Wraps any registered backend and injects the three failure modes a
long-running profiling service must survive — raised exceptions, latency
spikes (which per-call timeouts turn into ``ProviderCallTimeout``), and
corrupt ``CounterSet``s (which ``counter_set_error`` catches) — on a
*seeded schedule*: the rng draws a fixed number of variates per call in
call order, so two runs with the same seed inject exactly the same
faults regardless of which rates are enabled.  That determinism is what
makes the retry/backoff/breaker edge-case tests and the chaos acceptance
test reproducible.

The wrapper keeps the inner provider's ``name`` by default, so cache and
memo keys are unchanged — fault injection perturbs *availability*, never
identity.  Rates are adjustable at runtime (``configure``) so a test or
benchmark can trip a breaker with ``fault_rate=1.0`` and then measure
recovery after restoring it.
"""

from __future__ import annotations

import dataclasses
import random
import threading
import time
from typing import Optional

import numpy as np

from repro.analysis.resilience import TransientProviderError
from repro.core.counters import CounterSet


class InjectedFault(TransientProviderError):
    """The exception the fault schedule raises (transient by design)."""


class FaultInjectionProvider:
    """Chaos wrapper around any ``CounterProvider`` (see module docstring).

    Per ``collect`` call, three independent draws decide (in order)
    exception injection, latency injection, and result corruption; a
    corrupt result replaces ``O`` with NaNs — structurally detectable,
    never silently plausible.  ``stats`` counts calls and injections.
    """

    def __init__(self, inner, *, fault_rate: float = 0.0,
                 latency_rate: float = 0.0, latency_s: float = 0.05,
                 corrupt_rate: float = 0.0, seed: int = 0,
                 name: Optional[str] = None,
                 sleep=time.sleep) -> None:
        from repro.analysis.providers.base import get_provider
        self.inner = get_provider(inner)
        self.name = self.inner.name if name is None else name
        self._lock = threading.Lock()
        self._rng = random.Random(seed)
        self._sleep = sleep
        self.latency_s = latency_s
        self.configure(fault_rate=fault_rate, latency_rate=latency_rate,
                       corrupt_rate=corrupt_rate)
        self.stats = {"calls": 0, "faults": 0, "latency": 0, "corrupt": 0}

    def configure(self, *, fault_rate: Optional[float] = None,
                  latency_rate: Optional[float] = None,
                  corrupt_rate: Optional[float] = None) -> None:
        """Adjust injection rates at runtime (draw schedule unchanged)."""
        with self._lock:
            for attr, value in (("fault_rate", fault_rate),
                                ("latency_rate", latency_rate),
                                ("corrupt_rate", corrupt_rate)):
                if value is None:
                    continue
                if not 0.0 <= value <= 1.0:
                    raise ValueError(
                        f"{attr} must be in [0, 1], got {value}")
                setattr(self, attr, value)

    def stats_snapshot(self) -> dict:
        """Point-in-time copy of the injection accounting."""
        with self._lock:
            return dict(self.stats)

    def _draw(self) -> tuple[float, float, float]:
        with self._lock:
            self.stats["calls"] += 1
            # always three draws so the schedule is rate-independent
            return (self._rng.random(), self._rng.random(),
                    self._rng.random())

    def collect(self, spec, device) -> CounterSet:
        u_fault, u_latency, u_corrupt = self._draw()
        if u_fault < self.fault_rate:
            with self._lock:
                self.stats["faults"] += 1
            raise InjectedFault(
                f"injected fault on {spec.label!r} "
                f"(call {self.stats['calls']})")
        if u_latency < self.latency_rate:
            with self._lock:
                self.stats["latency"] += 1
            self._sleep(self.latency_s)
        cset = self.inner.collect(spec, device)
        if u_corrupt < self.corrupt_rate:
            with self._lock:
                self.stats["corrupt"] += 1
            return dataclasses.replace(
                cset, O=np.full_like(np.asarray(cset.O, np.float64),
                                     np.nan))
        return cset
