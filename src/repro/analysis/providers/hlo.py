"""HloProvider: roofline counters from a compiled step (dry-run path).

The scatter unit needs runtime data (it is data-dependent — that is the
paper's point), so this provider reports only the static side: FLOPs and
HBM bytes via ``compiled.cost_analysis()`` (or the trip-count-aware
``hlo.analyze_module`` walk when only module text is available) and
per-link collective wire traffic from the post-SPMD HLO text.  The
returned ``CounterSet`` has empty scatter counters; ``profile_counters``
then reports the three throughput servers (HBM/MXU/ICI) with an empty
per-core table.  Pair it with a trace/kernel collection of the same step
when the scatter verdict is also needed.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.providers.base import (collect_batch_fallback,
                                           register_provider)
from repro.core.counters import CounterFrame, CounterSet


class HloProvider:
    """Bytes/FLOPs/collective counters from compiled HLO."""

    name = "hlo"

    def collect_batch(self, specs: Sequence, device, *,
                      parallel: Optional[int] = None) -> CounterFrame:
        """Loop fallback: each artifact's cost analysis is an independent
        XLA call with no batched entry point.  All rows land on
        ``num_cores=1`` (the per-chip normalization below), so any mix of
        HLO specs frames rectangularly."""
        return collect_batch_fallback(self, specs, device, parallel)

    def collect(self, spec, device) -> CounterSet:
        from repro.core import hlo as hlo_mod  # lazy: keeps import light

        del device  # cost extraction is device-independent
        meta: dict = {}
        if spec.compiled is not None:
            flops, nbytes = hlo_mod.flops_and_bytes(spec.compiled)
            text = spec.hlo_text
            if text is None:
                text = spec.compiled.as_text()
            coll = hlo_mod.parse_collectives(text, spec.num_devices)
            wire = float(coll.total_wire_bytes)
            meta["collectives"] = coll.by_opcode()
        elif spec.hlo_text is not None:
            cost = hlo_mod.analyze_module(spec.hlo_text, spec.num_devices)
            flops, nbytes = float(cost.flops), float(cost.bytes)
            wire = float(cost.collective_wire_bytes)
            meta["unresolved_loops"] = cost.unresolved_loops
            if cost.collectives:
                meta["collectives"] = hlo_mod.CollectiveSummary(
                    ops=cost.collectives).by_opcode()
        else:
            raise ValueError(
                f"WorkloadSpec {spec.label!r} has no compiled/HLO source — "
                f"build it with WorkloadSpec.from_compiled(...)")
        # Whole-step artifacts are per-chip quantities: report against one
        # core so profile_counters does not dilute them by a core count the
        # compiler already accounted for.  A nonzero bytes_read/flops on
        # the spec is a caller override of the cost analysis — honor it,
        # as the other providers honor the same roofline-side fields.
        return CounterSet(
            label=spec.label, source=self.name, num_cores=1,
            bytes_read=spec.bytes_read or nbytes,
            flops=spec.flops or flops, ici_bytes=wire,
            overhead_cycles=spec.overhead_cycles, meta=meta)


register_provider(HloProvider())
