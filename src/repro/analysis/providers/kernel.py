"""InstrumentedKernelProvider: the measured counter path.

Launches the interpret-mode instrumented Pallas kernel described by the
spec and reads the in-kernel ``wave_degrees``/``wave_active`` counters
back (via the kernel families' ``collect_counters()`` hooks) — nothing is
synthesized on the host.  This is the paper's "measured" column: on real
hardware the same provider shape wraps the actual performance counters;
in this container the interpret-mode instrumentation is the measurement.

``indices`` sources are routed through the instrumented scatter-add
kernel (the index stream becomes a unit-value scatter), so even synthetic
streams can be cross-validated against in-kernel counters.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.analysis.providers.base import (collect_batch_fallback,
                                           register_provider)
from repro.core.counters import CounterFrame, CounterSet


class InstrumentedKernelProvider:
    """Counters read back from an instrumented Pallas launch."""

    name = "kernel"

    def collect_batch(self, specs: Sequence, device, *,
                      parallel: Optional[int] = None) -> CounterFrame:
        """Grouped loop fallback: interpret-mode launches have no batched
        form (each is a separate Pallas trace + execute), so the batch is
        one scalar ``collect`` per spec — still one provider call per
        sweep group from the Session's point of view."""
        return collect_batch_fallback(self, specs, device, parallel)

    def collect(self, spec, device) -> CounterSet:
        del device  # interpret-mode kernels are device-independent
        if spec.kernel is not None:
            # spec.run_kernel() owns the op dispatch and geometry
            # threading (one definition, shared with resolve_trace); the
            # per-family ops also expose collect_counters() hooks for
            # direct low-level use outside a Session.
            return CounterSet.from_trace(
                spec.run_kernel(), label=spec.label,
                num_cores=spec.num_cores, bytes_read=spec.bytes_read,
                flops=spec.flops, overhead_cycles=spec.overhead_cycles,
                source=self.name, meta={"op": spec.kernel.op})
        if spec.indices is not None:
            return self._collect_indices(spec)
        if spec.run is not None:
            # custom lazy source: by contract it runs an instrumented
            # kernel and returns its trace
            tr = spec.resolve_trace()
            return CounterSet.from_trace(
                tr, label=spec.label, num_cores=spec.num_cores,
                bytes_read=spec.bytes_read, flops=spec.flops,
                overhead_cycles=spec.overhead_cycles, source=self.name)
        raise ValueError(
            f"WorkloadSpec {spec.label!r} has no runnable source — the "
            f"'kernel' provider needs a kernel | indices | run spec, not "
            f"a pre-recorded trace or compiled artifact")

    def _collect_indices(self, spec) -> CounterSet:
        """Run a bare index stream through the instrumented scatter-add.

        Geometry defaults mirror ``trace_from_indices`` (waves_per_tile 1)
        so the 'trace' and 'kernel' providers agree bit-for-bit.  The
        stream length must be a multiple of the kernel tile: a shorter
        stream would be sentinel-padded by the launch, and the padding
        waves would be *counted* — the measured N/e would then silently
        diverge from the trace provider's (which models the raw stream),
        turning every ``validate()`` into a false alarm.  Refuse instead.
        """
        import numpy as np

        from repro.kernels.scatter_add import ops as scat_ops  # lazy: jax

        idx = np.asarray(spec.indices).reshape(-1)
        tile = scat_ops.sk.DEFAULT_TILE
        if idx.size % tile != 0:
            raise ValueError(
                f"WorkloadSpec {spec.label!r}: the 'kernel' provider needs "
                f"an index stream sized to a multiple of the scatter tile "
                f"({tile}); got {idx.size}. Pad the stream, or use "
                f"WorkloadSpec.from_scatter_add (both providers then share "
                f"the kernel's own sentinel padding).")
        return scat_ops.collect_counters(
            idx, np.ones(idx.shape, np.float32), spec.num_bins,
            label=spec.label, num_cores=spec.num_cores,
            job_class=spec.job_class,
            waves_per_tile=spec.waves_per_tile or 1,
            pipeline_depth=spec.pipeline_depth or 2,
            bytes_read=spec.bytes_read, flops=spec.flops,
            overhead_cycles=spec.overhead_cycles)


register_provider(InstrumentedKernelProvider())
