"""CounterProvider protocol + registry (the acquisition layer's contract).

The paper's pipeline is "performance counters -> queuing model ->
utilization verdict", and its validation (§5) hinges on comparing
*modeled* against *measured* counters.  A ``CounterProvider`` is one
counter source: it consumes a ``WorkloadSpec`` + ``Device`` and returns a
uniform ``repro.core.counters.CounterSet``, so every downstream consumer
(``profile_counters``, ``Session``, ``Session.validate``) is agnostic to
where the numbers came from.

Four providers ship, registered under the names the ``Session``
constructor accepts:

    ``trace``      — synthesize the committed index stream in numpy and
                     derive counters from it (the modeled path; default)
    ``kernel``     — run the interpret-mode instrumented Pallas kernel
                     and read ``wave_degrees``/``wave_active`` back (the
                     measured path)
    ``hlo``        — derive bytes/FLOPs/collective traffic from a
                     compiled artifact or HLO text (no scatter counters)
    ``microbench`` — trace counters plus a timing-model wall-time, the
                     container's stand-in for wall-clock measurement

The registry mirrors the device registry: look up by name with
``get_provider`` (instances pass through), extend with
``register_provider`` — e.g. a future hardware-counter provider on a
real TPU registers here and every Session feature works unchanged.
"""

from __future__ import annotations

from typing import Protocol, Union, runtime_checkable

from repro.core.counters import CounterSet


@runtime_checkable
class CounterProvider(Protocol):
    """One counter-acquisition backend (see module docstring)."""

    name: str

    def collect(self, spec, device) -> CounterSet:
        """Acquire the spec's counters on the given device bundle."""
        ...


PROVIDERS: dict[str, CounterProvider] = {}


def register_provider(provider: CounterProvider) -> CounterProvider:
    """Register a provider instance under ``provider.name``.

    Providers are stateless; one shared instance per name is registered
    (mirroring ``repro.analysis.register_device``).  Returns the provider
    so the call can decorate a module-level instantiation.
    """
    PROVIDERS[provider.name] = provider
    return provider


def get_provider(
    name_or_provider: Union[str, CounterProvider],
) -> CounterProvider:
    """Look up a registry entry; a provider instance passes through."""
    if not isinstance(name_or_provider, str):
        if isinstance(name_or_provider, CounterProvider):
            return name_or_provider
        raise TypeError(f"not a CounterProvider: {name_or_provider!r} "
                        f"(needs .name and .collect(spec, device))")
    try:
        return PROVIDERS[name_or_provider]
    except KeyError:
        known = ", ".join(sorted(PROVIDERS))
        raise KeyError(
            f"unknown provider {name_or_provider!r}; registered: {known}. "
            f"Use repro.analysis.register_provider() for custom sources."
        ) from None
