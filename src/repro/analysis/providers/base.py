"""CounterProvider protocol + registry (the acquisition layer's contract).

The paper's pipeline is "performance counters -> queuing model ->
utilization verdict", and its validation (§5) hinges on comparing
*modeled* against *measured* counters.  A ``CounterProvider`` is one
counter source: it consumes a ``WorkloadSpec`` + ``Device`` and returns a
uniform ``repro.core.counters.CounterSet``, so every downstream consumer
(``profile_counters``, ``Session``, ``Session.validate``) is agnostic to
where the numbers came from.

Four providers ship, registered under the names the ``Session``
constructor accepts:

    ``trace``      — synthesize the committed index stream in numpy and
                     derive counters from it (the modeled path; default)
    ``kernel``     — run the interpret-mode instrumented Pallas kernel
                     and read ``wave_degrees``/``wave_active`` back (the
                     measured path)
    ``hlo``        — derive bytes/FLOPs/collective traffic from a
                     compiled artifact or HLO text (no scatter counters)
    ``microbench`` — trace counters plus a timing-model wall-time, the
                     container's stand-in for wall-clock measurement

The registry mirrors the device registry: look up by name with
``get_provider`` (instances pass through), extend with
``register_provider`` — e.g. a future hardware-counter provider on a
real TPU registers here and every Session feature works unchanged.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Optional, Protocol, Sequence, Union, runtime_checkable

from repro.core.counters import CounterFrame, CounterSet


@runtime_checkable
class CounterProvider(Protocol):
    """One counter-acquisition backend (see module docstring).

    ``collect`` is the required surface.  Providers may additionally
    implement the batch extension

        collect_batch(specs, device, *, parallel=None) -> CounterFrame

    returning one frame row per spec, bit-for-bit equal row-wise to the
    scalar ``collect`` (``CounterFrame`` rows are rectangular, so all
    specs in one call must share ``num_cores`` — ``Session`` groups
    before calling).  It is deliberately *not* part of the runtime
    protocol: a minimal collect-only provider still registers and works
    everywhere, with ``provider_collect_batch`` supplying the loop
    fallback.
    """

    name: str

    def collect(self, spec, device) -> CounterSet:
        """Acquire the spec's counters on the given device bundle."""
        ...


def collect_batch_fallback(
    provider: CounterProvider,
    specs: Sequence,
    device,
    parallel: Optional[int] = None,
) -> CounterFrame:
    """Grouped/loop ``collect_batch`` for backends with no vectorized path.

    One scalar ``collect`` per spec (optionally on a thread pool when
    ``parallel`` > 1), stacked into a ``CounterFrame`` — trivially
    bit-for-bit equal row-wise to the scalar path.  The kernel and hlo
    providers delegate here, and so does any registered collect-only
    provider via ``provider_collect_batch``.
    """
    specs = list(specs)
    if not specs:
        raise ValueError("collect_batch needs at least one spec")
    workers = min(parallel or 1, len(specs))
    if workers > 1:
        with ThreadPoolExecutor(max_workers=workers) as pool:
            csets = list(pool.map(lambda s: provider.collect(s, device),
                                  specs))
    else:
        csets = [provider.collect(s, device) for s in specs]
    return CounterFrame.from_sets(csets)


def provider_collect_batch(
    provider: CounterProvider,
    specs: Sequence,
    device,
    parallel: Optional[int] = None,
) -> CounterFrame:
    """Dispatch to the provider's batch path, or the loop fallback.

    The single call site contract the ``Session`` batch executor uses:
    providers that implement ``collect_batch`` get the whole group at
    once; collect-only providers (including user-registered ones) are
    looped transparently.
    """
    batch = getattr(provider, "collect_batch", None)
    if batch is None:
        return collect_batch_fallback(provider, specs, device, parallel)
    return batch(specs, device, parallel=parallel)


PROVIDERS: dict[str, CounterProvider] = {}


def register_provider(provider: CounterProvider) -> CounterProvider:
    """Register a provider instance under ``provider.name``.

    Providers are stateless; one shared instance per name is registered
    (mirroring ``repro.analysis.register_device``).  Returns the provider
    so the call can decorate a module-level instantiation.
    """
    PROVIDERS[provider.name] = provider
    return provider


def get_provider(
    name_or_provider: Union[str, CounterProvider],
) -> CounterProvider:
    """Look up a registry entry; a provider instance passes through."""
    if not isinstance(name_or_provider, str):
        if isinstance(name_or_provider, CounterProvider):
            return name_or_provider
        raise TypeError(f"not a CounterProvider: {name_or_provider!r} "
                        f"(needs .name and .collect(spec, device))")
    try:
        return PROVIDERS[name_or_provider]
    except KeyError:
        known = ", ".join(sorted(PROVIDERS))
        raise KeyError(
            f"unknown provider {name_or_provider!r}; registered: {known}. "
            f"Use repro.analysis.register_provider() for custom sources."
        ) from None
