"""repro: shared-memory atomic bottleneck modeling (arXiv:2503.17893 repro).

Kept import-light on purpose: subpackages (``repro.analysis``,
``repro.kernels``, ``repro.service``, ``repro.obs``) pull in their own
dependencies lazily; importing ``repro`` itself must stay cheap so
``repro --version`` and tooling probes never pay the jax import.
"""

__version__ = "0.10.0"

__all__ = ["__version__"]
