"""repro.audit — static HLO contention linter over the model zoo.

Layers (providers -> **audit** -> advisor):

* ``scanner``  — instruction-graph walk of (pre-opt) HLO for
  atomic-shaped idioms (scatters, KV-cache DUS writes, one-hot and
  sort-segment histogram lowerings),
* ``rules``    — declarative catalog (ATOM001/002/003, BANK001,
  GEOM001 + the AUDIT000 module note) scoring each site with one
  columnar model pass — zero kernel executions,
* ``report``   — text/json/csv/SARIF renderers and ``# repro: noqa``
  suppression,
* ``zoo``      — config -> per-step pre-optimization HLO lowering
  (imports jax; kept out of this module's import path).

Entry points: ``audit_hlo`` (one module text), ``audit_source`` (text /
Lowered / Compiled / WorkloadSpec — what ``Session.audit`` calls), and
``audit_config`` (a zoo config end to end).
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.audit import rules as rules_mod
from repro.audit.report import (AuditReport, exit_code, merge, merge_sarif,
                                parse_noqa)
from repro.audit.rules import CATALOG, Finding, Rule
from repro.audit.scanner import AtomicSite, ScanResult, scan_hlo

__all__ = [
    "AtomicSite", "AuditReport", "CATALOG", "Finding", "Rule",
    "ScanResult", "attach_advice", "audit_config", "audit_hlo",
    "audit_source", "exit_code", "merge", "merge_sarif", "parse_noqa",
    "scan_hlo",
]


def _device_name(session) -> str:
    dev = getattr(session, "device", None)
    return getattr(dev, "name", str(dev))


def _make_session(device: str = "v5e"):
    from repro.analysis.session import Session  # lazy: keeps import light
    return Session(device)


def audit_hlo(text: str, *, session=None, label: str = "module",
              rules: Optional[Sequence[Rule]] = None,
              suppress: Sequence[str] = (), hlo_uri: str = "",
              num_cores: int = 8) -> AuditReport:
    """Scan one HLO module text and score every finding.

    Scoring synthesizes index streams and evaluates them in a single
    ``session.profile_sets`` pass; the session's trace/kernel providers
    are never invoked.
    """
    if session is None:
        session = _make_session()
    scan = scan_hlo(text)
    findings = rules_mod.evaluate(
        scan, session, label=label, rules=rules or CATALOG,
        suppress=suppress, hlo_uri=hlo_uri, num_cores=num_cores)
    return AuditReport(
        label=label, device=_device_name(session), findings=findings,
        steps=[label], sites_scanned=len(scan.sites),
        instructions_scanned=scan.num_instructions)


def attach_advice(report: AuditReport, session=None, *, depth: int = 2,
                  beam_width: int = 8, top_k: int = 3,
                  min_severity: str = "warning") -> AuditReport:
    """Run ``Session.advise`` on gating findings; attach the top transform.

    The ROADMAP's "audit findings -> advised scenarios" play: every
    non-suppressed finding at or above ``min_severity`` that carries a
    candidate ``WorkloadSpec`` gets the advisor's best-ranked transform
    composition (predicted speedup + post-transform bottleneck) as
    ``Finding.advice`` — rendered into SARIF ``properties.advise`` and
    the text report.  Specs are deduplicated by fingerprint so one
    advisor search serves every finding that shares a workload.
    """
    if session is None:
        session = _make_session()
    gate = rules_mod.SEVERITIES.index(min_severity)
    cache: dict = {}
    updated = []
    for f in report.findings:
        if (f.suppressed or f.spec is None or f.gate_rank() < gate
                or f.advice is not None):
            updated.append(f)
            continue
        key = f.spec.fingerprint()
        if key not in cache:
            adv = session.advise(f.spec, depth=depth,
                                 beam_width=beam_width, top_k=top_k)
            cache[key] = adv.best.summary() if adv.best else None
        if cache[key] is None:
            updated.append(f)
            continue
        import dataclasses
        updated.append(dataclasses.replace(f, advice=dict(cache[key])))
    report.findings = updated
    return report


def _source_text(source) -> str:
    """HLO text from str / WorkloadSpec / jax Lowered / jax Compiled."""
    if isinstance(source, str):
        return source
    hlo_text = getattr(source, "hlo_text", None)
    if hlo_text:
        return hlo_text
    compiled = getattr(source, "compiled", None)
    if compiled is not None:       # WorkloadSpec.from_compiled(...)
        return compiled.as_text()
    if hasattr(source, "compiler_ir"):    # jax Lowered: pre-opt HLO
        from repro.launch.lowering import pre_optimization_hlo
        return pre_optimization_hlo(source)
    if hasattr(source, "as_text"):        # jax Compiled: post-opt HLO
        return source.as_text()
    raise ValueError(
        f"cannot extract HLO from {type(source).__name__!r} — pass module "
        "text, a jax Lowered/Compiled, or a WorkloadSpec built with "
        "WorkloadSpec.from_compiled(...)")


def audit_source(source, *, session=None, label: str = "module",
                 rules: Optional[Sequence[Rule]] = None,
                 suppress: Sequence[str] = (),
                 num_cores: int = 8) -> AuditReport:
    """Audit any HLO-bearing source (what ``Session.audit`` delegates to)."""
    if label == "module":
        label = getattr(source, "label", label)
    return audit_hlo(_source_text(source), session=session, label=label,
                     rules=rules, suppress=suppress, num_cores=num_cores)


def config_noqa(arch: str) -> set[str]:
    """``# repro: noqa`` allowlist declared in a config's defining module."""
    import importlib
    import inspect

    from repro.configs import ARCHS
    try:
        mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
        return parse_noqa(inspect.getsource(mod))
    except Exception:
        return set()


def audit_config(arch: str, *, session=None,
                 steps: Optional[Sequence[str]] = None,
                 reduced: bool = False, variant: str = "base",
                 rules: Optional[Sequence[Rule]] = None,
                 extra_suppress: Sequence[str] = (),
                 hlo_sink=None, num_cores: int = 8) -> AuditReport:
    """Audit every applicable step of a zoo config.

    Suppressions come from ``# repro: noqa RULE,...`` comments in the
    config's defining module, plus ``extra_suppress``.  ``hlo_sink``,
    when given, is called with ``(step, hlo_text)`` per lowered step and
    returns the artifact URI recorded in SARIF locations (or None).
    """
    from repro.audit import zoo  # lazy: imports jax

    if session is None:
        session = _make_session()
    arch = zoo.normalize_arch(arch)
    suppress = set(extra_suppress) | config_noqa(arch)
    texts = zoo.lower_config_steps(arch, steps=steps, reduced=reduced,
                                   variant=variant)
    findings: list[Finding] = []
    done_steps: list[str] = []
    sites = instrs = 0
    for step, text in texts.items():
        uri = None
        if hlo_sink is not None:
            uri = hlo_sink(step, text)
        rep = audit_hlo(text, session=session, label=f"{arch}/{step}",
                        rules=rules, suppress=suppress,
                        hlo_uri=uri or "", num_cores=num_cores)
        findings.extend(rep.findings)
        done_steps.append(step)
        sites += rep.sites_scanned
        instrs += rep.instructions_scanned
    order = {"error": 0, "warning": 1, "note": 2}
    findings.sort(key=lambda f: (order[f.severity],
                                 -(f.utilization or 0.0), f.label))
    return AuditReport(
        label=arch, device=_device_name(session), findings=findings,
        steps=done_steps, sites_scanned=sites,
        instructions_scanned=instrs)
