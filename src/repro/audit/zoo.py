"""Model-zoo lowering for the audit: config -> per-step pre-opt HLO text.

Audits read *pre-optimization* HLO (``compiler_ir(dialect="hlo")``),
which is pre-SPMD: shapes are global, so lowering runs on a tiny
``(1, 1)`` compat mesh with no device-count override and no ``.compile()``
call — a full-size config lowers in about a second.  Importing this
module pulls in jax; the CLI defers the import until an audit actually
runs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

from repro.configs import ARCHS, SHAPES, get_config, shape_applicable
from repro.launch.lowering import (build_lowered, pre_optimization_hlo,
                                   shape_tuned_config)
from repro.launch.mesh import compat_make_mesh

# step name -> production shape audited for it
AUDIT_SHAPES = {
    "train": "train_4k",
    "prefill": "prefill_32k",
    "decode": "decode_32k",
}

# ``reduced=True`` smoke geometry: keeps lowering sub-second in tests
# while preserving every scatter/DUS idiom of the full shapes.
_REDUCED_GEOM = {"train": (4, 64), "prefill": (4, 256), "decode": (4, 256)}


def normalize_arch(name: str) -> str:
    """Accept underscore- or module-spelled config names (CLI/CI)."""
    if name in ARCHS:
        return name
    dashed = name.replace("_", "-")
    if dashed in ARCHS:
        return dashed
    for arch, module in ARCHS.items():   # e.g. zamba2_1p2b -> zamba2-1.2b
        if name == module:
            return arch
    raise KeyError(f"unknown config {name!r} (known: {', '.join(ARCHS)})")


def lower_config_steps(arch: str, *, steps: Optional[Sequence[str]] = None,
                       reduced: bool = False, variant: str = "base",
                       ) -> dict[str, str]:
    """Lower each requested step of a config; returns step -> HLO text.

    Inapplicable (config, shape) cells — per ``shape_applicable`` — are
    silently skipped, matching the dry-run grid.
    """
    arch = normalize_arch(arch)
    cfg0 = get_config(arch)
    if reduced:
        cfg0 = cfg0.reduced()
    mesh = compat_make_mesh((1, 1), ("data", "model"))
    out: dict[str, str] = {}
    for step in (steps or AUDIT_SHAPES):
        shape = SHAPES[AUDIT_SHAPES[step]]
        if reduced:
            gb, sl = _REDUCED_GEOM[step]
            shape = dataclasses.replace(shape, global_batch=gb, seq_len=sl)
        ok, _why = shape_applicable(cfg0, shape)
        if not ok:
            continue
        cfg, loss_chunk, train_kw = shape_tuned_config(cfg0, shape, variant)
        lowered = build_lowered(cfg, shape, mesh, loss_chunk=loss_chunk,
                                train_kw=train_kw)
        out[step] = pre_optimization_hlo(lowered)
    return out
