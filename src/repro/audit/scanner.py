"""Instruction-graph scan of (pre-optimization) HLO for atomic-shaped idioms.

Extends the ``_INSTR_RE`` line walk in ``repro.core.hlo`` into a full
call-graph traversal: starting at the entry computation, descend through
``while`` bodies (multiplying resolved trip counts, flagging unresolved
ones), ``call`` / ``fusion`` / ``conditional`` regions, and record every
site whose lowering lands on the shared-memory atomic unit:

* ``scatter`` / ``select-and-scatter`` without ``unique_indices=true`` —
  classified by combiner region (add -> FAO, compare/select -> CAS
  retry) and update window (scalar updates -> histogram / expert-count,
  row updates -> MoE token dispatch),
* ``dynamic-update-slice`` inside a loop body (KV-cache decode write),
* one-hot lowerings (``convert(compare(..., iota chain))`` or calls into
  jax's ``_one_hot*`` computations) feeding a ``dot`` (one-hot matmul)
  or ``reduce`` (dense histogram),
* key/value ``sort`` with integer keys (sort-segment dispatch prologue).

The scan targets *pre-optimization* HLO (``launch.lowering
.pre_optimization_hlo``) where these idioms are still explicit ops;
post-optimization CPU HLO rewrites scatters into ``while`` loops.  A
light fallback recognizes those rewritten loops by their surviving
``op_name`` metadata so ``Session.audit(compiled)`` still reports them.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

from repro.core import hlo

_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_REF_RE = re.compile(r"%?([\w.\-]+)")
_OPNAME_META_RE = re.compile(r'op_name="([^"]*)"')

# Producer hops the one-hot detector may cross inside one computation.
_CHAIN_OPS = ("broadcast", "reshape", "convert", "transpose", "copy")
_INT_DTYPES = ("s8", "u8", "s16", "u16", "s32", "u32", "s64", "u64")


@dataclasses.dataclass(frozen=True)
class AtomicSite:
    """One atomic-shaped instruction, with enough static context to rate it."""

    op_name: str                 # HLO instruction name (e.g. scatter.439)
    opcode: str
    kind: str                    # histogram_scatter | dispatch_scatter |
    #                              scatter | kv_cache_write | one_hot_matmul |
    #                              one_hot_histogram | sort_segment
    computation: str
    hlo_line: int                # 1-based line number in the scanned text
    operand_dtype: str = "f32"
    operand_shape: tuple = ()
    update_dtype: str = "f32"
    update_shape: tuple = ()
    index_dtype: str = "s32"
    num_bins: int = 1            # destination slots addressed by indices
    num_updates: int = 1         # independent indexed updates per execution
    row_elems: int = 1           # elements per update window
    combiner: str = "none"       # add | max | min | mul | overwrite | cas
    unique_indices: bool = False
    loop_depth: int = 0
    trip_count: int = 1          # product of resolved enclosing trip counts
    trip_unresolved: bool = False

    def describe(self) -> str:
        dest = f"{self.operand_dtype}{list(self.operand_shape)}"
        trips = f"{self.trip_count}{'?' if self.trip_unresolved else ''}"
        return (f"{self.opcode} {self.op_name} ({self.kind}) -> {dest}: "
                f"{self.num_updates} update(s) x {self.row_elems} elem(s) "
                f"into {self.num_bins} bin(s), combiner={self.combiner}, "
                f"loop_depth={self.loop_depth}, trips={trips}")


@dataclasses.dataclass
class ScanResult:
    sites: list[AtomicSite]
    num_instructions: int = 0
    num_computations: int = 0
    unresolved_loops: int = 0
    entry: Optional[str] = None

    def by_kind(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for s in self.sites:
            out[s.kind] = out.get(s.kind, 0) + 1
        return out


def _attr_dims(line: str, name: str) -> Optional[tuple]:
    m = re.search(re.escape(name) + r"=\{([0-9,]*)\}", line)
    if m is None:
        return None
    return tuple(int(d) for d in m.group(1).split(",") if d != "")


def _prod(dims) -> int:
    n = 1
    for d in dims:
        n *= int(d)
    return n


def _first_shape(shape_text: str) -> tuple[str, tuple]:
    dims = hlo.shape_dims(shape_text)
    if not dims:
        return "f32", ()
    dt, dd = dims[0]
    return dt, tuple(dd)


class _Scanner:
    def __init__(self, text: str):
        self.text = text
        self.comps = hlo.parse_computations(text)
        self.entry = hlo.find_entry(text)
        self.names = {c: {i.name: i for i in instrs}
                      for c, instrs in self.comps.items()}
        self.line_no: dict[str, int] = {}
        for n, line in enumerate(text.splitlines(), start=1):
            m = hlo._INSTR_RE.match(line)
            if m and m.group(1) not in self.line_no:
                self.line_no[m.group(1)] = n
        self.unresolved_loops = 0
        # op_name -> site (global instruction names dedup shared call paths;
        # keep the occurrence with the largest trip multiplier)
        self.sites: dict[str, AtomicSite] = {}

    # -- operand helpers --------------------------------------------------

    def _operand_refs(self, ins, comp: str) -> list[str]:
        sec = hlo.operand_section(ins.line, ins.opcode)
        local = self.names.get(comp, {})
        return [r for r in _REF_RE.findall(sec) if r in local]

    def _ref_shape(self, ref: str, comp: str) -> tuple[str, tuple]:
        ins = self.names.get(comp, {}).get(ref)
        if ins is None:
            return "f32", ()
        return _first_shape(ins.result)

    def _producer(self, ref: str, comp: str):
        return self.names.get(comp, {}).get(ref)

    def _chain_has_iota(self, ref: str, comp: str, depth: int = 5) -> bool:
        """Does ref's producer chain (elementwise-ish hops) reach an iota?"""
        seen = set()
        frontier = [(ref, 0)]
        while frontier:
            r, d = frontier.pop()
            if r in seen or d > depth:
                continue
            seen.add(r)
            ins = self._producer(r, comp)
            if ins is None:
                continue
            if ins.opcode == "iota":
                return True
            # follow through shape-preserving hops and tiny calls
            if ins.opcode in _CHAIN_OPS or ins.opcode == "compare":
                for rr in self._operand_refs(ins, comp):
                    frontier.append((rr, d + 1))
            elif ins.opcode == "call":
                for c in hlo.called_computations(ins.line):
                    if any(i.opcode == "iota"
                           for i in self.comps.get(c, [])):
                        return True
        return False

    def _combiner(self, line: str) -> str:
        for c in hlo.called_computations(line):
            ops = {i.opcode for i in self.comps.get(c, [])
                   if i.opcode != "parameter"}
            if not ops:
                return "overwrite"
            if ops <= {"add", "convert"}:
                return "add"
            if ops <= {"maximum", "convert"}:
                return "max"
            if ops <= {"minimum", "convert"}:
                return "min"
            if ops <= {"multiply", "convert"}:
                return "mul"
            if "compare" in ops or "select" in ops:
                return "cas"
            return "cas"
        return "none"

    # -- site constructors ------------------------------------------------

    def _add(self, site: AtomicSite) -> None:
        prev = self.sites.get(site.op_name)
        if prev is None or site.trip_count > prev.trip_count:
            self.sites[site.op_name] = site

    def _scatter_site(self, ins, comp, trip, depth, unres) -> None:
        refs = self._operand_refs(ins, comp)
        op_dt, op_shape = _first_shape(ins.result)
        idx_dt, upd_dt, upd_shape = "s32", op_dt, ()
        if len(refs) >= 3:
            # scatter(operand, indices, updates)
            op_dt, op_shape = self._ref_shape(refs[0], comp)
            idx_dt, _ = self._ref_shape(refs[1], comp)
            upd_dt, upd_shape = self._ref_shape(refs[2], comp)
        window = _attr_dims(ins.line, "update_window_dims") or ()
        sdims = _attr_dims(ins.line, "scatter_dims_to_operand_dims") or ()
        row = _prod(upd_shape[d] for d in window if d < len(upd_shape))
        n_upd = _prod(d for i, d in enumerate(upd_shape) if i not in window)
        bins = _prod(op_shape[d] for d in sdims if d < len(op_shape))
        combiner = self._combiner(ins.line)
        kind = "scatter"
        if row <= 1 and combiner in ("add", "max", "min", "mul"):
            kind = "histogram_scatter"
        elif row > 1 and combiner in ("overwrite", "add"):
            kind = "dispatch_scatter"
        self._add(AtomicSite(
            op_name=ins.name, opcode=ins.opcode, kind=kind, computation=comp,
            hlo_line=self.line_no.get(ins.name, 0),
            operand_dtype=op_dt, operand_shape=op_shape,
            update_dtype=upd_dt, update_shape=upd_shape, index_dtype=idx_dt,
            num_bins=max(1, bins), num_updates=max(1, n_upd),
            row_elems=max(1, row), combiner=combiner,
            unique_indices="unique_indices=true" in ins.line,
            loop_depth=depth, trip_count=trip, trip_unresolved=unres))

    def _dus_site(self, ins, comp, trip, depth, unres) -> None:
        if depth < 1:
            return  # only loop-carried updates (KV-cache decode writes)
        refs = self._operand_refs(ins, comp)
        buf_dt, buf_shape = _first_shape(ins.result)
        upd_dt, upd_shape, idx_dt = buf_dt, (), "s32"
        if len(refs) >= 2:
            buf_dt, buf_shape = self._ref_shape(refs[0], comp)
            upd_dt, upd_shape = self._ref_shape(refs[1], comp)
        if len(refs) >= 3:
            idx_dt, _ = self._ref_shape(refs[2], comp)
        buf_elems = _prod(buf_shape)
        upd_elems = max(1, _prod(upd_shape))
        if buf_elems <= upd_elems:
            return  # full overwrite, not an indexed update
        self._add(AtomicSite(
            op_name=ins.name, opcode=ins.opcode, kind="kv_cache_write",
            computation=comp, hlo_line=self.line_no.get(ins.name, 0),
            operand_dtype=buf_dt, operand_shape=buf_shape,
            update_dtype=upd_dt, update_shape=upd_shape, index_dtype=idx_dt,
            num_bins=max(1, buf_elems // upd_elems), num_updates=1,
            row_elems=upd_elems, combiner="overwrite",
            loop_depth=depth, trip_count=trip, trip_unresolved=unres))

    def _one_hot_site(self, ins, comp, trip, depth, unres,
                      oh_dt, oh_shape) -> None:
        bins = oh_shape[-1] if oh_shape else 1
        n_upd = _prod(oh_shape[:-1]) if len(oh_shape) > 1 else 1
        # consumer decides matmul vs dense histogram
        kind = "one_hot_histogram"
        for other in self.comps.get(comp, []):
            if ins.name in self._operand_refs(other, comp):
                if other.opcode == "dot":
                    kind = "one_hot_matmul"
                    break
                if other.opcode == "reduce":
                    kind = "one_hot_histogram"
                    break
        self._add(AtomicSite(
            op_name=ins.name, opcode=ins.opcode, kind=kind, computation=comp,
            hlo_line=self.line_no.get(ins.name, 0),
            operand_dtype=oh_dt, operand_shape=oh_shape,
            update_dtype=oh_dt, update_shape=oh_shape, index_dtype="s32",
            num_bins=max(1, bins), num_updates=max(1, n_upd),
            row_elems=1, combiner="add",
            loop_depth=depth, trip_count=trip, trip_unresolved=unres))

    def _sort_site(self, ins, comp, trip, depth, unres) -> None:
        refs = self._operand_refs(ins, comp)
        if len(refs) < 2:
            return  # plain value sort, not a key/value dispatch prologue
        key_dt, key_shape = self._ref_shape(refs[0], comp)
        if key_dt not in _INT_DTYPES:
            return
        self._add(AtomicSite(
            op_name=ins.name, opcode=ins.opcode, kind="sort_segment",
            computation=comp, hlo_line=self.line_no.get(ins.name, 0),
            operand_dtype=key_dt, operand_shape=key_shape,
            update_dtype=key_dt, update_shape=key_shape, index_dtype=key_dt,
            num_bins=max(1, _prod(key_shape)),
            num_updates=max(1, _prod(key_shape)), row_elems=1,
            combiner="none", loop_depth=depth, trip_count=trip,
            trip_unresolved=unres))

    def _rewritten_scatter_site(self, ins, comp, trip, depth, unres) -> None:
        """Post-optimization fallback: XLA:CPU rewrites scatters into while
        loops whose metadata op_name still says `.../scatter...`."""
        m = _OPNAME_META_RE.search(ins.line)
        opname = m.group(1) if m else ""
        dt, shape = _first_shape(ins.result)
        self._add(AtomicSite(
            op_name=ins.name, opcode="scatter", kind="scatter",
            computation=comp, hlo_line=self.line_no.get(ins.name, 0),
            operand_dtype=dt, operand_shape=shape,
            combiner="add" if "add" in opname else "overwrite",
            num_bins=max(1, _prod(shape)), loop_depth=depth,
            trip_count=trip, trip_unresolved=unres))

    # -- the walk ---------------------------------------------------------

    def scan(self) -> ScanResult:
        if self.entry is not None:
            self._walk(self.entry, trip=1, depth=0, unres=False, path=())
        else:
            # no ENTRY marker (fragment): scan every computation flat
            for comp in self.comps:
                self._walk(comp, trip=1, depth=0, unres=False, path=())
        sites = sorted(self.sites.values(),
                       key=lambda s: (s.hlo_line, s.op_name))
        return ScanResult(
            sites=sites,
            num_instructions=sum(len(v) for v in self.comps.values()),
            num_computations=len(self.comps),
            unresolved_loops=self.unresolved_loops,
            entry=self.entry)

    def _walk(self, comp: str, *, trip: int, depth: int, unres: bool,
              path: tuple) -> None:
        if comp in path:   # defensive: HLO call graphs are acyclic
            return
        path = path + (comp,)
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            if op == "while":
                mb = _BODY_RE.search(ins.line)
                mc = _COND_RE.search(ins.line)
                t = hlo.resolve_trip_count(self.comps, ins.line,
                                           mc.group(1) if mc else None)
                if t is None:
                    self.unresolved_loops += 1
                if self._looks_like_rewritten_scatter(ins):
                    self._rewritten_scatter_site(ins, comp, trip, depth,
                                                 unres or t is None)
                if mb:
                    self._walk(mb.group(1), trip=trip * (t or 1),
                               depth=depth + 1, unres=unres or t is None,
                               path=path)
                continue
            if op in ("scatter", "select-and-scatter"):
                self._scatter_site(ins, comp, trip, depth, unres)
                continue
            if op == "dynamic-update-slice":
                self._dus_site(ins, comp, trip, depth, unres)
                continue
            if op == "sort":
                self._sort_site(ins, comp, trip, depth, unres)
                continue
            if op == "convert":
                refs = self._operand_refs(ins, comp)
                p = self._producer(refs[0], comp) if refs else None
                if p is not None and p.opcode == "compare" and \
                        any(self._chain_has_iota(r, comp)
                            for r in self._operand_refs(p, comp)):
                    dt, shape = _first_shape(ins.result)
                    self._one_hot_site(ins, comp, trip, depth, unres,
                                       dt, shape)
                continue
            if op == "call":
                for c in hlo.called_computations(ins.line):
                    if c.lstrip("_").startswith("one_hot"):
                        dt, shape = _first_shape(ins.result)
                        self._one_hot_site(ins, comp, trip, depth, unres,
                                           dt, shape)
                    else:
                        self._walk(c, trip=trip, depth=depth, unres=unres,
                                   path=path)
                continue
            if op in ("fusion", "map", "conditional"):
                for c in hlo.called_computations(ins.line):
                    self._walk(c, trip=trip, depth=depth, unres=unres,
                               path=path)

    @staticmethod
    def _looks_like_rewritten_scatter(ins) -> bool:
        m = _OPNAME_META_RE.search(ins.line)
        return bool(m and "scatter" in m.group(1))


def scan_hlo(text: str) -> ScanResult:
    """Scan an HLO module text for atomic-shaped sites."""
    return _Scanner(text).scan()
