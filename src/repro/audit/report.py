"""Audit report container + text/json/csv/SARIF renderers + suppression.

SARIF output follows the 2.1.0 shape GitHub code-scanning upload
expects: one run, ``tool.driver`` with the rule catalog as
``reportingDescriptor``s, one ``result`` per finding with ``ruleIndex``
into that catalog, and in-source ``suppressions`` entries for findings
matched by a config's ``# repro: noqa RULE1,RULE2`` allowlist.

Severity map: our ``error``/``warning`` pass through; ``note`` maps to
SARIF level ``note``.
"""

from __future__ import annotations

import dataclasses
import inspect
import json
import re
from typing import Optional, Sequence

from repro.analysis.render import rows_to_csv
from repro.audit import rules as rules_mod
from repro.audit.rules import CATALOG, Finding

SARIF_SCHEMA = "https://json.schemastore.org/sarif-2.1.0.json"
SARIF_VERSION = "2.1.0"
TOOL_NAME = "repro-audit"

_NOQA_RE = re.compile(
    r"#\s*repro:\s*noqa:?\s*([A-Z]+\d+(?:\s*,\s*[A-Z]+\d+)*)")


def parse_noqa(source_text: str) -> set[str]:
    """Rule ids allowlisted via ``# repro: noqa ATOM001,GEOM001`` comments."""
    out: set[str] = set()
    for m in _NOQA_RE.finditer(source_text):
        out.update(t.strip() for t in m.group(1).split(","))
    return out


def noqa_for_object(obj) -> set[str]:
    """Suppressions declared in the module source defining ``obj``."""
    try:
        return parse_noqa(inspect.getsource(inspect.getmodule(obj)))
    except (OSError, TypeError):
        return set()


@dataclasses.dataclass
class AuditReport:
    """Findings for one audited target (a config, or one HLO module)."""

    label: str                       # e.g. config name, or module label
    device: str
    findings: list[Finding]
    steps: list[str] = dataclasses.field(default_factory=list)
    sites_scanned: int = 0
    instructions_scanned: int = 0

    # -- gating -----------------------------------------------------------

    def active(self) -> list[Finding]:
        return [f for f in self.findings if not f.suppressed]

    def gated(self, fail_on: str) -> list[Finding]:
        """Non-suppressed findings at or above the gate severity."""
        if fail_on == "never":
            return []
        gate = rules_mod.SEVERITIES.index(fail_on)
        return [f for f in self.active() if f.gate_rank() >= gate]

    def counts(self) -> dict[str, int]:
        out = {s: 0 for s in rules_mod.SEVERITIES}
        for f in self.active():
            out[f.severity] += 1
        return out

    # -- renderers --------------------------------------------------------

    def to_rows(self) -> list[dict]:
        rows = []
        for f in self.findings:
            row = {
                "rule": f.rule_id, "slug": f.rule_slug,
                "severity": f.severity, "label": f.label,
                "utilization": f.utilization,
                "baseline_utilization": f.baseline_utilization,
                "contention": f.contention, "bottleneck": f.bottleneck,
                "hint": f.hint, "fixit": f.fixit,
                "suppressed": f.suppressed, "message": f.message,
            }
            if f.site is not None:
                row.update({
                    "op": f.site.op_name, "kind": f.site.kind,
                    "bins": f.site.num_bins, "updates": f.site.num_updates,
                    "row_elems": f.site.row_elems,
                    "combiner": f.site.combiner,
                    "trip_count": f.site.trip_count,
                    "hlo_line": f.site.hlo_line,
                })
            rows.append(row)
        return rows

    def render(self, fmt: str = "text") -> str:
        if fmt == "text":
            return self._render_text()
        if fmt == "json":
            payload = {
                "tool": TOOL_NAME, "label": self.label,
                "device": self.device, "steps": self.steps,
                "sites_scanned": self.sites_scanned,
                "instructions_scanned": self.instructions_scanned,
                "counts": self.counts(),
                "findings": self.to_rows(),
            }
            return json.dumps(payload, indent=2, default=str)
        if fmt == "csv":
            return rows_to_csv(self.to_rows())
        if fmt == "sarif":
            return json.dumps(self.to_sarif(), indent=2)
        raise ValueError(f"unknown report format {fmt!r} "
                         "(expected 'text', 'json', 'csv' or 'sarif')")

    def _render_text(self) -> str:
        lines = [f"== audit {self.label} on {self.device} "
                 f"({self.sites_scanned} site(s) from "
                 f"{self.instructions_scanned} instruction(s), "
                 f"steps: {', '.join(self.steps) or '-'}) =="]
        if not self.findings:
            lines.append("no findings")
        for f in self.findings:
            sup = " [suppressed]" if f.suppressed else ""
            u = f" U={f.utilization:.0%}" if f.utilization is not None else ""
            c = (f" x{f.contention:.2f}" if f.contention is not None else "")
            lines.append(f"{f.severity.upper():>7} {f.rule_id} "
                         f"{f.label}{u}{c}{sup}")
            lines.append(f"        {f.message}")
            if f.fixit:
                lines.append(f"        fix: {f.fixit}")
            if f.advice:
                lines.append(
                    f"        advise: x"
                    f"{f.advice.get('predicted_speedup', 0):.3f} via "
                    f"{f.advice.get('transforms', '?')} -> "
                    f"{f.advice.get('predicted_bottleneck', '?')}")
        c = self.counts()
        lines.append(f"{c['error']} error(s), {c['warning']} warning(s), "
                     f"{c['note']} note(s)"
                     + (f", {len(self.findings) - len(self.active())} "
                        "suppressed" if len(self.active())
                        != len(self.findings) else ""))
        return "\n".join(lines) + "\n"

    # -- SARIF ------------------------------------------------------------

    def to_sarif(self) -> dict:
        rule_ids, descriptors = _rule_descriptors()

        results = []
        for f in self.findings:
            res = {
                "ruleId": f.rule_id,
                "ruleIndex": rule_ids.index(f.rule_id),
                "level": _sarif_level(f.severity),
                "message": {"text": f.message},
            }
            if f.hlo_uri:
                res["locations"] = [{
                    "physicalLocation": {
                        "artifactLocation": {"uri": f.hlo_uri},
                        "region": {"startLine": max(1, f.hlo_line)},
                    }
                }]
            props = {"label": f.label}
            if f.utilization is not None:
                props["predictedScatterUtilization"] = round(
                    f.utilization, 4)
            if f.contention is not None:
                props["contentionRatio"] = round(f.contention, 3)
            if f.bottleneck:
                props["bottleneck"] = f.bottleneck
            if f.fixit:
                props["fixit"] = f.fixit
            if f.advice:
                props["advise"] = f.advice
            res["properties"] = props
            if f.suppressed:
                res["suppressions"] = [{"kind": "inSource"}]
            results.append(res)

        return {
            "$schema": SARIF_SCHEMA,
            "version": SARIF_VERSION,
            "runs": [{
                "tool": {"driver": {
                    "name": TOOL_NAME,
                    "informationUri":
                        "https://github.com/paper-repro/repro",
                    "rules": descriptors,
                }},
                "results": results,
            }],
        }


def _rule_descriptors() -> tuple[list[str], list[dict]]:
    """The full reporting catalog: audit rules + AUDIT000 + KERN rules.

    ``repro audit`` and ``repro lint`` findings share one SARIF rule
    index, so their logs (and ``merge``d reports) interleave cleanly in
    one run.  The lint catalog is imported lazily — it is numpy-only,
    but keeping it out of module import keeps layering one-directional.
    """
    rule_ids: list[str] = []
    descriptors: list[dict] = []

    def add(rid, slug, summary, description, level):
        rule_ids.append(rid)
        descriptors.append({
            "id": rid, "name": _pascal(slug),
            "shortDescription": {"text": summary},
            "fullDescription": {"text": description},
            "defaultConfiguration": {"level": level},
        })

    for r in CATALOG:
        add(r.id, r.slug, r.summary, r.description,
            _sarif_level(r.base_severity))
    aid, aslug = rules_mod.AUDIT000
    add(aid, aslug, "while loop trip count could not be resolved",
        "Cost estimates multiply loop bodies by their trip counts; "
        "unresolved loops make per-site traffic a lower bound.", "note")
    try:
        from repro.lint.rules import KERN_CATALOG
    except ImportError:             # lint layer absent: audit-only catalog
        KERN_CATALOG = ()
    for r in KERN_CATALOG:
        add(r.id, r.slug, r.summary, r.description,
            _sarif_level(r.base_severity))
    return rule_ids, descriptors


def merge_sarif(docs: Sequence[dict]) -> dict:
    """Combine SARIF documents produced by this module into one run.

    Results are re-indexed against the emitting doc's own rule list by
    ``ruleId``, so audit and lint logs merge regardless of the rule
    order they were written with (the CI merged-artifact path).
    """
    rule_ids, descriptors = _rule_descriptors()
    results: list[dict] = []
    for doc in docs:
        for run in doc.get("runs", []):
            for res in run.get("results", []):
                res = dict(res)
                rid = res.get("ruleId")
                if rid in rule_ids:
                    res["ruleIndex"] = rule_ids.index(rid)
                else:
                    res.pop("ruleIndex", None)
                results.append(res)
    return {
        "$schema": SARIF_SCHEMA,
        "version": SARIF_VERSION,
        "runs": [{
            "tool": {"driver": {
                "name": TOOL_NAME,
                "informationUri": "https://github.com/paper-repro/repro",
                "rules": descriptors,
            }},
            "results": results,
        }],
    }


def merge(reports: Sequence[AuditReport], *, label: str = "zoo",
          ) -> AuditReport:
    """Combine per-config reports into one (the ``--all`` CLI path)."""
    reports = list(reports)
    device = reports[0].device if reports else "-"
    merged = AuditReport(
        label=label, device=device,
        findings=[f for r in reports for f in r.findings],
        steps=[s for r in reports for s in
               (f"{r.label}:{st}" for st in r.steps)],
        sites_scanned=sum(r.sites_scanned for r in reports),
        instructions_scanned=sum(r.instructions_scanned for r in reports))
    return merged


def _sarif_level(severity: str) -> str:
    return {"error": "error", "warning": "warning", "note": "note"}.get(
        severity, "note")


def _pascal(slug: str) -> str:
    return "".join(p.capitalize() for p in slug.split("-"))


def exit_code(report: AuditReport, fail_on: str) -> int:
    return 1 if report.gated(fail_on) else 0
