"""Declarative rule catalog rating scanned atomic sites with the model.

Each rule matches a class of :class:`~repro.audit.scanner.AtomicSite`,
derives a candidate :class:`~repro.analysis.workload.WorkloadSpec` (the
provenance record a user can re-profile or hand to the advisor), and
synthesizes a deterministic worst-plausible index stream for the site's
access pattern.  ``evaluate`` turns the synthesized streams into
``CounterSet``s directly (``trace_from_indices`` — pure numpy, NO
provider collection, NO kernel execution) and scores every finding in
one columnar ``Session.profile_sets`` pass, so each diagnostic carries
the model-predicted utilization and the bottleneck verdict's advisor
transform as its fix-it hint.

Severity model: every hazard stream is synthesized at one fixed
steady-state length and profiled next to a shared conflict-free
baseline stream of the same length/core count.  The *contention ratio*
— hazard scatter-unit utilization over baseline — isolates the cost of
the access pattern from launch size: ratios >= ~1.35 mean the modeled
atomic unit spends a third more cycles than conflict-free traffic
(``error``), >= ~1.10 a measurable excess (``warning``), anything less
reports as a ``note``.  Each rule caps how high its findings may
escalate (``max_severity``): bank-stride and geometry rules are
advisory and never gate a build on their own.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.analysis.workload import WorkloadSpec
from repro.audit.scanner import AtomicSite, ScanResult
from repro.core import bottleneck, timing
from repro.core import counters as counters_mod

SEVERITIES = ("note", "warning", "error")

# Every synthesized stream uses one fixed steady-state length: long
# enough that per-launch overhead is amortized and degree statistics
# dominate, and identical to the baseline stream so the contention
# ratio compares like with like.  Real site sizes (trip_count x
# num_updates) only gate *whether* a rule fires, never the score.
STREAM_LEN = 1 << 17

# contention-ratio thresholds (hazard U / conflict-free baseline U)
ERROR_RATIO = 1.35
WARN_RATIO = 1.10
# Destinations at or under this bin count guarantee intra-commit-group
# duplicates even under a perfectly balanced router (pigeonhole on the
# 32-lane commit group).
HOT_BIN_MAX = counters_mod.COMMIT_GROUP // 2

# verdict hint family -> shipped advisor transform (fix-it hint text).
FAMILY_TRANSFORMS = {
    "rotation": "ChannelRotation",
    "replication": "Replicate",
    "substitution": "CasToFao",
    "geometry": "SetWavesPerTile/SetPipelineDepth",
    "remap": "LaneInterleave",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One (rule, site) diagnostic with its model-predicted severity."""

    rule_id: str
    rule_slug: str
    severity: str                       # note | warning | error
    message: str
    label: str                          # spec label (config/step/site)
    site: Optional[AtomicSite] = None   # None for module-level findings
    utilization: Optional[float] = None  # predicted scatter-unit U
    bottleneck: str = ""
    hint: str = ""                      # compact action:family@unit
    fixit: str = ""                     # advisor transform suggestion
    suppressed: bool = False
    hlo_uri: str = ""                   # artifact the hlo_line refers to
    hlo_line: int = 0
    spec: Optional[WorkloadSpec] = None  # candidate workload (provenance)
    baseline_utilization: Optional[float] = None
    contention: Optional[float] = None  # utilization / baseline ratio
    advice: Optional[dict] = None       # attach_advice: top-ranked transform

    def gate_rank(self) -> int:
        return SEVERITIES.index(self.severity)


def _uniform_stream(site: AtomicSite, n: int) -> np.ndarray:
    # deterministic uniform draw seeded by the site geometry, so audits
    # are reproducible run to run
    rng = np.random.default_rng(abs(hash((site.num_bins, site.num_updates,
                                          site.row_elems))) % (2 ** 32))
    return rng.integers(0, max(1, site.num_bins), size=n).astype(np.int64)


class Rule:
    """Base rule: subclasses set ids and override matches/synthesize."""

    id = "RULE000"
    slug = "base"
    base_severity = "warning"
    max_severity = "error"   # ceiling the contention ratio may escalate to
    summary = ""
    description = ""
    job_class = timing.FAO

    def matches(self, site: AtomicSite) -> bool:
        raise NotImplementedError

    def synthesize(self, site: AtomicSite) -> np.ndarray:
        """Worst-plausible index stream for this hazard class."""
        return _uniform_stream(site, STREAM_LEN)

    def spec(self, site: AtomicSite, label: str,
             indices: Optional[np.ndarray] = None) -> WorkloadSpec:
        """Candidate WorkloadSpec a user can re-profile / hand to advise."""
        idx = self.synthesize(site) if indices is None else indices
        values = np.ones(idx.shape, dtype=np.float32)
        return WorkloadSpec.from_scatter_add(
            idx, values, max(2, site.num_bins), label=label,
            job_class=self.job_class)


class SameAddressHotBin(Rule):
    id = "ATOM001"
    slug = "same-address-hot-bin"
    base_severity = "warning"
    summary = "scatter destination has so few bins that every commit group serializes"
    description = (
        "The scatter writes into a destination with <= "
        f"{HOT_BIN_MAX} addressable bins (e.g. a per-expert counter for a "
        "small expert pool). By pigeonhole, every 32-lane commit group "
        "carries duplicate addresses even under a perfectly balanced "
        "router, so the atomic unit serializes each group; the modeled "
        "degree floor is ceil(32 / bins).")

    def matches(self, site: AtomicSite) -> bool:
        return (site.kind in ("histogram_scatter", "one_hot_histogram")
                and not site.unique_indices
                and site.num_bins <= HOT_BIN_MAX)

    def synthesize(self, site: AtomicSite) -> np.ndarray:
        # perfectly balanced round-robin: the FLOOR of the hazard — real
        # routers are more skewed, never less.
        return np.arange(STREAM_LEN, dtype=np.int64) % max(1, site.num_bins)


class CasRetryLoop(Rule):
    id = "ATOM002"
    slug = "cas-retry-loop"
    base_severity = "warning"
    summary = "scatter combiner needs compare-and-swap retries, not fetch-and-op"
    description = (
        "The scatter's combiner region is not a plain accumulate "
        "(add/min/max), so the lowering must use a read-modify-verify "
        "(CAS) loop; colliding lanes retry instead of queueing one "
        "atomic op each, amplifying contention. The CasToFao transform "
        "(or an order-insensitive combiner) removes the retry loop.")
    job_class = timing.CAS

    def matches(self, site: AtomicSite) -> bool:
        return (site.opcode in ("scatter", "select-and-scatter")
                and site.combiner == "cas" and not site.unique_indices)


class UnreplicatedHistogram(Rule):
    id = "ATOM003"
    slug = "unreplicated-histogram"
    base_severity = "warning"
    max_severity = "warning"   # replication advice is advisory
    summary = "many-bin histogram accumulates into one shared destination"
    description = (
        "A scalar-update accumulate scatter (histogram / expert-count / "
        "segment-sum) lands every lane's traffic on a single shared "
        "buffer. Uniform traffic still collides inside commit groups; "
        "skewed traffic serializes. Replicate the destination per core "
        "(Replicate transform) and reduce at the end.")

    def matches(self, site: AtomicSite) -> bool:
        return (site.kind in ("histogram_scatter", "one_hot_histogram")
                and not site.unique_indices
                and site.num_bins > HOT_BIN_MAX)


class StrideConflict(Rule):
    id = "BANK001"
    slug = "stride-conflict"
    base_severity = "warning"
    max_severity = "warning"   # banks are modeled, not measured: advisory
    summary = "row-granular writes stride commit-group-aligned banks"
    description = (
        "Row updates whose width is a multiple of the 32-lane commit "
        "group map successive rows onto the same bank offsets (MoE token "
        "dispatch rows, KV-cache lines). Colliding rows serialize at "
        "gcd(row_elems, 32) degree; the LaneInterleave remap (or padding "
        "the row) breaks the alignment.")

    def matches(self, site: AtomicSite) -> bool:
        return (site.kind in ("dispatch_scatter", "kv_cache_write")
                and not site.unique_indices
                and site.row_elems >= counters_mod.COMMIT_GROUP
                and site.row_elems % counters_mod.COMMIT_GROUP == 0)

    def synthesize(self, site: AtomicSite) -> np.ndarray:
        # conflict degree of commit-group-aligned rows
        d = math.gcd(site.row_elems, counters_mod.COMMIT_GROUP)
        return np.arange(STREAM_LEN, dtype=np.int64) // max(1, d)


class WavesExceedPipeline(Rule):
    id = "GEOM001"
    slug = "waves-exceed-pipeline"
    base_severity = "note"
    max_severity = "note"      # pure geometry: informational only
    summary = "launch enqueues far more waves than the pipeline can hold"
    description = (
        "The site's update stream spans orders of magnitude more waves "
        "than waves_per_tile x pipeline_depth can keep in flight, so "
        "issue overhead and drain bubbles dominate even without "
        "contention. Raise waves_per_tile / pipeline_depth "
        "(SetWavesPerTile / SetPipelineDepth).")

    # capacity of the default launch geometry across 8 cores
    _CAPACITY = 8 * 8 * 2 * 16

    def matches(self, site: AtomicSite) -> bool:
        if site.kind not in ("dispatch_scatter", "histogram_scatter",
                             "scatter", "sort_segment"):
            return False
        lanes = max(1, counters_mod.LANES // max(1, min(site.row_elems,
                                                        counters_mod.LANES)))
        waves = math.ceil(site.num_updates * max(1, site.trip_count) / lanes)
        return waves > self._CAPACITY

    def synthesize(self, site: AtomicSite) -> np.ndarray:
        # conflict-free stream: isolates the geometry (occupancy) effect
        return np.arange(STREAM_LEN, dtype=np.int64) % max(2, site.num_bins)

    def spec(self, site: AtomicSite, label: str,
             indices: Optional[np.ndarray] = None) -> WorkloadSpec:
        idx = self.synthesize(site) if indices is None else indices
        return WorkloadSpec.from_indices(
            idx, max(2, site.num_bins), label=label,
            job_class=self.job_class, waves_per_tile=1, pipeline_depth=2)


# AUDIT000 is module-level (no site match); emitted directly by evaluate().
AUDIT000 = ("AUDIT000", "unresolved-trip-count")

CATALOG: tuple[Rule, ...] = (
    SameAddressHotBin(), CasRetryLoop(), UnreplicatedHistogram(),
    StrideConflict(), WavesExceedPipeline(),
)


def rule_by_id(rule_id: str) -> Optional[Rule]:
    for r in CATALOG:
        if r.id == rule_id:
            return r
    return None


def _finding_severity(rule: Rule, contention: float) -> str:
    if contention >= ERROR_RATIO:
        sev = "error"
    elif contention >= WARN_RATIO:
        sev = "warning"
    else:
        sev = "note"
    # cap at the rule's ceiling (advisory rules never gate on their own)
    cap = SEVERITIES.index(rule.max_severity)
    return SEVERITIES[min(SEVERITIES.index(sev), cap)]


def _fixit(verdict) -> str:
    if verdict.hint is None:
        return ""
    transform = FAMILY_TRANSFORMS.get(verdict.hint.family,
                                      verdict.hint.family)
    return (f"{verdict.hint.action} on {verdict.hint.unit} via advisor "
            f"transform {transform}")


def evaluate(scan: ScanResult, session, *, label: str = "module",
             rules: Sequence[Rule] = CATALOG,
             suppress: Sequence[str] = (),
             hlo_uri: str = "", num_cores: int = 8) -> list[Finding]:
    """Match rules against a scan and score all candidates in one pass.

    Builds every candidate's CounterSet from its synthesized stream
    (pure numpy) and evaluates them in a single columnar
    ``session.profile_sets`` call — the session's trace/kernel providers
    are never invoked (``session.stats`` stays untouched).
    """
    suppress = set(suppress)
    candidates: list[tuple[Rule, AtomicSite]] = []
    for site in scan.sites:
        for rule in rules:
            if rule.matches(site):
                candidates.append((rule, site))

    # every candidate's stream (plus the shared baseline) goes through
    # one batched trace synthesis — the whole audit's wave degrees are a
    # few large numpy ops instead of one trace_from_indices per finding
    streams, classes, labels, specs = [], [], [], []
    for rule, site in candidates:
        idx = rule.synthesize(site)
        point_label = f"{label}/{site.op_name}"
        specs.append(rule.spec(site, point_label, indices=idx))
        streams.append(idx)
        classes.append(rule.job_class)
        labels.append(point_label)
    csets = []
    if streams:
        # shared conflict-free baseline: unique addresses, same length,
        # same core count — the denominator of every contention ratio
        streams.append(np.arange(STREAM_LEN, dtype=np.int64))
        classes.append(counters_mod.timing.FAO)
        labels.append(f"{label}/__baseline__")
        traces = counters_mod.traces_from_index_batch(
            streams, num_cores=num_cores, job_class=classes)
        csets = [counters_mod.CounterSet.from_trace(
            tr, label=lab, num_cores=num_cores,
            bytes_read=float(stream.size * 4), source="audit")
            for tr, lab, stream in zip(traces, labels, streams)]
    profiles = session.profile_sets(csets) if csets else []
    u_base = float(profiles[-1].scatter_utilization) if profiles else 1.0
    u_base = max(u_base, 1e-9)

    findings: list[Finding] = []
    for (rule, site), spec, prof in zip(candidates, specs, profiles):
        verdict = bottleneck.classify(prof)
        u = float(prof.scatter_utilization)
        contention = u / u_base
        severity = _finding_severity(rule, contention)
        msg = (f"{rule.summary}: {site.describe()}; predicted scatter "
               f"U={u:.0%}, {contention:.2f}x conflict-free baseline "
               f"({verdict.bottleneck}"
               f"{' saturated' if verdict.saturated else ''})")
        findings.append(Finding(
            rule_id=rule.id, rule_slug=rule.slug, severity=severity,
            message=msg, label=f"{label}/{site.op_name}", site=site,
            utilization=u, bottleneck=verdict.bottleneck,
            hint=verdict.hint.compact() if verdict.hint else "",
            fixit=_fixit(verdict), suppressed=rule.id in suppress,
            hlo_uri=hlo_uri, hlo_line=site.hlo_line, spec=spec,
            baseline_utilization=u_base, contention=contention))

    if scan.unresolved_loops:
        rid, slug = AUDIT000
        findings.append(Finding(
            rule_id=rid, rule_slug=slug, severity="note",
            message=(f"{scan.unresolved_loops} while loop(s) with "
                     "unresolved trip counts — per-site traffic estimates "
                     "are lower bounds"),
            label=label, suppressed=rid in suppress, hlo_uri=hlo_uri))

    order = {"error": 0, "warning": 1, "note": 2}
    findings.sort(key=lambda f: (order[f.severity],
                                 -(f.utilization or 0.0), f.label))
    return findings
