"""``python -m repro`` — the paper's tool as a command line.

Twelve subcommands over the ``repro.analysis`` Session API:

    devices    list registered devices and their table-cache state
    profile    one workload -> utilization report + verdict
    heatmap    per-bin contention attribution for one workload point:
               hit/replay counts per destination bin, per-bin max wave
               degree, and the per-wave contention sparkline
               (repro.obs.heatmap)
    sweep      cartesian grid sweep (sizes x geometry), batch-collected;
               --shards N --shard-index i slices the grid across
               processes (merging through the persistent counter cache),
               --merge assembles the full grid from the cache
    advise     search workload transforms, rank model-predicted fixes
    validate   multi-provider counter comparison (paper §5)
    compare    the §5 hist-vs-hist2 case study with a shift verdict
    audit      static HLO contention lint (model zoo / --hlo-file), can
               gate CI via --fail-on and emit SARIF
    lint       symbolic jaxpr-level kernel lint (KERN rules) over the
               registered Pallas kernels — same gate/SARIF machinery
    cache      persistent counter-cache maintenance: stats (entries,
               bytes, quarantined corrupt files, per-provider
               breakdown), clear, and prune --max-bytes
               (LRU-by-mtime eviction; always removes quarantined
               and orphaned tmp files first)
    serve      long-running localhost profiling daemon: JSON jobs over
               HTTP onto a bounded worker pool sharing one memo +
               persistent counter cache, with retries, per-call
               timeouts, circuit breakers and degraded fallbacks
               (see repro.service)
    client     stdlib HTTP client for a running daemon: health,
               status, schema, and job submission

``audit`` and ``lint`` share the gating surface (``--fail-on``,
``--suppress``, ``--advise``, ``--num-cores``, ``--no-artifact``) and
the report tail (artifact under ``results/cli/``, exit code 1 when a
non-suppressed finding reaches the gate); ``--advise`` runs the
advisor on every gating finding and attaches the top-ranked transform.

Every command prints its report to stdout (``--format text|json|csv``;
``devices`` and ``validate`` render ``text|json`` only, ``audit`` and
``lint`` add ``sarif`` — unsupported values are rejected by argparse
``choices`` before any work happens)
and can persist it with ``--output PATH``; ``sweep``, ``advise`` and
``compare`` additionally drop an artifact under ``results/cli/`` unless
told not to, and cache the collected counters under ``results/cache/``
(``--no-cache`` opts out) so a repeated run skips collection and goes
straight to the columnar batch model evaluation.  ``audit`` artifacts
(report + the scanned HLO dumps its SARIF locations point into) land
under ``results/cli/audit/``.
The CLI builds ordinary ``WorkloadSpec``s and calls the same Session
methods the Python API exposes, so its numbers are bit-identical to a
scripted run.
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Optional, Sequence

from repro import __version__
from repro.analysis import DEVICES, Session, WorkloadSpec
from repro.cli import workloads as wl
from repro.core import bottleneck

DEFAULT_JOBS = 8   # sweep-parallelism knob (thread pool over providers)


def _positive_int(text: str) -> int:
    """argparse type: an integer >= 1 (rejects 0/-N up front, exit 2)."""
    try:
        value = int(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid int value: {text!r}")
    if value < 1:
        raise argparse.ArgumentTypeError(
            f"must be a positive integer, got {text!r}")
    return value


def _nonneg_int(text: str) -> int:
    """argparse type: an integer >= 0."""
    try:
        value = int(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid int value: {text!r}")
    if value < 0:
        raise argparse.ArgumentTypeError(
            f"must be a non-negative integer, got {text!r}")
    return value


def _positive_float(text: str) -> float:
    """argparse type: a finite float > 0."""
    try:
        value = float(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid float value: {text!r}")
    if not value > 0 or value != value or value == float("inf"):
        raise argparse.ArgumentTypeError(
            f"must be a positive finite number, got {text!r}")
    return value


def _rate(text: str) -> float:
    """argparse type: a probability in [0, 1]."""
    try:
        value = float(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid float value: {text!r}")
    if not 0.0 <= value <= 1.0:
        raise argparse.ArgumentTypeError(
            f"must be in [0, 1], got {text!r}")
    return value


def _port(text: str) -> int:
    """argparse type: a TCP port (0 = ephemeral, for serve only)."""
    try:
        value = int(text)
    except ValueError:
        raise argparse.ArgumentTypeError(f"invalid int value: {text!r}")
    if not 0 <= value <= 65535:
        raise argparse.ArgumentTypeError(
            f"must be a port in [0, 65535], got {text!r}")
    return value


def results_dir() -> Path:
    """``results/`` at the repo root (``REPRO_RESULTS`` overrides).

    Delegates to the one shared resolution rule in
    ``repro.analysis.sweep_cache`` so CLI artifacts and the persistent
    counter cache can never disagree about where results live.
    """
    from repro.analysis.sweep_cache import results_root
    return results_root()


def _emit(report: str, args, default_artifact: Optional[str] = None) -> None:
    """Print the report; persist it when asked (or by default for sweeps).

    stdout carries only the report (parseable json/csv); the artifact
    path goes to stderr so piping the output stays clean.
    """
    sys.stdout.write(report if report.endswith("\n") else report + "\n")
    path = getattr(args, "output", None)
    if path is None and default_artifact is not None \
            and not getattr(args, "no_artifact", False):
        path = results_dir() / "cli" / default_artifact
    if path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(report)
        print(f"wrote {path}", file=sys.stderr)


def _session(args) -> Session:
    return Session(args.device, provider=args.provider,
                   cache_dir=args.cache_dir,
                   shift_tol=getattr(args, "shift_tol", bottleneck.SHIFT_TOL))


def _sweep_cache(args):
    """Sweep commands cache collected counters under results/cache/.

    A re-run of the same sweep (same provider + content fingerprints +
    device calibration) then skips counter collection entirely and goes
    straight to the batch model evaluation.  ``--no-cache`` opts out;
    the cache root follows ``results_dir()`` (so ``REPRO_RESULTS``
    relocates it, and ``rm -rf results/cache`` clears it).
    """
    if getattr(args, "no_cache", False):
        return False
    from repro.analysis import SweepCache
    return SweepCache()   # default root: results_dir() / "cache"


# -- subcommands -------------------------------------------------------------


def cmd_devices(args) -> int:
    rows = [DEVICES[name].describe(args.cache_dir)
            for name in sorted(DEVICES)]
    if args.format == "json":
        _emit(json.dumps(rows, indent=2), args)
        return 0
    lines = [f"{len(rows)} registered device(s):"]
    for r in rows:
        cached = "cached" if r["table_cached"] else "not built"
        lines.append(
            f"  {r['name']:>6}  {r['cores']} cores  "
            f"{r['clock_ghz']:.2f} GHz  {r['hbm_gbps']:7.0f} GB/s  "
            f"table: {cached:>9}  {r['description']}")
    _emit("\n".join(lines), args)
    return 0


def cmd_profile(args) -> int:
    specs, axes = wl.build_specs(args)
    specs = wl.expand_grid(specs, axes)
    if len(specs) != 1:
        raise ValueError(
            f"profile takes exactly one workload point, got {len(specs)} — "
            f"use 'sweep' for multi-value axes")
    sess = _session(args)
    sess.profile(specs[0])
    _emit(sess.report(args.format), args)
    return 0


def cmd_heatmap(args) -> int:
    """Per-bin contention attribution for exactly one workload point."""
    specs, axes = wl.build_specs(args)
    specs = wl.expand_grid(specs, axes)
    if len(specs) != 1:
        raise ValueError(
            f"heatmap takes exactly one workload point, got {len(specs)} — "
            f"use 'sweep' for multi-value axes")
    sess = _session(args)
    hm = sess.heatmap(specs[0], hot_degree=args.hot_degree)
    ext = {"text": "txt", "json": "json", "csv": "csv"}[args.format]
    _emit(hm.render(args.format, top_k=args.top_k), args,
          default_artifact=f"heatmap-{specs[0].label}.{ext}")
    return 0


def cmd_sweep(args) -> int:
    """Grid sweep; also one shard of a distributed sweep, or its merge.

    ``--shards N --shard-index i`` sweeps the deterministic stride
    ``specs[i::N]`` of the full grid — run one process per shard (any
    order, even concurrently: cache writes are atomic) and they share
    the persistent counter cache as the backing store.  ``--merge``
    then sweeps the full grid normally; with every point already cached
    it collects nothing and renders bit-identically to a single-process
    sweep (missing points are simply re-collected — the cache is an
    accelerator, never a correctness input).
    """
    base_specs, axes = wl.build_specs(args)
    specs = wl.expand_grid(base_specs, axes)
    devices = args.devices or [args.device]
    jobs = args.jobs if args.jobs is not None else min(DEFAULT_JOBS,
                                                       len(specs))
    results = {}
    stats = {"collected": 0, "memo_hits": 0, "disk_hits": 0}
    for dev in devices:
        sess = Session(dev, provider=args.provider,
                       cache_dir=args.cache_dir, shift_tol=args.shift_tol,
                       persistent_cache=_sweep_cache(args))
        results[sess.device.name] = sess.sweep(
            specs, parallel=jobs, shards=args.shards,
            shard_index=args.shard_index)
        for k in stats:
            stats[k] += sess.stats[k]
    tag = "-".join(results)
    if args.shards > 1:
        # per-shard artifact names keep concurrent shard processes from
        # overwriting each other's reports
        tag += f"-shard{args.shard_index}of{args.shards}"
    ext = {"text": "txt", "json": "json", "csv": "csv"}[args.format]
    report = _render_sweeps(results, args.format)
    if args.format == "text":
        # collection accounting footer (text only: json/csv stay parseable
        # and bit-identical between cold and warm runs)
        report = (report if report.endswith("\n") else report + "\n") + (
            f"cache: {stats['collected']} collected, "
            f"{stats['memo_hits']} memo hits, "
            f"{stats['disk_hits']} disk hits\n")
    _emit(report, args, default_artifact=f"sweep-{tag}.{ext}")
    return 0


def _render_sweeps(results: dict, fmt: str) -> str:
    """Render one or several per-device SweepResults as a single report.

    The single-device case is exactly ``SweepResult.render`` (the Session
    API's own output); a device axis nests json under device names and
    prefixes csv rows with a ``device`` column.
    """
    if len(results) == 1:
        return next(iter(results.values())).render(fmt)
    if fmt == "json":
        payload = {name: json.loads(r.render("json"))
                   for name, r in results.items()}
        return json.dumps({"devices": payload}, indent=2)
    if fmt == "csv":
        from repro.analysis.render import rows_to_csv
        rows = []
        for name, r in results.items():
            for row in r.to_rows():
                rows.append({"device": name, **row})
        return rows_to_csv(rows)
    return "\n".join(r.render("text") for r in results.values())


def cmd_advise(args) -> int:
    """Model-driven optimization advisor over one workload point.

    Enumerates transform compositions around the workload (channel
    rotation, bin replication, CAS→FAO substitution, launch geometry,
    lane interleave), scores every frontier with one columnar
    ``profile_batch`` evaluation, and prints the ranked predicted fixes.
    Counter collection is cache-aware like ``sweep`` (``results/cache/``
    by default, ``--no-cache`` opts out), so re-advising a workload
    collects nothing; ``--validate-top N`` re-checks the N top-ranked
    kernel-source candidates through the instrumented-kernel provider
    (paper §5's model-vs-measured).
    """
    specs, axes = wl.build_specs(args)
    specs = wl.expand_grid(specs, axes)
    if len(specs) != 1:
        raise ValueError(
            f"advise takes exactly one workload point, got {len(specs)} — "
            f"the advisor searches the transform space itself")
    sess = Session(args.device, provider=args.provider,
                   cache_dir=args.cache_dir,
                   persistent_cache=_sweep_cache(args))
    report = sess.advise(
        specs[0], depth=args.depth, beam_width=args.beam_width,
        top_k=args.top_k, validate_top=args.validate_top,
        parallel=args.jobs)
    ext = {"text": "txt", "json": "json", "csv": "csv"}[args.format]
    _emit(report.render(args.format), args,
          default_artifact=f"advise-{sess.device.name}.{ext}")
    return 0


def cmd_validate(args) -> int:
    specs, axes = wl.build_specs(args)
    specs = wl.expand_grid(specs, axes)
    if len(specs) != 1:
        raise ValueError(
            f"validate takes exactly one workload point, got {len(specs)}")
    sess = _session(args)
    report = sess.validate(specs[0], providers=args.providers)
    _emit(report.render(args.format), args)
    return 0


def cmd_compare(args) -> int:
    """Rerun the paper's §5 hist-vs-hist2 case study end to end.

    Mirrors ``examples/histogram_casestudy.py``: the device carries the
    case study's LLC emulation (``--llc-bytes``/``--miss-latency``/
    ``--hide-concurrency``), every (kind, size) is profiled under both
    the naive ``hist`` and the conflict-reordered ``hist2`` kernel, and
    the report carries both verdicts, the modeled speedup, the per-pair
    bottleneck shift, and the size-axis shift events per variant — the
    paper's headline result as one command.  All numbers come from the
    same ``Session.sweep`` the Python API runs, so they are bit-identical
    to a scripted session.
    """
    from repro.analysis import get_device
    from repro.core.profiler import CacheModel
    device = get_device(args.device).with_(cache=CacheModel(
        llc_bytes=args.llc_bytes, miss_latency_cycles=args.miss_latency,
        hide_concurrency=args.hide_concurrency))
    sess = Session(device, provider=args.provider,
                   cache_dir=args.cache_dir, shift_tol=args.shift_tol,
                   persistent_cache=_sweep_cache(args))

    def spec(kind, px, variant):
        img = wl.make_image(kind, px, seed=args.seed)
        return WorkloadSpec.from_histogram(
            img, label=f"{kind}/{px}px/{variant}", variant=variant,
            num_bins=args.num_bins, num_cores=args.num_cores,
            waves_per_tile=args.waves_per_tile,
            overhead_cycles=args.overhead_cycles)

    rows, size_shifts = [], []
    for kind in args.kind:
        # size-axis sweeps per variant (the casestudy's shift detection);
        # their counters populate the memo, so the per-size pair sweeps
        # below re-profile without re-collecting
        for variant in ("hist", "hist2"):
            res = sess.sweep([spec(kind, px, variant) for px in args.pixels],
                             parallel=args.jobs)
            size_shifts.extend(
                f"{kind}/{variant}: {s.unit_before}->{s.unit_after} "
                f"({s.label_before} -> {s.label_after})"
                for s in res.shifts)
        for px in args.pixels:
            result = sess.sweep(
                [spec(kind, px, "hist"), spec(kind, px, "hist2")])
            h, h2 = result.profiles
            shift = result.shifts[0] if result.shifts else None
            rows.append({
                "kind": kind,
                "pixels": px,
                "hist_U": h.scatter_utilization,
                "hist_bottleneck": h.bottleneck,
                "hist2_U": h2.scatter_utilization,
                "hist2_bottleneck": h2.bottleneck,
                "speedup": float(result.speedup_vs_first[1]),
                "shift": (f"{shift.unit_before}->{shift.unit_after}"
                          if shift else ""),
            })
    relieved = sum(1 for r in rows if r["hist_bottleneck"] == "scatter"
                   and r["hist2_bottleneck"] != "scatter")
    if relieved:
        verdict = (f"hist2 reordering moves the bottleneck off the "
                   f"shared-memory atomic unit at {relieved}/{len(rows)} "
                   f"points")
    elif size_shifts:
        verdict = (f"hist2 lowers scatter utilization but the leading unit "
                   f"is unchanged at every size; the bottleneck shifts "
                   f"along the size axis instead ({len(size_shifts)} "
                   f"event(s), see size-axis lines)")
    else:
        verdict = ("no bottleneck shift: hist2 reordering does not relieve "
                   "the shared-memory atomic unit at any swept point")

    if args.format == "json":
        payload = {"device": sess.device.name, "points": rows,
                   "size_shifts": size_shifts, "verdict": verdict}
        report = json.dumps(payload, indent=2)
    elif args.format == "csv":
        import csv as csv_mod
        import io
        buf = io.StringIO()
        w = csv_mod.DictWriter(buf, fieldnames=list(rows[0]))
        w.writeheader()
        w.writerows(rows)
        report = buf.getvalue()
    else:
        lines = [f"== compare: hist vs hist2 on {sess.device.name} =="]
        for r in rows:
            shift = f"  shift: {r['shift']}" if r["shift"] else ""
            lines.append(
                f"{r['kind']:>8} {r['pixels']:>9}px  "
                f"hist U={r['hist_U']:6.2%} ({r['hist_bottleneck']})  "
                f"hist2 U={r['hist2_U']:6.2%} ({r['hist2_bottleneck']})  "
                f"speedup x{r['speedup']:.2f}{shift}")
        for line in size_shifts:
            lines.append(f"size-axis bottleneck shift: {line}")
        lines.append(f"verdict: {verdict}")
        report = "\n".join(lines)
    ext = {"text": "txt", "json": "json", "csv": "csv"}[args.format]
    _emit(report, args,
          default_artifact=f"compare-{sess.device.name}.{ext}")
    return 0


def cmd_audit(args) -> int:
    """Static contention lint over compiled HLO — zero kernel executions.

    Targets: ``--config NAME`` lowers each applicable step of one zoo
    config to its pre-optimization HLO (no ``.compile()``), ``--all``
    audits the whole zoo, ``--hlo-file PATH`` audits an already-dumped
    module without importing jax.  The scanned HLO is dumped under
    ``results/cli/audit/hlo/`` so SARIF result locations point at real,
    openable artifacts; ``--fail-on SEVERITY`` turns findings at or
    above that severity into exit code 1 (the CI gate).
    """
    from repro import audit as audit_mod

    sess = Session(args.device, cache_dir=args.cache_dir)
    audit_dir = results_dir() / "cli" / "audit"
    dump_hlo = not args.no_artifact

    def sink_for(config: str):
        def sink(step: str, text: str) -> str:
            rel = f"hlo/{config.replace('-', '_')}__{step}.hlo"
            if dump_hlo:
                path = audit_dir / rel
                path.parent.mkdir(parents=True, exist_ok=True)
                path.write_text(text)
            # SARIF artifact URIs are relative to the report's directory
            return rel
        return sink

    if args.hlo_file:
        text = Path(args.hlo_file).read_text()
        label = Path(args.hlo_file).stem
        report = audit_mod.audit_hlo(
            text, session=sess, label=label,
            suppress=args.suppress or (), hlo_uri=args.hlo_file,
            num_cores=args.num_cores)
    else:
        from repro.audit import zoo
        if args.all:
            configs = sorted(zoo.ARCHS)
        elif args.config:
            configs = [zoo.normalize_arch(c) for c in args.config]
        else:
            raise ValueError(
                "audit needs a target: --config NAME, --all, or "
                "--hlo-file PATH")
        reports = []
        for config in configs:
            reports.append(audit_mod.audit_config(
                config, session=sess, steps=args.steps,
                reduced=args.reduced, variant=args.variant,
                extra_suppress=args.suppress or (),
                hlo_sink=sink_for(config), num_cores=args.num_cores))
        report = (reports[0] if len(reports) == 1
                  else audit_mod.merge(reports))
    return _finish_findings(report, args, sess, tool="audit")


def cmd_lint(args) -> int:
    """Symbolic jaxpr-level lint over the registered Pallas kernels.

    Traces each kernel (``--kernel`` selects a subset; default all) to
    its jaxpr — zero kernel executions — and walks it for scratch-memory
    scatter/accumulate sites.  Affine index streams get exact static
    degree counters (bit-for-bit the trace provider's); data-dependent
    ones emit KERN005 findings carrying a ``WorkloadSpec`` for dynamic
    audit.  Shares the audit's gate/artifact/SARIF tail, so
    ``repro lint --format sarif`` merges cleanly with audit logs.
    """
    from repro import lint as lint_mod

    if getattr(args, "list", False):
        _emit("\n".join(lint_mod.kernel_names()), args)
        return 0
    sess = Session(args.device, cache_dir=args.cache_dir)
    names = args.kernel or None
    if names and len(names) == 1:
        report = lint_mod.lint_kernel(
            names[0], session=sess, suppress=args.suppress or (),
            num_cores=args.num_cores)
    else:
        report = lint_mod.lint_registry(
            names, session=sess, suppress=args.suppress or (),
            num_cores=args.num_cores)
    return _finish_findings(report, args, sess, tool="lint")


def _finish_findings(report, args, sess, *, tool: str) -> int:
    """Shared ``audit``/``lint`` report tail (one implementation).

    Optionally attaches advisor picks (``--advise``), renders and
    persists the report under ``results/cli/<tool>/``, then converts
    ``--fail-on`` gating into the process exit code — so both
    subcommands gate CI identically.
    """
    from repro import audit as audit_mod

    if getattr(args, "advise", False):
        audit_mod.attach_advice(report, sess)
    ext = {"text": "txt", "json": "json", "csv": "csv",
           "sarif": "sarif"}[args.format]
    _emit(report.render(args.format), args,
          default_artifact=f"{tool}/{tool}-{report.label}.{ext}")
    rc = audit_mod.exit_code(report, args.fail_on)
    if rc:
        gated = report.gated(args.fail_on)
        print(f"{tool}: {len(gated)} finding(s) at or above "
              f"--fail-on {args.fail_on}", file=sys.stderr)
    return rc


def cmd_cache(args) -> int:
    """Persistent counter-cache maintenance (``results/cache/``).

    ``stats`` reports entry count, bytes on disk, and a per-provider
    breakdown (recovered from each entry's stored ``source`` field);
    ``clear`` removes everything; ``prune --max-bytes N`` evicts
    least-recently-written entries (LRU by mtime — every cache write
    refreshes it) until at most N bytes remain — the size bound a
    long-running shared cache needs.
    """
    from repro.analysis import SweepCache

    def fmt_bytes(n: int) -> str:
        return f"{n / 1e6:.2f} MB" if n >= 1e5 else f"{n} B"

    cache = SweepCache()
    if args.action == "stats":
        stats = cache.stats()
        if args.format == "json":
            _emit(json.dumps(stats, indent=2), args)
            return 0
        lines = [f"cache root: {stats['root']}",
                 f"{stats['entries']} entries, {fmt_bytes(stats['bytes'])}"]
        if stats["quarantined"]:
            lines.append(f"{stats['quarantined']} quarantined corrupt "
                         f"file(s) — 'cache prune' deletes them")
        for source, b in stats["by_provider"].items():
            lines.append(f"  {source:>12}  {b['entries']:>6} entries  "
                         f"{fmt_bytes(b['bytes']):>12}")
        _emit("\n".join(lines), args)
        return 0
    if args.action == "clear":
        removed = cache.clear()
        _emit(f"removed {removed} cache entries", args)
        return 0
    # prune (quarantined/tmp litter always goes; --max-bytes adds LRU)
    removed, freed = cache.prune(args.max_bytes)
    stats = cache.stats()
    _emit(f"pruned {removed} entries ({fmt_bytes(freed)}); "
          f"{stats['entries']} left ({fmt_bytes(stats['bytes'])})", args)
    return 0


def cmd_serve(args) -> int:
    """Run the localhost profiling daemon until interrupted.

    All resilience knobs (workers, queue depth, deadlines, retries,
    breaker thresholds, fault-injection rates for chaos testing) come in
    as flags, are range-checked up front by the argparse types, and land
    in one ``ServiceConfig``; the daemon itself lives in
    ``repro.service`` and is exercised in-process by the test suite.
    """
    from repro.service import ServiceConfig
    from repro.service.server import serve

    config = ServiceConfig(
        host=args.host, port=args.port, workers=args.workers,
        queue_depth=args.queue_depth, device=args.device,
        provider=args.provider, fallbacks=tuple(args.fallbacks),
        timeout_s=args.timeout, max_timeout_s=args.max_timeout,
        max_points=args.max_points, call_timeout_s=args.call_timeout,
        retries=args.retries, backoff_base_s=args.backoff_base,
        breaker_threshold=args.breaker_threshold,
        breaker_cooldown_s=args.breaker_cooldown,
        persistent_cache=not args.no_cache,
        fault_rate=args.fault_rate, latency_rate=args.latency_rate,
        latency_s=args.latency_s, corrupt_rate=args.corrupt_rate,
        fault_seed=args.fault_seed)
    serve(config, port_file=args.port_file)
    return 0


def cmd_client(args) -> int:
    """Talk to a running daemon (health / status / schema / submit)."""
    from repro.service import ServiceClient, ServiceError

    client = ServiceClient(args.host, args.port, timeout_s=args.timeout)
    try:
        if args.action == "submit":
            if args.job_file:
                payload = json.loads(Path(args.job_file).read_text())
            else:
                payload = json.loads(args.job)
            body = client.submit(payload,
                                 retries_on_busy=args.retries_on_busy)
        else:
            body = getattr(client, args.action)()
    except ServiceError as exc:
        print(f"error: {exc}" + (f" (HTTP {exc.status})"
                                 if exc.status else ""), file=sys.stderr)
        return 1
    except json.JSONDecodeError as exc:
        print(f"error: job payload is not valid JSON: {exc}",
              file=sys.stderr)
        return 2
    _emit(json.dumps(body, indent=2), args)
    return 0


# -- parser ------------------------------------------------------------------


def _add_common(p: argparse.ArgumentParser, *, formats=("text", "json",
                                                        "csv")) -> None:
    p.add_argument("--device", default="v5e",
                   help="device registry name (see 'devices'; default v5e)")
    p.add_argument("--provider", default="trace",
                   help="counter provider: trace|kernel|hlo|microbench "
                        "(default trace; hlo workloads auto-route to hlo)")
    p.add_argument("--format", choices=formats, default="text",
                   help="report format (default text)")
    p.add_argument("--output", metavar="PATH", default=None,
                   help="also write the report to PATH")
    p.add_argument("--cache-dir", default=None,
                   help="service-time table cache dir "
                        "(default results/tables/)")


def _add_gate(p: argparse.ArgumentParser, *, tool: str) -> None:
    """The audit/lint shared gating + artifact surface (satellite of the
    unified finding pipeline: one definition, two subcommands)."""
    p.add_argument("--fail-on", default="error",
                   choices=("never", "note", "warning", "error"),
                   help="exit 1 when any non-suppressed finding is at or "
                        "above this severity (default error)")
    p.add_argument("--suppress", nargs="+", default=None, metavar="RULE",
                   help="suppress rule ids (adds to in-source "
                        "# repro: noqa comments)")
    p.add_argument("--advise", action="store_true",
                   help="run the advisor on every gating finding and "
                        "attach the top-ranked transform (predicted "
                        "speedup + post-transform bottleneck)")
    p.add_argument("--num-cores", type=int, default=8,
                   help="cores the synthesized streams are scored on "
                        "(default 8)")
    p.add_argument("--no-artifact", action="store_true",
                   help=f"do not write the report artifacts under "
                        f"results/cli/{tool}/")


def _add_workload(p: argparse.ArgumentParser, *, multi: bool) -> None:
    n = {"nargs": "+"} if multi else {}
    g = p.add_argument_group("workload")
    g.add_argument("--workload", choices=wl.WORKLOADS, default="indices",
                   help="workload family (default indices)")
    g.add_argument("--size", type=wl.parse_int, default=None, **n,
                   help="index-stream length, e.g. 65536 or 2^16 "
                        "(indices/scatter)")
    g.add_argument("--pixels", type=wl.parse_int, default=None, **n,
                   help="image pixels, e.g. 2^20 (histogram)")
    g.add_argument("--dist", choices=("solid", "uniform"), default="uniform",
                   help="stream/image contents: solid=max contention, "
                        "uniform=low (default uniform)")
    g.add_argument("--variant", choices=("hist", "hist2"), default="hist",
                   help="histogram kernel variant (hist2 = conflict "
                        "reordering; default hist)")
    g.add_argument("--num-bins", type=int, default=256)
    g.add_argument("--num-segments", type=int, default=256,
                   help="scatter-add destination segments (default 256)")
    g.add_argument("--seed", type=int, default=0)
    g.add_argument("--hlo-file", default=None,
                   help="post-optimization HLO module text (hlo workload)")
    g.add_argument("--num-devices", type=int, default=1,
                   help="chips for HLO collective accounting (default 1)")
    g.add_argument("--label", default=None,
                   help="base label (default derived from the arguments)")
    geo = p.add_argument_group("launch geometry / roofline")
    geo.add_argument("--waves-per-tile", type=int, default=None, **n)
    geo.add_argument("--pipeline-depth", type=int, default=None, **n)
    geo.add_argument("--num-cores", type=int, default=8)
    geo.add_argument("--bytes-read", type=float, default=None)
    geo.add_argument("--flops", type=float, default=None)
    geo.add_argument("--overhead-cycles", type=float, default=500.0)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro",
        description="Shared-memory atomic bottleneck profiler "
                    "(the paper's two tools as a command line)")
    # handled by argparse before subcommand dispatch: `repro --version`
    # exits 0 without requiring (or running) any subcommand
    ap.add_argument("--version", action="version",
                    version=f"%(prog)s {__version__}")
    sub = ap.add_subparsers(dest="command", required=True)

    p = sub.add_parser("devices", help="list registered devices")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", metavar="PATH", default=None)
    p.add_argument("--cache-dir", default=None)
    p.set_defaults(func=cmd_devices)

    p = sub.add_parser("profile", help="profile one workload point")
    _add_common(p)
    _add_workload(p, multi=False)
    p.set_defaults(func=cmd_profile)

    p = sub.add_parser(
        "heatmap", help="per-bin contention attribution for one point")
    _add_common(p)
    _add_workload(p, multi=False)
    p.add_argument("--top-k", type=_positive_int, default=16,
                   help="bins shown in the text/json grid (default 16)")
    p.add_argument("--hot-degree", type=_positive_float, default=2.0,
                   help="wave degree at or above which a bin counts as "
                        "hot (default 2.0)")
    p.add_argument("--no-artifact", action="store_true",
                   help="do not write the report under results/cli/")
    p.set_defaults(func=cmd_heatmap)

    p = sub.add_parser(
        "sweep", help="grid sweep: sizes x geometry, concurrent points")
    _add_common(p)
    _add_workload(p, multi=True)
    p.add_argument("--devices", nargs="+", default=None, metavar="DEV",
                   help="sweep the grid on several devices "
                        "(outermost axis; overrides --device)")
    p.add_argument("--jobs", type=_positive_int, default=None,
                   help=f"concurrent collection threads (default "
                        f"min({DEFAULT_JOBS}, points); 1 = serial)")
    p.add_argument("--shards", type=_positive_int, default=1,
                   help="split the grid into this many deterministic "
                        "stride slices; this process sweeps only "
                        "--shard-index's slice (shards merge through the "
                        "persistent counter cache; default 1)")
    p.add_argument("--shard-index", type=_nonneg_int, default=0,
                   help="which slice of a --shards split this process "
                        "owns (0-based; default 0)")
    p.add_argument("--merge", action="store_true",
                   help="assemble the full grid from the persistent "
                        "counter cache (a warm full sweep: collects "
                        "nothing when every shard has run; incompatible "
                        "with --shards/--no-cache)")
    p.add_argument("--shift-tol", type=float, default=bottleneck.SHIFT_TOL,
                   help="relative lead a new unit needs to count as a "
                        "bottleneck shift (default %(default)s)")
    p.add_argument("--no-artifact", action="store_true",
                   help="do not write the default results/cli/ artifact")
    p.add_argument("--no-cache", action="store_true",
                   help="do not read/write the results/cache/ counter "
                        "cache (re-collect every point)")
    p.set_defaults(func=cmd_sweep)

    p = sub.add_parser(
        "advise",
        help="search workload transforms, rank predicted fixes")
    _add_common(p)
    _add_workload(p, multi=False)
    p.add_argument("--top-k", type=int, default=5,
                   help="how many ranked candidates to report "
                        "(default %(default)s)")
    p.add_argument("--validate-top", type=int, default=0,
                   help="re-validate the N top-ranked kernel-source "
                        "candidates via the kernel provider (default 0)")
    p.add_argument("--depth", type=int, default=2,
                   help="max transforms composed per candidate "
                        "(default %(default)s)")
    p.add_argument("--beam-width", type=int, default=8,
                   help="compositions each search level extends "
                        "(default %(default)s)")
    p.add_argument("--jobs", type=_positive_int, default=None,
                   help="concurrent collection threads per frontier")
    p.add_argument("--no-artifact", action="store_true",
                   help="do not write the default results/cli/ artifact")
    p.add_argument("--no-cache", action="store_true",
                   help="do not read/write the results/cache/ counter "
                        "cache (re-collect every candidate)")
    p.set_defaults(func=cmd_advise)

    p = sub.add_parser(
        "validate",
        help="multi-provider counter comparison (paper §5)")
    _add_common(p, formats=("text", "json"))
    _add_workload(p, multi=False)
    p.add_argument("--providers", nargs="+", default=["trace", "kernel"],
                   help="first provider is the reference "
                        "(default: trace kernel)")
    p.set_defaults(func=cmd_validate)

    p = sub.add_parser(
        "compare",
        help="§5 case study: hist vs hist2 bottleneck-shift verdict")
    _add_common(p)
    p.add_argument("--kind", nargs="+", choices=("solid", "uniform"),
                   default=["solid", "uniform"])
    p.add_argument("--pixels", type=wl.parse_int, nargs="+",
                   default=[2 ** 14, 2 ** 17, 2 ** 20])
    p.add_argument("--waves-per-tile", type=int, default=8,
                   help="launch occupancy (default 8, the casestudy's "
                        "shift-study setting)")
    p.add_argument("--num-bins", type=int, default=256)
    p.add_argument("--num-cores", type=int, default=8)
    p.add_argument("--overhead-cycles", type=float, default=500.0)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--shift-tol", type=float, default=bottleneck.SHIFT_TOL)
    # the casestudy's LLC emulation (examples/histogram_casestudy.py)
    p.add_argument("--llc-bytes", type=wl.parse_int, default=1 << 21)
    p.add_argument("--miss-latency", type=float, default=800.0)
    p.add_argument("--hide-concurrency", type=float, default=48.0)
    p.add_argument("--jobs", type=_positive_int, default=None,
                   help="concurrent collection threads per sweep")
    p.add_argument("--no-artifact", action="store_true")
    p.add_argument("--no-cache", action="store_true",
                   help="do not read/write the results/cache/ counter "
                        "cache (re-collect every point)")
    p.set_defaults(func=cmd_compare)

    p = sub.add_parser(
        "audit",
        help="static HLO contention lint over the model zoo (SARIF, "
             "CI gate)")
    _add_common(p, formats=("text", "json", "csv", "sarif"))
    p.add_argument("--config", nargs="+", default=None, metavar="NAME",
                   help="zoo config(s) to lower and audit (underscore or "
                        "dash spelling)")
    p.add_argument("--all", action="store_true",
                   help="audit every zoo config")
    p.add_argument("--hlo-file", default=None, metavar="PATH",
                   help="audit an already-dumped HLO module text instead "
                        "of lowering a config (no jax import)")
    p.add_argument("--steps", nargs="+", default=None,
                   choices=("train", "prefill", "decode"),
                   help="steps to lower per config (default: all "
                        "applicable)")
    p.add_argument("--reduced", action="store_true",
                   help="lower reduced configs on smoke shapes (fast; "
                        "same scatter idioms)")
    p.add_argument("--variant", default="base",
                   help="optimization variant for shape tuning "
                        "(default base)")
    _add_gate(p, tool="audit")
    p.set_defaults(func=cmd_audit)

    p = sub.add_parser(
        "lint",
        help="symbolic jaxpr-level Pallas kernel lint (KERN rules, "
             "SARIF, CI gate)")
    _add_common(p, formats=("text", "json", "csv", "sarif"))
    p.add_argument("--kernel", nargs="+", default=None, metavar="NAME",
                   help="registered kernel(s) to lint (default: all; "
                        "see --list)")
    p.add_argument("--list", action="store_true",
                   help="print the registered kernel names and exit")
    _add_gate(p, tool="lint")
    p.set_defaults(func=cmd_lint)

    p = sub.add_parser(
        "cache",
        help="persistent counter-cache maintenance (stats/clear/prune)")
    p.add_argument("action", choices=("stats", "clear", "prune"),
                   help="stats: entry count, bytes, per-provider "
                        "breakdown; clear: remove everything; prune: "
                        "LRU-by-mtime eviction down to --max-bytes")
    p.add_argument("--max-bytes", type=wl.parse_int, default=None,
                   metavar="N",
                   help="prune target: evict least-recently-written "
                        "entries until at most N bytes remain "
                        "(accepts 2^20 notation; required for prune)")
    p.add_argument("--format", choices=("text", "json"), default="text")
    p.add_argument("--output", metavar="PATH", default=None)
    p.set_defaults(func=cmd_cache)

    p = sub.add_parser(
        "serve",
        help="run the localhost profiling daemon (see repro.service)")
    p.add_argument("--host", default="127.0.0.1",
                   help="bind address (default 127.0.0.1 — localhost "
                        "only)")
    p.add_argument("--port", type=_port, default=8642,
                   help="TCP port; 0 binds an ephemeral port, printed "
                        "on start (default %(default)s)")
    p.add_argument("--port-file", default=None, metavar="PATH",
                   help="write the bound port to PATH (for scripts "
                        "using --port 0)")
    p.add_argument("--workers", type=_positive_int, default=4,
                   help="worker threads (default %(default)s)")
    p.add_argument("--queue-depth", type=_positive_int, default=32,
                   help="pending jobs before 429 load-shedding "
                        "(default %(default)s)")
    p.add_argument("--device", default="v5e",
                   help="default device for sessions (default v5e)")
    p.add_argument("--provider", default="trace",
                   help="primary counter provider (default trace)")
    p.add_argument("--fallbacks", nargs="+", default=["trace"],
                   metavar="PROVIDER",
                   help="degraded fallback chain after the primary "
                        "(default: trace)")
    p.add_argument("--timeout", type=_positive_float, default=30.0,
                   help="default per-job deadline seconds "
                        "(default %(default)s)")
    p.add_argument("--max-timeout", type=_positive_float, default=300.0,
                   help="largest timeout_s a job may request "
                        "(default %(default)s)")
    p.add_argument("--max-points", type=_positive_int, default=4096,
                   help="largest sweep grid a single job may expand to "
                        "(default %(default)s)")
    p.add_argument("--call-timeout", type=_positive_float, default=10.0,
                   help="per-provider-call timeout seconds "
                        "(default %(default)s)")
    p.add_argument("--retries", type=_nonneg_int, default=2,
                   help="transient-failure retries per provider "
                        "(default %(default)s; 0 disables)")
    p.add_argument("--backoff-base", type=_positive_float, default=0.05,
                   help="first retry backoff seconds, doubling per "
                        "attempt (default %(default)s)")
    p.add_argument("--breaker-threshold", type=_positive_int, default=5,
                   help="consecutive failures that open a provider's "
                        "circuit breaker (default %(default)s)")
    p.add_argument("--breaker-cooldown", type=_positive_float, default=5.0,
                   help="seconds an open breaker waits before its "
                        "half-open probe (default %(default)s)")
    p.add_argument("--no-cache", action="store_true",
                   help="disable the persistent counter cache (and with "
                        "it the cached-stale fallback)")
    chaos = p.add_argument_group(
        "fault injection (chaos testing; all off by default)")
    chaos.add_argument("--fault-rate", type=_rate, default=0.0,
                       help="probability a provider call raises an "
                            "injected transient fault")
    chaos.add_argument("--latency-rate", type=_rate, default=0.0,
                       help="probability a provider call sleeps "
                            "--latency-s first")
    chaos.add_argument("--latency-s", type=_positive_float, default=0.05,
                       help="injected latency seconds "
                            "(default %(default)s)")
    chaos.add_argument("--corrupt-rate", type=_rate, default=0.0,
                       help="probability a provider call returns "
                            "structurally corrupt counters")
    chaos.add_argument("--fault-seed", type=_nonneg_int, default=0,
                       help="seed for the deterministic injection "
                            "schedule (default %(default)s)")
    p.set_defaults(func=cmd_serve)

    p = sub.add_parser(
        "client",
        help="query or submit jobs to a running daemon")
    p.add_argument("action", choices=("health", "status", "schema",
                                      "submit"),
                   help="health/status/schema: GET endpoints; submit: "
                        "POST one job payload")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=_port, default=8642,
                   help="daemon port (default %(default)s)")
    p.add_argument("--timeout", type=_positive_float, default=60.0,
                   help="HTTP timeout seconds (default %(default)s)")
    p.add_argument("--job", default=None, metavar="JSON",
                   help="inline job payload for submit")
    p.add_argument("--job-file", default=None, metavar="PATH",
                   help="file with the job payload for submit")
    p.add_argument("--retries-on-busy", type=_nonneg_int, default=0,
                   help="retry 429 responses this many times, honoring "
                        "Retry-After (default 0)")
    p.add_argument("--output", metavar="PATH", default=None,
                   help="also write the response to PATH")
    p.set_defaults(func=cmd_client)

    return ap


def _validate_args(ap: argparse.ArgumentParser, args) -> None:
    """Cross-field argument validation, up front (argparse exit code 2).

    Per-field range checks live in the argparse types
    (``_positive_int``/``_nonneg_int``); anything relating two flags is
    checked here, before any session or device work starts.
    """
    shards = getattr(args, "shards", 1)
    shard_index = getattr(args, "shard_index", 0)
    if shard_index >= shards:
        ap.error(f"--shard-index {shard_index} is out of range for "
                 f"--shards {shards} (valid: 0..{shards - 1})")
    if getattr(args, "merge", False):
        if shards > 1 or shard_index:
            ap.error("--merge assembles the full grid from the cache; "
                     "drop --shards/--shard-index")
        if getattr(args, "no_cache", False):
            ap.error("--merge reads the persistent counter cache; it "
                     "cannot be combined with --no-cache")
    if args.command == "cache":
        if args.action == "prune" and args.max_bytes is None:
            ap.error("cache prune requires --max-bytes")
        if args.max_bytes is not None and args.max_bytes < 0:
            ap.error(f"--max-bytes must be >= 0, got {args.max_bytes}")
    if args.command == "serve":
        if args.max_timeout < args.timeout:
            ap.error(f"--max-timeout {args.max_timeout} must be >= "
                     f"--timeout {args.timeout}")
        if args.call_timeout > args.max_timeout:
            ap.error(f"--call-timeout {args.call_timeout} must be <= "
                     f"--max-timeout {args.max_timeout} (a single call "
                     f"may not outlive any job deadline)")
    if args.command == "client":
        if args.port == 0:
            ap.error("--port 0 is only meaningful for serve (ephemeral "
                     "bind); the client needs the daemon's actual port")
        if args.action == "submit":
            if bool(args.job) == bool(args.job_file):
                ap.error("submit needs exactly one of --job JSON or "
                         "--job-file PATH")
        elif args.job or args.job_file:
            ap.error(f"--job/--job-file only apply to submit, not "
                     f"{args.action!r}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = build_parser()
    args = ap.parse_args(argv)
    _validate_args(ap, args)
    # hlo specs carry no wave trace: route them to the hlo provider unless
    # the user explicitly picked another backend
    if getattr(args, "workload", None) == "hlo" \
            and getattr(args, "provider", None) == "trace":
        args.provider = "hlo"
    try:
        return args.func(args)
    except (ValueError, KeyError, FileNotFoundError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
