"""Command-line front-end over the ``repro.analysis`` Session API.

``python -m repro <command>`` (or ``python -m repro.cli``) exposes the
paper's tools without writing Python: see ``repro.cli.main`` for the
subcommands and ``repro.cli.workloads`` for the declarative workload
arguments.  Import surface: ``main(argv) -> int``.
"""

from repro.cli.main import build_parser, main  # noqa: F401
