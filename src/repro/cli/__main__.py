"""``python -m repro.cli`` — same entry as ``python -m repro``."""

import sys

from repro.cli.main import main

if __name__ == "__main__":
    sys.exit(main())
