"""Declarative workload construction for the command line.

Translates parsed CLI arguments into the ``WorkloadSpec``s the Session
API consumes — synthetic index streams, image histograms (``--variant
hist|hist2``), scatter-adds, and HLO text files — plus the grid axes the
sweep engine expands.  Everything here is argument plumbing; the specs
themselves are ordinary ``repro.analysis.WorkloadSpec``s, so a CLI run
is bit-identical to the equivalent Python session.
"""

from __future__ import annotations

import numpy as np

from repro.analysis.workload import WorkloadSpec
from repro.data.images import make_image

WORKLOADS = ("indices", "histogram", "scatter", "hlo")


def parse_int(text: str) -> int:
    """Integer with ``2^k`` power notation (sizes read like the paper)."""
    text = text.strip()
    if "^" in text:
        base, exp = text.split("^", 1)
        return int(base) ** int(exp)
    return int(text)


def make_indices(dist: str, size: int, num_bins: int,
                 seed: int) -> np.ndarray:
    """Synthetic scatter-destination stream (the paper's two extremes)."""
    if dist == "solid":
        return np.zeros(size, np.int64)       # maximum contention, e -> 32
    if dist == "uniform":
        rng = np.random.default_rng(seed)
        return rng.integers(0, num_bins, size)  # low contention, e ~ 2-3
    raise ValueError(f"unknown distribution {dist!r}")


def _spec_kwargs(args) -> dict:
    """Roofline/geometry overrides shared by every workload family."""
    kw = {"num_cores": args.num_cores,
          "overhead_cycles": args.overhead_cycles}
    if args.bytes_read is not None:
        kw["bytes_read"] = args.bytes_read
    if args.flops is not None:
        kw["flops"] = args.flops
    return kw


def _as_list(value, default):
    """Single-value commands store scalars, sweeps store lists."""
    if value is None:
        return list(default)
    return list(value) if isinstance(value, (list, tuple)) else [value]


def _labeler(base: str, values: list):
    """Per-point labels: a user-supplied base label must stay unique
    across a multi-value size axis, or sweep rows and shift events become
    indistinguishable ("foo -> foo")."""
    multi = base is not None and len(values) > 1

    def label(value, default: str) -> str:
        if base is None:
            return default
        return f"{base}-{value}" if multi else base
    return label


def build_specs(args) -> tuple[list[WorkloadSpec], dict]:
    """(base specs, grid axes) from parsed workload arguments.

    One base spec per size/pixel value (stream *content* is not a spec
    field, so it cannot be a ``grid`` axis); launch geometry provided as
    lists becomes the grid axes that ``WorkloadSpec.grid`` expands.
    """
    specs: list[WorkloadSpec] = []
    if args.workload == "indices":
        sizes = _as_list(args.size, [1 << 16])
        label = _labeler(args.label, sizes)
        for size in sizes:
            idx = make_indices(args.dist, size, args.num_bins, args.seed)
            specs.append(WorkloadSpec.from_indices(
                idx, args.num_bins,
                label=label(size, f"{args.dist}-{size}"),
                **_spec_kwargs(args)))
    elif args.workload == "histogram":
        pixels = _as_list(args.pixels, [1 << 16])
        label = _labeler(args.label, pixels)
        for px in pixels:
            img = make_image(args.dist, px, seed=args.seed)
            specs.append(WorkloadSpec.from_histogram(
                img, label=label(px, f"{args.dist}-{args.variant}-{px}px"),
                variant=args.variant, num_bins=args.num_bins,
                **_spec_kwargs(args)))
    elif args.workload == "scatter":
        sizes = _as_list(args.size, [1 << 16])
        label = _labeler(args.label, sizes)
        for size in sizes:
            ids = make_indices(args.dist, size, args.num_segments, args.seed)
            values = np.ones(size, np.float32)
            specs.append(WorkloadSpec.from_scatter_add(
                ids, values, args.num_segments,
                label=label(size, f"{args.dist}-scatter-{size}"),
                **_spec_kwargs(args)))
    elif args.workload == "hlo":
        if not args.hlo_file:
            raise ValueError("--workload hlo needs --hlo-file PATH")
        with open(args.hlo_file) as f:
            text = f.read()
        label = args.label or f"hlo-{args.hlo_file}"
        specs.append(WorkloadSpec.from_compiled(
            hlo_text=text, label=label, num_devices=args.num_devices,
            **_spec_kwargs(args)))
    else:
        raise ValueError(f"unknown workload {args.workload!r}")

    axes: dict = {}
    wpt = getattr(args, "waves_per_tile", None)
    depth = getattr(args, "pipeline_depth", None)
    if isinstance(wpt, (list, tuple)):
        axes["waves_per_tile"] = [int(v) for v in wpt]
    elif wpt is not None:
        specs = [s.with_(waves_per_tile=int(wpt)) for s in specs]
    if isinstance(depth, (list, tuple)):
        axes["pipeline_depth"] = [int(v) for v in depth]
    elif depth is not None:
        specs = [s.with_(pipeline_depth=int(depth)) for s in specs]
    return specs, axes


def expand_grid(specs: list[WorkloadSpec],
                axes: dict) -> list[WorkloadSpec]:
    """Cartesian product of base specs with the geometry axes."""
    if not axes:
        return specs
    out: list[WorkloadSpec] = []
    for spec in specs:
        out.extend(spec.grid(**axes))
    return out
