"""``python -m repro`` — the profiler's command-line entry point."""

import sys

from repro.cli.main import main

if __name__ == "__main__":
    sys.exit(main())
