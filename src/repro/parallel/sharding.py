"""Partition-spec assignment for every parameter / state / input tree.

Strategy (DESIGN.md §5): 2-D weight sharding — the TP dim over ``model``,
the other big dim over ``data`` (FSDP-style; XLA inserts the all-gathers /
reduce-scatters) — batch over ``(pod, data)``, experts EP-sharded over
``data`` (whole experts) + TP over ``model`` (expert hidden), KV caches
sequence-sharded over ``model`` for the 32k/500k decode shapes.

Specs are assigned by parameter *path name*, which the model code keeps
deliberately conventional (wq/wk/wv/wo, w_gate/w_up/w_down, in_proj/
out_proj, embed.table, ...).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

# parent-key name -> (spec for 2D weight)
_COL_PARALLEL = {"wq", "wk", "wv", "w_gate", "w_up", "lm_head", "cm_k",
                 "cm_r", "wr", "wg", "in_proj", "mix_a", "decay_a"}
_ROW_PARALLEL = {"wo", "w_down", "cm_v", "out_proj", "decay_b"}


def _path_names(path) -> list[str]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
    return names


def param_spec_for_path(path, leaf, cfg: ModelConfig) -> P:
    names = _path_names(path)
    nd = getattr(leaf, "ndim", 0)
    in_moe_ep = cfg.is_moe and cfg.num_experts >= 64  # EP for big-E archs

    if "embed" in names and names[-1] == "table":
        return P("model", "data")
    if names and names[-1] == "b":
        # bias (possibly layer-stacked): TP-shard the feature dim only,
        # and only for column-parallel parents (row-parallel outputs are
        # replicated after the reduce)
        for name in reversed(names):
            if name in _COL_PARALLEL:
                return P(*(None,) * (nd - 1), "model")
            if name in _ROW_PARALLEL:
                return P()
        return P()
    # MoE stacked expert weights (leading E dim, then layer-stacking may
    # add more leading dims; match by suffix name and take last 3 dims).
    if names and names[-1] in ("w_gate", "w_up") and nd >= 3 and cfg.is_moe \
            and "shared" not in names:
        lead = (None,) * (nd - 3)
        e_ax = "data" if in_moe_ep else None
        return P(*lead, e_ax, None, "model")
    if names and names[-1] == "w_down" and nd >= 3 and cfg.is_moe \
            and "shared" not in names:
        lead = (None,) * (nd - 3)
        e_ax = "data" if in_moe_ep else None
        return P(*lead, e_ax, "model", None)
    for name in reversed(names):
        if name in _COL_PARALLEL:
            if nd >= 2:
                lead = (None,) * (nd - 2)
                return P(*lead, "data", "model")
            if nd == 1 and names[-1] == "b":
                return P("model")
            return P()
        if name in _ROW_PARALLEL:
            if nd >= 2:
                lead = (None,) * (nd - 2)
                return P(*lead, "model", "data")
            return P()
    return P()  # norms, scalars, router, conv, biases of row-parallel


def param_pspecs(params, cfg: ModelConfig):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec_for_path(path, leaf, cfg), params)


def param_shardings(params, cfg: ModelConfig, mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(params, cfg))


# -- inputs / caches ---------------------------------------------------------


def batch_pspecs(batch_tree, data_axes=("pod", "data")):
    def spec(path, leaf):
        nd = getattr(leaf, "ndim", 0)
        if nd == 0:
            return P()
        return P(data_axes, *(None,) * (nd - 1))
    return jax.tree_util.tree_map_with_path(spec, batch_tree)


def cache_pspecs(cache_tree, cfg: ModelConfig, data_axes=("pod", "data"),
                 seq_axis: Optional[str] = "model"):
    """KV caches: batch over data axes, *sequence* over the model axis
    (flash-decode layout: works for any kv-head count and spreads the
    32k/500k cache).  SSM states: batch over data, heads over model."""

    def spec(path, leaf):
        names = _path_names(path)
        nd = getattr(leaf, "ndim", 0)
        name = names[-1] if names else ""
        if name in ("xk", "xv") and nd >= 4:
            # cross-attention K/V: short frozen source (1500 frames / image
            # tokens) — replicate the source dim, shard batch only
            lead = (None,) * (nd - 4)
            return P(*lead, data_axes, None, None, None)
        if name in ("k", "v") and nd >= 4:
            lead = (None,) * (nd - 4)
            return P(*lead, data_axes, None, seq_axis, None)
        if name in ("s", "h") and nd >= 4:   # rwkv/mamba states (B,H,...)
            lead = (None,) * (nd - 4)
            return P(*lead, data_axes, seq_axis, None, None)
        if name == "conv" and nd >= 3:       # (B, W-1, conv_dim)
            lead = (None,) * (nd - 3)
            return P(*lead, data_axes, None, None)
        if name in ("last", "cm_last") and nd >= 2:
            lead = (None,) * (nd - 2)
            return P(*lead, data_axes, None)
        return P()

    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def opt_state_pspecs(opt_state, params_specs):
    """AdamW state: m/v/master mirror the param specs; scalars replicated."""
    return {
        "m": params_specs,
        "v": params_specs,
        "master": params_specs,
        "count": P(),
    }
