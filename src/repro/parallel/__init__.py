"""parallel subpackage."""
