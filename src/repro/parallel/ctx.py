"""Distribution context: the mesh and axis names models shard against.

Models are pure functions; they consult this context (set by the launcher
or a ``use_mesh`` scope) for sharding constraints and shard_map wrapping.
When no context is set (unit tests, single-CPU smoke runs) every helper
degrades to a no-op / local path.
"""

from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MeshCtx:
    mesh: object                       # jax.sharding.Mesh
    data_axes: tuple[str, ...]         # batch-sharding axes (incl. pod)
    tp_axis: str                       # tensor-parallel axis
    seq_axis: Optional[str] = None     # sequence-parallel axis (long ctx)

    @property
    def batch_spec(self) -> P:
        return P(self.data_axes)

    @property
    def num_data_shards(self) -> int:
        n = 1
        for a in self.data_axes:
            n *= self.mesh.shape[a]
        return n


_local = threading.local()


def current() -> Optional[MeshCtx]:
    return getattr(_local, "ctx", None)


@contextlib.contextmanager
def use_mesh(mesh, data_axes=("data",), tp_axis: str = "model",
             seq_axis: Optional[str] = None):
    prev = current()
    _local.ctx = MeshCtx(mesh=mesh, data_axes=tuple(data_axes),
                         tp_axis=tp_axis, seq_axis=seq_axis)
    try:
        with mesh:
            yield _local.ctx
    finally:
        _local.ctx = prev


def shard(x, *spec) -> object:
    """Constrain `x` to NamedSharding(mesh, P(*spec)) when a mesh is set."""
    ctx = current()
    if ctx is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def _axes_size(mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard_batch(x) -> object:
    """Shard the leading (batch) dim over the data axes (skip if it does
    not divide — e.g. the batch=1 long-context decode cells)."""
    ctx = current()
    if ctx is None:
        return x
    n = _axes_size(ctx.mesh, ctx.data_axes)
    if x.shape[0] % n or x.shape[0] < n:
        return x
    spec = (ctx.data_axes,) + (None,) * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_heads(x, head_axis: int = 1) -> object:
    """Constraint for (B, H, T, hd)-shaped tensors: batch over data axes,
    heads over the TP axis (skipped when H does not divide)."""
    ctx = current()
    if ctx is None:
        return x
    tp = ctx.mesh.shape[ctx.tp_axis]
    if x.shape[head_axis] % tp or x.shape[head_axis] < tp:
        return x
    nb = _axes_size(ctx.mesh, ctx.data_axes)
    lead = ctx.data_axes if (x.shape[0] % nb == 0 and x.shape[0] >= nb) \
        else None
    spec = [None] * x.ndim
    spec[0] = lead
    spec[head_axis] = ctx.tp_axis
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_batch_tp(x) -> object:
    """Activation constraint: batch over data axes + LAST dim over the TP
    axis.  Applied to projection outputs (q/k/v, FFN hidden, logits) so
    the partitioner keeps the per-layer matmuls tensor-parallel instead of
    replicating them across the model axis."""
    ctx = current()
    if ctx is None:
        return x
    tp = ctx.mesh.shape[ctx.tp_axis]
    if x.shape[-1] % tp or x.shape[-1] < tp:
        return x
    nb = _axes_size(ctx.mesh, ctx.data_axes)
    lead = ctx.data_axes if (x.shape[0] % nb == 0 and x.shape[0] >= nb) \
        else None
    spec = (lead,) + (None,) * (x.ndim - 2) + (ctx.tp_axis,)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))
