"""Symbolic per-element index expressions over grid/wave/lane variables.

The lint's middle layer: a tiny expression language describing, for every
array a Pallas kernel computes, the value of each element as a function
of the array's own coordinates (``Iota``), the grid position
(``ProgramId``), and the operand blocks (``Data``).  The jaxpr
interpreter in :mod:`repro.lint.tracing` builds these expressions; this
module owns the node types, the dependency analysis (is an index stream
affine in grid/lane variables, or does it read runtime data?), and an
exact numpy evaluator.

Evaluation semantics mirror jax's lowering bit for bit where it matters
for integer index math: ``rem`` is the *truncated* (C-style) remainder
``lax.rem`` uses (``jnp.remainder``'s floor-mod correction chain is then
reproduced by the surrounding ``select_n`` expressions themselves), and
integer ``div`` truncates toward zero.  Anything the interpreter cannot
model becomes :class:`Opaque`, which poisons dependency analysis instead
of crashing it — an opaque stream is simply reported as "needs dynamic
audit" (KERN005) rather than proved.

No jax imports here: the expression algebra and evaluator are pure
numpy, so the audit/SARIF layer can import the lint rule catalog without
pulling in jax.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np


@dataclasses.dataclass(frozen=True, eq=False)
class Expr:
    """Base node: every expression knows its array shape and dtype."""

    shape: tuple
    dtype: Any


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    value: Any = 0          # python scalar or ndarray


@dataclasses.dataclass(frozen=True, eq=False)
class Iota(Expr):
    """Value = the element's own coordinate along ``dim`` (lane/step id)."""

    dim: int = 0


@dataclasses.dataclass(frozen=True, eq=False)
class ProgramId(Expr):
    """The grid index along ``axis`` (scalar, per kernel instance)."""

    axis: int = 0


@dataclasses.dataclass(frozen=True, eq=False)
class Data(Expr):
    """Contents of an operand ref's block (a runtime-data leaf)."""

    ref: int = 0
    name: str = ""


@dataclasses.dataclass(frozen=True, eq=False)
class Elem(Expr):
    """Elementwise op over broadcast-compatible args (incl. select_n)."""

    op: str = ""
    args: tuple = ()


@dataclasses.dataclass(frozen=True, eq=False)
class Reindex(Expr):
    """Pure coordinate remap: reshape/transpose/broadcast/slice."""

    kind: str = ""
    src: Optional[Expr] = None
    info: tuple = ()


@dataclasses.dataclass(frozen=True, eq=False)
class Opaque(Expr):
    """An unmodeled computation; poisons static analysis, never crashes."""

    reason: str = ""
    args: tuple = ()        # kept so tags/deps can flow through


# -- dependency analysis -----------------------------------------------------


def _walk(expr: Expr, seen: set) -> list[Expr]:
    if id(expr) in seen:
        return []
    seen.add(id(expr))
    out = [expr]
    children: tuple = ()
    if isinstance(expr, Elem):
        children = expr.args
    elif isinstance(expr, Reindex):
        children = (expr.src,)
    elif isinstance(expr, Opaque):
        children = expr.args
    for c in children:
        if isinstance(c, Expr):
            out.extend(_walk(c, seen))
    return out


def walk(expr: Expr) -> list[Expr]:
    """Every distinct node in the expression DAG (shared nodes once)."""
    return _walk(expr, set())


def data_refs(expr: Expr) -> set[int]:
    """Operand refs the expression reads — empty means data-independent."""
    return {n.ref for n in walk(expr) if isinstance(n, Data)}


def program_axes(expr: Expr) -> set[int]:
    """Grid axes the expression depends on (affine-over-grid variables)."""
    return {n.axis for n in walk(expr) if isinstance(n, ProgramId)}


def opaque_reasons(expr: Expr) -> list[str]:
    return [n.reason for n in walk(expr) if isinstance(n, Opaque)]


def is_zero(expr: Expr) -> bool:
    """Structurally provably all-zero (init-store detection)."""
    if isinstance(expr, Const):
        return bool(np.all(np.asarray(expr.value) == 0))
    if isinstance(expr, Reindex):
        return is_zero(expr.src)
    if isinstance(expr, Elem) and expr.op == "convert":
        return is_zero(expr.args[0])
    return False


# -- evaluation --------------------------------------------------------------


class EvalError(Exception):
    """Raised when an expression cannot be evaluated (opaque/mismatch)."""


def _trunc_div(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if np.issubdtype(np.asarray(a).dtype, np.integer):
        q = np.floor_divide(a, b)
        r = a - q * b
        # floor -> trunc correction for mixed signs
        return q + ((r != 0) & ((a < 0) != (b < 0)))
    return a / b


def _apply_elem(op: str, args: list[np.ndarray], dtype) -> np.ndarray:
    if op == "convert":
        return args[0].astype(dtype)
    if op == "select_n":
        pred, cases = args[0], args[1:]
        if len(cases) == 2:
            return np.where(pred.astype(bool), cases[1], cases[0])
        idx = pred.astype(np.int64)
        stacked = np.stack(np.broadcast_arrays(*cases))
        return np.take_along_axis(
            stacked, idx[None].astype(np.int64), axis=0)[0]
    a = args[0]
    b = args[1] if len(args) > 1 else None
    if op == "add":
        return a + b
    if op == "sub":
        return a - b
    if op == "mul":
        return a * b
    if op == "div":
        return _trunc_div(a, b)
    if op == "rem":
        return np.fmod(a, b)        # truncated remainder, like lax.rem
    if op == "max":
        return np.maximum(a, b)
    if op == "min":
        return np.minimum(a, b)
    if op == "eq":
        return a == b
    if op == "ne":
        return a != b
    if op == "lt":
        return a < b
    if op == "le":
        return a <= b
    if op == "gt":
        return a > b
    if op == "ge":
        return a >= b
    if op == "and":
        return np.bitwise_and(a, b)
    if op == "or":
        return np.bitwise_or(a, b)
    if op == "xor":
        return np.bitwise_xor(a, b)
    if op == "not":
        return np.bitwise_not(a)
    if op == "neg":
        return -a
    raise EvalError(f"unknown elementwise op {op!r}")


def evaluate(expr: Expr, env: dict) -> np.ndarray:
    """Exact numpy evaluation of ``expr`` at one grid step.

    ``env`` maps ``("ref", i)`` to that operand's block contents and
    ``("pid", axis)`` to the grid index.  Results are memoized per DAG
    node, so shared subexpressions evaluate once.
    """
    memo: dict[int, np.ndarray] = {}

    def ev(e: Expr) -> np.ndarray:
        got = memo.get(id(e))
        if got is not None:
            return got
        if isinstance(e, Const):
            out = np.broadcast_to(np.asarray(e.value, dtype=e.dtype), e.shape)
        elif isinstance(e, Iota):
            n = e.shape[e.dim]
            view = [1] * len(e.shape)
            view[e.dim] = n
            out = np.broadcast_to(
                np.arange(n, dtype=e.dtype).reshape(view), e.shape)
        elif isinstance(e, ProgramId):
            try:
                out = np.asarray(env[("pid", e.axis)], dtype=e.dtype)
            except KeyError:
                raise EvalError(f"program_id({e.axis}) unbound")
        elif isinstance(e, Data):
            try:
                block = np.asarray(env[("ref", e.ref)])
            except KeyError:
                raise EvalError(f"ref {e.ref} ({e.name}) has no block bound")
            if tuple(block.shape) != tuple(e.shape):
                raise EvalError(
                    f"ref {e.ref} block shape {block.shape} != expression "
                    f"shape {e.shape} (indexed access)")
            out = block
        elif isinstance(e, Elem):
            args = [ev(a) for a in e.args]
            out = np.broadcast_to(
                np.asarray(_apply_elem(e.op, args, e.dtype)), e.shape)
            if out.dtype != np.dtype(e.dtype):
                out = out.astype(e.dtype)
        elif isinstance(e, Reindex):
            src = ev(e.src)
            if e.kind == "reshape":
                out = np.ascontiguousarray(src).reshape(e.shape)
            elif e.kind == "transpose":
                out = src.transpose(e.info)
            elif e.kind == "broadcast":
                view = [1] * len(e.shape)
                for i, d in enumerate(e.info):
                    view[d] = src.shape[i]
                out = np.broadcast_to(src.reshape(view), e.shape)
            elif e.kind == "slice":
                starts, limits, strides = e.info
                out = src[tuple(slice(s, li, st)
                                for s, li, st in zip(starts, limits, strides))]
            else:
                raise EvalError(f"unknown reindex kind {e.kind!r}")
        elif isinstance(e, Opaque):
            raise EvalError(f"opaque computation: {e.reason}")
        else:
            raise EvalError(f"unknown node {type(e).__name__}")
        memo[id(e)] = out
        return out

    return ev(expr)


def squeeze_axis(expr: Expr, axis: int) -> Expr:
    """Drop a size-1 axis (the one-hot comparison's bin axis)."""
    if expr.shape[axis] != 1:
        raise ValueError(f"axis {axis} of {expr.shape} is not size 1")
    new_shape = tuple(s for i, s in enumerate(expr.shape) if i != axis)
    return Reindex(shape=new_shape, dtype=expr.dtype, kind="reshape",
                   src=expr)
