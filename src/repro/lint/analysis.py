"""Static degree derivation: symbolic streams -> exact paper counters.

Where a scatter site's index stream is *static* — data-independent, or
dependent only on operands that are provably constant (a solid-color
probe image) — the stream can be evaluated per grid step with plain
numpy and fed through the very same ``trace_from_indices`` /
``CounterSet.from_trace`` pipeline the dynamic ``TraceProvider`` uses.
The derived counters are therefore **bit-for-bit identical** to what
trace synthesis would produce, with zero kernel executions and zero
provider collections (``Session.stats`` untouched); tests and the
``lint_static_vs_trace`` benchmark pin that equality on the paper's §5
hist/hist2 kernels.

Fast path: when the stream does not depend on ``program_id`` either,
every grid step commits the same tile stream, so degrees are computed
once per tile and tiled across the launch — the static derivation then
costs one tile evaluation instead of a full-stream synthesis.

Streams that read non-constant operand data classify as
``data-dependent`` and fall back to the dynamic audit path (KERN005
carries the probe ``WorkloadSpec`` for the existing sweep machinery).
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.core import counters as counters_mod
from repro.lint import symbolic as sym
from repro.lint.tracing import KernelModel, ScatterSite, analyze_callable

STATIC = "static"
DATA_DEPENDENT = "data-dependent"
OPAQUE = "opaque"


@dataclasses.dataclass
class StaticDerivation:
    """Outcome of classifying + evaluating one scatter site's stream."""

    classification: str                  # static | data-dependent | opaque
    site: ScatterSite
    model: KernelModel
    reasons: list
    tile_stream: Optional[np.ndarray] = None   # one grid step's indices
    reps: int = 1                              # grid steps (tile-periodic)
    stream: Optional[np.ndarray] = None        # full committed stream
    mean_degree: Optional[float] = None
    floor_degree: Optional[float] = None

    @property
    def is_static(self) -> bool:
        return self.classification == STATIC


def _constant_operand(arr: np.ndarray) -> bool:
    arr = np.asarray(arr)
    return arr.size > 0 and bool(np.all(arr == arr.flat[0]))


def derive_stream(model: KernelModel, site: ScatterSite,
                  operands) -> StaticDerivation:
    """Classify a site's symbolic stream and, when static, evaluate it.

    ``operands`` are the launch inputs in *ref order* (the kernel's
    in_specs order); entries may be None when unknown.  Evaluation walks
    the grid in row-major order — the order a Pallas grid iterates and
    the order ``committed_index_stream`` concatenates tiles.
    """
    record = model.record
    reasons = sym.opaque_reasons(site.stream)
    if reasons:
        return StaticDerivation(OPAQUE, site, model,
                                [f"unmodeled op: {r}" for r in reasons])

    refs = sorted(sym.data_refs(site.stream))
    pids = sorted(sym.program_axes(site.stream))
    for r in refs:
        if r >= record.num_inputs:
            return StaticDerivation(
                DATA_DEPENDENT, site, model,
                [f"stream reads output/scratch ref {r}"])
        if r >= len(operands) or operands[r] is None:
            return StaticDerivation(
                DATA_DEPENDENT, site, model,
                [f"stream reads ref {r} with no operand bound"])
    if not all(_constant_operand(operands[r]) for r in refs):
        return StaticDerivation(
            DATA_DEPENDENT, site, model,
            [f"stream reads non-constant operand ref(s) {refs}"])

    steps = list(itertools.product(*(range(g) for g in record.grid)))
    reasons = ([f"affine over grid axes {pids}"] if pids
               else ["grid-invariant (tile-periodic)"])
    if refs:
        reasons.append(f"operand ref(s) {refs} provably constant")

    def _env(step):
        env = {("pid", a): s for a, s in enumerate(step)}
        for r in refs:
            env[("ref", r)] = record.block_for(r, operands[r], step)
        return env

    try:
        if not pids:
            tile = np.asarray(
                sym.evaluate(site.stream, _env(steps[0]))).reshape(-1)
            full = np.tile(tile, len(steps))
        else:
            parts = [np.asarray(
                sym.evaluate(site.stream, _env(s))).reshape(-1)
                for s in steps]
            tile, full = None, np.concatenate(parts)
    except sym.EvalError as e:
        return StaticDerivation(OPAQUE, site, model, [str(e)])

    return StaticDerivation(STATIC, site, model, reasons,
                            tile_stream=tile, reps=len(steps), stream=full)


def degree_stats(deriv: StaticDerivation) -> StaticDerivation:
    """Fill mean/floor degree on a static derivation (in place).

    ``floor_degree`` is the reorder-achievable lower bound: a lane remap
    can spread a wave's traffic across its *distinct* destinations but
    cannot create new ones, so per wave the best possible commit-group
    max multiplicity is ceil(group / min(distinct, group)).  hist-solid
    waves hold 4 distinct bins -> floor 8 vs observed 32; hist2 already
    sits on its floor (8) and lints clean.
    """
    if not deriv.is_static or deriv.stream is None:
        return deriv
    lanes, group = counters_mod.LANES, counters_mod.COMMIT_GROUP
    stream = deriv.stream
    n = stream.shape[0]
    w = max(1, n // lanes) if n % lanes == 0 else None
    if deriv.tile_stream is not None and \
            deriv.tile_stream.shape[0] % lanes == 0:
        tile2d = deriv.tile_stream.reshape(-1, lanes)
        deg = np.tile(
            counters_mod._degrees_full_waves(tile2d, group), deriv.reps)
        uniq = np.array([len(np.unique(row)) for row in tile2d], float)
        floors = np.ceil(group / np.minimum(uniq, group))
        deriv.floor_degree = float(np.mean(np.tile(floors, deriv.reps)))
    elif w:
        waves = stream.reshape(w, lanes)
        deg = counters_mod._degrees_full_waves(waves, group)
        uniq = np.array([len(np.unique(row)) for row in waves], float)
        deriv.floor_degree = float(np.mean(
            np.ceil(group / np.minimum(uniq, group))))
    else:
        deg = np.array([counters_mod.wave_degree(stream)])
        uniq = np.array([len(np.unique(stream))], float)
        deriv.floor_degree = float(np.ceil(group / min(uniq[0], group)))
    deriv.mean_degree = float(np.mean(deg))
    return deriv


# -- spec -> launcher --------------------------------------------------------


def _pad_rows(arr: np.ndarray, tile: int) -> np.ndarray:
    """Zero-pad the leading axis to a tile multiple (matches ops.py)."""
    n = arr.shape[0]
    pad = (-n) % tile
    if pad:
        arr = np.concatenate(
            [arr, np.zeros((pad,) + arr.shape[1:], arr.dtype)])
    return arr


@dataclasses.dataclass
class LintTarget:
    """Everything needed to lint one kernel launch statically."""

    label: str
    fn: object                   # traceable launcher (make_jaxpr target)
    args: tuple                  # launcher arguments (may be abstract)
    operands: tuple              # inputs in ref order (numpy, or None)
    spec: Optional[object] = None          # probe WorkloadSpec
    module: Optional[object] = None        # source module (noqa scope)
    job_class: Optional[int] = None        # derived scatter job class
    waves_per_tile: Optional[int] = None


def target_from_spec(spec) -> LintTarget:
    """Build a traceable launcher from a ``WorkloadSpec.kernel`` source."""
    import jax.numpy as jnp

    if spec.kernel is None:
        raise ValueError(f"spec {spec.label!r} has no KernelSource")
    p = spec.kernel.params
    if spec.kernel.op == "histogram":
        from repro.kernels.histogram import kernel as hist_kernel
        from repro.kernels.histogram import ops as hist_ops

        img = _pad_rows(np.asarray(p["img"], np.int32),
                        hist_kernel.DEFAULT_TILE)
        num_bins = int(p.get("num_bins", 256))
        reorder = p.get("variant", "hist") == "hist2"
        weighted = bool(p.get("weighted", False))
        operands = [img]
        args = [jnp.asarray(img)]
        if weighted:
            w = np.ones((img.shape[0],), np.float32)
            operands.append(w)
            args.append(jnp.asarray(w))

        def fn(im, *w):
            return hist_kernel.histogram_pallas(
                im, num_bins=num_bins, reorder=reorder,
                weights=w[0] if w else None)

        return LintTarget(
            label=spec.label, fn=fn, args=tuple(args),
            operands=tuple(operands), spec=spec, module=hist_kernel,
            job_class=hist_ops.histogram_job_class(
                force_fao=bool(p.get("force_fao", True)), weighted=weighted),
            waves_per_tile=spec.waves_per_tile
            or hist_ops.default_waves_per_tile(p["img"]))

    if spec.kernel.op == "scatter_add":
        from repro.kernels.scatter_add import kernel as scat_kernel
        from repro.kernels.scatter_add import ops as scat_ops

        ids = _pad_rows(np.asarray(p["ids"], np.int32),
                        scat_kernel.DEFAULT_TILE)
        values = _pad_rows(np.asarray(p["values"], np.float32),
                           scat_kernel.DEFAULT_TILE)
        if values.ndim == 1:
            values = values[:, None]
        num_segments = int(p["num_segments"])

        def fn(v, i):
            return scat_kernel.scatter_add_pallas(v, i, num_segments)

        # in_specs order is (ids, values): ref 0 = ids, ref 1 = values
        return LintTarget(
            label=spec.label, fn=fn,
            args=(jnp.asarray(values), jnp.asarray(ids)),
            operands=(ids, values), spec=spec, module=scat_kernel,
            job_class=int(p.get("job_class", spec.job_class)),
            waves_per_tile=spec.waves_per_tile
            or scat_ops.default_waves_per_tile())

    raise ValueError(
        f"no lint launcher for KernelSource op {spec.kernel.op!r}")


def analyze_target(target: LintTarget) -> list[KernelModel]:
    return analyze_callable(target.fn, *target.args, name=target.label)


# -- counters ----------------------------------------------------------------


def _trace_from_derivation(deriv: StaticDerivation, spec, *,
                           job_class: int, waves_per_tile: int):
    """Mirror of ``TraceProvider._synthesize``'s trace construction.

    The tile-periodic fast path computes degrees on one tile and tiles
    them — bit-identical to ``trace_from_indices`` on the full stream
    because that function's bulk path is itself per-wave over the same
    commit groups (`_degrees_full_waves` rows don't interact).
    """
    lanes = counters_mod.LANES
    pd = spec.pipeline_depth or 2
    tile = deriv.tile_stream
    if tile is not None and tile.shape[0] % lanes == 0 \
            and tile.shape[0] > 0:
        deg_tile = counters_mod._degrees_full_waves(
            tile.reshape(-1, lanes), counters_mod.COMMIT_GROUP)
        degree = np.tile(deg_tile, deriv.reps)
        num_waves = degree.shape[0]
        tiles = np.arange(num_waves) // max(waves_per_tile, 1)
        return counters_mod.WaveTrace(
            degree=degree,
            job_class=np.full(num_waves, job_class, np.int32),
            core=(tiles % spec.num_cores).astype(np.int32),
            lanes_active=np.full(num_waves, float(lanes)),
            waves_per_tile=waves_per_tile,
            pipeline_depth=pd)
    return counters_mod.trace_from_indices(
        deriv.stream, spec.num_bins, num_cores=spec.num_cores,
        job_class=job_class, waves_per_tile=waves_per_tile,
        pipeline_depth=pd)


def derive_counters(spec, *, target: Optional[LintTarget] = None,
                    model: Optional[KernelModel] = None):
    """(CounterSet, StaticDerivation) for a spec's kernel — statically.

    Returns ``(None, derivation)`` when the stream is data-dependent or
    opaque (use the dynamic ``TraceProvider`` path instead).  Never
    executes the kernel: tracing is ``jax.make_jaxpr``, evaluation is
    numpy.
    """
    if target is None:
        target = target_from_spec(spec)
    if model is None:
        models = analyze_target(target)
        with_sites = [m for m in models if m.sites]
        if not with_sites:
            raise ValueError(
                f"no scatter site found in {target.label!r} "
                f"({len(models)} pallas_call(s) traced)")
        model = with_sites[0]
    deriv = derive_stream(model, model.sites[0], target.operands)
    if not deriv.is_static:
        return None, deriv
    degree_stats(deriv)
    trace = _trace_from_derivation(
        deriv, spec, job_class=target.job_class,
        waves_per_tile=target.waves_per_tile)
    cset = counters_mod.CounterSet.from_trace(
        trace, label=spec.label, num_cores=spec.num_cores,
        bytes_read=spec.bytes_read, flops=spec.flops,
        overhead_cycles=spec.overhead_cycles, source="trace")
    return cset, deriv
