"""Trace Pallas kernels to jaxprs and extract symbolic scatter sites.

`jax.make_jaxpr` traces a launcher *without executing the kernel*; the
resulting jaxpr contains a ``pallas_call`` equation whose params carry
the inner kernel jaxpr and the grid mapping.  This module walks that
inner jaxpr with an abstract interpreter over the expression language in
:mod:`repro.lint.symbolic`, recognizing the idioms the repo's kernels
(and Pallas scatter/histogram kernels generally) are built from:

* the one-hot scatter idiom — ``eq(stream[:, None], iota(dim=1))``
  reduced with ``reduce_sum`` (popcount/histogram) or contracted with
  ``dot_general`` (row scatter-add) and accumulated into an output ref;
* ``pl.when(pl.program_id(a) == 0)`` init guards around zero stores;
* read-modify-write accumulation (``get`` → combine → ``swap`` on the
  same ref) and retry loops (``while`` bodies containing ``swap``).

The output is a :class:`KernelModel` per ``pallas_call``: scatter sites
with *symbolic index streams*, per-ref init-guard axes, and the grid
axes each ref's block index depends on.  Everything downstream —
classifying a stream as affine/static vs data-dependent, deriving exact
degree counters, rule evaluation — lives in :mod:`repro.lint.analysis`
and :mod:`repro.lint.rules`.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import numpy as np

from repro.lint import symbolic as sym


# -- one-hot idiom tags ------------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class OneHotTag:
    """``eq(stream, iota(dim=bin_axis))`` — a one-hot scatter mask."""

    stream: sym.Expr            # token-indexed bin id, bin axis squeezed out
    bin_axis: int
    num_bins: int
    stream_len: int


@dataclasses.dataclass(frozen=True, eq=False)
class AccumTag:
    """A one-hot mask reduced over tokens — a scatter-shaped update."""

    onehot: OneHotTag
    kind: str                   # "one_hot_popcount" | "one_hot_matmul"
    row_elems: int              # elements updated per bin row


@dataclasses.dataclass
class ScatterSite:
    """One accumulate-into-ref site found in a kernel jaxpr."""

    ref: int
    ref_name: str
    stream: sym.Expr
    stream_len: int
    num_bins: int
    kind: str
    row_elems: int
    rmw: bool                   # value reads the ref's previous contents
    guard_axes: frozenset       # init-guard program_id axes at this site


@dataclasses.dataclass
class WriteRecord:
    ref: int
    rmw: bool
    is_zero_init: bool
    guard_axes: frozenset


@dataclasses.dataclass
class KernelModel:
    name: str
    grid: tuple
    num_inputs: int
    num_outputs: int
    block_shapes: list
    block_dep_axes: list        # per ref: frozenset of grid axes, or None
    sites: list
    writes: list
    init_guards: dict           # ref -> set of guarded program_id axes
    has_while: bool = False
    while_has_swap: bool = False
    num_eqns: int = 0
    source_file: str = ""
    source_line: int = 0

    def dep_axes(self, ref: int):
        if 0 <= ref < len(self.block_dep_axes):
            return self.block_dep_axes[ref]
        return None


@dataclasses.dataclass
class PallasRecord:
    """Raw pieces of one ``pallas_call`` equation."""

    name: str
    grid: tuple
    jaxpr: Any                  # inner kernel jaxpr (jax.core.Jaxpr)
    consts: list
    block_mappings: list
    num_inputs: int
    num_outputs: int
    num_index_operands: int

    def block_shape(self, ref: int):
        bm = self.block_mappings[ref]
        return tuple(int(b) for b in bm.block_shape)

    def block_for(self, ref: int, operand, step: tuple) -> np.ndarray:
        """Fetch the block an operand ref sees at one grid step."""
        import jax

        bm = self.block_mappings[ref]
        closed = bm.index_map_jaxpr
        coords = jax.core.eval_jaxpr(closed.jaxpr, closed.consts, *step)
        shape = self.block_shape(ref)
        arr = np.asarray(operand)
        slices = tuple(
            slice(int(c) * int(b), (int(c) + 1) * int(b))
            for c, b in zip(coords, shape))
        return arr[slices]


# -- pallas_call discovery ---------------------------------------------------


def _subjaxprs(value):
    import jax

    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _subjaxprs(v)


def _index_map_dep_axes(bm) -> Optional[frozenset]:
    """Grid axes a block index map depends on; None if not a plain map."""
    import jax

    jx = bm.index_map_jaxpr.jaxpr
    if jx.eqns:
        return None
    pos = {id(v): i for i, v in enumerate(jx.invars)}
    deps = set()
    for ov in jx.outvars:
        if isinstance(ov, jax.core.Literal):
            continue
        i = pos.get(id(ov))
        if i is None:
            return None
        deps.add(i)
    return frozenset(deps)


def find_pallas_calls(fn: Callable, *args, **kwargs) -> list[PallasRecord]:
    """Trace ``fn`` (no kernel execution) and collect pallas_call records."""
    import jax

    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    records: list[PallasRecord] = []

    def visit(jaxpr):
        for eqn in jaxpr.eqns:
            if eqn.primitive.name == "pallas_call":
                gm = eqn.params["grid_mapping"]
                inner = eqn.params["jaxpr"]
                if isinstance(inner, jax.core.ClosedJaxpr):
                    inner_jaxpr, consts = inner.jaxpr, list(inner.consts)
                else:
                    inner_jaxpr, consts = inner, []
                name = str(eqn.params.get("name_and_src_info", "pallas_call"))
                records.append(PallasRecord(
                    name=name.split(" ")[0],
                    grid=tuple(int(g) for g in gm.grid),
                    jaxpr=inner_jaxpr,
                    consts=consts,
                    block_mappings=list(gm.block_mappings),
                    num_inputs=int(getattr(gm, "num_inputs",
                                           len(gm.block_mappings) - 1)),
                    num_outputs=int(getattr(gm, "num_outputs", 1)),
                    num_index_operands=int(
                        getattr(gm, "num_index_operands", 0)),
                ))
            for sub in _subjaxprs(list(eqn.params.values())):
                visit(sub)

    visit(closed.jaxpr)
    return records


# -- the abstract interpreter ------------------------------------------------


@dataclasses.dataclass(frozen=True, eq=False)
class SymVal:
    expr: sym.Expr
    tags: frozenset = frozenset()


@dataclasses.dataclass(frozen=True, eq=False)
class RefVal:
    ref: int
    name: str


_ELEMENTWISE = {
    "add", "sub", "mul", "max", "min", "div", "rem",
    "eq", "ne", "lt", "le", "gt", "ge", "and", "or", "xor", "not", "neg",
    "select_n", "integer_pow", "sign", "floor", "ceil", "round",
}


def _avals(var):
    return tuple(var.aval.shape), var.aval.dtype


def _strip_reindex(expr: sym.Expr) -> sym.Expr:
    while isinstance(expr, sym.Reindex):
        expr = expr.src
    return expr


def _strip_convert(expr: sym.Expr) -> sym.Expr:
    while isinstance(expr, sym.Elem) and expr.op == "convert":
        expr = expr.args[0]
    return expr


def _guard_axis(pred: sym.Expr) -> Optional[int]:
    """Axis ``a`` if ``pred`` is (a convert of) ``program_id(a) == 0``."""
    pred = _strip_convert(_strip_reindex(pred))
    if isinstance(pred, sym.Elem) and pred.op == "eq":
        a, b = (_strip_convert(_strip_reindex(x)) for x in pred.args[:2])
        for pid, zero in ((a, b), (b, a)):
            if isinstance(pid, sym.ProgramId) and sym.is_zero(zero):
                return pid.axis
    return None


def _resolve_iota_axis(expr: sym.Expr) -> Optional[int]:
    """Output axis an iota counts along, tracked through broadcasts."""
    if isinstance(expr, sym.Elem) and expr.op == "convert":
        return _resolve_iota_axis(expr.args[0])
    if isinstance(expr, sym.Iota):
        return expr.dim
    if isinstance(expr, sym.Reindex) and expr.kind == "broadcast":
        inner = _resolve_iota_axis(expr.src)
        if inner is None or inner >= len(expr.info):
            return None
        return int(expr.info[inner])
    return None


def _drop_axis(expr: sym.Expr, axis: int) -> Optional[sym.Expr]:
    """Expr without ``axis``, valid iff provably constant along it.

    jnp's broadcasting lowers ``flat[:, None] == iota(...)`` with the
    stream side broadcast up to the full (tokens, bins) shape; this
    peels those broadcasts back off the bin axis.  Returns None when
    constancy along the axis cannot be shown structurally (then the eq
    is not a one-hot against that iota).
    """
    if expr.shape[axis] == 1:
        return sym.squeeze_axis(expr, axis)
    if isinstance(expr, sym.Elem) and expr.op == "convert":
        inner = _drop_axis(expr.args[0], axis)
        if inner is None:
            return None
        return sym.Elem(shape=inner.shape, dtype=expr.dtype, op="convert",
                        args=(inner,))
    if isinstance(expr, sym.Reindex) and expr.kind == "broadcast":
        new_shape = tuple(s for i, s in enumerate(expr.shape) if i != axis)
        if axis not in expr.info:
            info = tuple(d - (d > axis) for d in expr.info)
            return sym.Reindex(shape=new_shape, dtype=expr.dtype,
                               kind="broadcast", src=expr.src, info=info)
        i = expr.info.index(axis)
        if expr.src.shape[i] == 1:
            inner = sym.squeeze_axis(expr.src, i)
            info = tuple(d - (d > axis)
                         for j, d in enumerate(expr.info) if j != i)
            return sym.Reindex(shape=new_shape, dtype=expr.dtype,
                               kind="broadcast", src=inner, info=info)
    return None


def _onehot_from_eq(lhs: SymVal, rhs: SymVal, out_shape) -> Optional[OneHotTag]:
    """Detect ``stream == iota(dim=d)`` where stream is flat along d."""
    for iota_side, stream_side in ((lhs, rhs), (rhs, lhs)):
        d = _resolve_iota_axis(iota_side.expr)
        if d is None or d >= len(out_shape):
            continue
        stream = stream_side.expr
        if len(stream.shape) == len(out_shape):
            flat = _drop_axis(stream, d)
        elif len(stream.shape) == len(out_shape) - 1:
            flat = stream
        else:
            flat = None
        if flat is None:
            continue
        stream_len = int(np.prod(flat.shape)) if flat.shape else 1
        return OneHotTag(stream=flat, bin_axis=d,
                         num_bins=int(out_shape[d]), stream_len=stream_len)
    return None


def _contains_ref_read(expr: sym.Expr, ref: int) -> bool:
    return ref in sym.data_refs(expr)


def _jaxpr_has_swap(jaxpr) -> bool:
    for eqn in jaxpr.eqns:
        if eqn.primitive.name in ("swap", "addupdate"):
            return True
        for sub in _subjaxprs(list(eqn.params.values())):
            if _jaxpr_has_swap(sub):
                return True
    return False


class _Interpreter:
    def __init__(self, record: PallasRecord, model: KernelModel):
        self.record = record
        self.model = model
        self.guard_stack: list[tuple] = []      # (pred_expr, branch_index)

    # env helpers ------------------------------------------------------

    def _read(self, env, var):
        import jax

        if isinstance(var, jax.core.Literal):
            val = np.asarray(var.val)
            return SymVal(sym.Const(shape=tuple(val.shape), dtype=val.dtype,
                                    value=val))
        got = env.get(var)
        if got is None:                 # DropVar / unbound: never crash
            shape, dtype = _avals(var)
            return SymVal(sym.Opaque(shape=shape, dtype=dtype,
                                     reason="unbound var"))
        return got

    def _guard_axes(self) -> frozenset:
        axes = set()
        for pred, _branch in self.guard_stack:
            ax = _guard_axis(pred)
            if ax is not None:
                axes.add(ax)
        return frozenset(axes)

    # write handling ---------------------------------------------------

    def _record_write(self, ref_val: RefVal, value: SymVal):
        rmw = _contains_ref_read(value.expr, ref_val.ref)
        zero_init = sym.is_zero(value.expr)
        guard_axes = self._guard_axes()
        self.model.writes.append(WriteRecord(
            ref=ref_val.ref, rmw=rmw, is_zero_init=zero_init,
            guard_axes=guard_axes))
        if zero_init and guard_axes:
            self.model.init_guards.setdefault(
                ref_val.ref, set()).update(guard_axes)
        for tag in value.tags:
            if isinstance(tag, AccumTag):
                self.model.sites.append(ScatterSite(
                    ref=ref_val.ref, ref_name=ref_val.name,
                    stream=tag.onehot.stream,
                    stream_len=tag.onehot.stream_len,
                    num_bins=tag.onehot.num_bins,
                    kind=tag.kind, row_elems=tag.row_elems,
                    rmw=rmw, guard_axes=guard_axes))
                break

    # main loop --------------------------------------------------------

    def run(self, jaxpr, consts, in_vals):
        env: dict = {}
        for var, c in zip(jaxpr.constvars, consts):
            arr = np.asarray(c) if not hasattr(c, "aval") else None
            if arr is not None:
                env[var] = SymVal(sym.Const(
                    shape=tuple(arr.shape), dtype=arr.dtype, value=arr))
            else:
                shape, dtype = _avals(var)
                env[var] = SymVal(sym.Opaque(
                    shape=shape, dtype=dtype, reason="traced const"))
        for var, v in zip(jaxpr.invars, in_vals):
            env[var] = v
        for eqn in jaxpr.eqns:
            self.model.num_eqns += 1
            self._eqn(env, eqn)
        outs = []
        for var in jaxpr.outvars:
            outs.append(self._read(env, var))
        return outs

    def _opaque_outs(self, env, eqn, reason, tags=frozenset()):
        for ov in eqn.outvars:
            shape, dtype = _avals(ov)
            env[ov] = SymVal(sym.Opaque(shape=shape, dtype=dtype,
                                        reason=reason), tags)

    def _eqn(self, env, eqn):
        name = eqn.primitive.name
        handler = getattr(self, "_prim_" + name.replace("-", "_"), None)
        if handler is not None:
            handler(env, eqn)
            return
        if name in _ELEMENTWISE:
            self._elementwise(env, eqn, name)
            return
        # unknown primitive: opaque, but tags still flow through so a
        # one-hot mask passing an unmodeled op can still reach its swap
        tags = frozenset()
        for iv in eqn.invars:
            v = self._read(env, iv)
            if isinstance(v, SymVal):
                tags |= v.tags
        self._opaque_outs(env, eqn, reason=name, tags=tags)

    # primitive handlers -----------------------------------------------

    def _elementwise(self, env, eqn, op):
        args = tuple(self._read(env, iv) for iv in eqn.invars)
        shape, dtype = _avals(eqn.outvars[0])
        tags = frozenset().union(*(a.tags for a in args))
        expr = sym.Elem(shape=shape, dtype=dtype, op=op,
                        args=tuple(a.expr for a in args))
        if op == "eq" and len(args) == 2:
            tag = _onehot_from_eq(args[0], args[1], shape)
            if tag is not None:
                tags = tags | {tag}
        env[eqn.outvars[0]] = SymVal(expr, tags)

    def _prim_program_id(self, env, eqn):
        shape, dtype = _avals(eqn.outvars[0])
        env[eqn.outvars[0]] = SymVal(sym.ProgramId(
            shape=shape, dtype=dtype, axis=int(eqn.params["axis"])))

    def _prim_iota(self, env, eqn):
        shape, dtype = _avals(eqn.outvars[0])
        env[eqn.outvars[0]] = SymVal(sym.Iota(
            shape=shape, dtype=dtype, dim=int(eqn.params["dimension"])))

    def _prim_convert_element_type(self, env, eqn):
        arg = self._read(env, eqn.invars[0])
        shape, dtype = _avals(eqn.outvars[0])
        env[eqn.outvars[0]] = SymVal(
            sym.Elem(shape=shape, dtype=dtype, op="convert",
                     args=(arg.expr,)), arg.tags)

    def _reindex(self, env, eqn, kind, info):
        arg = self._read(env, eqn.invars[0])
        shape, dtype = _avals(eqn.outvars[0])
        env[eqn.outvars[0]] = SymVal(
            sym.Reindex(shape=shape, dtype=dtype, kind=kind, src=arg.expr,
                        info=info), arg.tags)

    def _prim_broadcast_in_dim(self, env, eqn):
        dims = tuple(int(d) for d in eqn.params["broadcast_dimensions"])
        self._reindex(env, eqn, "broadcast", dims)

    def _prim_reshape(self, env, eqn):
        if eqn.params.get("dimensions") is not None:
            self._opaque_outs(env, eqn, reason="permuting reshape")
            return
        self._reindex(env, eqn, "reshape", ())

    def _prim_squeeze(self, env, eqn):
        self._reindex(env, eqn, "reshape", ())

    def _prim_expand_dims(self, env, eqn):
        self._reindex(env, eqn, "reshape", ())

    def _prim_transpose(self, env, eqn):
        perm = tuple(int(p) for p in eqn.params["permutation"])
        self._reindex(env, eqn, "transpose", perm)

    def _prim_slice(self, env, eqn):
        starts = tuple(int(s) for s in eqn.params["start_indices"])
        limits = tuple(int(s) for s in eqn.params["limit_indices"])
        strides = eqn.params.get("strides") or (1,) * len(starts)
        strides = tuple(int(s) for s in strides)
        self._reindex(env, eqn, "slice", (starts, limits, strides))

    def _prim_get(self, env, eqn):
        ref = env.get(eqn.invars[0])
        shape, dtype = _avals(eqn.outvars[0])
        if isinstance(ref, RefVal):
            env[eqn.outvars[0]] = SymVal(sym.Data(
                shape=shape, dtype=dtype, ref=ref.ref, name=ref.name))
        else:
            self._opaque_outs(env, eqn, reason="get on unknown ref")

    def _prim_swap(self, env, eqn):
        ref = env.get(eqn.invars[0])
        if isinstance(ref, RefVal) and len(eqn.invars) >= 2:
            value = self._read(env, eqn.invars[1])
            self._record_write(ref, value)
            shape, dtype = _avals(eqn.outvars[0])
            env[eqn.outvars[0]] = SymVal(sym.Data(
                shape=shape, dtype=dtype, ref=ref.ref, name=ref.name))
        else:
            self._opaque_outs(env, eqn, reason="swap on unknown ref")

    def _prim_addupdate(self, env, eqn):
        ref = env.get(eqn.invars[0])
        if isinstance(ref, RefVal) and len(eqn.invars) >= 2:
            value = self._read(env, eqn.invars[1])
            shape, dtype = value.expr.shape, value.expr.dtype
            # addupdate is inherently read-modify-write: model it as
            # ref <- ref + value so rmw detection sees the self-read
            prev = sym.Data(shape=shape, dtype=dtype, ref=ref.ref,
                            name=ref.name)
            summed = SymVal(sym.Elem(shape=shape, dtype=dtype, op="add",
                                     args=(prev, value.expr)), value.tags)
            self._record_write(ref, summed)
        for ov in eqn.outvars:
            shape, dtype = _avals(ov)
            env[ov] = SymVal(sym.Opaque(shape=shape, dtype=dtype,
                                        reason="addupdate token"))

    def _prim_cond(self, env, eqn):
        import jax

        pred = self._read(env, eqn.invars[0])
        branches = eqn.params["branches"]
        operands = [self._read(env, iv) for iv in eqn.invars[1:]]
        outs_per_branch = []
        for k, br in enumerate(branches):
            self.guard_stack.append((pred.expr, k))
            try:
                outs_per_branch.append(
                    self.run(br.jaxpr, list(br.consts), operands))
            finally:
                self.guard_stack.pop()
        for i, ov in enumerate(eqn.outvars):
            if isinstance(ov, jax.core.DropVar):
                continue
            shape, dtype = _avals(ov)
            tags = frozenset()
            for outs in outs_per_branch:
                if i < len(outs):
                    tags |= outs[i].tags
            env[ov] = SymVal(sym.Opaque(shape=shape, dtype=dtype,
                                        reason="cond join"), tags)

    def _prim_while(self, env, eqn):
        self.model.has_while = True
        body = eqn.params.get("body_jaxpr")
        if body is not None and _jaxpr_has_swap(body.jaxpr):
            self.model.while_has_swap = True
        self._opaque_outs(env, eqn, reason="while loop")

    def _prim_scan(self, env, eqn):
        inner = eqn.params.get("jaxpr")
        if inner is not None and _jaxpr_has_swap(inner.jaxpr):
            self.model.has_while = True
            self.model.while_has_swap = True
        self._opaque_outs(env, eqn, reason="scan loop")

    def _inline_call(self, env, eqn, closed):
        operands = [self._read(env, iv) for iv in eqn.invars]
        outs = self.run(closed.jaxpr, list(closed.consts), operands)
        import jax

        for ov, val in zip(eqn.outvars, outs):
            if not isinstance(ov, jax.core.DropVar):
                env[ov] = val

    def _prim_pjit(self, env, eqn):
        self._inline_call(env, eqn, eqn.params["jaxpr"])

    def _prim_closed_call(self, env, eqn):
        self._inline_call(env, eqn, eqn.params["call_jaxpr"])

    def _prim_custom_jvp_call(self, env, eqn):
        self._inline_call(env, eqn, eqn.params["call_jaxpr"])

    def _prim_custom_vjp_call_jaxpr(self, env, eqn):
        self._inline_call(env, eqn, eqn.params["fun_jaxpr"])

    def _prim_reduce_sum(self, env, eqn):
        arg = self._read(env, eqn.invars[0])
        axes = tuple(int(a) for a in eqn.params["axes"])
        tags = set()
        for tag in arg.tags:
            if isinstance(tag, OneHotTag) and tag.bin_axis not in axes:
                tags.add(AccumTag(onehot=tag, kind="one_hot_popcount",
                                  row_elems=1))
            elif isinstance(tag, AccumTag):
                tags.add(tag)
        self._opaque_outs(env, eqn, reason="reduce_sum",
                          tags=frozenset(tags))

    def _prim_dot_general(self, env, eqn):
        lhs = self._read(env, eqn.invars[0])
        rhs = self._read(env, eqn.invars[1])
        out_shape, _ = _avals(eqn.outvars[0])
        tags = set()
        for tag in lhs.tags | rhs.tags:
            if isinstance(tag, OneHotTag):
                row = int(out_shape[-1]) if out_shape else 1
                tags.add(AccumTag(onehot=tag, kind="one_hot_matmul",
                                  row_elems=row))
            elif isinstance(tag, AccumTag):
                tags.add(tag)
        self._opaque_outs(env, eqn, reason="dot_general",
                          tags=frozenset(tags))


# -- entry point -------------------------------------------------------------


def analyze_callable(fn: Callable, *args, name: str = "",
                     **kwargs) -> list[KernelModel]:
    """Trace ``fn`` and build a KernelModel per pallas_call (no exec)."""
    import inspect

    records = find_pallas_calls(fn, *args, **kwargs)
    models = []
    src_file, src_line = "", 0
    target = inspect.unwrap(fn)
    try:
        src_file = inspect.getsourcefile(target) or ""
        _, src_line = inspect.getsourcelines(target)
    except (OSError, TypeError):
        pass
    for record in records:
        model = KernelModel(
            name=name or record.name, grid=record.grid,
            num_inputs=record.num_inputs, num_outputs=record.num_outputs,
            block_shapes=[record.block_shape(i)
                          for i in range(len(record.block_mappings))],
            block_dep_axes=[_index_map_dep_axes(bm)
                            for bm in record.block_mappings],
            sites=[], writes=[], init_guards={},
            source_file=src_file, source_line=src_line)
        interp = _Interpreter(record, model)
        nio = record.num_index_operands
        refs = record.jaxpr.invars[nio:]
        in_vals: list = []
        for var in record.jaxpr.invars[:nio]:
            shape, dtype = _avals(var)
            in_vals.append(SymVal(sym.Opaque(shape=shape, dtype=dtype,
                                             reason="index operand")))
        for i, var in enumerate(refs):
            in_vals.append(RefVal(ref=i, name=str(var)))
        interp.run(record.jaxpr, record.consts, in_vals)
        model.record = record    # analysis needs block fetch + grid
        models.append(model)
    return models
