"""repro.lint — symbolic jaxpr-level atomic race & bank-conflict lint.

Layers (kernels -> **lint** -> audit report/SARIF -> advisor):

* ``symbolic`` — expression AST over grid/wave/lane variables with an
  exact numpy evaluator (jax-free),
* ``tracing``  — ``jax.make_jaxpr`` of each kernel launcher, abstract
  interpretation of the inner Pallas jaxpr into scatter sites, init
  guards, RMW/retry structure,
* ``analysis`` — static classification of index streams; where static,
  exact degree counters bit-for-bit equal to ``TraceProvider``'s with
  zero kernel executions,
* ``rules``    — the KERN001–KERN005 catalog, scored through the same
  columnar ``profile_sets`` pass and rendered by the same
  ``AuditReport``/SARIF machinery as ``repro.audit``,
* ``registry`` — the repo's Pallas kernels with deterministic probes.

Entry points: ``lint_kernel`` (one registered kernel), ``lint_registry``
(all of them, merged report — what ``Session.lint`` and ``repro lint``
call), ``lint_spec`` (any ``WorkloadSpec`` carrying a ``KernelSource``),
and ``derive_counters`` (the static counter path by itself).

Suppression: ``# repro: noqa KERN002`` comments *in the kernel source
file* suppress that rule for kernels defined there (surfacing as SARIF
``suppressions: [{"kind": "inSource"}]`` entries), same syntax the audit
honors in zoo configs.
"""

from __future__ import annotations

from typing import Optional, Sequence

from repro.audit.report import AuditReport, noqa_for_object
from repro.lint.analysis import (LintTarget, StaticDerivation,
                                 derive_counters, target_from_spec)
from repro.lint.rules import (KERN_CATALOG, KernelRule, KernelSite,
                              evaluate_target, kern_rule_by_id)

__all__ = [
    "AuditReport", "KERN_CATALOG", "KernelRule", "KernelSite",
    "LintTarget", "StaticDerivation", "derive_counters",
    "evaluate_target", "kern_rule_by_id", "kernel_names", "lint_kernel",
    "lint_registry", "lint_spec", "lint_target", "target_from_spec",
]


def kernel_names() -> list[str]:
    from repro.lint import registry
    return registry.names()


def _make_session(device: str = "v5e"):
    from repro.analysis.session import Session
    return Session(device)


def _device_name(session) -> str:
    dev = getattr(session, "device", None)
    return getattr(dev, "name", str(dev))


def lint_target(target: LintTarget, *, session=None,
                suppress: Sequence[str] = (),
                num_cores: Optional[int] = None) -> AuditReport:
    """Lint one prepared target; suppressions include in-source noqa."""
    from repro.lint.analysis import analyze_target

    if session is None:
        session = _make_session()
    suppress = set(suppress)
    if target.module is not None:
        suppress |= noqa_for_object(target.module)
    models = analyze_target(target)
    findings = evaluate_target(target, session, models=models,
                               suppress=suppress, num_cores=num_cores)
    return AuditReport(
        label=target.label, device=_device_name(session),
        findings=findings, steps=[target.label],
        sites_scanned=sum(len(m.sites) for m in models),
        instructions_scanned=sum(m.num_eqns for m in models))


def lint_kernel(name: str, *, session=None, suppress: Sequence[str] = (),
                num_cores: Optional[int] = None) -> AuditReport:
    """Lint one registered kernel by name (see ``kernel_names()``)."""
    from repro.lint import registry

    return lint_target(registry.build_target(name), session=session,
                       suppress=suppress, num_cores=num_cores)


def lint_registry(names: Optional[Sequence[str]] = None, *, session=None,
                  suppress: Sequence[str] = (),
                  num_cores: Optional[int] = None) -> AuditReport:
    """Lint registered kernels (all by default) into one merged report."""
    from repro.audit.report import merge
    from repro.lint import registry

    if session is None:
        session = _make_session()
    reports = [lint_kernel(n, session=session, suppress=suppress,
                           num_cores=num_cores)
               for n in (names or registry.names())]
    merged = merge(reports, label="kernels")
    order = {"error": 0, "warning": 1, "note": 2}
    merged.findings.sort(key=lambda f: (order[f.severity],
                                        -(f.utilization or 0.0), f.label))
    return merged


def lint_spec(spec, *, session=None, suppress: Sequence[str] = (),
              num_cores: Optional[int] = None) -> AuditReport:
    """Lint any ``WorkloadSpec`` that carries a ``KernelSource``."""
    return lint_target(target_from_spec(spec), session=session,
                       suppress=suppress, num_cores=num_cores)
