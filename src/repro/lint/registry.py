"""Registered lint targets: the repo's Pallas kernels with probe inputs.

Each entry builds a :class:`~repro.lint.analysis.LintTarget`: a
traceable launcher, its operands in ref order, and (where the workload
layer models it) a probe :class:`WorkloadSpec` whose geometry mirrors
the paper's §5 study (``examples/advisor_histogram.py``: solid 2^15-px
image, 8 waves per tile, 2500-cycle overhead) — so a KERN001 finding's
``--advise`` run lands in the paper's up-to-30% rotation band.

Probes are deterministic (fixed rng seed): lint output is reproducible
run to run, like the audit's synthesized streams.
"""

from __future__ import annotations

from typing import Callable

import numpy as np

PROBE_PIXELS = 1 << 15
PROBE_WAVES_PER_TILE = 8
PROBE_OVERHEAD_CYCLES = 2500.0
_SEED = 0


def _hist_target(variant: str):
    from repro.data.images import make_image
    from repro.analysis.workload import WorkloadSpec
    from repro.lint.analysis import target_from_spec

    spec = WorkloadSpec.from_histogram(
        make_image("solid", PROBE_PIXELS), label=f"{variant}-solid",
        variant=variant, waves_per_tile=PROBE_WAVES_PER_TILE,
        overhead_cycles=PROBE_OVERHEAD_CYCLES)
    return target_from_spec(spec)


def _hist_weighted_target():
    from repro.data.images import make_image
    from repro.analysis.workload import WorkloadSpec
    from repro.lint.analysis import target_from_spec

    spec = WorkloadSpec.from_histogram(
        make_image("solid", PROBE_PIXELS), label="hist-weighted-solid",
        variant="hist", weighted=True,
        waves_per_tile=PROBE_WAVES_PER_TILE,
        overhead_cycles=PROBE_OVERHEAD_CYCLES)
    return target_from_spec(spec)


def _scatter_add_target():
    from repro.analysis.workload import WorkloadSpec
    from repro.lint.analysis import target_from_spec

    rng = np.random.default_rng(_SEED)
    n, d, segs = 8192, 32, 4096
    ids = rng.integers(0, segs, size=n).astype(np.int32)
    values = np.ones((n, d), np.float32)
    spec = WorkloadSpec.from_scatter_add(
        ids, values, segs, label="scatter_add-uniform")
    return target_from_spec(spec)


def _moe_dispatch_target():
    import jax.numpy as jnp

    from repro.analysis.workload import WorkloadSpec
    from repro.core import timing
    from repro.kernels.scatter_add import kernel as scat_kernel
    from repro.kernels.scatter_add import ops as scat_ops
    from repro.lint.analysis import LintTarget

    rng = np.random.default_rng(_SEED)
    n, experts = 8192, 64
    ids = rng.integers(0, experts, size=n).astype(np.int32)
    spec = WorkloadSpec.from_scatter_add(
        ids, np.zeros((n, 1), np.float32), experts,
        label="moe_dispatch-uniform", job_class=timing.POPC)

    def fn(i):
        return scat_kernel.bincount_pallas(i, experts)

    return LintTarget(
        label="moe_dispatch-uniform", fn=fn, args=(jnp.asarray(ids),),
        operands=(ids,), spec=spec, module=scat_kernel,
        job_class=timing.POPC,
        waves_per_tile=scat_ops.default_waves_per_tile())


def _flash_attention_target():
    import jax
    import jax.numpy as jnp

    from repro.kernels.flash_attention import kernel as flash_kernel
    from repro.lint.analysis import LintTarget

    qkv = jax.ShapeDtypeStruct((4, 1024, 64), jnp.float32)

    def fn(q, k, v):
        return flash_kernel.flash_attention_pallas(q, k, v)

    return LintTarget(
        label="flash_attention", fn=fn, args=(qkv, qkv, qkv),
        operands=(None, None, None), spec=None, module=flash_kernel,
        job_class=None, waves_per_tile=None)


KERNELS: dict[str, Callable] = {
    "hist": lambda: _hist_target("hist"),
    "hist2": lambda: _hist_target("hist2"),
    "hist_weighted": _hist_weighted_target,
    "scatter_add": _scatter_add_target,
    "moe_dispatch": _moe_dispatch_target,
    "flash_attention": _flash_attention_target,
}


def names() -> list[str]:
    return list(KERNELS)


def build_target(name: str):
    try:
        build = KERNELS[name]
    except KeyError:
        raise KeyError(
            f"unknown lint kernel {name!r} (registered: "
            f"{', '.join(KERNELS)})") from None
    return build()
