"""KERN rule family: symbolic-analysis findings over Pallas kernels.

Counterpart of :mod:`repro.audit.rules`, one layer deeper: where the
audit rules pattern-match HLO shapes and synthesize *worst-plausible*
streams, these rules run on :class:`~repro.lint.tracing.KernelModel`s
whose index streams were derived **exactly** — so KERN001's degree is a
proof, not a guess, and conflict-freedom is certified by absence.

    KERN001  affine-hot-bin          static degree above the reorder floor
    KERN002  bank-stride-conflict    commit-group-aligned row updates
    KERN003  unsynchronized-rmw-race accumulate into a shared block with
                                     no init guard on the sharing axis
    KERN004  cas-retry-loop          CAS-class combiner or swap-in-loop
    KERN005  data-dependent-index    needs dynamic audit (carries a
                                     WorkloadSpec for the sweep path)

Findings are the same :class:`~repro.audit.rules.Finding` dataclass the
audit emits, scored through the same one-pass ``session.profile_sets``
columnar evaluation and rendered by the same report/SARIF machinery —
``repro lint`` and ``repro audit`` merge into one log.  KERN003 is a
correctness finding (fixed ``error``); KERN005 is informational (fixed
``note``) so ``--advise`` and ``--fail-on warning`` skip it.

Import-light by design (numpy only): the SARIF renderer pulls this
catalog in for rule descriptors without dragging jax along.
"""

from __future__ import annotations

import dataclasses
import math
import os
from types import SimpleNamespace
from typing import Optional, Sequence

import numpy as np

from repro.audit import rules as audit_rules
from repro.audit.rules import Finding
from repro.core import bottleneck, timing
from repro.core import counters as counters_mod
from repro.lint import analysis as lan


@dataclasses.dataclass(frozen=True)
class KernelSite:
    """Lint-side site record, row/report-compatible with ``AtomicSite``."""

    op_name: str
    kind: str                    # one_hot_popcount | one_hot_matmul | rmw
    num_bins: int
    num_updates: int             # total updates across the whole launch
    row_elems: int
    combiner: str                # add | cas | popc
    trip_count: int              # grid steps
    hlo_line: int = 0            # kernel source line (def line)
    classification: str = ""     # static | data-dependent | opaque

    def describe(self) -> str:
        return (f"{self.op_name} ({self.kind}, {self.classification}): "
                f"{self.num_updates} updates over {self.trip_count} grid "
                f"step(s) into {self.num_bins} bin(s), "
                f"row width {self.row_elems}")


@dataclasses.dataclass(frozen=True)
class KernelRule:
    """Catalog metadata; matching logic lives in ``evaluate_target``."""

    id: str
    slug: str
    summary: str
    description: str
    base_severity: str = "warning"
    max_severity: str = "error"


KERN001 = KernelRule(
    id="KERN001", slug="affine-hot-bin",
    summary="statically proven commit-group serialization above the "
            "reorder-achievable floor",
    description=(
        "The kernel's scatter index stream is affine in grid/lane "
        "variables (or reads provably constant operands), so its exact "
        "per-wave serialization degree distribution was derived without "
        "running the kernel. The mean degree exceeds the floor a lane "
        "remap could achieve given each wave's distinct destinations — "
        "the paper's Listing-1 hazard: hist commits a solid tile at "
        "degree 32 where channel rotation (hist2) reaches the floor of "
        "8. The ChannelRotation transform family closes the gap."))

KERN002 = KernelRule(
    id="KERN002", slug="bank-stride-conflict",
    summary="row-granular scatter updates stride commit-group-aligned "
            "banks",
    description=(
        "A one-hot matmul scatter updates rows whose element width is a "
        "multiple of the 32-lane commit group, so successive rows land "
        "on the same bank offsets and colliding rows serialize at "
        "gcd(row_elems, 32) degree. Pad the row or apply the "
        "LaneInterleave remap."),
    base_severity="note", max_severity="warning")

KERN003 = KernelRule(
    id="KERN003", slug="unsynchronized-rmw-race",
    summary="read-modify-write accumulation into a block shared across "
            "a grid axis with no init guard on that axis",
    description=(
        "The kernel accumulates into an output ref whose block index "
        "map does not depend on some grid axis (the block is shared "
        "across that axis), but no `pl.when(pl.program_id(axis) == 0)` "
        "zero-initialization guards it. On any backend that may "
        "parallelize or reorder that axis this is a non-atomic RMW "
        "race; even sequentially the first step accumulates into "
        "uninitialized memory."),
    base_severity="error", max_severity="error")

KERN004 = KernelRule(
    id="KERN004", slug="cas-retry-loop",
    summary="scatter combiner is CAS-class: colliding lanes retry "
            "instead of queueing one atomic each",
    description=(
        "The accumulation is not a plain integer fetch-and-op (a "
        "weighted/float combiner, or a swap inside a retry loop), so "
        "the modeled scatter unit services it at CAS cost — each "
        "conflicting lane re-reads, recombines and re-verifies. The "
        "CasToFao transform (integer re-quantization or an "
        "order-insensitive combiner) removes the retry loop."))

KERN005 = KernelRule(
    id="KERN005", slug="data-dependent-index",
    summary="scatter index stream reads runtime data — needs dynamic "
            "audit",
    description=(
        "The site's index expression depends on non-constant operand "
        "values, so its degree distribution cannot be proved "
        "statically. The finding carries the probe WorkloadSpec; run "
        "it through the dynamic sweep path (`repro sweep` / "
        "`Session.profile`) to measure the contention this lint cannot "
        "derive."),
    base_severity="note", max_severity="note")

KERN_CATALOG: tuple[KernelRule, ...] = (
    KERN001, KERN002, KERN003, KERN004, KERN005)


def kern_rule_by_id(rule_id: str) -> Optional[KernelRule]:
    for r in KERN_CATALOG:
        if r.id == rule_id:
            return r
    return None


_COMBINER = {timing.FAO: "add", timing.CAS: "cas", timing.POPC: "popc"}


def _source_uri(model) -> str:
    path = model.source_file
    if not path:
        return ""
    try:
        rel = os.path.relpath(path)
    except ValueError:
        return path
    return path if rel.startswith("..") else rel


def _default_geom(target):
    return SimpleNamespace(
        label=target.label, num_bins=256, num_cores=8, pipeline_depth=2,
        waves_per_tile=None, bytes_read=0.0, flops=0.0,
        overhead_cycles=500.0)


def _site_record(target, model, site, deriv) -> KernelSite:
    trip = int(np.prod(model.grid)) if model.grid else 1
    return KernelSite(
        op_name=model.name, kind=site.kind,
        num_bins=site.num_bins,
        num_updates=site.stream_len * trip,
        row_elems=site.row_elems,
        combiner=_COMBINER.get(target.job_class or timing.FAO, "add"),
        trip_count=trip, hlo_line=model.source_line,
        classification=deriv.classification)


def evaluate_target(target, session, *, models=None,
                    suppress: Sequence[str] = (),
                    num_cores: Optional[int] = None) -> list[Finding]:
    """Run the KERN catalog over one lint target's kernel models.

    All static findings are scored in a single columnar
    ``session.profile_sets`` pass against per-site conflict-free
    baselines of identical length, geometry and (for KERN001) job
    class — the contention ratio then reuses the audit's severity
    thresholds.  No kernel executions, no provider collections.
    """
    if models is None:
        models = lan.analyze_target(target)
    suppress = set(suppress)
    spec = target.spec or _default_geom(target)
    cores = num_cores or getattr(spec, "num_cores", 8)
    job = target.job_class if target.job_class is not None else timing.FAO
    wpt = target.waves_per_tile or 1
    pd = spec.pipeline_depth or 2

    # (rule, model, site-record, deriv, extra) scored candidates collect
    # csets in pairs (site, baseline); unscored findings are emitted raw
    scored: list[dict] = []
    findings: list[Finding] = []
    csets: list = []

    def _emit(rule, model, ksite, message, *, severity=None, spec_=None,
              hint="", fixit=""):
        findings.append(Finding(
            rule_id=rule.id, rule_slug=rule.slug,
            severity=severity or rule.base_severity, message=message,
            label=f"{target.label}/{ksite.op_name}", site=ksite,
            hint=hint, fixit=fixit, suppressed=rule.id in suppress,
            hlo_uri=_source_uri(model), hlo_line=ksite.hlo_line,
            spec=spec_))

    # baselines are plain index streams, so their trace synthesis is
    # deferred and batched (one traces_from_index_batch call for the
    # whole target) — the site traces themselves come from the symbolic
    # derivation, which is already a few vectorized ops per site
    baseline_streams: list = []
    baseline_jobs: list = []

    def _queue_scored(rule, model, ksite, deriv, *, base_job, message_fn):
        trace = lan._trace_from_derivation(
            deriv, spec, job_class=job, waves_per_tile=wpt)
        n = deriv.stream.shape[0]
        common = dict(num_cores=cores, bytes_read=spec.bytes_read,
                      flops=spec.flops,
                      overhead_cycles=spec.overhead_cycles, source="lint")
        csets.append(counters_mod.CounterSet.from_trace(
            trace, label=f"{target.label}/{ksite.op_name}", **common))
        csets.append(None)  # baseline slot, filled by the batch below
        baseline_streams.append(np.arange(n, dtype=np.int64))
        baseline_jobs.append(base_job)
        scored.append(dict(rule=rule, model=model, ksite=ksite,
                           deriv=deriv, message_fn=message_fn,
                           common=common))

    for model in models:
        grid_axes = set(range(len(model.grid)))

        # KERN003: unguarded RMW accumulation into a shared block
        flagged_refs = set()
        for w in model.writes:
            if not w.rmw or w.is_zero_init or w.ref in flagged_refs:
                continue
            deps = model.dep_axes(w.ref)
            if deps is None:
                continue
            shared = grid_axes - set(deps)
            missing = shared - model.init_guards.get(w.ref, set())
            if not missing:
                continue
            flagged_refs.add(w.ref)
            trip = int(np.prod(model.grid)) if model.grid else 1
            ksite = KernelSite(
                op_name=model.name, kind="rmw",
                num_bins=0, num_updates=0, row_elems=0,
                combiner="add", trip_count=trip,
                hlo_line=model.source_line, classification="structural")
            _emit(KERN003, model, ksite,
                  f"{KERN003.summary}: ref {w.ref} of {model.name} is "
                  f"shared across grid axis(es) {sorted(missing)} "
                  f"(block index map ignores them) but carries no "
                  f"`pl.when(program_id == 0)` zero-init on those axes",
                  fixit="guard the first accumulation with "
                        "pl.when(pl.program_id(axis) == 0) "
                        "zero-initialization")

        # KERN004 (structural): a swap inside a while/retry loop is
        # CAS-shaped even when no scatter site could be derived from it
        if model.while_has_swap and not model.sites:
            trip = int(np.prod(model.grid)) if model.grid else 1
            ksite = KernelSite(
                op_name=model.name, kind="rmw", num_bins=0,
                num_updates=0, row_elems=0, combiner="cas",
                trip_count=trip, hlo_line=model.source_line,
                classification="structural")
            _emit(KERN004, model, ksite,
                  f"{KERN004.summary}: swap inside a while/retry loop in "
                  f"{model.name}; no scatter site could be derived, so "
                  f"the retry contention is unmodeled",
                  spec_=target.spec,
                  fixit="advisor transform CasToFao")

        for site in model.sites:
            deriv = lan.degree_stats(
                lan.derive_stream(model, site, target.operands))
            ksite = _site_record(target, model, site, deriv)

            # KERN002: commit-group-aligned row scatter (advisory)
            if (site.kind == "one_hot_matmul"
                    and site.row_elems >= counters_mod.COMMIT_GROUP
                    and site.row_elems % counters_mod.COMMIT_GROUP == 0):
                stride_deg = math.gcd(site.row_elems,
                                      counters_mod.COMMIT_GROUP)
                _emit(KERN002, model, ksite,
                      f"{KERN002.summary}: {ksite.describe()}; modeled "
                      f"bank-conflict stride degree "
                      f"{stride_deg} (= gcd(row_elems, "
                      f"{counters_mod.COMMIT_GROUP}))",
                      severity="warning" if stride_deg >= 2 else "note",
                      spec_=target.spec,
                      fixit="pad the update row or apply the "
                            "LaneInterleave remap")

            # KERN004: CAS-class combiner / swap inside a retry loop
            if model.while_has_swap or job == timing.CAS:
                why = ("swap inside a while/retry loop"
                       if model.while_has_swap
                       else "non-integer (weighted) combiner lowers to "
                            "CAS-class service")
                if deriv.is_static:
                    _queue_scored(
                        KERN004, model, ksite, deriv, base_job=timing.FAO,
                        message_fn=lambda u, c, v, k=ksite, w=why: (
                            f"{KERN004.summary}: {k.describe()}; {w}; "
                            f"predicted scatter U={u:.0%}, {c:.2f}x "
                            f"conflict-free FAO baseline "
                            f"({v.bottleneck}"
                            f"{' saturated' if v.saturated else ''})"))
                else:
                    _emit(KERN004, model, ksite,
                          f"{KERN004.summary}: {ksite.describe()}; {why}",
                          spec_=target.spec,
                          fixit="advisor transform CasToFao")

            # KERN001 / KERN005: the static-vs-dynamic fork
            if deriv.is_static:
                if deriv.mean_degree > deriv.floor_degree + 1e-9:
                    _queue_scored(
                        KERN001, model, ksite, deriv, base_job=job,
                        message_fn=lambda u, c, v, k=ksite, d=deriv: (
                            f"{KERN001.summary}: {k.describe()}; derived "
                            f"mean degree {d.mean_degree:.1f} vs reorder "
                            f"floor {d.floor_degree:.1f}; predicted "
                            f"scatter U={u:.0%}, {c:.2f}x conflict-free "
                            f"baseline ({v.bottleneck}"
                            f"{' saturated' if v.saturated else ''})"))
                # at the floor: conflict behaviour is proven optimal for
                # this stream — certified clean, no finding
            else:
                _emit(KERN005, model, ksite,
                      f"{KERN005.summary}: {ksite.describe()}; "
                      f"{'; '.join(deriv.reasons)}",
                      spec_=target.spec,
                      fixit="profile the attached WorkloadSpec via "
                            "`repro sweep` / `Session.profile`")

    if scored:
        base_traces = counters_mod.traces_from_index_batch(
            baseline_streams, num_cores=cores, job_class=baseline_jobs,
            waves_per_tile=wpt, pipeline_depth=pd)
        for i, tr in enumerate(base_traces):
            csets[2 * i + 1] = counters_mod.CounterSet.from_trace(
                tr, label=f"{target.label}/__baseline__",
                **scored[i]["common"])
        profiles = session.profile_sets(csets)
        for i, cand in enumerate(scored):
            prof, base = profiles[2 * i], profiles[2 * i + 1]
            u = float(prof.scatter_utilization)
            u_base = max(float(base.scatter_utilization), 1e-9)
            contention = u / u_base
            verdict = bottleneck.classify(prof)
            rule = cand["rule"]
            findings.append(Finding(
                rule_id=rule.id, rule_slug=rule.slug,
                severity=audit_rules._finding_severity(rule, contention),
                message=cand["message_fn"](u, contention, verdict),
                label=f"{target.label}/{cand['ksite'].op_name}",
                site=cand["ksite"], utilization=u,
                bottleneck=verdict.bottleneck,
                hint=verdict.hint.compact() if verdict.hint else "",
                fixit=audit_rules._fixit(verdict),
                suppressed=rule.id in suppress,
                hlo_uri=_source_uri(cand["model"]),
                hlo_line=cand["ksite"].hlo_line, spec=target.spec,
                baseline_utilization=u_base, contention=contention))

    order = {"error": 0, "warning": 1, "note": 2}
    findings.sort(key=lambda f: (order[f.severity],
                                 -(f.utilization or 0.0), f.label))
    return findings
