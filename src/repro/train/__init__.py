"""train subpackage."""
