"""Training step: microbatched gradient accumulation, remat, bf16 grads
with f32 accumulation, optional gradient compression for the cross-pod
all-reduce, AdamW update.

``make_train_step(model, tcfg, ocfg)`` returns a pure ``step(state, batch)``
suitable for ``jax.jit`` with in/out shardings from ``parallel.sharding``.
The microbatch loop is a ``lax.scan`` over a reshaped global batch, so
per-microbatch activation peaks (the 4k-seq attention scores and the 256k
f32 logits) stay bounded regardless of global batch size.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.optim import adamw
from repro.parallel import ctx as pctx


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    accum_steps: int = 1
    loss_chunk: int = 0          # sequence-chunked xent (0 = off)
    grad_dtype: str = "bfloat16"  # wire dtype of the DP all-reduce
    compress_grads: bool = False  # bf16 wire + f32 accumulate (error-safe:
                                  # accumulation happens in f32 before cast)
    constrain_grad_sharding: bool = False  # pin per-micro grads to the
                                  # param layout (reduce-scatter instead of
                                  # full-tensor gathers in the accum loop)


def make_loss_fn(model, tcfg: TrainConfig):
    def loss_fn(params, micro_batch):
        loss, metrics = model.loss(params, micro_batch,
                                   loss_chunk=tcfg.loss_chunk)
        return loss, metrics
    return loss_fn


def make_train_step(model, tcfg: TrainConfig, ocfg: adamw.AdamWConfig,
                    grad_pspecs=None):
    loss_fn = make_loss_fn(model, tcfg)
    grad_fn = jax.grad(loss_fn, has_aux=True)
    wire_dt = jnp.dtype(tcfg.grad_dtype)

    def constrain(g):
        if not tcfg.constrain_grad_sharding:
            return g
        ctx = pctx.current()
        specs = grad_pspecs
        if specs is None and ctx is not None:
            from repro.parallel import sharding as shd
            specs = shd.param_pspecs(g, model.cfg)
        if ctx is None or specs is None:
            return g
        from jax.sharding import NamedSharding
        return jax.tree.map(
            lambda x, s: jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx.mesh, s)), g, specs)

    def step(state: dict, batch: dict):
        params, opt_state = state["params"], state["opt"]

        if tcfg.accum_steps == 1:
            grads, metrics = grad_fn(params, batch)
        else:
            def reshape(x):
                b = x.shape[0]
                micro = b // tcfg.accum_steps
                return x.reshape(tcfg.accum_steps, micro, *x.shape[1:])

            micro_batches = jax.tree.map(reshape, batch)

            def accum(carry, mb):
                g_acc, _ = carry
                g, metrics = grad_fn(params, mb)
                g = constrain(g)
                g = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g, metrics), None

            g0 = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params))
            (grads, metrics), _ = jax.lax.scan(
                accum, (g0, _zero_metrics()), micro_batches)
            grads = jax.tree.map(lambda g: g / tcfg.accum_steps, grads)

        if tcfg.compress_grads:
            # Cast the DP-reduced gradient to the wire dtype; accumulation
            # already happened in f32, so this only quantizes the final
            # all-reduce payload (cross-pod bandwidth lever).
            grads = jax.tree.map(lambda g: g.astype(wire_dt), grads)

        new_params, new_opt, opt_metrics = adamw.update(
            grads, opt_state, ocfg,
            params_dtype=jax.tree.leaves(params)[0].dtype)
        metrics = dict(metrics, **opt_metrics)
        new_state = {"params": new_params, "opt": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


def _zero_metrics():
    return {"xent": jnp.float32(0.0), "aux": jnp.float32(0.0)}


def init_state(model, rng, ocfg: Optional[adamw.AdamWConfig] = None) -> dict:
    params = model.init(rng)
    return {"params": params, "opt": adamw.init(params),
            "step": jnp.zeros((), jnp.int32)}
