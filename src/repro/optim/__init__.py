"""optim subpackage."""
