"""AdamW with f32 master weights, built for sharded pytrees.

State = {m, v, master, count}: m/v/master mirror the parameter tree (and
its shardings — ZeRO-style, the big leaves are already 2-D sharded over
(data, model)); params stay bf16 for compute and are re-derived from the
f32 master each step.  Global-norm clipping and a cosine schedule with
linear warmup are included; all math in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params) -> dict:
    f32 = lambda t: jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), t)
    return {
        "m": f32(params),
        "v": f32(params),
        "master": jax.tree.map(lambda x: x.astype(jnp.float32), params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree))
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state: dict, cfg: AdamWConfig,
           params_dtype=jnp.bfloat16) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    count = state["count"] + 1
    lr = schedule(cfg, count)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))

    def leaf(g, m, v, master):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** count.astype(jnp.float32))
        vhat = v / (1 - cfg.b2 ** count.astype(jnp.float32))
        upd = mhat / (jnp.sqrt(vhat) + cfg.eps)
        decay = cfg.weight_decay * master if master.ndim >= 2 else 0.0
        master = master - lr * (upd + decay)
        return m, v, master

    flat = jax.tree.map(leaf, grads, state["m"], state["v"], state["master"],
                        is_leaf=lambda x: isinstance(x, jnp.ndarray) or
                        hasattr(x, "shape"))
    m = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    v = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    master = jax.tree.map(lambda t: t[2], flat,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_params = jax.tree.map(lambda x: x.astype(params_dtype), master)
    new_state = {"m": m, "v": v, "master": master, "count": count}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
