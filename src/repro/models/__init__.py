"""models subpackage."""
