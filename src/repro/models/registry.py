"""Model construction + batch stubs: one entry point for every arch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import CausalLM
from repro.models.whisper import WhisperModel


def build_model(cfg: ModelConfig):
    if cfg.family == "audio":
        return WhisperModel(cfg)
    return CausalLM(cfg)


def make_batch(cfg: ModelConfig, batch: int, seq: int, rng=None) -> dict:
    """Synthetic batch with the modality stubs the arch needs."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(rng, 3)
    tokens = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size,
                                jnp.int32)
    out = {"tokens": tokens, "labels": tokens}
    if cfg.family == "audio":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.encoder_frames, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02
    if cfg.family == "vlm":
        out["image_embeds"] = jax.random.normal(
            k3, (batch, cfg.image_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.02
    return out
