"""Grouped-query attention with every variant the assigned archs need.

Flags: QKV bias (qwen), attention-logit softcap (gemma2), sliding window
(gemma2 local layers / zamba2 long-context), cross-attention
(whisper/llama-vision), bidirectional (whisper encoder), KV-cache decode,
and a blockwise (flash-style, online-softmax) path for long prefill.

Shape conventions: activations (B, T, d); Q heads H, KV heads KV with
H % KV == 0; per-head dim ``head_dim``.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    logit_softcap: Optional[float] = None
    window: Optional[int] = None        # sliding-window size (None = full)
    causal: bool = True
    rope_theta: float = 10000.0
    use_rope: bool = True
    dtype: str = "bfloat16"
    # Megatron-style GQA TP: replicate KV heads across the query groups so
    # every attention tensor carries the full H dim and shards over the
    # model axis (H % tp == 0 even when KV heads < tp).  §Perf lever: keeps
    # the (Tq, Tk) scores TP-sharded instead of replicated.
    tp_expand_heads: bool = False
    # §Perf lever P9: round-trip the scores through bf16 right after the
    # f32-accumulated QK^T.  Forward accumulation stays f32 (MXU); the
    # convert boundary makes the softmax-backward cotangents re-enter the
    # projection transposes in bf16, halving the dx TP all-reduce wire.
    bf16_score_grad: bool = False

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim


def init(key, cfg: AttnConfig) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    dt = jnp.dtype(cfg.dtype)
    return {
        "wq": layers.dense_init(kq, cfg.d_model, cfg.q_dim, dt, cfg.qkv_bias),
        "wk": layers.dense_init(kk, cfg.d_model, cfg.kv_dim, dt, cfg.qkv_bias),
        "wv": layers.dense_init(kv, cfg.d_model, cfg.kv_dim, dt, cfg.qkv_bias),
        "wo": layers.dense_init(ko, cfg.q_dim, cfg.d_model, dt, False),
    }


def _split_heads(x: jnp.ndarray, n: int, hd: int) -> jnp.ndarray:
    b, t, _ = x.shape
    return x.reshape(b, t, n, hd).transpose(0, 2, 1, 3)  # (B, n, T, hd)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    b, n, t, hd = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, t, n * hd)


def _mask_bias(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int], kv_len: Optional[jnp.ndarray] = None
               ) -> jnp.ndarray:
    """(Tq, Tk) additive mask from absolute positions."""
    ok = k_pos[None, :] >= 0  # ring-buffer slots never written are < 0
    if causal:
        ok &= k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    if kv_len is not None:
        ok &= k_pos[None, :] < kv_len
    return jnp.where(ok, 0.0, NEG_INF)


def _sdpa(q, k, v, bias, softcap_val, scale, bf16_grad=False):
    """q (B,KV,G,Tq,hd), k/v (B,KV,Tk,hd), bias (Tq,Tk)."""
    scores = jnp.einsum("bkgqh,bkth->bkgqt", q, k,
                        preferred_element_type=jnp.float32) * scale
    if bf16_grad:
        scores = scores.astype(jnp.bfloat16).astype(jnp.float32)
    scores = layers.softcap(scores, softcap_val)
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", probs.astype(v.dtype), v)
    return out


def _sdpa_blockwise(q, k, v, q_pos, k_pos, causal, window, softcap_val,
                    scale, kv_block: int, kv_len=None):
    """Online-softmax attention, scanning KV blocks (flash-style, pure jnp).

    Keeps peak memory at (B,KV,G,Tq,kv_block) instead of (...,Tk): the
    long-prefill path.  Accumulates in f32.
    """
    b, kv_h, g, tq, hd = q.shape
    tk = k.shape[2]
    assert tk % kv_block == 0
    nblk = tk // kv_block

    def step(carry, blk):
        m, l, acc = carry
        ks = jax.lax.dynamic_slice_in_dim(k, blk * kv_block, kv_block, 2)
        vs = jax.lax.dynamic_slice_in_dim(v, blk * kv_block, kv_block, 2)
        kp = jax.lax.dynamic_slice_in_dim(k_pos, blk * kv_block, kv_block, 0)
        s = jnp.einsum("bkgqh,bkth->bkgqt", q, ks,
                       preferred_element_type=jnp.float32) * scale
        s = layers.softcap(s, softcap_val)
        s = s + _mask_bias(q_pos, kp, causal, window, kv_len)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqt,bkth->bkgqh", p.astype(vs.dtype), vs).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    init = (jnp.full((b, kv_h, g, tq), NEG_INF, jnp.float32),
            jnp.zeros((b, kv_h, g, tq), jnp.float32),
            jnp.zeros((b, kv_h, g, tq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(step, init, jnp.arange(nblk))
    return acc / jnp.maximum(l, 1e-30)[..., None]


def _sdpa_blockwise_2d(q, k, v, q_pos, k_pos, causal, window, softcap_val,
                       scale, q_block: int, kv_block: int, kv_len=None):
    """Flash-style attention chunked over BOTH q and kv blocks.

    Peak live memory per step: (B,KV,G,q_block,kv_block) — independent of
    sequence length on both axes.  This is the long-prefill / train path
    (§Perf hillclimb: removes the (Tq,Tk) f32 score materialization)."""
    b, kv_h, g, tq, hd = q.shape
    assert tq % q_block == 0
    nq = tq // q_block

    def one_q(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * q_block, q_block, 3)
        qp = jax.lax.dynamic_slice_in_dim(q_pos, i * q_block, q_block, 0)
        return _sdpa_blockwise(qc, k, v, qp, k_pos, causal, window,
                               softcap_val, scale, kv_block, kv_len)

    out = jax.lax.map(one_q, jnp.arange(nq))       # (nq,B,KV,G,qb,hd)
    return jnp.moveaxis(out, 0, 3).reshape(b, kv_h, g, tq, hd)


def attend(
    params: dict,
    x: jnp.ndarray,
    cfg: AttnConfig,
    *,
    positions: Optional[jnp.ndarray] = None,
    kv_x: Optional[jnp.ndarray] = None,      # cross-attention source
    kv_positions: Optional[jnp.ndarray] = None,
    cache: Optional[dict] = None,            # decode: {"k","v","pos"}
    kv_block: Optional[int] = None,          # blockwise path when set
    q_block: Optional[int] = None,           # + q-chunking (flash) when set
) -> tuple[jnp.ndarray, Optional[dict]]:
    """Returns (output (B,T,d), updated cache or None)."""
    b, t, _ = x.shape
    g = cfg.num_heads // cfg.num_kv_heads
    scale = cfg.head_dim ** -0.5

    q = _split_heads(pctx.shard_batch_tp(layers.dense(params["wq"], x)),
                     cfg.num_heads, cfg.head_dim)
    src = x if kv_x is None else kv_x
    k = _split_heads(pctx.shard_batch_tp(layers.dense(params["wk"], src)),
                     cfg.num_kv_heads, cfg.head_dim)
    v = _split_heads(pctx.shard_batch_tp(layers.dense(params["wv"], src)),
                     cfg.num_kv_heads, cfg.head_dim)

    if positions is None:
        positions = jnp.arange(t)
    if kv_positions is None:
        kv_positions = positions if kv_x is None else jnp.arange(src.shape[1])

    if cfg.use_rope and kv_x is None:
        qc, qs = layers.rope_angles(positions, cfg.head_dim, cfg.rope_theta)
        q = layers.apply_rope(q, qc, qs)
        kc, ks_ = layers.rope_angles(kv_positions, cfg.head_dim, cfg.rope_theta)
        k = layers.apply_rope(k, kc, ks_)

    new_cache = None
    kv_len = None
    if cache is not None:
        # Decode: write new K/V into the cache ring and attend over the
        # buffer with a validity mask.  The buffer may be smaller than the
        # sequence (sliding-window cache): slot = pos % buf, and each
        # slot's *absolute* position is recovered for masking — unwritten
        # slots get negative positions and are masked out.  K was RoPE'd
        # with absolute positions before the write, so eviction is free.
        pos = cache["pos"]
        buf = cache["k"].shape[2]
        slot = pos % buf
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, 2)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, 2)
        k, v = ck, cv
        slots = jnp.arange(buf)
        last_write = pos + t - 1
        kv_positions = last_write - ((last_write - slots) % buf)
        kv_len = pos + t
        new_cache = {"k": ck, "v": cv, "pos": pos + t}

    if cfg.tp_expand_heads and g > 1:
        k = jnp.repeat(k, g, axis=1)        # (B, H, Tk, hd)
        v = jnp.repeat(v, g, axis=1)
        q = pctx.shard_heads(q)
        k = pctx.shard_heads(k)
        v = pctx.shard_heads(v)
        qg = q.reshape(b, cfg.num_heads, 1, q.shape[2], cfg.head_dim)
    else:
        q = pctx.shard_heads(q)
        qg = q.reshape(b, cfg.num_kv_heads, g, q.shape[2], cfg.head_dim)
    causal = cfg.causal and kv_x is None
    if kv_block is not None and q_block is not None \
            and qg.shape[3] % q_block == 0 and qg.shape[3] > q_block:
        out = _sdpa_blockwise_2d(qg, k, v, positions, kv_positions, causal,
                                 cfg.window, cfg.logit_softcap, scale,
                                 q_block, kv_block, kv_len)
    elif kv_block is not None:
        out = _sdpa_blockwise(qg, k, v, positions, kv_positions, causal,
                              cfg.window, cfg.logit_softcap, scale, kv_block,
                              kv_len)
    else:
        bias = _mask_bias(positions, kv_positions, causal, cfg.window, kv_len)
        out = _sdpa(qg, k, v, bias, cfg.logit_softcap, scale,
                    bf16_grad=cfg.bf16_score_grad)
    out = out.astype(x.dtype).reshape(b, cfg.num_heads, q.shape[2],
                                      cfg.head_dim)
    merged = pctx.shard_batch_tp(_merge_heads(out))
    return layers.dense(params["wo"], merged), new_cache


def init_cache(cfg: AttnConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> dict:
    """Decode KV cache buffers.  For windowed layers the buffer is the
    window size (sliding-window cache) — the long_500k enabler."""
    buf = max_len if cfg.window is None else min(max_len, cfg.window)
    shape = (batch, cfg.num_kv_heads, buf, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.array(0, jnp.int32)}
