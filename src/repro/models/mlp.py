"""Gated MLP (SwiGLU/GeGLU) — the dense FFN used by every assigned arch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx


def init(key, d_model: int, d_ff: int, dtype=jnp.bfloat16,
         activation: str = "silu") -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    del activation  # static; passed to apply() instead
    return {
        "w_gate": layers.dense_init(k1, d_model, d_ff, dtype),
        "w_up": layers.dense_init(k2, d_model, d_ff, dtype),
        "w_down": layers.dense_init(k3, d_ff, d_model, dtype),
    }


_ACT = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def apply(p: dict, x: jnp.ndarray, activation: str = "silu") -> jnp.ndarray:
    act = _ACT[activation]
    h = act(pctx.shard_batch_tp(layers.dense(p["w_gate"], x))) * \
        pctx.shard_batch_tp(layers.dense(p["w_up"], x))
    return layers.dense(p["w_down"], h)
