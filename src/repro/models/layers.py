"""Shared model building blocks: norms, embeddings, RoPE, losses.

Pure functions over parameter pytrees (dicts).  Initialization functions
return shape/dtype-matched pytrees; every layer is scan-stackable (params
may carry a leading layer axis added by the caller).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale: float, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init (matches common LM init schemes)."""
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.bfloat16,
               bias: bool = False) -> dict:
    w = truncated_normal_init(key, (d_in, d_out), d_in ** -0.5, dtype)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(d: int, dtype=jnp.bfloat16) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return (h * jax.lax.rsqrt(var + eps)).astype(x.dtype) * p["scale"]


def layernorm_init(d: int, dtype=jnp.bfloat16) -> dict:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.var(h, axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return h.astype(x.dtype) * p["scale"] + p["bias"]


def embed_init(key, vocab: int, d: int, dtype=jnp.bfloat16) -> dict:
    # d**-0.5 keeps tied-unembedding logits O(1) at init.
    return {"table": truncated_normal_init(key, (vocab, d), d ** -0.5, dtype)}


def embed(p: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["table"], tokens, axis=0)


def unembed(p: dict, x: jnp.ndarray) -> jnp.ndarray:
    """Tied unembedding: (..., d) @ (V, d)^T -> (..., V)."""
    return x @ p["table"].T


def softcap(x: jnp.ndarray, cap: Optional[float]) -> jnp.ndarray:
    """Gemma-2 style logit soft-capping."""
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# -- rotary position embeddings --------------------------------------------


def rope_angles(positions: jnp.ndarray, head_dim: int,
                theta: float = 10000.0) -> tuple[jnp.ndarray, jnp.ndarray]:
    """cos/sin tables for given positions; (..., head_dim/2)."""
    half = head_dim // 2
    freq = theta ** (-np.arange(0, half) * 2.0 / head_dim)
    ang = positions[..., None].astype(jnp.float32) * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray
               ) -> jnp.ndarray:
    """x: (..., T, head_dim); cos/sin: (T, head_dim/2) broadcastable."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    rx1 = x1 * cos - x2 * sin
    rx2 = x2 * cos + x1 * sin
    return jnp.concatenate([rx1, rx2], axis=-1).astype(x.dtype)


def sinusoidal_positions(num: int, d: int) -> jnp.ndarray:
    """Whisper-style fixed sinusoidal embeddings, (num, d) f32."""
    half = d // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    pos = np.arange(num)[:, None] * freq[None, :]
    return jnp.asarray(np.concatenate([np.sin(pos), np.cos(pos)], axis=1),
                       jnp.float32)


# -- losses ------------------------------------------------------------------


def softmax_xent(logits: jnp.ndarray, labels: jnp.ndarray,
                 mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean cross-entropy; logits (..., V) any dtype, computed in f32."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if mask is not None:
        nll = nll * mask
        return nll.sum() / jnp.maximum(mask.sum(), 1.0)
    return nll.mean()
