"""Generic causal LM covering the dense / MoE / VLM / gemma2 / rwkv / hybrid
families via a *grouped layer scan*.

Every architecture is expressed as ``n_groups`` repetitions of a small
group of sub-blocks (+ an optional ragged tail), so the whole stack lowers
to one ``lax.scan`` with stacked parameters — tiny HLO even for 94-layer
models, uniform sharding specs, and natural per-group remat:

  dense / moe      group = ("attn",)                      x L
  gemma2           group = ("attn_local", "attn_global")  x L/2
  llama-vision     group = ("attn",)*5 + ("cross",)       x L/5
  rwkv6            group = ("rwkv",)                      x L
  zamba2           group = ("mamba",)*k + ("shared_attn",) x L//k, tail L%k

"shared_attn" weights are shared across groups (zamba2); its KV caches are
per-invocation (stacked over groups).  "cross" layers carry their own
stacked weights and attend to frozen image-embedding K/V.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mamba2, mlp, moe, rwkv6
from repro.parallel import ctx as pctx


# ---------------------------------------------------------------------------
# Layer plan
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerPlan:
    group_kinds: tuple[str, ...]
    n_groups: int
    tail_kinds: tuple[str, ...] = ()


def layer_plan(cfg: ModelConfig) -> LayerPlan:
    if cfg.rwkv:
        return LayerPlan(("rwkv",), cfg.num_layers)
    if cfg.family in ("ssm", "hybrid") and cfg.ssm_state:
        if cfg.attn_every:
            k = cfg.attn_every
            n = cfg.num_layers // k
            tail = cfg.num_layers - n * k
            return LayerPlan(("mamba",) * k + ("shared_attn",), n,
                             ("mamba",) * tail)
        return LayerPlan(("mamba",), cfg.num_layers)
    if cfg.cross_attn_every:
        k = cfg.cross_attn_every
        assert cfg.num_layers % k == 0
        return LayerPlan(("attn",) * k + ("cross",), cfg.num_layers // k)
    if cfg.attn_pattern == "local_global":
        assert cfg.num_layers % 2 == 0
        return LayerPlan(("attn_local", "attn_global"), cfg.num_layers // 2)
    return LayerPlan(("attn",), cfg.num_layers)


def _attn_cfg(cfg: ModelConfig, kind: str) -> attention.AttnConfig:
    window = cfg.window if kind == "attn_local" else None
    if kind == "shared_attn" and cfg.family == "hybrid":
        window = cfg.window  # zamba2 long-context posture (DESIGN §4)
    return attention.AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        qkv_bias=cfg.qkv_bias, logit_softcap=cfg.attn_softcap,
        window=window, causal=True, rope_theta=cfg.rope_theta,
        use_rope=kind != "cross", dtype=cfg.dtype,
        tp_expand_heads=cfg.attn_tp_expand,
        bf16_score_grad=cfg.attn_bf16_score_grad)


def _norm_init(cfg, d=None):
    d = d or cfg.d_model
    return (layers.rmsnorm_init(d, jnp.dtype(cfg.dtype))
            if cfg.norm == "rmsnorm"
            else layers.layernorm_init(d, jnp.dtype(cfg.dtype)))


def _norm(cfg, p, x):
    return (layers.rmsnorm(p, x) if cfg.norm == "rmsnorm"
            else layers.layernorm(p, x))


def _moe_cfg(cfg: ModelConfig) -> moe.MoEConfig:
    return moe.MoEConfig(
        d_model=cfg.d_model, d_expert=cfg.d_expert,
        num_experts=cfg.num_experts, top_k=cfg.top_k,
        num_shared_experts=cfg.num_shared_experts,
        activation=cfg.activation, dtype=cfg.dtype,
        capacity_factor=cfg.moe_capacity_factor,
        bf16_combine=cfg.moe_bf16_combine)


# ---------------------------------------------------------------------------
# Sub-block init / apply
# ---------------------------------------------------------------------------


def _sub_init(key, cfg: ModelConfig, kind: str) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    if kind == "rwkv":
        rc = rwkv6.RWKVConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                              dtype=cfg.dtype)
        return {"norm1": _norm_init(cfg), "norm2": _norm_init(cfg),
                "mix": rwkv6.init(k1, rc)}
    if kind == "mamba":
        mc = mamba2.Mamba2Config(d_model=cfg.d_model, state_dim=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim,
                                 chunk=cfg.ssm_chunk, dtype=cfg.dtype)
        return {"norm": _norm_init(cfg), "ssm": mamba2.init(k1, mc)}
    p = {"norm1": _norm_init(cfg),
         "attn": attention.init(k1, _attn_cfg(cfg, kind)),
         "norm2": _norm_init(cfg)}
    if kind == "cross":
        p["ffn"] = mlp.init(k2, cfg.d_model, cfg.d_ff, dt, cfg.activation)
        p["gate_attn"] = jnp.zeros((), jnp.float32)
        p["gate_ffn"] = jnp.zeros((), jnp.float32)
    elif kind == "shared_attn" or not cfg.is_moe:
        p["ffn"] = mlp.init(k2, cfg.d_model, cfg.d_ff, dt, cfg.activation)
    else:
        p["ffn"] = moe.init(k2, _moe_cfg(cfg))
    return p


def _ffn_apply(cfg: ModelConfig, p, h, kind: str):
    """Returns (out, aux, dispatch_ids or None)."""
    if kind in ("cross", "shared_attn") or not cfg.is_moe:
        return mlp.apply(p, h, cfg.activation), 0.0, None
    mesh_ctx = pctx.current()
    mcfg = _moe_cfg(cfg)
    if mesh_ctx is None:
        b, s, d = h.shape
        out, aux, disp = moe.apply_local(p, h.reshape(b * s, d), mcfg)
        return out.reshape(b, s, d), aux, disp
    if mcfg.use_ep:
        out, aux, disp = moe.apply_ep(
            p, h, mcfg, mesh_ctx.mesh, data_axes=mesh_ctx.data_axes,
            tp_axis=mesh_ctx.tp_axis,
            ep_axis=mesh_ctx.data_axes[-1])
    else:
        out, aux, disp = moe.apply_sharded(
            p, h, mcfg, mesh_ctx.mesh, data_axes=mesh_ctx.data_axes,
            tp_axis=mesh_ctx.tp_axis)
    return out, aux, disp


def _sub_apply(cfg: ModelConfig, kind: str, p: dict, h: jnp.ndarray,
               *, mode: str, cache: Optional[dict], positions,
               image_embeds=None, kv_block=None, q_block=None):
    """One sub-block.  Returns (h, aux, new_cache, dispatch_ids)."""
    aux = 0.0
    disp = None
    if kind == "rwkv":
        rc = rwkv6.RWKVConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                              dtype=cfg.dtype)
        if mode == "decode":
            tm, st = rwkv6.time_mix_decode(
                p["mix"], _norm(cfg, p["norm1"], h),
                {"s": cache["s"], "last": cache["last"]}, rc)
            h = h + tm
            x2 = _norm(cfg, p["norm2"], h)
            cm = rwkv6.channel_mix(p["mix"], x2, last=cache["cm_last"])
            h = h + cm
            new_cache = {"s": st["s"], "last": st["last"],
                         "cm_last": x2[:, 0, :]}
            return h, aux, new_cache, disp
        x1 = _norm(cfg, p["norm1"], h)
        h = h + rwkv6.time_mix(p["mix"], x1, rc, impl=cfg.rwkv_impl)
        x2 = _norm(cfg, p["norm2"], h)
        h = h + rwkv6.channel_mix(p["mix"], x2)
        return h, aux, None, disp
    if kind == "mamba":
        mc = mamba2.Mamba2Config(d_model=cfg.d_model, state_dim=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim,
                                 chunk=cfg.ssm_chunk, dtype=cfg.dtype)
        xn = _norm(cfg, p["norm"], h)
        if mode == "decode":
            out, st = mamba2.decode_step(p["ssm"], xn, cache, mc)
            return h + out, aux, st, disp
        return h + mamba2.apply(p["ssm"], xn, mc), aux, None, disp

    acfg = _attn_cfg(cfg, kind)
    xn = _norm(cfg, p["norm1"], h)
    if kind == "cross":
        if cache is not None:  # decode: frozen image K/V from cache
            attn_out, _ = _cross_from_cache(p, xn, acfg, cache)
            new_cache = cache
        else:
            attn_out, new_cache = attention.attend(
                p["attn"], xn, acfg, positions=positions,
                kv_x=image_embeds, cache=None, kv_block=None)
        h = h + jnp.tanh(p["gate_attn"]).astype(h.dtype) * attn_out
        ffn_out, aux, disp = _ffn_apply(cfg, p["ffn"], _norm(
            cfg, p["norm2"], h), kind)
        h = h + jnp.tanh(p["gate_ffn"]).astype(h.dtype) * ffn_out
        return h, aux, new_cache, disp

    attn_out, new_cache = attention.attend(
        p["attn"], xn, acfg, positions=positions, cache=cache,
        kv_block=kv_block, q_block=q_block)
    h = h + attn_out
    ffn_out, aux, disp = _ffn_apply(cfg, p["ffn"],
                                    _norm(cfg, p["norm2"], h), kind)
    h = h + ffn_out
    return h, aux, new_cache, disp


def _cross_from_cache(p, xn, acfg, cache):
    """Cross-attention against precomputed image K/V (decode path)."""
    b, t, _ = xn.shape
    q = layers.dense(p["attn"]["wq"], xn).reshape(
        b, t, acfg.num_heads, acfg.head_dim).transpose(0, 2, 1, 3)
    g = acfg.num_heads // acfg.num_kv_heads
    qg = q.reshape(b, acfg.num_kv_heads, g, t, acfg.head_dim)
    scores = jnp.einsum("bkgqh,bkth->bkgqt", qg, cache["k"],
                        preferred_element_type=jnp.float32)
    scores = scores * acfg.head_dim ** -0.5
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", probs.astype(cache["v"].dtype),
                     cache["v"])
    out = out.reshape(b, acfg.num_heads, t, acfg.head_dim)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
    return layers.dense(p["attn"]["wo"], out), None


def _chunked_xent(model, params, h, labels, loss_chunk: int) -> jnp.ndarray:
    """Next-token xent, optionally scanning sequence chunks so the f32
    (B, chunk, V) logits never materialize at full sequence length —
    the 256k-vocab memory lever for the large dense archs."""
    h_in, gold = h[:, :-1], labels[:, 1:]
    t = h_in.shape[1]
    if not loss_chunk or t <= loss_chunk:
        logits = model.unembed_logits(params, h_in)
        return layers.softmax_xent(logits, gold)
    pad = (-t) % loss_chunk
    mask = jnp.ones_like(gold, jnp.float32)
    if pad:
        h_in = jnp.pad(h_in, ((0, 0), (0, pad), (0, 0)))
        gold = jnp.pad(gold, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    n = (t + pad) // loss_chunk

    def body(acc, i):
        hc = jax.lax.dynamic_slice_in_dim(h_in, i * loss_chunk, loss_chunk, 1)
        gc = jax.lax.dynamic_slice_in_dim(gold, i * loss_chunk, loss_chunk, 1)
        mc = jax.lax.dynamic_slice_in_dim(mask, i * loss_chunk, loss_chunk, 1)
        logits = model.unembed_logits(params, hc)
        logits = logits.astype(jnp.float32)
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        g = jnp.take_along_axis(logits, gc[..., None], axis=-1)[..., 0]
        return acc + jnp.sum((logz - g) * mc), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), jnp.arange(n))
    return total / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------


def _sub_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
               params_sub=None, image_embeds=None):
    dt = jnp.dtype(cfg.dtype)
    if kind == "rwkv":
        rc = rwkv6.RWKVConfig(d_model=cfg.d_model, d_ff=cfg.d_ff,
                              dtype=cfg.dtype)
        st = rwkv6.init_state(rc, batch)
        return {"s": st["s"], "last": st["last"].astype(dt),
                "cm_last": st["cm_last"].astype(dt)}
    if kind == "mamba":
        mc = mamba2.Mamba2Config(d_model=cfg.d_model, state_dim=cfg.ssm_state,
                                 head_dim=cfg.ssm_head_dim,
                                 chunk=cfg.ssm_chunk, dtype=cfg.dtype)
        st = mamba2.init_state(mc, batch)
        return {"h": st["h"], "conv": st["conv"].astype(dt)}
    if kind == "cross":
        acfg = _attn_cfg(cfg, kind)
        k = layers.dense(params_sub["attn"]["wk"], image_embeds)
        v = layers.dense(params_sub["attn"]["wv"], image_embeds)
        b, ti, _ = image_embeds.shape
        k = k.reshape(b, ti, acfg.num_kv_heads, acfg.head_dim
                      ).transpose(0, 2, 1, 3)
        v = v.reshape(b, ti, acfg.num_kv_heads, acfg.head_dim
                      ).transpose(0, 2, 1, 3)
        return {"k": k, "v": v}
    acfg = _attn_cfg(cfg, kind)
    c = attention.init_cache(acfg, batch, max_len, dt)
    return {"k": c["k"], "v": c["v"]}  # pos passed externally per step


# ---------------------------------------------------------------------------
# The model
# ---------------------------------------------------------------------------


class CausalLM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.plan = layer_plan(cfg)

    # -- init ---------------------------------------------------------------

    def init(self, rng) -> dict:
        cfg = self.cfg
        plan = self.plan
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, 8)
        params: dict[str, Any] = {
            "embed": layers.embed_init(keys[0], cfg.padded_vocab, cfg.d_model,
                                       dt),
            "final_norm": _norm_init(cfg),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = layers.dense_init(
                keys[1], cfg.d_model, cfg.padded_vocab, dt)

        group: dict[str, Any] = {}
        for i, kind in enumerate(plan.group_kinds):
            if kind == "shared_attn":
                continue
            sub_keys = jax.random.split(jax.random.fold_in(keys[2], i),
                                        plan.n_groups)
            group[f"sub{i}"] = jax.vmap(
                lambda k: _sub_init(k, cfg, kind))(sub_keys)
        params["groups"] = group
        if "shared_attn" in plan.group_kinds:
            params["shared_attn"] = _sub_init(keys[3], cfg, "shared_attn")
        if plan.tail_kinds:
            params["tail"] = [
                _sub_init(jax.random.fold_in(keys[4], i), cfg, kind)
                for i, kind in enumerate(plan.tail_kinds)]
        return params

    # -- forward (train) ------------------------------------------------------

    def hidden(self, params, tokens, *, image_embeds=None):
        """Final-norm hidden states (B, T, d) + MoE aux loss."""
        cfg, plan = self.cfg, self.plan
        h = layers.embed(params["embed"], tokens)
        if cfg.family == "audio":
            raise ValueError("use whisper.WhisperModel for audio")
        h = pctx.shard_batch(h)
        positions = jnp.arange(tokens.shape[1])
        kv_block = cfg.kv_block if cfg.attn_impl == "blockwise" else None
        q_block = cfg.q_block or None

        def group_body(carry, group_params):
            h, aux = carry
            for i, kind in enumerate(plan.group_kinds):
                p = (params["shared_attn"] if kind == "shared_attn"
                     else group_params[f"sub{i}"])
                h, a, _, _ = _sub_apply(
                    cfg, kind, p, h, mode="train", cache=None,
                    positions=positions, image_embeds=image_embeds,
                    kv_block=kv_block, q_block=q_block)
                h = pctx.shard_batch(h)
                aux = aux + a
            return (h, aux), None

        if cfg.remat == "block":
            group_body = jax.checkpoint(group_body)
        (h, aux), _ = jax.lax.scan(group_body, (h, 0.0), params["groups"])
        for i, kind in enumerate(plan.tail_kinds):
            h, a, _, _ = _sub_apply(cfg, kind, params["tail"][i], h,
                                    mode="train", cache=None,
                                    positions=positions, kv_block=kv_block,
                                    q_block=q_block)
            aux = aux + a
        h = _norm(cfg, params["final_norm"], h)
        return h, aux

    def unembed_logits(self, params, h):
        cfg = self.cfg
        logits = (layers.unembed(params["embed"], h)
                  if cfg.tie_embeddings
                  else layers.dense(params["lm_head"], h))
        logits = pctx.shard_batch_tp(logits)  # vocab TP-sharded
        return layers.softcap(logits.astype(jnp.float32), cfg.final_softcap)

    def forward(self, params, tokens, *, image_embeds=None):
        h, aux = self.hidden(params, tokens, image_embeds=image_embeds)
        return self.unembed_logits(params, h), aux

    def loss(self, params, batch, *, loss_chunk: int = 0):
        h, aux = self.hidden(params, batch["tokens"],
                             image_embeds=batch.get("image_embeds"))
        xent = _chunked_xent(self, params, h, batch["labels"], loss_chunk)
        aux = jnp.asarray(aux, jnp.float32)
        total = xent + 0.001 * aux if self.cfg.is_moe else xent
        return total, {"xent": xent, "aux": aux}

    # -- serving --------------------------------------------------------------

    def init_cache(self, params, batch: int, max_len: int,
                   image_embeds=None):
        cfg, plan = self.cfg, self.plan

        def one_group(g):
            caches = {}
            for i, kind in enumerate(plan.group_kinds):
                psub = None
                img = None
                if kind == "cross":
                    psub = jax.tree.map(lambda a: a[g],
                                        params["groups"][f"sub{i}"])
                    img = image_embeds
                caches[f"sub{i}"] = _sub_cache(cfg, kind, batch, max_len,
                                               psub, img)
            return caches

        stacked = jax.tree.map(
            lambda *xs: jnp.stack(xs),
            *[one_group(g) for g in range(plan.n_groups)]) \
            if plan.n_groups > 1 else jax.tree.map(
                lambda x: x[None], one_group(0))
        tail = [
            _sub_cache(cfg, kind, batch, max_len, params["tail"][i], None)
            for i, kind in enumerate(plan.tail_kinds)]
        return {"groups": stacked, "tail": tail}

    def decode_step(self, params, tokens, cache, *, pos):
        """tokens (B, 1); pos scalar int32 — absolute position."""
        cfg, plan = self.cfg, self.plan
        h = layers.embed(params["embed"], tokens)
        h = pctx.shard_batch(h)
        positions = pos + jnp.arange(1)

        def group_body(h, xs):
            group_params, group_cache = xs
            new_caches = {}
            for i, kind in enumerate(plan.group_kinds):
                p = (params["shared_attn"] if kind == "shared_attn"
                     else group_params[f"sub{i}"])
                c = group_cache[f"sub{i}"]
                if kind in ("attn", "attn_local", "attn_global",
                            "shared_attn"):
                    c = dict(c, pos=pos)
                h, _, nc, _ = _sub_apply(cfg, kind, p, h, mode="decode",
                                         cache=c, positions=positions)
                if nc is not None and "pos" in nc:
                    nc = {k: v for k, v in nc.items() if k != "pos"}
                new_caches[f"sub{i}"] = nc if nc is not None else c
            return h, new_caches

        h, new_group_caches = jax.lax.scan(
            group_body, h, (params["groups"], cache["groups"]))
        new_tail = []
        for i, kind in enumerate(plan.tail_kinds):
            c = cache["tail"][i]
            if kind in ("attn", "attn_local", "attn_global", "shared_attn"):
                c = dict(c, pos=pos)
            h, _, nc, _ = _sub_apply(cfg, kind, params["tail"][i], h,
                                     mode="decode", cache=c,
                                     positions=positions)
            if nc is not None and "pos" in nc:
                nc = {k: v for k, v in nc.items() if k != "pos"}
            new_tail.append(nc if nc is not None else c)
        h = _norm(cfg, params["final_norm"], h)
        logits = (layers.unembed(params["embed"], h)
                  if cfg.tie_embeddings
                  else layers.dense(params["lm_head"], h))
        logits = layers.softcap(logits.astype(jnp.float32),
                                cfg.final_softcap)
        return logits, {"groups": new_group_caches, "tail": new_tail}
