"""Mamba-2 block (SSD, arXiv:2405.21060) — used by zamba2's backbone.

Selective state-space with scalar-per-head decay, evaluated with the
chunked state-space-duality algorithm: intra-chunk quadratic (matmul) term
+ inter-chunk state recurrence (scan over chunks).  Decode carries the
(H, P, N) state and a small causal-conv ring — O(1) in sequence length,
which is what makes zamba2 a long_500k arch.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    state_dim: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64
    dtype: str = "bfloat16"

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def num_heads(self) -> int:
        return self.d_inner // self.head_dim

    @property
    def conv_dim(self) -> int:
        return self.d_inner + 2 * self.state_dim


def init(key, cfg: Mamba2Config) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    d_in_proj = 2 * cfg.d_inner + 2 * cfg.state_dim + cfg.num_heads
    return {
        "in_proj": layers.dense_init(ks[0], cfg.d_model, d_in_proj, dt),
        "conv_w": layers.truncated_normal_init(
            ks[1], (cfg.conv_width, cfg.conv_dim), 0.3, dt),
        "conv_b": jnp.zeros((cfg.conv_dim,), dt),
        "a_log": jnp.zeros((cfg.num_heads,), jnp.float32),   # A = -exp(a_log)
        "dt_bias": jnp.zeros((cfg.num_heads,), jnp.float32),
        "d_skip": jnp.ones((cfg.num_heads,), jnp.float32),
        "norm": layers.rmsnorm_init(cfg.d_inner, dt),
        "out_proj": layers.dense_init(ks[2], cfg.d_inner, cfg.d_model, dt),
    }


def _split_proj(proj: jnp.ndarray, cfg: Mamba2Config):
    zi = cfg.d_inner
    xi = zi + cfg.d_inner
    bi = xi + cfg.state_dim
    ci = bi + cfg.state_dim
    return (proj[..., :zi], proj[..., zi:xi], proj[..., xi:bi],
            proj[..., bi:ci], proj[..., ci:])


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv via shifted adds; x (B,T,C), w (W,C)."""
    width = w.shape[0]
    out = x * w[-1]
    for i in range(1, width):
        shifted = jnp.roll(x, i, axis=1)
        if init_state is None:
            shifted = shifted.at[:, :i].set(0.0)
        else:
            shifted = shifted.at[:, :i].set(init_state[:, width - 1 - i:
                                                       width - 1 - i + i])
        out = out + shifted * w[-1 - i]
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, a, b_mat, c_mat, chunk: int):
    """SSD: x (B,T,H,P), dt (B,T,H) f32, a (H,) f32 (negative),
    b/c (B,T,N).  Returns y (B,T,H,P) f32 and final state (B,H,P,N)."""
    bsz, t0, h, p = x.shape
    n = b_mat.shape[-1]
    pad = (-t0) % chunk
    if pad:  # zero x/dt rows contribute nothing; dt=0 means decay exp(0)=1
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0)))
    t = t0 + pad
    nc = t // chunk
    da = (dt * a).reshape(bsz, nc, chunk, h)             # log decay per step
    xdt = (x.astype(jnp.float32) * dt[..., None]).reshape(
        bsz, nc, chunk, h, p)
    bs = b_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cs = c_mat.astype(jnp.float32).reshape(bsz, nc, chunk, n)
    cum = jnp.cumsum(da, axis=2)                         # inclusive
    # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) (c_i.b_j) dtx_j
    decay_i = jnp.exp(cum)                               # (b,c,l,h)
    decay_j = jnp.exp(-cum)
    scores = jnp.einsum("bcln,bcmn->bclm", cs, bs)       # (b,c,l,m)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    pair = scores[:, :, None] * (decay_i.transpose(0, 1, 3, 2)[..., None]
                                 * decay_j.transpose(0, 1, 3, 2)[:, :, :, None]
                                 * tri[None, None, None])
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", pair, xdt)
    # chunk summary state: S_c = sum_j exp(cum_L - cum_j) dtx_j b_j^T
    w_total = cum[:, :, -1]                              # (b,c,h)
    k_tail = jnp.exp(w_total[:, :, None] - cum)          # (b,c,l,h)
    s_chunk = jnp.einsum("bclh,bclhp,bcln->bchpn", k_tail, xdt, bs)

    def step(hprev, inp):
        wt, sc = inp
        return jnp.exp(wt)[..., None, None] * hprev + sc, hprev

    s0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    h_last, h_in = jax.lax.scan(
        step, s0, (w_total.transpose(1, 0, 2), s_chunk.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)                 # (b,c,h,p,n)
    y_inter = jnp.einsum("bclh,bcln,bchpn->bclhp", decay_i, cs, h_in)
    y = (y_intra + y_inter).reshape(bsz, t, h, p)
    return y[:, :t0], h_last


def apply(p: dict, x: jnp.ndarray, cfg: Mamba2Config) -> jnp.ndarray:
    bsz, t, _ = x.shape
    proj = layers.dense(p["in_proj"], x)
    z, xin, b_mat, c_mat, dt_raw = _split_proj(proj, cfg)
    z, xin = pctx.shard_batch_tp(z), pctx.shard_batch_tp(xin)
    xbc = jnp.concatenate([xin, b_mat, c_mat], axis=-1)
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin = xbc[..., :cfg.d_inner]
    b_mat = xbc[..., cfg.d_inner:cfg.d_inner + cfg.state_dim]
    c_mat = xbc[..., cfg.d_inner + cfg.state_dim:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(bsz, t, cfg.num_heads, cfg.head_dim)
    y, _ = _ssd_chunked(xh, dt, a, b_mat, c_mat, cfg.chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(bsz, t, cfg.d_inner).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z))
    return layers.dense(p["out_proj"], y)


def decode_step(p: dict, x: jnp.ndarray, state: dict, cfg: Mamba2Config):
    """x (B,1,d); state {"h": (B,H,P,N) f32, "conv": (B,W-1,conv_dim)}."""
    bsz = x.shape[0]
    proj = layers.dense(p["in_proj"], x)[:, 0]
    z, xin, b_mat, c_mat, dt_raw = _split_proj(proj, cfg)
    xbc = jnp.concatenate([xin, b_mat, c_mat], axis=-1)  # (B, conv_dim)
    window = jnp.concatenate([state["conv"], xbc[:, None]], axis=1)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                   p["conv_w"].astype(jnp.float32)) + p["conv_b"])
    conv_out = conv_out.astype(x.dtype)
    xin = conv_out[..., :cfg.d_inner]
    b_mat = conv_out[..., cfg.d_inner:cfg.d_inner + cfg.state_dim]
    c_mat = conv_out[..., cfg.d_inner + cfg.state_dim:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["a_log"])
    xh = xin.reshape(bsz, cfg.num_heads, cfg.head_dim).astype(jnp.float32)
    decay = jnp.exp(dt * a)                               # (B,H)
    upd = jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], b_mat.astype(jnp.float32))
    h_new = decay[..., None, None] * state["h"] + upd
    y = jnp.einsum("bhpn,bn->bhp", h_new, c_mat.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, cfg.d_inner).astype(x.dtype)
    y = layers.rmsnorm(p["norm"], y * jax.nn.silu(z[:, None]))
    out = layers.dense(p["out_proj"], y)
    return out, {"h": h_new, "conv": window[:, 1:]}


def init_state(cfg: Mamba2Config, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.num_heads, cfg.head_dim, cfg.state_dim),
                       jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.conv_dim),
                          jnp.bfloat16),
    }
