"""RWKV-6 "Finch" block (arXiv:2404.05892): data-dependent decay linear RNN.

Implements the time-mix (WKV6) and channel-mix sub-blocks with the
DDLerp token-shift interpolation and the low-rank data-dependent decay.
Two WKV evaluation paths:

  * ``scan``   — the faithful per-token recurrence (baseline),
  * ``chunked`` — chunk-parallel evaluation (intra-chunk matmul form +
    inter-chunk state scan), the TPU-friendly path used for training and
    the long_500k shape (§Perf hillclimb subject).

State per head: S (N_k x N_v) with N = head_dim; decode carries (S, last
token) only — O(1) in sequence length, which is why rwkv6 runs the
long_500k cell.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.parallel import ctx as pctx


@dataclasses.dataclass(frozen=True)
class RWKVConfig:
    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    mix_lora: int = 32
    d_ff: int = 0               # channel-mix hidden (3.5x d_model default)
    dtype: str = "bfloat16"

    @property
    def num_heads(self) -> int:
        return self.d_model // self.head_dim

    @property
    def ffn_dim(self) -> int:
        return self.d_ff or int(3.5 * self.d_model)


_MIX_NAMES = ("w", "k", "v", "r", "g")


def init(key, cfg: RWKVConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, n = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 16)
    p = {
        "mix_base": jnp.zeros((len(_MIX_NAMES), d), dt),   # mu_i
        "mix_x": jnp.zeros((d,), dt),                      # mu_x
        "mix_a": layers.truncated_normal_init(
            ks[0], (d, len(_MIX_NAMES) * cfg.mix_lora), d ** -0.5, dt),
        "mix_b": layers.truncated_normal_init(
            ks[1], (len(_MIX_NAMES), cfg.mix_lora, d),
            cfg.mix_lora ** -0.5, dt),
        "decay_base": jnp.full((d,), -6.0, jnp.float32),   # w0
        "decay_a": layers.truncated_normal_init(
            ks[2], (d, cfg.decay_lora), d ** -0.5, dt),
        "decay_b": layers.truncated_normal_init(
            ks[3], (cfg.decay_lora, d), cfg.decay_lora ** -0.5, dt),
        "bonus": jnp.zeros((cfg.num_heads, n), jnp.float32),  # u
        "wr": layers.dense_init(ks[4], d, d, dt),
        "wk": layers.dense_init(ks[5], d, d, dt),
        "wv": layers.dense_init(ks[6], d, d, dt),
        "wg": layers.dense_init(ks[7], d, d, dt),
        "wo": layers.dense_init(ks[8], d, d, dt),
        "ln_x": layers.layernorm_init(d, dt),              # per-head GN approx
        # channel mix
        "cm_mix_k": jnp.full((d,), 0.5, dt),
        "cm_mix_r": jnp.full((d,), 0.5, dt),
        "cm_k": layers.dense_init(ks[9], d, cfg.ffn_dim, dt),
        "cm_v": layers.dense_init(ks[10], cfg.ffn_dim, d, dt),
        "cm_r": layers.dense_init(ks[11], d, d, dt),
    }
    return p


def _token_shift(x: jnp.ndarray, last: jnp.ndarray | None = None):
    """x (B,T,d) -> previous-token x; position 0 sees `last` (or zeros)."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, :1]) if last is None else last[:, None, :]
    return jnp.concatenate([first, prev[:, 1:]], axis=1)


def _ddlerp(p: dict, x: jnp.ndarray, x_prev: jnp.ndarray):
    """Data-dependent interpolation for the 5 mix streams (RWKV6)."""
    xx = x_prev - x
    base = x + xx * p["mix_x"]
    lora = jnp.tanh(base @ p["mix_a"])                      # (B,T,5*Lm)
    lora = lora.reshape(x.shape[:-1] + (len(_MIX_NAMES), -1))
    adj = jnp.einsum("btml,mld->btmd", lora.astype(x.dtype), p["mix_b"])
    outs = []
    for i, _ in enumerate(_MIX_NAMES):
        mi = p["mix_base"][i] + adj[..., i, :]
        outs.append(x + xx * mi)
    return outs  # xw, xk, xv, xr, xg


def _decay(p: dict, xw: jnp.ndarray) -> jnp.ndarray:
    """Per-channel data-dependent log-decay (negative), f32 (B,T,d)."""
    lora = jnp.tanh(xw @ p["decay_a"]).astype(jnp.float32) @ \
        p["decay_b"].astype(jnp.float32)
    return -jnp.exp(p["decay_base"] + lora)  # log w_t <= 0


def _wkv_scan(r, k, v, logw, u):
    """Faithful recurrence.  r,k,v (B,T,H,N); logw (B,T,H,N); u (H,N)."""
    def step(s, inp):
        r_t, k_t, v_t, w_t = inp                   # (B,H,N)
        kv = jnp.einsum("bhk,bhv->bhkv", k_t, v_t)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = jnp.exp(w_t)[..., None] * s + kv
        return s, y

    b, t, h, n = r.shape
    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), logw.transpose(1, 0, 2, 3))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3)                # (B,T,H,N)


def _wkv_chunked(r, k, v, logw, u, chunk: int = 64):
    """Chunk-parallel WKV6: intra-chunk matmul + inter-chunk state scan.

    Within a chunk of length L the contribution of token j to output i>j is
    r_i . (prod_{j<u<=i} w_u) (k_j x v_j); plus the u-bonus diagonal and the
    carried-in state decayed to position i.  All per-chunk terms are
    matmuls over (L, L) or (L, N) — MXU-shaped.
    """
    b, t0, h, n = r.shape
    pad = (-t0) % chunk
    if pad:  # zero r/k/v rows contribute nothing; logw=0 means decay 1
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, logw = z(r), z(k), z(v), z(logw)
    t = t0 + pad
    c = t // chunk
    rs = r.reshape(b, c, chunk, h, n).astype(jnp.float32)
    ks = k.reshape(b, c, chunk, h, n).astype(jnp.float32)
    vs = v.reshape(b, c, chunk, h, n).astype(jnp.float32)
    ws = logw.reshape(b, c, chunk, h, n)
    cum = jnp.cumsum(ws, axis=2)                    # inclusive cumsum of logw
    # y_t reads the state *before* w_t is applied (scan semantics), so the
    # pairwise decay for (i, j), i > j is sum_{u=j+1}^{i-1} w_u
    # = cum_excl_i - cum_incl_j with cum_excl = cum - w.
    r_dec = rs * jnp.exp(cum - ws)                  # r_i * exp(cum_{i-1})
    k_dec = ks * jnp.exp(-cum)                      # k_j * exp(-cum_j)
    scores = jnp.einsum("bclhn,bcmhn->bchlm", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32), -1)
    scores = scores * tri[None, None, None]
    diag = jnp.einsum("bclhn,hn,bclhn->bclh", rs, u, ks)
    y_intra = jnp.einsum("bchlm,bcmhn->bclhn", scores, vs)
    y_intra = y_intra + diag[..., None] * vs
    # chunk summary state: S_c = sum_j exp(cum_L - cum_j) k_j x v_j
    w_total = cum[:, :, -1]                         # (b,c,h,n)
    k_tail = ks * jnp.exp(w_total[:, :, None] - cum)
    s_chunk = jnp.einsum("bclhk,bclhv->bchkv", k_tail, vs)
    # inter-chunk scan: H_c = exp(w_total_c) H_{c-1} + S_c
    def step(hprev, inp):
        wt, sc = inp                                # (b,h,n), (b,h,n,n)
        hnew = jnp.exp(wt)[..., None] * hprev + sc
        return hnew, hprev

    s0 = jnp.zeros((b, h, n, n), jnp.float32)
    _, h_in = jax.lax.scan(
        step, s0, (w_total.transpose(1, 0, 2, 3),
                   s_chunk.transpose(1, 0, 2, 3, 4)))
    h_in = h_in.transpose(1, 0, 2, 3, 4)            # (b,c,h,n,n) state entering chunk
    y_inter = jnp.einsum("bclhk,bchkv->bclhv", r_dec, h_in)
    y = (y_intra + y_inter).reshape(b, t, h, n)
    return y[:, :t0]


def time_mix(p: dict, x: jnp.ndarray, cfg: RWKVConfig, impl: str = "chunked",
             chunk: int = 64) -> jnp.ndarray:
    b, t, d = x.shape
    h, n = cfg.num_heads, cfg.head_dim
    xw, xk, xv, xr, xg = _ddlerp(p, x, _token_shift(x))
    tp = pctx.shard_batch_tp
    logw = tp(_decay(p, xw)).reshape(b, t, h, n)
    r = tp(layers.dense(p["wr"], xr)).reshape(b, t, h, n).astype(jnp.float32)
    k = tp(layers.dense(p["wk"], xk)).reshape(b, t, h, n).astype(jnp.float32)
    v = tp(layers.dense(p["wv"], xv)).reshape(b, t, h, n).astype(jnp.float32)
    g = tp(layers.dense(p["wg"], xg))
    if impl == "scan":
        y = _wkv_scan(r, k, v, logw, p["bonus"])
    else:
        y = _wkv_chunked(r, k, v, logw, p["bonus"], chunk)
    y = y.reshape(b, t, d).astype(x.dtype)
    y = layers.layernorm(p["ln_x"], y)
    return layers.dense(p["wo"], y * jax.nn.silu(g))


def time_mix_decode(p: dict, x: jnp.ndarray, state: dict, cfg: RWKVConfig):
    """One-token step.  x (B,1,d); state {"s": (B,H,N,N) f32, "last": (B,d)}."""
    b, _, d = x.shape
    h, n = cfg.num_heads, cfg.head_dim
    x_prev = state["last"][:, None, :]
    xw, xk, xv, xr, xg = _ddlerp(p, x, x_prev)
    logw = _decay(p, xw).reshape(b, h, n)
    r = layers.dense(p["wr"], xr).reshape(b, h, n).astype(jnp.float32)
    k = layers.dense(p["wk"], xk).reshape(b, h, n).astype(jnp.float32)
    v = layers.dense(p["wv"], xv).reshape(b, h, n).astype(jnp.float32)
    g = layers.dense(p["wg"], xg)
    kv = jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv",
                   r, state["s"] + p["bonus"][None, :, :, None] * kv)
    s_new = jnp.exp(logw)[..., None] * state["s"] + kv
    y = y.reshape(b, 1, d).astype(x.dtype)
    y = layers.layernorm(p["ln_x"], y)
    out = layers.dense(p["wo"], y * jax.nn.silu(g))
    return out, {"s": s_new, "last": x[:, 0, :]}


def channel_mix(p: dict, x: jnp.ndarray, last=None) -> jnp.ndarray:
    xp = _token_shift(x, last)
    xk = x + (xp - x) * p["cm_mix_k"]
    xr = x + (xp - x) * p["cm_mix_r"]
    k = jnp.square(jax.nn.relu(
        pctx.shard_batch_tp(layers.dense(p["cm_k"], xk))))
    return jax.nn.sigmoid(layers.dense(p["cm_r"], xr)) * \
        layers.dense(p["cm_v"], k)


def init_state(cfg: RWKVConfig, batch: int) -> dict:
    h, n = cfg.num_heads, cfg.head_dim
    return {
        "s": jnp.zeros((batch, h, n, n), jnp.float32),
        "last": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
        "cm_last": jnp.zeros((batch, cfg.d_model), jnp.bfloat16),
    }
