"""Whisper-small backbone (arXiv:2212.04356): encoder-decoder transformer.

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed mel-frame embeddings (B, frames, d_model); the encoder is the
12-layer bidirectional stack over those frames, the decoder a 12-layer
causal stack with cross-attention.  LayerNorm + GELU + learned-free
sinusoidal positions (no RoPE), matching the paper's architecture family.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention, layers, mlp
from repro.parallel import ctx as pctx


def _acfg(cfg: ModelConfig, causal: bool) -> attention.AttnConfig:
    return attention.AttnConfig(
        d_model=cfg.d_model, num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads, head_dim=cfg.resolved_head_dim,
        qkv_bias=True, causal=causal, use_rope=False, dtype=cfg.dtype)


def _block_init(key, cfg: ModelConfig, cross: bool) -> dict:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "norm1": layers.layernorm_init(cfg.d_model, dt),
        "attn": attention.init(ks[0], _acfg(cfg, True)),
        "norm2": layers.layernorm_init(cfg.d_model, dt),
        "ffn": mlp.init(ks[1], cfg.d_model, cfg.d_ff, dt, "gelu"),
    }
    if cross:
        p["norm_c"] = layers.layernorm_init(cfg.d_model, dt)
        p["cross"] = attention.init(ks[2], _acfg(cfg, False))
    return p


class WhisperModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    def init(self, rng) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        keys = jax.random.split(rng, 4)
        enc_keys = jax.random.split(keys[0], cfg.encoder_layers)
        dec_keys = jax.random.split(keys[1], cfg.num_layers)
        return {
            "embed": layers.embed_init(keys[2], cfg.padded_vocab, cfg.d_model,
                                       dt),
            "enc_blocks": jax.vmap(
                lambda k: _block_init(k, cfg, cross=False))(enc_keys),
            "enc_norm": layers.layernorm_init(cfg.d_model, dt),
            "dec_blocks": jax.vmap(
                lambda k: _block_init(k, cfg, cross=True))(dec_keys),
            "dec_norm": layers.layernorm_init(cfg.d_model, dt),
        }

    # -- encoder -------------------------------------------------------------

    def encode(self, params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        f = frames.shape[1]
        h = frames + layers.sinusoidal_positions(f, cfg.d_model).astype(
            frames.dtype)
        h = pctx.shard_batch(h)
        acfg = _acfg(cfg, causal=False)

        def body(h, p):
            xn = layers.layernorm(p["norm1"], h)
            a, _ = attention.attend(p["attn"], xn, acfg)
            h = h + a
            h = h + mlp.apply(p["ffn"], layers.layernorm(p["norm2"], h),
                              "gelu")
            return pctx.shard_batch(h), None

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["enc_blocks"])
        return layers.layernorm(params["enc_norm"], h)

    # -- decoder -------------------------------------------------------------

    def _dec_embed(self, params, tokens, pos0: int | jnp.ndarray = 0):
        cfg = self.cfg
        t = tokens.shape[1]
        h = layers.embed(params["embed"], tokens)
        pos_tab = layers.sinusoidal_positions(
            max(t, 1) if isinstance(pos0, int) and pos0 == 0 else t,
            cfg.d_model)
        if isinstance(pos0, int) and pos0 == 0:
            h = h + pos_tab[:t].astype(h.dtype)
        else:  # decode: single absolute position
            ang = layers.sinusoidal_positions(1, cfg.d_model)
            del ang  # decode adds position via rope-free sinusoid lookup
            h = h + _sinusoid_at(pos0, cfg.d_model).astype(h.dtype)
        return h

    def forward(self, params, tokens, frames):
        cfg = self.cfg
        enc = self.encode(params, frames)
        h = self._dec_embed(params, tokens)
        h = pctx.shard_batch(h)
        acfg = _acfg(cfg, causal=True)
        xcfg = _acfg(cfg, causal=False)

        def body(h, p):
            xn = layers.layernorm(p["norm1"], h)
            a, _ = attention.attend(
                p["attn"], xn, acfg,
                kv_block=cfg.kv_block if cfg.attn_impl == "blockwise" else None)
            h = h + a
            xc = layers.layernorm(p["norm_c"], h)
            a, _ = attention.attend(p["cross"], xc, xcfg, kv_x=enc)
            h = h + a
            h = h + mlp.apply(p["ffn"], layers.layernorm(p["norm2"], h),
                              "gelu")
            return pctx.shard_batch(h), None

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, params["dec_blocks"])
        h = layers.layernorm(params["dec_norm"], h)
        return layers.unembed(params["embed"], h), 0.0

    def loss(self, params, batch, *, loss_chunk: int = 0):
        del loss_chunk  # 52k vocab: full logits are fine
        logits, _ = self.forward(params, batch["tokens"], batch["frames"])
        xent = layers.softmax_xent(logits[:, :-1], batch["labels"][:, 1:])
        return xent, {"xent": xent, "aux": jnp.float32(0.0)}

    # -- serving -------------------------------------------------------------

    def init_cache(self, params, batch: int, max_len: int, frames=None):
        cfg = self.cfg
        dt = jnp.dtype(cfg.dtype)
        enc = self.encode(params, frames)
        acfg = _acfg(cfg, causal=True)

        def per_layer(p):
            sc = attention.init_cache(acfg, batch, max_len, dt)
            # precompute frozen cross K/V from encoder output
            kvh, hd = acfg.num_kv_heads, acfg.head_dim
            k = layers.dense(p["cross"]["wk"], enc)
            v = layers.dense(p["cross"]["wv"], enc)
            f = enc.shape[1]
            k = k.reshape(batch, f, kvh, hd).transpose(0, 2, 1, 3)
            v = v.reshape(batch, f, kvh, hd).transpose(0, 2, 1, 3)
            return {"k": sc["k"], "v": sc["v"], "xk": k, "xv": v}

        return jax.vmap(per_layer)(params["dec_blocks"])

    def decode_step(self, params, tokens, cache, *, pos):
        cfg = self.cfg
        h = self._dec_embed(params, tokens, pos0=pos)
        h = pctx.shard_batch(h)
        acfg = _acfg(cfg, causal=True)

        def body(h, xs):
            p, c = xs
            xn = layers.layernorm(p["norm1"], h)
            a, nc = attention.attend(p["attn"], xn, acfg,
                                     positions=pos + jnp.arange(1),
                                     cache={"k": c["k"], "v": c["v"],
                                            "pos": pos})
            h = h + a
            xc = layers.layernorm(p["norm_c"], h)
            a, _ = _cross_cached(p["cross"], xc, acfg, c["xk"], c["xv"])
            h = h + a
            h = h + mlp.apply(p["ffn"], layers.layernorm(p["norm2"], h),
                              "gelu")
            return h, {"k": nc["k"], "v": nc["v"], "xk": c["xk"],
                       "xv": c["xv"]}

        h, new_cache = jax.lax.scan(body, h, (params["dec_blocks"], cache))
        h = layers.layernorm(params["dec_norm"], h)
        return layers.unembed(params["embed"], h), new_cache


def _sinusoid_at(pos, d: int):
    import numpy as np
    half = d // 2
    freq = jnp.asarray(
        np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1)),
        jnp.float32)
    ang = pos.astype(jnp.float32) * freq
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]


def _cross_cached(p_attn, xn, acfg, k, v):
    b, t, _ = xn.shape
    q = layers.dense(p_attn["wq"], xn).reshape(
        b, t, acfg.num_heads, acfg.head_dim).transpose(0, 2, 1, 3)
    g = acfg.num_heads // acfg.num_kv_heads
    qg = q.reshape(b, acfg.num_kv_heads, g, t, acfg.head_dim)
    scores = jnp.einsum("bkgqh,bkth->bkgqt", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores * acfg.head_dim ** -0.5
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqt,bkth->bkgqh", probs.astype(v.dtype), v)
    out = out.reshape(b, acfg.num_heads, t, acfg.head_dim)
    out = out.transpose(0, 2, 1, 3).reshape(b, t, -1)
    return layers.dense(p_attn["wo"], out), None
