"""Mixture-of-Experts layer: top-k routing + sort + ragged_dot expert compute.

Distribution design (DESIGN.md §5): tokens stay resident on their
(pod, data) shard; expert weights are TP-sharded on the expert-hidden dim
over the ``model`` axis and replicated over data.  Inside a shard_map the
layer (per data shard):

  1. routes tokens (softmax top-k),
  2. sorts the (token, expert-slot) stream by expert id — a *local* sort,
  3. counts tokens per expert with a bincount — **the paper's histogram**:
     the dispatch count's conflict structure is data-dependent (a
     collapsed router is the "solid image", a balanced router the
     "uniform image") and the instrumented path prices it with the
     queuing model,
  4. runs capacity-free ragged_dot expert matmuls (no token dropping),
  5. psums partial outputs over ``model`` (the intra-expert TP reduce),
  6. unsorts and combines with the top-k gate weights.

A classic whole-expert EP layout (all_to_all over an expert axis) is the
main alternative; §Perf compares the collective profiles.

The layer is scan-stackable and grad-safe (ragged_dot has transpose
rules; sort/gather transpose to scatter).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models import layers


def _shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across jax versions (experimental on older jax).

    The old experimental version needs ``check_rep=False``: its replication
    check breaks transposition of collectives that receive a symbolic Zero
    cotangent (e.g. grads through ``out`` while ``aux`` is unused).
    """
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as sm_old
    return sm_old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_expert: int              # per-expert hidden (d_ff of one expert)
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    router_aux_coef: float = 0.001
    activation: str = "silu"
    dtype: str = "bfloat16"
    capacity_factor: float = 1.25   # EP path only (GShard semantics)
    bf16_combine: bool = False      # keep the EP return path (unsort +
                                    # all_to_all back + scatter) in bf16:
                                    # halves the TP-psum/a2a wire traffic;
                                    # slots are write-once so the scatter
                                    # loses no precision

    @property
    def use_ep(self) -> bool:
        """Whole-expert EP (all_to_all) for big expert counts; the small-E
        archs keep experts replicated over data and TP-shard the hidden."""
        return self.num_experts >= 64


def init(key, cfg: MoEConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    kr, k1, k2, k3, ks = jax.random.split(key, 5)
    scale_in = cfg.d_model ** -0.5
    scale_out = cfg.d_expert ** -0.5
    p = {
        "router": layers.dense_init(kr, cfg.d_model, cfg.num_experts, dt),
        "w_gate": layers.truncated_normal_init(
            k1, (cfg.num_experts, cfg.d_model, cfg.d_expert), scale_in, dt),
        "w_up": layers.truncated_normal_init(
            k2, (cfg.num_experts, cfg.d_model, cfg.d_expert), scale_in, dt),
        "w_down": layers.truncated_normal_init(
            k3, (cfg.num_experts, cfg.d_expert, cfg.d_model), scale_out, dt),
    }
    if cfg.num_shared_experts:
        from repro.models import mlp
        p["shared"] = mlp.init(ks, cfg.d_model,
                               cfg.d_expert * cfg.num_shared_experts, dt)
    return p


def route(p: dict, x: jnp.ndarray, cfg: MoEConfig):
    """Router: returns (gates (T,k) f32, ids (T,k) i32, aux_loss scalar)."""
    logits = (x.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    density = jnp.mean(
        jax.nn.one_hot(ids[..., 0], cfg.num_experts, dtype=jnp.float32),
        axis=tuple(range(ids.ndim - 1)))
    mean_probs = probs.mean(axis=tuple(range(probs.ndim - 1)))
    aux = cfg.num_experts * jnp.sum(density * mean_probs)
    return gates, ids, aux


def _expert_ffn_sorted(p: dict, xs: jnp.ndarray, group_sizes: jnp.ndarray,
                       cfg: MoEConfig, axis_name: Optional[str]):
    """ragged_dot FFN over expert-sorted rows; psum partial d_model out.

    NOTE: XLA:CPU lowers ragged_dot as an E-dense loop (every expert sees
    every row), inflating FLOPs by ~E/k; kept as an option for TPU (where
    Mosaic lowers it tightly) — the default path is the capacity-grouped
    batched matmul below.
    """
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = (act(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes))
         * jax.lax.ragged_dot(xs, p["w_up"], group_sizes))
    y = jax.lax.ragged_dot(h.astype(xs.dtype), p["w_down"], group_sizes)
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
    return y


def _expert_ffn_grouped(p: dict, xs: jnp.ndarray, sorted_ids: jnp.ndarray,
                        num_experts: int, capacity: int, cfg: MoEConfig,
                        axis_name: Optional[str]):
    """Capacity-grouped expert FFN: scatter expert-sorted rows into fixed
    (E, C, d) buffers, run ONE batched matmul per projection (tight FLOPs:
    E*C = Tk*cf), gather back.  Overflow rows are dropped (GShard capacity
    semantics); their combine weight contribution is zero."""
    tk, d = xs.shape
    counts = jnp.bincount(sorted_ids, length=num_experts)
    start = jnp.cumsum(counts) - counts
    pos = jnp.arange(tk) - jnp.take(start, sorted_ids)
    keep = pos < capacity
    pos_safe = jnp.where(keep, pos, capacity)       # OOB -> dropped
    buf = jnp.zeros((num_experts, capacity, d), xs.dtype)
    buf = buf.at[sorted_ids, pos_safe].set(xs, mode="drop")
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[cfg.activation]
    h = act(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h.astype(buf.dtype), p["w_down"])
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
    rows = y.at[sorted_ids, pos_safe].get(mode="drop", fill_value=0.0)
    return jnp.where(keep[:, None], rows, 0.0)


def apply_local(p: dict, x: jnp.ndarray, cfg: MoEConfig,
                axis_name: Optional[str] = None):
    """MoE over local tokens x (T, d).  Runs inside shard_map (axis_name =
    TP axis to psum over) or unsharded on one device (axis_name=None).

    Returns (out (T, d), aux_loss, dispatch_ids (T*k,) expert stream in
    issue order — the instrumented profiler's index stream).
    """
    t, d = x.shape
    gates, ids, aux = route(p, x, cfg)           # (T,k)
    flat_ids = ids.reshape(-1)                   # (T*k,)
    order = jnp.argsort(flat_ids)                # local sort by expert
    xrep = jnp.repeat(x, cfg.top_k, axis=0)      # (T*k, d) slot-major
    xs = jnp.take(xrep, order, axis=0)
    sorted_ids = jnp.take(flat_ids, order)
    capacity = max(1, int(flat_ids.shape[0] / cfg.num_experts
                          * cfg.capacity_factor))
    y_sorted = _expert_ffn_grouped(p, xs, sorted_ids, cfg.num_experts,
                                   capacity, cfg, axis_name)
    inv = jnp.argsort(order)
    y = jnp.take(y_sorted, inv, axis=0).reshape(t, cfg.top_k, d)
    out = jnp.einsum("tkd,tk->td", y.astype(jnp.float32),
                     gates).astype(x.dtype)
    if cfg.num_shared_experts:
        from repro.models import mlp
        out = out + mlp.apply(p["shared"], x, cfg.activation)
    return out, aux, flat_ids


def _ep_local(p: dict, x_local: jnp.ndarray, cfg: MoEConfig,
              ep_axis: str, tp_axis: str, data_axes) -> tuple:
    """Whole-expert EP body (runs inside shard_map).

    x_local (T, d) tokens of this data shard; p holds E/D whole experts
    (TP-sharded on the expert hidden over ``tp_axis``).  GShard-style
    fixed-capacity all_to_all dispatch: per-destination-shard buffers of
    ``cap`` rows, overflow dropped (the residual path carries the token).
    The dispatch bincount is the paper's histogram — returned for the
    instrumented profiler.
    """
    if hasattr(jax.lax, "axis_size"):
        d_shards = jax.lax.axis_size(ep_axis)
    else:  # older jax: axis size via an all-reduce of ones
        d_shards = jax.lax.psum(1, ep_axis)
    t, d = x_local.shape
    e_local = cfg.num_experts // d_shards
    gates, ids, aux = route(p, x_local, cfg)            # (T,k)
    flat_ids = ids.reshape(-1)                          # (Tk,)
    tk = flat_ids.shape[0]
    order = jnp.argsort(flat_ids)
    sorted_ids = jnp.take(flat_ids, order)
    xs = jnp.take(jnp.repeat(x_local, cfg.top_k, axis=0), order, axis=0)

    cap = max(1, int(tk / d_shards * cfg.capacity_factor))
    dst = sorted_ids // e_local                         # ascending
    counts_dst = jnp.bincount(dst, length=d_shards)
    start = jnp.cumsum(counts_dst) - counts_dst
    pos_in_dst = jnp.arange(tk) - jnp.take(start, dst)
    keep = pos_in_dst < cap
    pos_safe = jnp.where(keep, pos_in_dst, cap)         # OOB -> dropped

    send_x = jnp.zeros((d_shards, cap, d), xs.dtype)
    send_x = send_x.at[dst, pos_safe].set(xs, mode="drop")
    send_id = jnp.full((d_shards, cap), e_local, jnp.int32)  # invalid
    send_id = send_id.at[dst, pos_safe].set(
        (sorted_ids % e_local).astype(jnp.int32), mode="drop")
    send_slot = jnp.full((d_shards, cap), tk, jnp.int32)     # OOB -> drop
    send_slot = send_slot.at[dst, pos_safe].set(
        order.astype(jnp.int32), mode="drop")

    recv_x = jax.lax.all_to_all(send_x, ep_axis, 0, 0, tiled=False)
    recv_id = jax.lax.all_to_all(send_id, ep_axis, 0, 0, tiled=False)
    rx = recv_x.reshape(d_shards * cap, d)
    rid = recv_id.reshape(-1)

    order2 = jnp.argsort(rid)
    rs = jnp.take(rx, order2, axis=0)
    rids = jnp.take(rid, order2)                        # invalid id=e_local
    cap2 = max(1, int(rx.shape[0] / e_local * cfg.capacity_factor))
    # invalid rows (id == e_local) scatter out-of-range -> dropped
    y = _expert_ffn_grouped(p, rs, rids, e_local, cap2, cfg, tp_axis)
    y = jnp.take(y, jnp.argsort(order2), axis=0)        # unsort locally
    comb_dt = x_local.dtype if cfg.bf16_combine else jnp.float32
    back = jax.lax.all_to_all(
        y.reshape(d_shards, cap, d).astype(comb_dt), ep_axis, 0, 0)

    y_flat = jnp.zeros((tk + 1, d), comb_dt)
    y_flat = y_flat.at[send_slot.reshape(-1)].add(
        back.reshape(-1, d).astype(comb_dt), mode="drop")
    y_tok = y_flat[:tk].reshape(t, cfg.top_k, d)
    out = jnp.einsum("tkd,tk->td", y_tok.astype(jnp.float32),
                     gates).astype(x_local.dtype)
    if cfg.num_shared_experts:
        from repro.models import mlp
        out = out + mlp.apply(p["shared"], x_local, cfg.activation)
    aux = jax.lax.pmean(aux, data_axes)
    aux = jax.lax.pmean(aux, tp_axis)
    return out, aux, flat_ids


def apply_ep(p: dict, x: jnp.ndarray, cfg: MoEConfig, mesh,
             data_axes=("pod", "data"), tp_axis: str = "model",
             ep_axis: str = "data"):
    """Whole-expert EP over `ep_axis` + intra-expert TP over `tp_axis`.

    Expert weights sharded P(ep, None, tp); tokens P(data_axes).
    Experts replicate over pod (pure DP across pods).
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape

    def local_fn(p_local, x_local):
        bl, sl, _ = x_local.shape
        out, aux, disp = _ep_local(p_local, x_local.reshape(bl * sl, d),
                                   cfg, ep_axis, tp_axis, data_axes)
        return out.reshape(bl, sl, d), aux, disp

    pspec = {
        "router": {"w": P()},
        "w_gate": P(ep_axis, None, tp_axis),
        "w_up": P(ep_axis, None, tp_axis),
        "w_down": P(ep_axis, tp_axis, None),
    }
    if cfg.num_shared_experts:
        pspec["shared"] = {"w_gate": P(None, tp_axis),
                           "w_up": P(None, tp_axis),
                           "w_down": P(tp_axis, None)}
    out, aux, disp = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec, P(data_axes)),
        out_specs=(P(data_axes), P(), P(data_axes)),
    )(p, x)
    return out, aux, disp


def apply_sharded(p: dict, x: jnp.ndarray, cfg: MoEConfig, mesh,
                  data_axes=("pod", "data"), tp_axis: str = "model"):
    """shard_map wrapper: x (B, S, d) batch-sharded; experts TP-sharded.

    Used by the big-model train/serve steps; smoke tests use apply_local.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape

    def local_fn(p_local, x_local):
        bl, sl, _ = x_local.shape
        out, aux, disp = apply_local(
            p_local, x_local.reshape(bl * sl, d), cfg, axis_name=tp_axis)
        aux = jax.lax.pmean(aux, data_axes)
        aux = jax.lax.pmean(aux, tp_axis)
        return out.reshape(bl, sl, d), aux, disp

    pspec = {
        "router": {"w": P()},
        "w_gate": P(None, None, tp_axis),
        "w_up": P(None, None, tp_axis),
        "w_down": P(None, tp_axis, None),
    }
    if cfg.num_shared_experts:
        pspec["shared"] = {"w_gate": P(None, tp_axis),
                           "w_up": P(None, tp_axis),
                           "w_down": P(tp_axis, None)}
    out, aux, disp = _shard_map(
        local_fn, mesh=mesh,
        in_specs=(pspec, P(data_axes)),
        out_specs=(P(data_axes), P(), P(data_axes)),
    )(p, x)
    return out, aux, disp
