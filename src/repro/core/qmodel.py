"""Operational single-server queuing model (paper §3).

Implements, verbatim where possible:

  * the sampled total-time table ``T(n, e, c)`` and its linear
    interpolation with the ``T(0, ., .) = 0`` boundary (paper Eqs. 1-2),
  * the mean-service-time-between-completions law ``S = T / n``
    (paper Eq. 3, from Denning & Buzen's operational analysis: in the
    controlled microbenchmark all ``A`` arrivals are queued at once so the
    load is ``n = A``, and job flow balance gives completions ``C = A``),
  * the basic/derived operational quantities of paper Tables 1-2 and the
    utilization estimate ``U = B / T`` with ``B = N * S(n_hat, e, c)``.

The model is deliberately *operational*: it makes no stochastic
assumptions, only uses measured (here: instrumented/modeled) quantities,
and does not attempt to mirror the internal architecture of the unit
(paper §3: a load-dependent single server is sufficient).
"""

from __future__ import annotations

import dataclasses
import io
import json
from typing import Optional, Sequence

import numpy as np

from repro.core import timing

Array = np.ndarray


# ---------------------------------------------------------------------------
# Multilinear interpolation on a regular grid
# ---------------------------------------------------------------------------


def _interp_axis_weights(grid: Array, x: Array) -> tuple[Array, Array, Array]:
    """Return (lo_idx, hi_idx, hi_weight) for 1-D linear interpolation.

    Queries outside the grid clamp to the boundary (the paper's tables
    cover the full feasible range, so clamping only triggers on numerical
    noise or deliberately saturated queries such as e > e_max).
    """
    x = np.clip(x, grid[0], grid[-1])
    hi = np.searchsorted(grid, x, side="left")
    hi = np.clip(hi, 1, len(grid) - 1)
    lo = hi - 1
    span = grid[hi] - grid[lo]
    w = np.where(span > 0, (x - grid[lo]) / np.where(span > 0, span, 1.0), 0.0)
    return lo, hi, w


def trilinear(
    values: Array,
    grids: Sequence[Array],
    query: Sequence[Array],
) -> Array:
    """Multilinear interpolation of ``values`` (shape = grid lens) at query."""
    assert len(grids) == values.ndim == len(query)
    los, his, ws = [], [], []
    for g, q in zip(grids, query):
        lo, hi, w = _interp_axis_weights(np.asarray(g, np.float64), np.asarray(q, np.float64))
        los.append(lo)
        his.append(hi)
        ws.append(w)
    out = 0.0
    ndim = values.ndim
    for corner in range(1 << ndim):
        idx = []
        weight = 1.0
        for d in range(ndim):
            if corner >> d & 1:
                idx.append(his[d])
                weight = weight * ws[d]
            else:
                idx.append(los[d])
                weight = weight * (1.0 - ws[d])
        out = out + weight * values[tuple(idx)]
    return out


class TableInterpolator:
    """Precompiled multilinear interpolation on a regular grid.

    ``trilinear`` re-derives everything per call; this factors the lookup
    into (1) per-axis clamp/searchsorted weights and (2) ONE flat gather
    over all ``2^ndim`` corner values, everything vectorized over the
    query batch.  Numerically it is *bit-identical* to ``trilinear`` —
    same clamping, same corner enumeration order, same weight-product
    order, same accumulation order — but a sweep's thousands of
    ``S(n, e, c)`` lookups become a single fused numpy pass instead of
    thousands of Python calls (see ``profiler.profile_batch``).
    """

    def __init__(self, values: Array, grids: Sequence[Array]) -> None:
        self.values = np.ascontiguousarray(values, np.float64)
        self.grids = [np.ascontiguousarray(g, np.float64) for g in grids]
        if len(self.grids) != self.values.ndim:
            raise ValueError(
                f"need one grid per value axis: {len(self.grids)} grids "
                f"for a {self.values.ndim}-d table")
        for g, size in zip(self.grids, self.values.shape):
            if len(g) != size:
                raise ValueError(
                    f"grid length {len(g)} does not match axis size {size}")
        self._flat = self.values.reshape(-1)
        # element strides of the (C-contiguous) value array, per axis
        self._strides = [
            int(np.prod(self.values.shape[d + 1:], dtype=np.int64))
            for d in range(self.values.ndim)
        ]

    def __call__(self, *query) -> Array:
        """Interpolate at ``query`` (one array per axis, broadcastable)."""
        if len(query) != len(self.grids):
            raise ValueError(f"expected {len(self.grids)} query arrays, "
                             f"got {len(query)}")
        qs = [np.asarray(q, np.float64) for q in query]
        if len(qs) > 1:
            qs = list(np.broadcast_arrays(*qs))
        los, his, ws = [], [], []
        for g, q in zip(self.grids, qs):
            lo, hi, w = _interp_axis_weights(g, q)
            # a single-point axis yields hi == 0, lo == -1: trilinear's
            # tuple indexing wraps -1 to that same single element, but a
            # *flat* index must not go negative — clamp to the identical
            # element explicitly (w == 0 there, so the value is unchanged)
            los.append(np.maximum(lo, 0))
            his.append(hi)
            ws.append(w)
        ndim = len(self.grids)
        shape = np.shape(qs[0])
        ncorners = 1 << ndim
        idx = np.empty((ncorners,) + shape, np.intp)
        for corner in range(ncorners):
            flat = np.zeros(shape, np.intp)
            for d in range(ndim):
                pick = his[d] if corner >> d & 1 else los[d]
                flat += pick * self._strides[d]
            idx[corner] = flat
        vals = self._flat.take(idx)          # one gather for all corners
        out = 0.0
        for corner in range(ncorners):
            weight = 1.0
            for d in range(ndim):
                if corner >> d & 1:
                    weight = weight * ws[d]
                else:
                    weight = weight * (1.0 - ws[d])
            out = out + weight * vals[corner]
        return out


# ---------------------------------------------------------------------------
# Service-time table (paper §3.2, Fig. 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ServiceTimeTable:
    """Sampled ``T(n, e, c)`` with interpolated lookup (paper Eqs. 1-3).

    Sampled on a rectangular grid (n, e, c/n): the paper samples integral
    ``c <= n``, which is a ragged grid; storing the class-mix axis as the
    CAS *fraction* ``c/n`` is an equivalent rectangularization (linear in
    ``c`` at fixed ``n``, per the paper's observed roughly-linear class-mix
    behaviour) that keeps Eq. 2's linear interpolation well-defined
    everywhere.  ``n_grid`` includes 0 with ``T = 0`` (Eq. 1).

    ``popc_T`` is the companion 2-D table ``T_popc(n, e)`` for the
    POPC-class pipeline (Ampere ``ATOMS.POPC.INC`` analogue, paper §2);
    the paper treats POPC kernels as a separate instruction class.
    """

    n_grid: Array           # (Nn,) including 0
    e_grid: Array           # (Ne,)
    cfrac_grid: Array       # (Nc,) in [0, 1]
    T: Array                # (Nn, Ne, Nc) cycles, T[0] == 0
    popc_T: Optional[Array] = None  # (Nn, Ne) cycles
    clock_hz: float = timing.V5E_SCATTER.clock_hz
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        self.n_grid = np.asarray(self.n_grid, np.float64)
        self.e_grid = np.asarray(self.e_grid, np.float64)
        self.cfrac_grid = np.asarray(self.cfrac_grid, np.float64)
        self.T = np.asarray(self.T, np.float64)
        if self.n_grid[0] != 0.0:
            raise ValueError("n_grid must start at 0 (paper Eq. 1 boundary)")
        if not np.allclose(self.T[0], 0.0):
            raise ValueError("T(0, ., .) must be 0 (paper Eq. 1)")

    # -- lookups ----------------------------------------------------------

    def total_time(self, n, e, c) -> Array:
        """Interpolated T(n, e, c) in cycles (paper Eq. 2)."""
        n = np.asarray(n, np.float64)
        e = np.asarray(e, np.float64)
        c = np.asarray(c, np.float64)
        cfrac = np.where(n > 0, c / np.where(n > 0, n, 1.0), 0.0)
        return trilinear(self.T, (self.n_grid, self.e_grid, self.cfrac_grid),
                         (n, e, cfrac))

    def service_time(self, n, e, c) -> Array:
        """S(n, e, c) = T(n, e, c) / n in cycles (paper Eq. 3); S := 0 at n=0."""
        n = np.asarray(n, np.float64)
        t = self.total_time(n, e, c)
        return np.where(n > 0, t / np.where(n > 0, n, 1.0), 0.0)

    def popc_service_time(self, n, e) -> Array:
        if self.popc_T is None:
            raise ValueError("table has no POPC-class samples")
        n = np.asarray(n, np.float64)
        t = trilinear(self.popc_T, (self.n_grid, self.e_grid),
                      (n, np.asarray(e, np.float64)))
        return np.where(n > 0, t / np.where(n > 0, n, 1.0), 0.0)

    def service_seconds(self, n, e, c) -> Array:
        return self.service_time(n, e, c) / self.clock_hz

    # -- precompiled batch lookups ----------------------------------------

    def interpolator(self) -> TableInterpolator:
        """Precompiled ``T(n, e, cfrac)`` interpolator, built once per table.

        The table is immutable in practice (built by Tool 1, then only
        read), so the compiled axis data is cached on first use.
        """
        interp = getattr(self, "_interp", None)
        if interp is None:
            interp = TableInterpolator(
                self.T, (self.n_grid, self.e_grid, self.cfrac_grid))
            self._interp = interp
        return interp

    def popc_interpolator(self) -> TableInterpolator:
        """Precompiled ``T_popc(n, e)`` interpolator (2-D companion table)."""
        if self.popc_T is None:
            raise ValueError("table has no POPC-class samples")
        interp = getattr(self, "_popc_interp", None)
        if interp is None:
            interp = TableInterpolator(self.popc_T,
                                       (self.n_grid, self.e_grid))
            self._popc_interp = interp
        return interp

    def service_time_batch(self, n, e, c) -> Array:
        """Vectorized ``service_time`` over whole query arrays.

        Bit-identical to calling ``service_time`` elementwise (same cfrac
        rectangularization, same clamping, same corner arithmetic via
        ``TableInterpolator``), but one fused pass — the batch profiler's
        hot lookup.
        """
        n = np.asarray(n, np.float64)
        e = np.asarray(e, np.float64)
        c = np.asarray(c, np.float64)
        cfrac = np.where(n > 0, c / np.where(n > 0, n, 1.0), 0.0)
        t = self.interpolator()(n, e, cfrac)
        return np.where(n > 0, t / np.where(n > 0, n, 1.0), 0.0)

    def popc_service_time_batch(self, n, e) -> Array:
        """Vectorized ``popc_service_time`` (see ``service_time_batch``)."""
        n = np.asarray(n, np.float64)
        t = self.popc_interpolator()(n, np.asarray(e, np.float64))
        return np.where(n > 0, t / np.where(n > 0, n, 1.0), 0.0)

    # -- (de)serialization -------------------------------------------------

    def save(self, path: str) -> None:
        # compressed since PR 4 (the grid is highly regular, ~6x smaller);
        # ``load`` reads both this and the uncompressed .npz artifacts
        # written by earlier revisions (np.load is format-agnostic)
        np.savez_compressed(
            path,
            n_grid=self.n_grid,
            e_grid=self.e_grid,
            cfrac_grid=self.cfrac_grid,
            T=self.T,
            popc_T=self.popc_T if self.popc_T is not None else np.zeros(0),
            clock_hz=np.float64(self.clock_hz),
            meta=np.str_(json.dumps(self.meta, default=float)),
        )

    @classmethod
    def load(cls, path: str) -> "ServiceTimeTable":
        z = np.load(path)
        popc = z["popc_T"]
        meta = json.loads(str(z["meta"])) if "meta" in z.files else {}
        return cls(
            n_grid=z["n_grid"],
            e_grid=z["e_grid"],
            cfrac_grid=z["cfrac_grid"],
            T=z["T"],
            popc_T=popc if popc.size else None,
            clock_hz=float(z["clock_hz"]),
            meta=meta,
        )


# ---------------------------------------------------------------------------
# Basic operational quantities (paper Table 1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class BasicCounters:
    """Per-core basic quantities (paper Table 1, superscript (i)).

    On GPU these come from NVProf/NCU; here they come from in-kernel Pallas
    instrumentation and the compiled artifact (see core.counters for the
    mapping table).  ``n_true`` is our extension: the paper notes "No GPU
    performance counter directly measures n and we recommend GPU
    manufacturers add one" — Pallas instrumentation lets us emit it.
    """

    O: float                 # total serialization transactions (global)
    N_f: float               # FAO-class wave jobs on this core
    N_c: float               # CAS-class wave jobs on this core
    T_cycles: float          # active cycles on this core
    occupancy: float         # achieved fraction of max in-flight jobs [0,1]
    N_p: float = 0.0         # POPC-class wave jobs on this core
    n_true: Optional[float] = None  # instrumented time-avg queue length
    core_id: int = 0


@dataclasses.dataclass
class CoreUtilization:
    """Derived quantities (paper Table 2) + utilization for one core."""

    core_id: int
    N: float          # total jobs
    n_hat: float      # average parallelism estimate
    e: float          # average serialization degree per job
    c: float          # average queued CAS-class jobs
    S_cycles: float   # interpolated service time
    B_cycles: float   # busy time  B = N * S
    T_cycles: float   # measurement window
    U: float          # utilization B / T


def derive_core_utilization(
    counters: Sequence[BasicCounters],
    table: ServiceTimeTable,
    n_max: Optional[float] = None,
    use_true_n: bool = False,
) -> list[CoreUtilization]:
    """Paper Table 2, applied per core.

    ``e`` is computed globally (``e = O / sum_i N^(i)``) because the paper's
    O-counter analogue aggregates across cores; per-core quantities use the
    per-core counters.  With ``use_true_n`` the instrumented queue length
    replaces the occupancy-based estimate ``n_hat = o * n_max`` — the paper
    identifies the occupancy estimate as the cause of >100% utilization
    readings.  ``n_max`` defaults to the table's own load axis upper bound
    (the table is built once per device, so its grid *is* the device's
    maximum in-flight job count).
    """
    if n_max is None:
        n_max = float(table.n_grid[-1])
    total_jobs = sum(cc.N_f + cc.N_c + cc.N_p for cc in counters)
    e_global = (sum(cc.O for cc in counters) / total_jobs) if total_jobs else 1.0
    out = []
    for cc in counters:
        n_jobs = cc.N_f + cc.N_c + cc.N_p
        if use_true_n and cc.n_true is not None:
            n_hat = cc.n_true
        else:
            n_hat = cc.occupancy * n_max
        n_faocas = cc.N_f + cc.N_c
        c_avg = n_hat * (cc.N_c / n_faocas) if n_faocas > 0 else 0.0
        s = float(table.service_time(n_hat, e_global, c_avg)) if n_faocas else 0.0
        busy = n_faocas * s
        if cc.N_p > 0 and table.popc_T is not None:
            s_p = float(table.popc_service_time(n_hat, e_global))
            busy += cc.N_p * s_p
        u = busy / cc.T_cycles if cc.T_cycles > 0 else 0.0
        out.append(CoreUtilization(
            core_id=cc.core_id, N=n_jobs, n_hat=n_hat, e=e_global, c=c_avg,
            S_cycles=s, B_cycles=busy, T_cycles=cc.T_cycles, U=u,
        ))
    return out


# ---------------------------------------------------------------------------
# Operational laws (Denning & Buzen 1978) — used by property tests and the
# straggler detector; kept standalone so other servers (MXU/HBM/ICI) reuse
# them.
# ---------------------------------------------------------------------------


def throughput(completions: float, window: float) -> float:
    """X = C / T."""
    return completions / window if window > 0 else 0.0


def utilization_law(x: float, s: float) -> float:
    """U = X * S."""
    return x * s


def littles_law_queue(x: float, response_time: float) -> float:
    """n = X * R."""
    return x * response_time


def flow_balanced(arrivals: float, completions: float, tol: float = 0.0) -> bool:
    """Job flow balance |A - C| <= tol (paper §3.2 requires C = A)."""
    return abs(arrivals - completions) <= tol


# ---------------------------------------------------------------------------
# Report rendering
# ---------------------------------------------------------------------------


def render_utilization_report(
    rows: Sequence[CoreUtilization],
    title: str = "shared-scatter unit utilization",
) -> str:
    buf = io.StringIO()
    buf.write(f"== {title} ==\n")
    buf.write(f"{'core':>5} {'N':>12} {'n_hat':>8} {'e':>7} {'c':>8} "
              f"{'S(cyc)':>9} {'B(cyc)':>12} {'T(cyc)':>12} {'U':>7}\n")
    for r in rows:
        buf.write(f"{r.core_id:>5} {r.N:>12.0f} {r.n_hat:>8.2f} {r.e:>7.2f} "
                  f"{r.c:>8.2f} {r.S_cycles:>9.2f} {r.B_cycles:>12.0f} "
                  f"{r.T_cycles:>12.0f} {r.U:>7.2%}\n")
    if rows:
        mean_u = float(np.mean([r.U for r in rows]))
        buf.write(f"mean utilization: {mean_u:.2%}\n")
    return buf.getvalue()
