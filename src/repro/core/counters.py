"""Basic operational quantities (paper Table 1) from kernel instrumentation.

The GPU paper reads NVProf/NCU counters; our counters come from the
instrumented Pallas kernels, which emit a *wave trace*: one record per
scatter wave job with its serialization degree, job class, and the core it
was scheduled on.  This module aggregates a trace into per-core
``BasicCounters``:

    O      <- sum of per-wave serialization degrees (total transactions;
              the analogue of smsp__l1tex_mem_shared_op_atom.sum, which
              counts bank-conflict replays)
    N_f/N_c/N_p <- per-class wave job counts per core
    T      <- modeled active cycles per core (from the kernel-time model
              in core.profiler, which includes the non-scatter work)
    o      <- achieved occupancy: avg in-flight waves / n_max

It also reproduces the paper's estimation gap: ``n_hat = o * n_max``
(their only option) versus the instrumented true queue length ``n_true``
(our addition; the paper explicitly recommends hardware add this counter).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import timing
from repro.core.qmodel import BasicCounters

LANES = 1024        # 8 x 128 VPU lane group = one wave
COMMIT_GROUP = 32   # lanes that commit to VMEM together; conflicts
                    # serialize within a group (GPU warp/bank analogue)


def wave_degree(indices: np.ndarray, lanes: int = LANES,
                group: int = COMMIT_GROUP) -> float:
    """Serialization degree of one wave of scatter indices.

    The VPU commit path retires ``group`` lanes per pass; duplicate
    destination indices within a commit group must serialize (the analogue
    of same-address shared-memory atomic replays in a 32-thread warp).
    The wave's degree is the mean over commit groups of the max duplicate
    multiplicity — exactly the quantity the paper's ``O`` counter
    (replay count) divided by ``N`` (warp-instructions) measures:
    solid-color histograms give 32, uniform-random ~2-3, conflict-free 1.
    """
    idx = np.asarray(indices).reshape(-1)
    if idx.size == 0:
        return 1.0
    pad = (-idx.size) % group
    if pad:
        # pad with unique sentinels so padding never adds conflicts
        sentinel = idx.max(initial=0) + 1 + np.arange(pad)
        idx = np.concatenate([idx, sentinel])
    g = idx.reshape(-1, group)
    eq = g[:, :, None] == g[:, None, :]          # (G, group, group)
    mult = eq.sum(axis=2)                        # duplicate multiplicity
    return float(np.mean(mult.max(axis=1)))


def geometry_occupancy(num_waves: int, waves_per_tile: int,
                       pipeline_depth: int, n_max: int) -> float:
    """Achieved concurrency fraction from launch geometry.

    In-flight jobs = waves per tile x pipeline depth, capped by n_max and
    by the total work available.
    """
    inflight = min(waves_per_tile * pipeline_depth, n_max, max(num_waves, 1))
    return inflight / n_max


def geometry_true_n(num_waves: int, waves_per_tile: int,
                    pipeline_depth: int, n_max: int) -> float:
    """Instrumented time-average queue length from launch geometry.

    All waves of a tile are issued together; with double buffering the
    queue holds up to waves_per_tile * depth jobs while the tail drains to
    0.  The time-average over a long launch sits near the issued
    concurrency, degraded by the drain fraction.
    """
    if num_waves == 0:
        return 0.0
    burst = min(waves_per_tile * pipeline_depth, n_max)
    full_bursts = num_waves // max(burst, 1)
    tail = num_waves - full_bursts * burst
    # time-weighted average of a sawtooth: mean of (burst .. 1)
    avg_full = (burst + 1) / 2.0
    avg_tail = (tail + 1) / 2.0 if tail else 0.0
    w_full = full_bursts * burst
    w_tail = tail
    denom = w_full + w_tail
    return (avg_full * w_full + avg_tail * w_tail) / denom if denom else 0.0


@dataclasses.dataclass
class WaveTrace:
    """Per-wave instrumentation records for one kernel launch."""

    degree: np.ndarray          # (W,) serialization degree per wave (>= 1)
    job_class: np.ndarray       # (W,) timing.FAO / timing.CAS / timing.POPC
    core: np.ndarray            # (W,) core the wave's tile was scheduled on
    lanes_active: np.ndarray    # (W,) active lanes (<= LANES)
    waves_per_tile: int = 1     # launch geometry: waves issued per grid tile
    pipeline_depth: int = 2     # Pallas double buffering

    def __post_init__(self) -> None:
        self.degree = np.asarray(self.degree, np.float64)
        self.job_class = np.asarray(self.job_class, np.int32)
        self.core = np.asarray(self.core, np.int32)
        self.lanes_active = np.asarray(self.lanes_active, np.float64)

    @property
    def num_waves(self) -> int:
        return int(self.degree.shape[0])

    def with_geometry(self, waves_per_tile: Optional[int] = None,
                      pipeline_depth: Optional[int] = None) -> "WaveTrace":
        """Copy of this trace with a different launch geometry.

        The per-wave records are shared (they are measurement, not
        geometry); only the occupancy-defining launch parameters change.
        Prefer this over mutating ``waves_per_tile`` in place.
        """
        return dataclasses.replace(
            self,
            waves_per_tile=self.waves_per_tile if waves_per_tile is None
            else int(waves_per_tile),
            pipeline_depth=self.pipeline_depth if pipeline_depth is None
            else int(pipeline_depth),
        )

    def occupancy(self, n_max: int) -> float:
        """Achieved concurrency fraction (see ``geometry_occupancy``)."""
        return geometry_occupancy(self.num_waves, self.waves_per_tile,
                                  self.pipeline_depth, n_max)

    def true_n(self, n_max: int) -> float:
        """Instrumented time-avg queue length (see ``geometry_true_n``)."""
        return geometry_true_n(self.num_waves, self.waves_per_tile,
                               self.pipeline_depth, n_max)


def concat_traces(traces: Sequence[WaveTrace]) -> WaveTrace:
    return WaveTrace(
        degree=np.concatenate([t.degree for t in traces]),
        job_class=np.concatenate([t.job_class for t in traces]),
        core=np.concatenate([t.core for t in traces]),
        lanes_active=np.concatenate([t.lanes_active for t in traces]),
        waves_per_tile=traces[0].waves_per_tile,
        pipeline_depth=traces[0].pipeline_depth,
    )  # geometry from the first trace: concat is per-launch, not cross-launch


def _degrees_full_waves(idx: np.ndarray, group: int,
                        chunk: int = 2048) -> np.ndarray:
    """``wave_degree`` for a (..., wave) block of *complete* waves at once.

    The trailing axis is the wave; any leading axes — a single launch's
    (W,) wave list, or a whole sweep's (P, W) points-by-waves grid — are
    flattened, processed in chunks, and restored on the way out.
    Bit-identical to calling ``wave_degree`` per row: the maximum
    multiplicity within a commit group equals the longest run of equal
    values once the group is sorted, so the O(group^2) pairwise-equality
    tensor collapses to a sort plus O(group) run-length passes — exact
    integer counts either way, fed through the same int64 ``mean`` over
    the same group axis (the per-row result never depends on which chunk
    a row lands in).  The big ops release the GIL — which is what lets
    ``Session.sweep``'s thread pool actually overlap points — and the
    chunking bounds the sorted copy's working set.
    """
    idx = np.asarray(idx)
    lead = idx.shape[:-1]
    wave = idx.shape[-1]
    flat = idx.reshape(-1, wave)
    W = flat.shape[0]
    out = np.empty(W, np.float64)
    G = wave // group
    ar = np.arange(group, dtype=np.int64)
    for st in range(0, W, chunk):
        g = flat[st:st + chunk].reshape(-1, G, group)
        s = np.sort(g, axis=-1)
        start = np.empty(s.shape, bool)     # True where a new run begins
        start[..., 0] = True
        start[..., 1:] = s[..., 1:] != s[..., :-1]
        first = np.maximum.accumulate(np.where(start, ar, 0), axis=-1)
        mult = (ar - first).max(axis=-1) + 1    # (n, G) max multiplicity
        out[st:st + chunk] = mult.mean(axis=1)
    return out.reshape(lead)


def trace_from_indices(
    indices: np.ndarray,
    num_bins: int,
    *,
    num_cores: int = 1,
    wave: int = LANES,
    job_class: int = timing.FAO,
    waves_per_tile: int = 1,
    pipeline_depth: int = 2,
) -> WaveTrace:
    """Build the wave trace a kernel's instrumentation would emit.

    ``indices`` is the flat stream of scatter destinations; waves are
    consecutive ``wave``-sized groups; tiles round-robin across cores the
    way a Pallas grid schedules across TensorCores.  The per-wave degree is
    ceil(active / distinct): a wave whose lanes all hit one bin serializes
    fully; all-distinct commits in one pass.  This mirrors what
    ``kernels/instrumentation.py`` computes inside the kernel.
    """
    idx = np.asarray(indices).reshape(-1)
    n = idx.shape[0]
    num_waves = max(1, -(-n // wave))
    degree = np.empty(num_waves, np.float64)
    active = np.empty(num_waves, np.float64)
    # complete waves go through the vectorized bulk path; at most one
    # trailing partial wave (sentinel-padded) keeps the scalar one
    full = n // wave if wave % COMMIT_GROUP == 0 else 0
    if full:
        degree[:full] = _degrees_full_waves(
            idx[:full * wave].reshape(full, wave), COMMIT_GROUP)
        active[:full] = wave
    for w in range(full, num_waves):
        part = idx[w * wave:(w + 1) * wave]
        active[w] = part.shape[0]
        degree[w] = wave_degree(part)
    tiles = np.arange(num_waves) // max(waves_per_tile, 1)
    cores = (tiles % num_cores).astype(np.int32)
    return WaveTrace(
        degree=degree,
        job_class=np.full(num_waves, job_class, np.int32),
        core=cores,
        lanes_active=active,
        waves_per_tile=waves_per_tile,
        pipeline_depth=pipeline_depth,
    )


def _per_point(value, num_points: int, name: str) -> list:
    """Broadcast a scalar parameter to P points (sequences pass through)."""
    if isinstance(value, (list, tuple, np.ndarray)):
        out = list(value)
        if len(out) != num_points:
            raise ValueError(f"{name} has {len(out)} entries for "
                             f"{num_points} index streams")
        return out
    return [value] * num_points


def traces_from_index_batch(
    index_streams: Sequence[np.ndarray],
    *,
    num_cores=1,
    wave: int = LANES,
    job_class=timing.FAO,
    waves_per_tile=1,
    pipeline_depth=2,
) -> list[WaveTrace]:
    """Batch ``trace_from_indices``: P index streams -> P wave traces.

    The whole grid's complete waves go through ``_degrees_full_waves`` as
    one stacked (P', W, wave) tensor per stream-length group, instead of
    one call per point — this is what makes a cold sweep's collection
    cost a handful of large numpy ops.  Each per-point parameter accepts
    either a scalar (shared by all points) or a length-P sequence.

    Bit-for-bit equal to calling ``trace_from_indices`` per stream: the
    degree math is row-independent (stacking only adds a leading axis the
    kernel never mixes across), trailing partial waves keep the scalar
    sentinel-padded path, and the tile/core round-robin is computed per
    point exactly as before.
    """
    streams = [np.asarray(s).reshape(-1) for s in index_streams]
    P = len(streams)
    cores_l = _per_point(num_cores, P, "num_cores")
    class_l = _per_point(job_class, P, "job_class")
    wpt_l = _per_point(waves_per_tile, P, "waves_per_tile")
    depth_l = _per_point(pipeline_depth, P, "pipeline_depth")
    degrees: list = [None] * P
    actives: list = [None] * P
    by_length: dict = {}
    for i, s in enumerate(streams):
        by_length.setdefault(s.shape[0], []).append(i)
    for n, members in by_length.items():
        num_waves = max(1, -(-n // wave))
        full = n // wave if wave % COMMIT_GROUP == 0 else 0
        deg = np.empty((len(members), num_waves), np.float64)
        act = np.empty((len(members), num_waves), np.float64)
        if full:
            stacked = np.stack(
                [streams[i][:full * wave].reshape(full, wave)
                 for i in members])
            deg[:, :full] = _degrees_full_waves(stacked, COMMIT_GROUP)
            act[:, :full] = wave
        for row, i in enumerate(members):
            s = streams[i]
            for w in range(full, num_waves):
                part = s[w * wave:(w + 1) * wave]
                act[row, w] = part.shape[0]
                deg[row, w] = wave_degree(part)
            degrees[i] = deg[row].copy()
            actives[i] = act[row].copy()
    out = []
    for i in range(P):
        num_waves = degrees[i].shape[0]
        tiles = np.arange(num_waves) // max(wpt_l[i], 1)
        out.append(WaveTrace(
            degree=degrees[i],
            job_class=np.full(num_waves, class_l[i], np.int32),
            core=(tiles % cores_l[i]).astype(np.int32),
            lanes_active=actives[i],
            waves_per_tile=wpt_l[i],
            pipeline_depth=depth_l[i],
        ))
    return out


# ---------------------------------------------------------------------------
# CounterSet: the uniform counter bundle every acquisition backend returns
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CounterSet:
    """Uniform paper-Table-1 counter bundle, independent of its source.

    Every ``repro.analysis.providers`` backend — synthetic trace,
    instrumented Pallas kernel, HLO cost analysis, microbenchmark timing —
    returns one of these, and ``core.profiler.profile_counters`` consumes
    it.  The scatter-unit counters are per-core arrays (length
    ``num_cores``); a source with no scatter visibility (HLO) leaves them
    zero and only fills the roofline side (``bytes_read``/``flops``/
    ``ici_bytes``).  ``wall_time_s`` is filled when the source actually
    timed something (microbench path); ``None`` means modeled-only.
    """

    label: str
    source: str = "trace"
    num_cores: int = 1
    # scatter-unit counters, one entry per core ((num_cores,) arrays):
    O: np.ndarray = None            # serialization transactions per core
    N_f: np.ndarray = None          # FAO-class wave jobs per core
    N_c: np.ndarray = None          # CAS-class wave jobs per core
    N_p: np.ndarray = None          # POPC-class wave jobs per core
    lanes_active: float = float(LANES)  # mean active lanes per wave
    # launch geometry (defines the occupancy estimate n_hat):
    num_waves: int = 0
    waves_per_tile: int = 1
    pipeline_depth: int = 2
    # roofline-side counters:
    bytes_read: float = 0.0
    flops: float = 0.0
    ici_bytes: float = 0.0          # per-link collective wire traffic
    overhead_cycles: float = 500.0
    wall_time_s: Optional[float] = None
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        for name in ("O", "N_f", "N_c", "N_p"):
            v = getattr(self, name)
            if v is None:
                v = np.zeros(self.num_cores)
            setattr(self, name, np.asarray(v, np.float64))

    # -- derived (paper Table 2 inputs) -----------------------------------

    @property
    def N(self) -> np.ndarray:
        """Total wave jobs per core."""
        return self.N_f + self.N_c + self.N_p

    @property
    def total_jobs(self) -> float:
        return float(np.sum(self.N))

    @property
    def total_O(self) -> float:
        return float(np.sum(self.O))

    @property
    def e(self) -> float:
        """Global average serialization degree e = O / N (paper Table 2)."""
        n = self.total_jobs
        return self.total_O / n if n else 1.0

    def occupancy(self, n_max: int) -> float:
        return geometry_occupancy(self.num_waves, self.waves_per_tile,
                                  self.pipeline_depth, n_max)

    def true_n(self, n_max: int) -> float:
        return geometry_true_n(self.num_waves, self.waves_per_tile,
                               self.pipeline_depth, n_max)

    # -- construction / conversion ----------------------------------------

    @classmethod
    def from_trace(cls, trace: "WaveTrace", *, label: str = "",
                   num_cores: int = 1, bytes_read: float = 0.0,
                   flops: float = 0.0, overhead_cycles: float = 500.0,
                   source: str = "trace", wall_time_s: Optional[float] = None,
                   meta: Optional[dict] = None) -> "CounterSet":
        """Aggregate a wave trace into the per-core counter bundle."""
        O = np.zeros(num_cores)
        n_f = np.zeros(num_cores)
        n_c = np.zeros(num_cores)
        n_p = np.zeros(num_cores)
        for core in range(num_cores):
            sel = trace.core == core
            O[core] = float(np.sum(trace.degree[sel]))
            cls_sel = trace.job_class[sel]
            n_f[core] = float(np.sum(cls_sel == timing.FAO))
            n_c[core] = float(np.sum(cls_sel == timing.CAS))
            n_p[core] = float(np.sum(cls_sel == timing.POPC))
        lanes = (float(np.mean(trace.lanes_active))
                 if trace.num_waves else float(LANES))
        return cls(
            label=label, source=source, num_cores=num_cores,
            O=O, N_f=n_f, N_c=n_c, N_p=n_p, lanes_active=lanes,
            num_waves=trace.num_waves, waves_per_tile=trace.waves_per_tile,
            pipeline_depth=trace.pipeline_depth,
            bytes_read=bytes_read, flops=flops,
            overhead_cycles=overhead_cycles, wall_time_s=wall_time_s,
            meta=dict(meta or {}),
        )

    def to_basic_counters(self, T_cycles_per_core: np.ndarray,
                          n_max: int) -> list[BasicCounters]:
        """Per-core ``BasicCounters`` against a given measurement window."""
        occ = self.occupancy(n_max)
        n_true = self.true_n(n_max)
        return [
            BasicCounters(
                O=float(self.O[core]), N_f=float(self.N_f[core]),
                N_c=float(self.N_c[core]), N_p=float(self.N_p[core]),
                T_cycles=float(T_cycles_per_core[core]),
                occupancy=occ, n_true=n_true, core_id=core)
            for core in range(self.num_cores)
        ]


def bitwise_equal(a: CounterSet, b: CounterSet,
                  ignore: Sequence[str] = ()) -> bool:
    """Exact field-by-field equality of two counter bundles.

    Arrays must match in dtype, shape, and every bit; floats compare with
    ``==`` (no tolerance).  This is the acceptance check for the batch
    collection path: ``collect_batch(specs).row(i)`` must pass against
    ``collect(specs[i])`` for every provider.  ``ignore`` names fields to
    skip — callers comparing providers that *measure* (microbench) pass
    ``("wall_time_s", "meta")``, since two wall-clock readings never
    agree bit for bit even on the scalar path.
    """
    for field in dataclasses.fields(CounterSet):
        if field.name in ignore:
            continue
        va = getattr(a, field.name)
        vb = getattr(b, field.name)
        if isinstance(va, np.ndarray) or isinstance(vb, np.ndarray):
            if not (isinstance(va, np.ndarray) and isinstance(vb, np.ndarray)):
                return False
            if va.dtype != vb.dtype or va.shape != vb.shape:
                return False
            if not np.array_equal(va, vb):
                return False
        elif va != vb:
            return False
    return True


def countersets_from_traces(
    traces: Sequence["WaveTrace"],
    *,
    labels: Sequence[str],
    num_cores=1,
    bytes_read=0.0,
    flops=0.0,
    overhead_cycles=500.0,
    source: str = "trace",
) -> list["CounterSet"]:
    """Batch ``CounterSet.from_trace``: P wave traces -> P counter bundles.

    Traces sharing a core-assignment pattern are aggregated as stacked
    (P', W) columns — one masked row-sum per core for the whole group
    instead of per-trace numpy calls, which is where a large sweep's
    aggregation time actually goes.  Bit-identical to per-trace
    ``from_trace``: rows with the same core pattern select the same wave
    columns, and a row of the stacked masked sum / mean reduces the same
    contiguous values in the same order as the scalar call.  Per-trace
    parameters accept a scalar or a length-P sequence, as in
    ``traces_from_index_batch``.
    """
    traces = list(traces)
    P = len(traces)
    labels = list(labels)
    if len(labels) != P:
        raise ValueError(f"{len(labels)} labels for {P} traces")
    cores_l = _per_point(num_cores, P, "num_cores")
    bytes_l = _per_point(bytes_read, P, "bytes_read")
    flops_l = _per_point(flops, P, "flops")
    ovh_l = _per_point(overhead_cycles, P, "overhead_cycles")
    out: list = [None] * P
    groups: dict = {}
    for i, tr in enumerate(traces):
        if tr.num_waves == 0:       # degenerate: keep the scalar reference
            out[i] = CounterSet.from_trace(
                traces[i], label=labels[i], num_cores=cores_l[i],
                bytes_read=bytes_l[i], flops=flops_l[i],
                overhead_cycles=ovh_l[i], source=source)
            continue
        key = (tr.num_waves, cores_l[i], tr.core.tobytes())
        groups.setdefault(key, []).append(i)
    for (num_waves, C, _), members in groups.items():
        deg = np.stack([traces[i].degree for i in members])         # (P', W)
        cls = np.stack([traces[i].job_class for i in members])
        lanes = np.stack([traces[i].lanes_active for i in members])
        core_pattern = traces[members[0]].core
        O = np.zeros((len(members), C))
        n_f = np.zeros((len(members), C))
        n_c = np.zeros((len(members), C))
        n_p = np.zeros((len(members), C))
        for c in range(C):
            sel = core_pattern == c
            O[:, c] = np.sum(deg[:, sel], axis=1)
            cls_sel = cls[:, sel]
            n_f[:, c] = np.sum(cls_sel == timing.FAO, axis=1)
            n_c[:, c] = np.sum(cls_sel == timing.CAS, axis=1)
            n_p[:, c] = np.sum(cls_sel == timing.POPC, axis=1)
        lanes_mean = np.mean(lanes, axis=1)
        for row, i in enumerate(members):
            tr = traces[i]
            out[i] = CounterSet(
                label=labels[i], source=source, num_cores=C,
                O=O[row].copy(), N_f=n_f[row].copy(),
                N_c=n_c[row].copy(), N_p=n_p[row].copy(),
                lanes_active=float(lanes_mean[row]),
                num_waves=tr.num_waves, waves_per_tile=tr.waves_per_tile,
                pipeline_depth=tr.pipeline_depth,
                bytes_read=bytes_l[i], flops=flops_l[i],
                overhead_cycles=ovh_l[i],
            )
    return out


# ---------------------------------------------------------------------------
# CounterFrame: a columnar (struct-of-arrays) stack of CounterSets
# ---------------------------------------------------------------------------


def _occupancy_batch(num_waves: np.ndarray, waves_per_tile: np.ndarray,
                     pipeline_depth: np.ndarray, n_max: int) -> np.ndarray:
    """Vectorized ``geometry_occupancy`` (identical min-chain, per point)."""
    inflight = np.minimum(np.minimum(waves_per_tile * pipeline_depth, n_max),
                          np.maximum(num_waves, 1))
    return inflight / float(n_max)


def _true_n_batch(num_waves: np.ndarray, waves_per_tile: np.ndarray,
                  pipeline_depth: np.ndarray, n_max: int) -> np.ndarray:
    """Vectorized ``geometry_true_n`` (same sawtooth algebra, per point)."""
    burst = np.minimum(waves_per_tile * pipeline_depth, n_max)
    safe_burst = np.maximum(burst, 1)
    full_bursts = num_waves // safe_burst
    tail = num_waves - full_bursts * burst
    avg_full = (burst + 1) / 2.0
    avg_tail = np.where(tail > 0, (tail + 1) / 2.0, 0.0)
    w_full = full_bursts * burst
    denom = w_full + tail
    num = avg_full * w_full + avg_tail * tail
    return np.where(denom > 0, num / np.where(denom > 0, denom, 1), 0.0)


def _sequential_row_sum(arr: np.ndarray) -> np.ndarray:
    """Left-to-right row sums of a (P, C) array.

    Matches the accumulation order of a Python ``sum`` over per-core
    scalars (the scalar model path), which numpy's pairwise ``np.sum``
    does not guarantee — keeping the batch profiler bit-identical to the
    per-point reference.  C is the core count (<= a few dozen), so the
    Python loop is over columns only.
    """
    out = np.zeros(arr.shape[0], np.float64)
    for col in range(arr.shape[1]):
        out = out + arr[:, col]
    return out


@dataclasses.dataclass
class CounterFrame:
    """Struct-of-arrays stack of ``CounterSet``s: shape = points x cores.

    The batch-profiling engine's input: where a ``CounterSet`` holds one
    launch's per-core counters, a ``CounterFrame`` holds a whole sweep's
    as (P, C) columns, so the §3 queueing model evaluates in whole-array
    numpy ops (``profiler.profile_batch``) instead of a per-point Python
    loop.  The stack is rectangular — every row must share ``num_cores``
    (``Session`` groups heterogeneous sweeps before framing).
    """

    labels: list                    # (P,) point labels
    sources: list                   # (P,) provider names
    num_cores: int                  # C, uniform across rows
    O: np.ndarray                   # (P, C) serialization transactions
    N_f: np.ndarray                 # (P, C) FAO-class wave jobs
    N_c: np.ndarray                 # (P, C) CAS-class wave jobs
    N_p: np.ndarray                 # (P, C) POPC-class wave jobs
    lanes_active: np.ndarray        # (P,) mean active lanes per wave
    num_waves: np.ndarray           # (P,) launch geometry
    waves_per_tile: np.ndarray      # (P,)
    pipeline_depth: np.ndarray      # (P,)
    bytes_read: np.ndarray          # (P,) roofline side
    flops: np.ndarray               # (P,)
    ici_bytes: np.ndarray           # (P,)
    overhead_cycles: np.ndarray     # (P,)
    wall_time_s: list               # (P,) Optional[float] per point
    meta: list                      # (P,) per-point meta dicts

    @classmethod
    def from_sets(cls, csets: Sequence["CounterSet"]) -> "CounterFrame":
        """Stack CounterSets column-wise; rejects ragged core counts."""
        csets = list(csets)
        if not csets:
            raise ValueError("CounterFrame needs at least one CounterSet")
        cores = {cs.num_cores for cs in csets}
        if len(cores) != 1:
            raise ValueError(
                f"CounterFrame rows must share num_cores, got {sorted(cores)}"
                f" — group the sweep by core count first")
        return cls(
            labels=[cs.label for cs in csets],
            sources=[cs.source for cs in csets],
            num_cores=csets[0].num_cores,
            O=np.stack([cs.O for cs in csets]),
            N_f=np.stack([cs.N_f for cs in csets]),
            N_c=np.stack([cs.N_c for cs in csets]),
            N_p=np.stack([cs.N_p for cs in csets]),
            lanes_active=np.array([cs.lanes_active for cs in csets]),
            num_waves=np.array([cs.num_waves for cs in csets], np.int64),
            waves_per_tile=np.array([cs.waves_per_tile for cs in csets],
                                    np.int64),
            pipeline_depth=np.array([cs.pipeline_depth for cs in csets],
                                    np.int64),
            bytes_read=np.array([cs.bytes_read for cs in csets], np.float64),
            flops=np.array([cs.flops for cs in csets], np.float64),
            ici_bytes=np.array([cs.ici_bytes for cs in csets], np.float64),
            overhead_cycles=np.array([cs.overhead_cycles for cs in csets],
                                     np.float64),
            wall_time_s=[cs.wall_time_s for cs in csets],
            meta=[cs.meta for cs in csets],
        )

    def __len__(self) -> int:
        return len(self.labels)

    @property
    def num_points(self) -> int:
        return len(self.labels)

    def row(self, i: int) -> "CounterSet":
        """Reconstruct row ``i`` as a standalone ``CounterSet``."""
        return CounterSet(
            label=self.labels[i], source=self.sources[i],
            num_cores=self.num_cores,
            O=self.O[i].copy(), N_f=self.N_f[i].copy(),
            N_c=self.N_c[i].copy(), N_p=self.N_p[i].copy(),
            lanes_active=float(self.lanes_active[i]),
            num_waves=int(self.num_waves[i]),
            waves_per_tile=int(self.waves_per_tile[i]),
            pipeline_depth=int(self.pipeline_depth[i]),
            bytes_read=float(self.bytes_read[i]),
            flops=float(self.flops[i]),
            ici_bytes=float(self.ici_bytes[i]),
            overhead_cycles=float(self.overhead_cycles[i]),
            wall_time_s=self.wall_time_s[i],
            meta=dict(self.meta[i]),
        )

    # -- derived columns (vectorized paper-Table-2 inputs) -----------------

    @property
    def N(self) -> np.ndarray:
        """Total wave jobs per (point, core) — (N_f + N_c) + N_p, the
        scalar path's addition order."""
        return (self.N_f + self.N_c) + self.N_p

    @property
    def total_jobs(self) -> np.ndarray:
        """(P,) total jobs per point (sequential core sum, see above)."""
        return _sequential_row_sum(self.N)

    @property
    def total_O(self) -> np.ndarray:
        """(P,) total transactions per point (sequential core sum)."""
        return _sequential_row_sum(self.O)

    @property
    def e(self) -> np.ndarray:
        """(P,) global serialization degree e = O / N (1.0 where idle)."""
        jobs = self.total_jobs
        return np.where(jobs > 0, self.total_O / np.where(jobs > 0, jobs, 1.0),
                        1.0)

    def occupancy(self, n_max: int) -> np.ndarray:
        return _occupancy_batch(self.num_waves, self.waves_per_tile,
                                self.pipeline_depth, n_max)

    def true_n(self, n_max: int) -> np.ndarray:
        return _true_n_batch(self.num_waves, self.waves_per_tile,
                             self.pipeline_depth, n_max)


def collect_basic_counters(
    trace: WaveTrace,
    *,
    num_cores: int,
    T_cycles_per_core: Optional[np.ndarray] = None,
    params: Optional[timing.ScatterUnitParams] = None,
) -> list[BasicCounters]:
    """Aggregate a wave trace into per-core paper-Table-1 counters.

    ``T_cycles_per_core`` is filled in by the kernel-time model (it
    includes non-scatter work and overheads); when omitted it defaults to
    the scatter busy time itself (utilization 1.0), which is only useful
    for unit tests.
    """
    if params is None:
        params = timing.V5E_SCATTER
    out: list[BasicCounters] = []
    occupancy = trace.occupancy(params.n_max)
    n_true = trace.true_n(params.n_max)
    for core in range(num_cores):
        sel = trace.core == core
        deg = trace.degree[sel]
        cls = trace.job_class[sel]
        o_count = float(np.sum(deg))  # transactions, incl. conflict replays
        n_f = float(np.sum(cls == timing.FAO))
        n_c = float(np.sum(cls == timing.CAS))
        n_p = float(np.sum(cls == timing.POPC))
        if T_cycles_per_core is not None:
            t = float(T_cycles_per_core[core])
        else:
            t = float(timing.total_time_cycles(
                n_f + n_c + n_p, max(1.0, o_count / max(deg.size, 1)),
                n_c, n_p, params))
        out.append(BasicCounters(
            O=o_count, N_f=n_f, N_c=n_c, N_p=n_p,
            T_cycles=t, occupancy=occupancy, n_true=n_true, core_id=core))
    return out
