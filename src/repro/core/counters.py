"""Basic operational quantities (paper Table 1) from kernel instrumentation.

The GPU paper reads NVProf/NCU counters; our counters come from the
instrumented Pallas kernels, which emit a *wave trace*: one record per
scatter wave job with its serialization degree, job class, and the core it
was scheduled on.  This module aggregates a trace into per-core
``BasicCounters``:

    O      <- sum of per-wave serialization degrees (total transactions;
              the analogue of smsp__l1tex_mem_shared_op_atom.sum, which
              counts bank-conflict replays)
    N_f/N_c/N_p <- per-class wave job counts per core
    T      <- modeled active cycles per core (from the kernel-time model
              in core.profiler, which includes the non-scatter work)
    o      <- achieved occupancy: avg in-flight waves / n_max

It also reproduces the paper's estimation gap: ``n_hat = o * n_max``
(their only option) versus the instrumented true queue length ``n_true``
(our addition; the paper explicitly recommends hardware add this counter).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import timing
from repro.core.qmodel import BasicCounters

LANES = 1024        # 8 x 128 VPU lane group = one wave
COMMIT_GROUP = 32   # lanes that commit to VMEM together; conflicts
                    # serialize within a group (GPU warp/bank analogue)


def wave_degree(indices: np.ndarray, lanes: int = LANES,
                group: int = COMMIT_GROUP) -> float:
    """Serialization degree of one wave of scatter indices.

    The VPU commit path retires ``group`` lanes per pass; duplicate
    destination indices within a commit group must serialize (the analogue
    of same-address shared-memory atomic replays in a 32-thread warp).
    The wave's degree is the mean over commit groups of the max duplicate
    multiplicity — exactly the quantity the paper's ``O`` counter
    (replay count) divided by ``N`` (warp-instructions) measures:
    solid-color histograms give 32, uniform-random ~2-3, conflict-free 1.
    """
    idx = np.asarray(indices).reshape(-1)
    if idx.size == 0:
        return 1.0
    pad = (-idx.size) % group
    if pad:
        # pad with unique sentinels so padding never adds conflicts
        sentinel = idx.max(initial=0) + 1 + np.arange(pad)
        idx = np.concatenate([idx, sentinel])
    g = idx.reshape(-1, group)
    eq = g[:, :, None] == g[:, None, :]          # (G, group, group)
    mult = eq.sum(axis=2)                        # duplicate multiplicity
    return float(np.mean(mult.max(axis=1)))


@dataclasses.dataclass
class WaveTrace:
    """Per-wave instrumentation records for one kernel launch."""

    degree: np.ndarray          # (W,) serialization degree per wave (>= 1)
    job_class: np.ndarray       # (W,) timing.FAO / timing.CAS / timing.POPC
    core: np.ndarray            # (W,) core the wave's tile was scheduled on
    lanes_active: np.ndarray    # (W,) active lanes (<= LANES)
    waves_per_tile: int = 1     # launch geometry: waves issued per grid tile
    pipeline_depth: int = 2     # Pallas double buffering

    def __post_init__(self) -> None:
        self.degree = np.asarray(self.degree, np.float64)
        self.job_class = np.asarray(self.job_class, np.int32)
        self.core = np.asarray(self.core, np.int32)
        self.lanes_active = np.asarray(self.lanes_active, np.float64)

    @property
    def num_waves(self) -> int:
        return int(self.degree.shape[0])

    def with_geometry(self, waves_per_tile: Optional[int] = None,
                      pipeline_depth: Optional[int] = None) -> "WaveTrace":
        """Copy of this trace with a different launch geometry.

        The per-wave records are shared (they are measurement, not
        geometry); only the occupancy-defining launch parameters change.
        Prefer this over mutating ``waves_per_tile`` in place.
        """
        return dataclasses.replace(
            self,
            waves_per_tile=self.waves_per_tile if waves_per_tile is None
            else int(waves_per_tile),
            pipeline_depth=self.pipeline_depth if pipeline_depth is None
            else int(pipeline_depth),
        )

    def occupancy(self, n_max: int) -> float:
        """Achieved concurrency fraction from launch geometry.

        In-flight jobs = waves per tile x pipeline depth, capped by n_max
        and by the total work available.
        """
        inflight = min(self.waves_per_tile * self.pipeline_depth,
                       n_max, max(self.num_waves, 1))
        return inflight / n_max

    def true_n(self, n_max: int) -> float:
        """Instrumented time-average queue length.

        All waves of a tile are issued together; with double buffering the
        queue holds up to waves_per_tile * depth jobs while the tail drains
        to 0.  The time-average over a long launch sits near the issued
        concurrency, degraded by the drain fraction.
        """
        if self.num_waves == 0:
            return 0.0
        burst = min(self.waves_per_tile * self.pipeline_depth, n_max)
        full_bursts = self.num_waves // max(burst, 1)
        tail = self.num_waves - full_bursts * burst
        # time-weighted average of a sawtooth: mean of (burst .. 1)
        avg_full = (burst + 1) / 2.0
        avg_tail = (tail + 1) / 2.0 if tail else 0.0
        w_full = full_bursts * burst
        w_tail = tail
        denom = w_full + w_tail
        return (avg_full * w_full + avg_tail * w_tail) / denom if denom else 0.0


def concat_traces(traces: Sequence[WaveTrace]) -> WaveTrace:
    return WaveTrace(
        degree=np.concatenate([t.degree for t in traces]),
        job_class=np.concatenate([t.job_class for t in traces]),
        core=np.concatenate([t.core for t in traces]),
        lanes_active=np.concatenate([t.lanes_active for t in traces]),
        waves_per_tile=traces[0].waves_per_tile,
        pipeline_depth=traces[0].pipeline_depth,
    )  # geometry from the first trace: concat is per-launch, not cross-launch


def trace_from_indices(
    indices: np.ndarray,
    num_bins: int,
    *,
    num_cores: int = 1,
    wave: int = LANES,
    job_class: int = timing.FAO,
    waves_per_tile: int = 1,
    pipeline_depth: int = 2,
) -> WaveTrace:
    """Build the wave trace a kernel's instrumentation would emit.

    ``indices`` is the flat stream of scatter destinations; waves are
    consecutive ``wave``-sized groups; tiles round-robin across cores the
    way a Pallas grid schedules across TensorCores.  The per-wave degree is
    ceil(active / distinct): a wave whose lanes all hit one bin serializes
    fully; all-distinct commits in one pass.  This mirrors what
    ``kernels/instrumentation.py`` computes inside the kernel.
    """
    idx = np.asarray(indices).reshape(-1)
    n = idx.shape[0]
    num_waves = max(1, -(-n // wave))
    degree = np.empty(num_waves, np.float64)
    active = np.empty(num_waves, np.float64)
    for w in range(num_waves):
        part = idx[w * wave:(w + 1) * wave]
        active[w] = part.shape[0]
        degree[w] = wave_degree(part)
    tiles = np.arange(num_waves) // max(waves_per_tile, 1)
    cores = (tiles % num_cores).astype(np.int32)
    return WaveTrace(
        degree=degree,
        job_class=np.full(num_waves, job_class, np.int32),
        core=cores,
        lanes_active=active,
        waves_per_tile=waves_per_tile,
        pipeline_depth=pipeline_depth,
    )


def collect_basic_counters(
    trace: WaveTrace,
    *,
    num_cores: int,
    T_cycles_per_core: Optional[np.ndarray] = None,
    params: Optional[timing.ScatterUnitParams] = None,
) -> list[BasicCounters]:
    """Aggregate a wave trace into per-core paper-Table-1 counters.

    ``T_cycles_per_core`` is filled in by the kernel-time model (it
    includes non-scatter work and overheads); when omitted it defaults to
    the scatter busy time itself (utilization 1.0), which is only useful
    for unit tests.
    """
    if params is None:
        params = timing.V5E_SCATTER
    out: list[BasicCounters] = []
    occupancy = trace.occupancy(params.n_max)
    n_true = trace.true_n(params.n_max)
    for core in range(num_cores):
        sel = trace.core == core
        deg = trace.degree[sel]
        cls = trace.job_class[sel]
        o_count = float(np.sum(deg))  # transactions, incl. conflict replays
        n_f = float(np.sum(cls == timing.FAO))
        n_c = float(np.sum(cls == timing.CAS))
        n_p = float(np.sum(cls == timing.POPC))
        if T_cycles_per_core is not None:
            t = float(T_cycles_per_core[core])
        else:
            t = float(timing.total_time_cycles(
                n_f + n_c + n_p, max(1.0, o_count / max(deg.size, 1)),
                n_c, n_p, params))
        out.append(BasicCounters(
            O=o_count, N_f=n_f, N_c=n_c, N_p=n_p,
            T_cycles=t, occupancy=occupancy, n_true=n_true, core_id=core))
    return out
