"""Bottleneck classification and shift detection (paper §4.1).

The paper's headline capability: given utilization estimates across a
sweep (image sizes, batch sizes, router temperatures, ...), say *which
unit bounds each point* and flag where the bottleneck *shifts* — e.g. the
histogram moving from the shared-memory atomic unit to global memory at
~2^20 pixels, "unambiguously represented in our model's results".
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.core.profiler import WorkloadProfile

SATURATED = 0.90   # unit considered saturated (a bottleneck) above this
UNDERUTILIZED = 0.50


@dataclasses.dataclass
class BottleneckVerdict:
    label: str
    bottleneck: str
    utilization: float
    saturated: bool
    comment: str = ""


@dataclasses.dataclass
class ShiftEvent:
    index: int
    label_before: str
    label_after: str
    unit_before: str
    unit_after: str


def classify(profile: WorkloadProfile) -> BottleneckVerdict:
    name = profile.bottleneck
    u = profile.unit(name).utilization if profile.units else 0.0
    if u >= SATURATED:
        comment = f"{name} saturated — optimizing other units will not help"
    elif u <= UNDERUTILIZED:
        comment = ("no unit saturated — latency/overhead bound "
                   "(raise concurrency or fuse launches)")
    else:
        comment = f"{name} leading but unsaturated"
    return BottleneckVerdict(label=profile.label, bottleneck=name,
                             utilization=u, saturated=u >= SATURATED,
                             comment=comment)


def detect_shifts(profiles: Sequence[WorkloadProfile]) -> list[ShiftEvent]:
    """Find sweep points where the dominant unit changes."""
    events = []
    for i in range(1, len(profiles)):
        a, b = profiles[i - 1], profiles[i]
        if a.bottleneck != b.bottleneck:
            events.append(ShiftEvent(
                index=i, label_before=a.label, label_after=b.label,
                unit_before=a.bottleneck, unit_after=b.bottleneck))
    return events


def speedup_estimate(before: WorkloadProfile, after: WorkloadProfile) -> float:
    """Predicted speedup of `after` over `before` from modeled windows."""
    t0 = float(np.max(before.T_cycles))
    t1 = float(np.max(after.T_cycles))
    return t0 / t1 if t1 > 0 else float("inf")
