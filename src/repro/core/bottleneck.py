"""Bottleneck classification and shift detection (paper §4.1).

The paper's headline capability: given utilization estimates across a
sweep (image sizes, batch sizes, router temperatures, ...), say *which
unit bounds each point* and flag where the bottleneck *shifts* — e.g. the
histogram moving from the shared-memory atomic unit to global memory at
~2^20 pixels, "unambiguously represented in our model's results".
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.profiler import WorkloadProfile

SATURATED = 0.90   # unit considered saturated (a bottleneck) above this
UNDERUTILIZED = 0.50


@dataclasses.dataclass(frozen=True)
class Hint:
    """Machine-usable optimization hint attached to a verdict.

    ``comment`` is prose for humans; ``Hint`` is the same advice as
    data: which ``unit`` the advice targets, a stable ``action`` id, and
    the ``repro.advisor`` transform ``family`` that implements it — so a
    tool (or the advisor itself) can act on a verdict without parsing
    English.
    """

    unit: str                # the unit the advice targets
    action: str              # stable id: reduce_contention | ...
    family: str              # advisor transform family implementing it

    def compact(self) -> str:
        """Flat ``action:family@unit`` form for text/csv cells."""
        return f"{self.action}:{self.family}@{self.unit}"


# Per-unit advice for a saturated (or leading) server: what to do about
# it, and which advisor transform family does that.  Units without a
# shipped transform family still get a stable family name so the hint
# remains actionable by external tooling.
_UNIT_HINTS = {
    "scatter": ("reduce_contention", "rotation"),
    "hbm": ("reduce_traffic", "tiling"),
    "mxu": ("reduce_flops", "precision"),
    "ici": ("reduce_collectives", "sharding"),
}


def _hint_for(name: str, u: float) -> Hint:
    if u <= UNDERUTILIZED:
        # nothing saturated: concurrency/overhead is the lever
        return Hint(unit=name, action="raise_concurrency",
                    family="geometry")
    action, family = _UNIT_HINTS.get(name, ("rebalance", "geometry"))
    return Hint(unit=name, action=action, family=family)


@dataclasses.dataclass
class BottleneckVerdict:
    label: str
    bottleneck: str
    utilization: float
    saturated: bool
    comment: str = ""
    hint: Optional[Hint] = None


@dataclasses.dataclass
class ShiftEvent:
    index: int
    label_before: str
    label_after: str
    unit_before: str
    unit_after: str


def classify(profile: WorkloadProfile) -> BottleneckVerdict:
    name = profile.bottleneck
    # "none" (every unit idle) is a verdict, not a unit: look it up safely
    u = _unit_utilization(profile, name) if profile.units else 0.0
    if u >= SATURATED:
        comment = f"{name} saturated — optimizing other units will not help"
    elif u <= UNDERUTILIZED:
        comment = ("no unit saturated — latency/overhead bound "
                   "(raise concurrency or fuse launches)")
    else:
        comment = f"{name} leading but unsaturated"
    return BottleneckVerdict(label=profile.label, bottleneck=name,
                             utilization=u, saturated=u >= SATURATED,
                             comment=comment, hint=_hint_for(name, u))


SHIFT_TOL = 0.02   # relative lead a new unit needs to count as a shift


def _unit_utilization(profile: WorkloadProfile, name: str) -> float:
    try:
        return profile.unit(name).utilization
    except KeyError:
        return 0.0


def detect_shifts(profiles: Sequence[WorkloadProfile],
                  tol: float = SHIFT_TOL) -> list[ShiftEvent]:
    """Find sweep points where the dominant unit changes.

    A bare argmax flip is noisy: two unsaturated units within rounding
    error of each other flip leadership from point to point without any
    real change in what bounds the workload.  A shift therefore only
    fires when the candidate unit *leads the currently held bottleneck by
    a relative margin* of ``tol`` at that point; near-ties keep the held
    unit (hysteresis), so a sweep through a crossover emits one event,
    not a flicker of them.
    """
    events = []
    if not profiles:
        return events
    current = profiles[0].bottleneck
    for i in range(1, len(profiles)):
        b = profiles[i]
        candidate = b.bottleneck
        if candidate == current:
            continue
        u_new = _unit_utilization(b, candidate)
        u_held = _unit_utilization(b, current)
        if u_new <= u_held * (1.0 + tol):
            continue   # within the tie margin: not a real shift
        events.append(ShiftEvent(
            index=i, label_before=profiles[i - 1].label, label_after=b.label,
            unit_before=current, unit_after=candidate))
        current = candidate
    return events


def speedup_estimate(before: WorkloadProfile, after: WorkloadProfile) -> float:
    """Predicted speedup of `after` over `before` from modeled windows.

    Two degenerate cases: both windows zero means "nothing modeled on
    either side" and the only honest answer is parity (1.0), while a zero
    ``after`` window against real ``before`` work is a broken profile —
    an infinite speedup must never propagate silently into reports.
    """
    t0 = float(np.max(before.T_cycles))
    t1 = float(np.max(after.T_cycles))
    if t1 > 0:
        return t0 / t1
    if t0 == 0:
        return 1.0
    raise ValueError(
        f"speedup_estimate: profile {after.label!r} has a zero modeled "
        f"window (T_cycles all zero) — cannot report a finite speedup "
        f"over {before.label!r}")
