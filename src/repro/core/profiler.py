"""Tool 2 (paper §3.4): profile a workload and report per-unit utilization.

Mirrors the paper's second tool: collect the Table-1 counters from a run,
instantiate the single-server model, and emit per-core utilization of the
scatter ("shared-memory atomic") unit — together with the companion
throughput servers (HBM, MXU, ICI) so bottleneck *shifts* are visible
(paper §4.1: at ~2^20 pixels the histogram bottleneck shifts from the
atomic unit to global memory).

Kernel-time model
-----------------
The paper measures T (active cycles) with a counter.  Without hardware we
model a kernel launch's active cycles per core as

    T = overhead + max(B_scatter, T_mem_effective) + issue_tail

where B_scatter is the queue model's busy time and T_mem_effective is the
HBM stream time inflated by latency exposure when the working set spills
the last-level cache and concurrency is too low to hide the miss latency —
the mechanism behind the paper's observed bottleneck shift.  The cache
constants are documented emulation knobs (`CacheModel`), not TPU specs.
"""

from __future__ import annotations

import dataclasses
import io
from typing import Optional, Sequence

import numpy as np

from repro.core import counters as counters_mod
from repro.core import qmodel, timing


@dataclasses.dataclass(frozen=True)
class CacheModel:
    """Last-level-cache emulation for latency-exposure effects."""

    llc_bytes: float = 4 * 1024**2
    miss_latency_cycles: float = 500.0
    hide_concurrency: float = 8.0   # in-flight requests that fully hide misses


@dataclasses.dataclass
class UnitUtilization:
    name: str
    busy_cycles: float
    window_cycles: float

    @property
    def utilization(self) -> float:
        return self.busy_cycles / self.window_cycles if self.window_cycles else 0.0


@dataclasses.dataclass
class WorkloadProfile:
    """Per-launch profile: the paper's report, plus companion units."""

    label: str
    per_core: list[qmodel.CoreUtilization]
    units: list[UnitUtilization]
    T_cycles: np.ndarray          # per core
    params: dict = dataclasses.field(default_factory=dict)

    @property
    def scatter_utilization(self) -> float:
        return float(np.mean([c.U for c in self.per_core])) if self.per_core else 0.0

    @property
    def e(self) -> float:
        """Job-weighted serialization degree across cores (= global O/N)."""
        jobs = float(sum(c.N for c in self.per_core))
        return (float(sum(c.e * c.N for c in self.per_core)) / jobs
                if jobs else 0.0)

    @property
    def n_hat(self) -> float:
        """Peak per-core concurrency estimate across cores."""
        return (float(max(c.n_hat for c in self.per_core))
                if self.per_core else 0.0)

    @property
    def bottleneck(self) -> str:
        best, best_u = "none", 0.0
        for u in self.units:
            if u.utilization > best_u:
                best, best_u = u.name, u.utilization
        return best

    def unit(self, name: str) -> UnitUtilization:
        for u in self.units:
            if u.name == name:
                return u
        raise KeyError(name)

    def render(self) -> str:
        buf = io.StringIO()
        buf.write(f"== profile: {self.label} ==\n")
        buf.write(qmodel.render_utilization_report(self.per_core))
        for u in self.units:
            buf.write(f"unit {u.name:>12}: busy={u.busy_cycles:>12.0f} cyc  "
                      f"U={u.utilization:6.2%}\n")
        buf.write(f"bottleneck: {self.bottleneck}\n")
        return buf.getvalue()


def profile_counters(
    cset: counters_mod.CounterSet,
    table: qmodel.ServiceTimeTable,
    *,
    params: Optional[timing.ScatterUnitParams] = None,
    chip: Optional[timing.ChipParams] = None,
    cache: Optional[CacheModel] = None,
    use_true_n: bool = False,
) -> WorkloadProfile:
    """Profile one launch from a uniform ``CounterSet`` (any provider).

    This is the single entry every counter source funnels into: the
    legacy trace path (``profile_scatter_workload``) and all
    ``repro.analysis.providers`` backends build a ``CounterSet`` and
    delegate here.  Two-phase, like the paper: (1) the queue model's busy
    time B from the counters (B needs no T); (2) model the measurement
    window T per core from all units and overheads; (3) derive U = B / T.

    ``params``/``chip``/``cache`` default to the v5e model; pass a
    ``repro.analysis.Device``'s bundle (or use ``Session.profile``) to
    target other hardware.
    """
    if params is None:
        params = timing.V5E_SCATTER
    if chip is None:
        chip = timing.V5E
    if cache is None:
        cache = CacheModel()
    num_cores = cset.num_cores
    # Phase 1: scatter busy time per core (empty-counter sources skip it).
    # B = N * S depends only on the counters, never on the window T, so
    # this single model pass serves phase 3 too — the placeholder window
    # (ones) only touches the U column, which phase 3 overwrites.
    if cset.total_jobs > 0:
        basic = cset.to_basic_counters(np.ones(num_cores), params.n_max)
        prelim = qmodel.derive_core_utilization(
            basic, table, n_max=params.n_max, use_true_n=use_true_n)
        scatter_busy = np.array([c.B_cycles for c in prelim])
        n_hat = prelim[0].n_hat if prelim else 1.0
    else:
        prelim = []
        scatter_busy = np.zeros(num_cores)
        n_hat = 1.0

    # Phase 2: companion units and the kernel-time model.
    bytes_per_cycle = chip.hbm_bw / chip.clock_hz
    mem_ideal = (cset.bytes_read / num_cores) / bytes_per_cycle
    # Latency exposure: when the working set spills the LLC, each tile's
    # leading access exposes miss latency unless concurrency hides it.
    # Scatter-visible sources only: the heuristic reads the launch
    # geometry, which an HLO-only CounterSet doesn't have.
    if cset.total_jobs > 0 and cset.bytes_read > cache.llc_bytes:
        hide = min(1.0, n_hat / cache.hide_concurrency)
        tiles = max(1.0, cset.num_waves / max(cset.waves_per_tile, 1))
        exposure = (tiles / num_cores) * cache.miss_latency_cycles * (1.0 - hide)
    else:
        exposure = 0.0
    mem_eff = mem_ideal + exposure
    compute_cycles = (cset.flops / num_cores) / (chip.peak_bf16_flops
                                                 / chip.clock_hz)
    ici_cycles = cset.ici_bytes / (chip.ici_bw_per_link / chip.clock_hz)

    T = cset.overhead_cycles + np.maximum(
        scatter_busy,
        np.maximum(mem_eff, np.maximum(compute_cycles, ici_cycles)))

    # Phase 3: utilization against the modeled window.  B and S are
    # T-independent, so reuse phase 1's rows verbatim (no second
    # derive_core_utilization pass, no re-interpolation) and only swap in
    # the modeled window and the resulting U = B / T.
    per_core = [
        dataclasses.replace(
            row, T_cycles=float(T[i]),
            U=row.B_cycles / float(T[i]) if T[i] > 0 else 0.0)
        for i, row in enumerate(prelim)
    ]

    window = float(np.max(T))
    # One fixed unit set for every source: sweeps stack unit names across
    # points, so membership must not depend on a point's values (an
    # ici-less point in a collective sweep would otherwise crash the
    # stacking), and a server missing from the report could never be
    # named as the bottleneck it is.
    units = [
        UnitUtilization("scatter", float(np.mean(scatter_busy)), window),
        UnitUtilization("hbm", float(mem_eff), window),
        UnitUtilization("mxu", float(compute_cycles), window),
        UnitUtilization("ici", float(ici_cycles), window),
    ]
    return WorkloadProfile(
        label=cset.label, per_core=per_core, units=units, T_cycles=T,
        params={"bytes_read": cset.bytes_read, "flops": cset.flops,
                "overhead_cycles": cset.overhead_cycles,
                "use_true_n": use_true_n, "source": cset.source,
                "wall_time_s": cset.wall_time_s,
                "meta": dict(cset.meta)},
    )


def profile_batch(
    frame: counters_mod.CounterFrame,
    table: qmodel.ServiceTimeTable,
    *,
    params: Optional[timing.ScatterUnitParams] = None,
    chip: Optional[timing.ChipParams] = None,
    cache: Optional[CacheModel] = None,
    use_true_n: bool = False,
) -> list[WorkloadProfile]:
    """Profile a whole ``CounterFrame`` in one columnar model pass.

    Point-for-point equivalent to calling ``profile_counters`` on each
    row (same U, n-hat, e, busy times, windows, unit set — verified by
    the batch-equivalence test suite), but the entire §3 pipeline —
    occupancy geometry, the global e, c per core, ``S(n, e, c)`` via the
    precompiled ``TableInterpolator``, busy time B, the kernel-time
    window T, the four companion units — runs as whole-(P, C)-array
    numpy ops.  A sweep's thousands of service-time lookups collapse
    into one fused gather instead of thousands of Python ``trilinear``
    calls; only the final (cheap) ``WorkloadProfile`` assembly loops.

    Bottleneck argmax and the shift-hysteresis tolerance live on the
    returned ``WorkloadProfile``/``bottleneck.detect_shifts`` exactly as
    for the scalar path, so verdicts and shift events are identical by
    construction once the arrays match.
    """
    if params is None:
        params = timing.V5E_SCATTER
    if chip is None:
        chip = timing.V5E
    if cache is None:
        cache = CacheModel()
    P, C = frame.num_points, frame.num_cores
    if P == 0:
        return []
    n_max = params.n_max

    # -- phase 1: scatter busy time, all points x cores at once ----------
    total_jobs = frame.total_jobs                       # (P,)
    has_jobs = total_jobs > 0
    e_global = frame.e                                  # (P,)
    n_hat_pt = (frame.true_n(n_max) if use_true_n
                else frame.occupancy(n_max) * n_max)    # (P,)
    n_hat_b = np.broadcast_to(n_hat_pt[:, None], (P, C))
    e_b = np.broadcast_to(e_global[:, None], (P, C))
    n_faocas = frame.N_f + frame.N_c                    # (P, C)
    has_fc = n_faocas > 0
    c_avg = np.where(
        has_fc, n_hat_b * (frame.N_c / np.where(has_fc, n_faocas, 1.0)), 0.0)
    S = np.where(has_fc, table.service_time_batch(n_hat_b, e_b, c_avg), 0.0)
    busy = n_faocas * S
    if table.popc_T is not None and np.any(frame.N_p > 0):
        S_p = table.popc_service_time_batch(n_hat_b, e_b)
        busy = busy + np.where(frame.N_p > 0, frame.N_p * S_p, 0.0)
    scatter_busy = np.where(has_jobs[:, None], busy, 0.0)

    # -- phase 2: companion units and the kernel-time window -------------
    num_cores_f = float(C)
    bytes_per_cycle = chip.hbm_bw / chip.clock_hz
    mem_ideal = (frame.bytes_read / num_cores_f) / bytes_per_cycle  # (P,)
    exp_cond = has_jobs & (frame.bytes_read > cache.llc_bytes)
    hide = np.minimum(1.0, n_hat_pt / cache.hide_concurrency)
    tiles = np.maximum(1.0,
                       frame.num_waves / np.maximum(frame.waves_per_tile, 1))
    exposure = np.where(
        exp_cond,
        (tiles / num_cores_f) * cache.miss_latency_cycles * (1.0 - hide),
        0.0)
    mem_eff = mem_ideal + exposure
    compute_cycles = (frame.flops / num_cores_f) / (chip.peak_bf16_flops
                                                    / chip.clock_hz)
    ici_cycles = frame.ici_bytes / (chip.ici_bw_per_link / chip.clock_hz)
    T = frame.overhead_cycles[:, None] + np.maximum(
        scatter_busy,
        np.maximum(mem_eff[:, None],
                   np.maximum(compute_cycles[:, None], ici_cycles[:, None])))

    # -- phase 3: utilization + per-point assembly -----------------------
    U = np.where(T > 0, scatter_busy / np.where(T > 0, T, 1.0), 0.0)
    scatter_mean = np.mean(scatter_busy, axis=1)        # (P,)
    window = np.max(T, axis=1)                          # (P,)
    N_pc = frame.N
    profiles = []
    for i in range(P):
        if has_jobs[i]:
            per_core = [
                qmodel.CoreUtilization(
                    core_id=core, N=float(N_pc[i, core]),
                    n_hat=float(n_hat_pt[i]), e=float(e_global[i]),
                    c=float(c_avg[i, core]), S_cycles=float(S[i, core]),
                    B_cycles=float(scatter_busy[i, core]),
                    T_cycles=float(T[i, core]), U=float(U[i, core]))
                for core in range(C)
            ]
        else:
            per_core = []
        w = float(window[i])
        units = [
            UnitUtilization("scatter", float(scatter_mean[i]), w),
            UnitUtilization("hbm", float(mem_eff[i]), w),
            UnitUtilization("mxu", float(compute_cycles[i]), w),
            UnitUtilization("ici", float(ici_cycles[i]), w),
        ]
        profiles.append(WorkloadProfile(
            label=frame.labels[i], per_core=per_core, units=units,
            T_cycles=T[i].copy(),
            params={"bytes_read": float(frame.bytes_read[i]),
                    "flops": float(frame.flops[i]),
                    "overhead_cycles": float(frame.overhead_cycles[i]),
                    "use_true_n": use_true_n, "source": frame.sources[i],
                    "wall_time_s": frame.wall_time_s[i],
                    "meta": dict(frame.meta[i] or {})},
        ))
    return profiles


def profile_scatter_workload(
    trace: counters_mod.WaveTrace,
    table: qmodel.ServiceTimeTable,
    *,
    label: str = "",
    bytes_read: float = 0.0,
    flops: float = 0.0,
    num_cores: int = 8,
    overhead_cycles: float = 2000.0,
    params: Optional[timing.ScatterUnitParams] = None,
    chip: Optional[timing.ChipParams] = None,
    cache: Optional[CacheModel] = None,
    use_true_n: bool = False,
) -> WorkloadProfile:
    """Profile one scatter-heavy launch from its wave trace (legacy entry).

    Aggregates the trace into a ``CounterSet`` and delegates to
    ``profile_counters`` — kept for the pre-provider call sites; new code
    should go through ``repro.analysis.Session`` / a provider.
    """
    cset = counters_mod.CounterSet.from_trace(
        trace, label=label, num_cores=num_cores, bytes_read=bytes_read,
        flops=flops, overhead_cycles=overhead_cycles)
    return profile_counters(cset, table, params=params, chip=chip,
                            cache=cache, use_true_n=use_true_n)


def profile_compiled_step(
    compiled,
    *,
    label: str,
    chips: int,
    hlo_text: Optional[str] = None,
    chip: timing.ChipParams = timing.V5E,
) -> WorkloadProfile:
    """Whole-step profile from a compiled artifact (dry-run path).

    The scatter unit needs runtime data (it is data-dependent — that is
    the paper's point), so this path reports the three static units; the
    scatter report is attached by the caller when an instrumented run (or
    synthetic trace) is available.
    """
    from repro.core import hlo as hlo_mod
    flops, nbytes = hlo_mod.flops_and_bytes(compiled)
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = hlo_mod.parse_collectives(text, chips)
    mxu = flops / (chip.peak_bf16_flops / chip.clock_hz)
    hbm = nbytes / (chip.hbm_bw / chip.clock_hz)
    ici = coll.total_wire_bytes / (chip.ici_bw_per_link / chip.clock_hz)
    window = max(mxu, hbm, ici, 1.0)
    units = [
        UnitUtilization("mxu", mxu, window),
        UnitUtilization("hbm", hbm, window),
        UnitUtilization("ici", ici, window),
    ]
    return WorkloadProfile(label=label, per_core=[], units=units,
                           T_cycles=np.array([window]))


def utilization_sweep(
    profiles: Sequence[WorkloadProfile],
) -> dict[str, np.ndarray]:
    """Stack unit utilizations across a parameter sweep (for Figs. 3-4).

    Unit membership is the *union* across all points, in first-appearance
    order, with 0.0 filled where a point lacks the unit — heterogeneous
    sweeps (e.g. mixing an HLO-only point into a scatter sweep, or custom
    profiles with extra servers) must not KeyError on names the first
    profile happens to miss.  An empty sweep has no axes to stack: ``{}``.
    """
    if not profiles:
        return {}
    names: list[str] = []
    for p in profiles:
        for u in p.units:
            if u.name not in names:
                names.append(u.name)

    def util(p: WorkloadProfile, name: str) -> float:
        try:
            return p.unit(name).utilization
        except KeyError:
            return 0.0

    out = {n: np.array([util(p, n) for p in profiles]) for n in names}
    out["scatter_model"] = np.array([p.scatter_utilization for p in profiles])
    return out
