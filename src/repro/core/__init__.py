"""Core: operational single-server queuing model of data-dependent TPU
bottlenecks (Dong & Pai 2025, adapted from GPU shared-memory atomics to the
TPU VMEM scatter/accumulate path) plus the dry-run roofline machinery."""

from repro.core.qmodel import (  # noqa: F401
    BasicCounters,
    CoreUtilization,
    ServiceTimeTable,
    derive_core_utilization,
    render_utilization_report,
)
from repro.core.timing import CAS, FAO, POPC, V5E, V5E_SCATTER  # noqa: F401
from repro.core.microbench import build_table, make_pattern  # noqa: F401
from repro.core.counters import (  # noqa: F401
    CounterSet,
    WaveTrace,
    trace_from_indices,
)
from repro.core.profiler import (  # noqa: F401
    CacheModel,
    WorkloadProfile,
    profile_compiled_step,
    profile_counters,
    profile_scatter_workload,
)
from repro.core.bottleneck import classify, detect_shifts  # noqa: F401

# -- deprecation shims -------------------------------------------------------
# The session-style entry points live in repro.analysis; these forwards keep
# pre-analysis call sites (and muscle memory) working.  The direct names
# above (build_table, profile_scatter_workload, ...) remain supported for
# low-level use, but new workloads should integrate via repro.analysis.

_ANALYSIS_NAMES = ("Session", "SweepResult", "WorkloadSpec", "Device",
                   "get_device", "register_device", "DEVICES")


def __getattr__(name):
    if name in _ANALYSIS_NAMES:
        import warnings

        import repro.analysis as _analysis
        warnings.warn(
            f"repro.core.{name} is deprecated; import {name} from "
            f"repro.analysis instead", DeprecationWarning, stacklevel=2)
        return getattr(_analysis, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
