"""Core: operational single-server queuing model of data-dependent TPU
bottlenecks (Dong & Pai 2025, adapted from GPU shared-memory atomics to the
TPU VMEM scatter/accumulate path) plus the dry-run roofline machinery."""

from repro.core.qmodel import (  # noqa: F401
    BasicCounters,
    CoreUtilization,
    ServiceTimeTable,
    derive_core_utilization,
    render_utilization_report,
)
from repro.core.timing import CAS, FAO, POPC, V5E, V5E_SCATTER  # noqa: F401
from repro.core.microbench import build_table, make_pattern  # noqa: F401
from repro.core.counters import WaveTrace, trace_from_indices  # noqa: F401
from repro.core.profiler import (  # noqa: F401
    CacheModel,
    WorkloadProfile,
    profile_compiled_step,
    profile_scatter_workload,
)
from repro.core.bottleneck import classify, detect_shifts  # noqa: F401
