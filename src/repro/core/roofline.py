"""Three-term roofline from the compiled dry-run artifact (spec §ROOFLINE).

    compute term    = HLO_FLOPs  / (chips * peak_FLOP/s)
    memory term     = HLO_bytes  / (chips * HBM_bw)
    collective term = collective_bytes / (chips * link_bw)

plus MODEL_FLOPS = 6 N D (dense) / 6 N_active D (MoE) and the useful-compute
ratio.  XLA's cost analysis on an SPMD-partitioned module reports
*per-partition* FLOPs/bytes; ``probe_cost_normalization()`` verifies this
empirically once per process (a 512-device CPU run is still one program;
we do not trust an assumption we can measure), and totals are scaled to
whole-program quantities before the formulas above are applied.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

from repro.core import hlo, timing

V5E = timing.V5E


@functools.cache
def probe_cost_normalization() -> float:
    """Return multiplier m such that total_flops = reported_flops * m * chips.

    Compiles a known matmul sharded across all local devices and compares
    cost_analysis FLOPs with the analytic count.  m ~= 1/chips means the
    report is already whole-program; m ~= 1 means per-partition.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    ndev = len(jax.devices())
    if ndev == 1:
        return 1.0
    mesh = jax.make_mesh((ndev,), ("x",))
    m, k, n = 256, 256, 256 * ndev
    xs = jax.ShapeDtypeStruct((m, k), jnp.float32,
                              sharding=NamedSharding(mesh, P()))
    ws = jax.ShapeDtypeStruct((k, n), jnp.float32,
                              sharding=NamedSharding(mesh, P(None, "x")))
    compiled = jax.jit(lambda x, w: x @ w).lower(xs, ws).compile()
    flops, _ = hlo.flops_and_bytes(compiled)
    true_flops = 2.0 * m * k * n
    if flops <= 0:
        return 1.0
    ratio = true_flops / flops  # = chips if per-partition, 1 if total
    return ratio / ndev  # per-chip multiplier


@dataclasses.dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh_name: str
    chips: int
    # whole-program quantities
    hlo_flops: float
    hlo_bytes: float
    collective_operand_bytes: float   # per-device operand-byte sum (spec)
    collective_wire_bytes: float      # ring-model per-link traffic
    # terms (seconds)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    model_flops: float = 0.0
    bytes_per_device: float = 0.0
    collective_counts: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        chip = V5E
        self.compute_s = self.hlo_flops / (self.chips * chip.peak_bf16_flops)
        self.memory_s = self.hlo_bytes / (self.chips * chip.hbm_bw)
        # collective_operand_bytes is per-device; scaling by chips and then
        # dividing by (chips * link_bw) per the spec formula reduces to
        # per-device bytes / link_bw.  The ring-model estimate is reported
        # alongside as the tighter wire-time bound.
        self.collective_s = self.collective_operand_bytes / chip.ici_bw_per_link

    @property
    def collective_wire_s(self) -> float:
        return self.collective_wire_bytes / V5E.ici_bw_per_link

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": max(self.collective_s, self.collective_wire_s)}
        return max(terms, key=terms.get)

    @property
    def step_lower_bound_s(self) -> float:
        return max(self.compute_s, self.memory_s,
                   self.collective_s, self.collective_wire_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the step bound spent on *useful* compute."""
        useful_s = self.model_flops / (self.chips * V5E.peak_bf16_flops)
        lb = self.step_lower_bound_s
        return useful_s / lb if lb > 0 else 0.0

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.hlo_flops if self.hlo_flops else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(dominant=self.dominant,
                 useful_ratio=self.useful_ratio,
                 roofline_fraction=self.roofline_fraction,
                 collective_wire_s=self.collective_wire_s,
                 step_lower_bound_s=self.step_lower_bound_s)
        return d


def model_flops_dense(n_params: float, tokens: float) -> float:
    return 6.0 * n_params * tokens


def model_flops_moe(n_active_params: float, tokens: float) -> float:
    return 6.0 * n_active_params * tokens


def from_compiled(
    compiled,
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    model_flops: float,
    hlo_text: Optional[str] = None,
) -> RooflineTerms:
    """Build roofline terms from a compiled dry-run artifact."""
    text = hlo_text if hlo_text is not None else compiled.as_text()
    # Trip-count-aware analyzer (compiled.cost_analysis() counts while
    # bodies once — useless for scan-heavy steps; see core.hlo).
    cost = hlo.analyze_module(text, chips)
    total_flops = cost.flops * chips      # module is per-partition
    total_bytes = cost.bytes * chips
    coll = cost
    mem = hlo.memory_analysis_dict(compiled)
    bytes_per_device = float(
        mem.get("argument_size_in_bytes", 0)
        + mem.get("output_size_in_bytes", 0)
        + mem.get("temp_size_in_bytes", 0)
        - mem.get("alias_size_in_bytes", 0))
    by_opcode: dict[str, dict] = {}
    for o in coll.collectives:
        d = by_opcode.setdefault(o.opcode, {"count": 0, "operand_bytes": 0,
                                            "wire_bytes": 0.0})
        d["count"] += 1
        d["operand_bytes"] += o.operand_bytes
        d["wire_bytes"] += o.wire_bytes
    return RooflineTerms(
        arch=arch, shape=shape, mesh_name=mesh_name, chips=chips,
        hlo_flops=total_flops, hlo_bytes=total_bytes,
        collective_operand_bytes=float(coll.collective_operand_bytes),
        collective_wire_bytes=float(coll.collective_wire_bytes),
        model_flops=model_flops,
        bytes_per_device=bytes_per_device,
        collective_counts=by_opcode,
    )


def render_markdown_row(t: RooflineTerms) -> str:
    return (f"| {t.arch} | {t.shape} | {t.mesh_name} | "
            f"{t.compute_s*1e3:.2f} | {t.memory_s*1e3:.2f} | "
            f"{t.collective_s*1e3:.2f} / {t.collective_wire_s*1e3:.2f} | "
            f"{t.dominant} | {t.useful_ratio:.2f} | "
            f"{t.roofline_fraction:.1%} | {t.bytes_per_device/2**30:.2f} |")


MARKDOWN_HEADER = (
    "| arch | shape | mesh | compute (ms) | memory (ms) | "
    "collective op/wire (ms) | dominant | useful | roofline | GiB/dev |\n"
    "|---|---|---|---|---|---|---|---|---|---|")
