"""Compiled-HLO introspection: FLOPs, bytes, and per-collective traffic.

This is the dry-run "profile" source (no real TPU in this container):
``compiled.cost_analysis()`` supplies HLO FLOPs / bytes-accessed, and the
post-SPMD HLO text supplies every collective op with operand shapes and
replica groups.  ``collective_bytes`` is NOT in cost_analysis, so we parse
the module text and sum operand sizes per collective opcode, per the spec.

The text parsed here is the per-partition SPMD module, so operand sizes
are *per-device* shard sizes.  We report both the raw per-device operand
byte sum (the spec's quantity) and a ring-model wire-time estimate that
accounts for group size k (all-gather moves (k-1)/k of the full buffer
through each link; all-reduce twice that).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1,
    "e4m3": 1, "e5m2": 1, "f4e2m1fn": 1,
}

# opcode -> per-link traffic multiplier as a function of group size k,
# relative to the summed *input operand* bytes s (per device):
#   all-gather: each device contributes s and receives (k-1)s -> ring moves
#     (k-1)*s per link;  all-reduce: reduce-scatter + all-gather = 2(k-1)/k
#     on the full buffer = 2(k-1)*s_in/k ... we use input-operand based
#     forms so everything keys off operand sizes, matching the spec.
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast", "ragged-all-to-all",
)

# Dims may carry dynamic-size markers (`s32[<=16]`); tuples may nest one
# level and carry layout annotations on elements and on the tuple itself:
# `(f32[8,128]{1,0}, s32[])` or `((f32[2], s32[]), f32[4]{0})`.
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,<=]*)\]")
_ARRAY_SHAPE_PAT = r"[a-z0-9]+\[[0-9,<=]*\](?:\{[^}]*\})?"
_TUPLE_SHAPE_PAT = r"\((?:[^()]|\([^()]*\))*\)(?:\{[^}]*\})?"
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    rf"({_TUPLE_SHAPE_PAT}|{_ARRAY_SHAPE_PAT})\s+"
    r"([\w\-]+)\(")


def _dim_int(d: str) -> int:
    """Parse one dim token, tolerating dynamic-size markers (`<=16`)."""
    return int(d.lstrip("<="))
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def shape_bytes(shape_text: str) -> int:
    """Sum bytes over every `dtype[dims]` token in a shape/operand string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= _dim_int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveOp:
    opcode: str
    name: str
    operand_bytes: int     # per-device summed input operand bytes
    group_size: int        # replica group size k (1 = no comm)
    wire_bytes: float      # ring-model per-link traffic estimate

    @staticmethod
    def ring_wire_bytes(opcode: str, operand_bytes: int, k: int) -> float:
        if k <= 1:
            return 0.0
        if opcode.startswith("all-reduce"):
            return 2.0 * operand_bytes * (k - 1) / k
        if opcode.startswith("all-gather"):
            return float(operand_bytes) * (k - 1)
        if opcode.startswith("reduce-scatter"):
            return float(operand_bytes) * (k - 1) / k
        if opcode.startswith(("all-to-all", "ragged-all-to-all")):
            return float(operand_bytes) * (k - 1) / k
        if opcode.startswith(("collective-permute", "collective-broadcast")):
            return float(operand_bytes)
        return float(operand_bytes)


@dataclasses.dataclass
class CollectiveSummary:
    ops: list[CollectiveOp]

    @property
    def total_operand_bytes(self) -> int:
        return sum(o.operand_bytes for o in self.ops)

    @property
    def total_wire_bytes(self) -> float:
        return sum(o.wire_bytes for o in self.ops)

    def by_opcode(self) -> dict[str, dict]:
        agg: dict[str, dict] = {}
        for o in self.ops:
            d = agg.setdefault(o.opcode, {"count": 0, "operand_bytes": 0,
                                          "wire_bytes": 0.0})
            d["count"] += 1
            d["operand_bytes"] += o.operand_bytes
            d["wire_bytes"] += o.wire_bytes
        return agg


def _base_opcode(opcode: str) -> Optional[str]:
    # `all-gather-start`, `all-reduce-start` etc.: count -start, skip -done.
    if opcode.endswith("-done"):
        return None
    for c in _COLLECTIVES:
        if opcode == c or opcode == c + "-start":
            return c
    return None


def parse_collectives(hlo_text: str, num_devices: int) -> CollectiveSummary:
    """Extract every collective op with operand bytes + replica group size."""
    # First pass: map instruction name -> result shape text (for operands
    # referenced by name without an inline shape).
    shapes: dict[str, str] = {}
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if m:
            shapes[m.group(1)] = m.group(2)

    ops: list[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, _result_shape, opcode = m.groups()
        base = _base_opcode(opcode)
        if base is None:
            continue
        # Operand list: text between the first '(' after opcode and the
        # matching ')'.  Operands are printed with inline shapes in
        # post-optimization dumps; fall back to name lookup otherwise.
        start = line.index(opcode + "(") + len(opcode) + 1
        depth, end = 1, start
        while end < len(line) and depth:
            if line[end] == "(":
                depth += 1
            elif line[end] == ")":
                depth -= 1
            end += 1
        operand_text = line[start:end - 1]
        obytes = shape_bytes(operand_text)
        if obytes == 0:
            for ref in re.findall(r"%([\w.\-]+)", operand_text):
                obytes += shape_bytes(shapes.get(ref, ""))
        k = _parse_group_size(line, num_devices)
        ops.append(CollectiveOp(
            opcode=base, name=name, operand_bytes=obytes, group_size=k,
            wire_bytes=CollectiveOp.ring_wire_bytes(base, obytes, k)))
    return CollectiveSummary(ops=ops)


def _parse_group_size(line: str, num_devices: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # iota format: replica_groups=[num_groups,group_size]<=[N]...
        return max(1, int(m.group(2)))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        first = [t for t in m.group(1).split(",") if t.strip() != ""]
        return max(1, len(first))
    return num_devices


# ---------------------------------------------------------------------------
# Full-module cost model with loop trip-count accounting
# ---------------------------------------------------------------------------
#
# XLA's HloCostAnalysis (and thus compiled.cost_analysis()) counts a while
# body ONCE, so any scan-heavy program (layer stacks, grad accumulation,
# blockwise attention) is undercounted by orders of magnitude.  This
# analyzer walks the computation call graph, multiplies while bodies by
# their detected trip count (scan lowers to `compare(iv, constant), LT`),
# counts dot FLOPs exactly from shapes + contracting dims, approximates
# elementwise FLOPs at 1/elem, and models bytes at fusion boundaries
# (operands + outputs of top-level ops), which mirrors XLA's post-fusion
# HBM-traffic model.  Collective operand bytes get the same multipliers.

_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
# Pre-optimization dumps (`lowered.compiler_ir("hlo").as_hlo_text()`) print
# computation headers without signatures: `region_9.143 {` / `ENTRY main.847 {`.
_COMP_BARE_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\{\s*$")
_CALLED_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_CMP_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REF_RE = re.compile(r"%([\w.\-]+)")

_ELEMWISE = {
    "add", "subtract", "multiply", "divide", "power", "exponential",
    "exponential-minus-one", "log", "log-plus-one", "tanh", "sqrt", "rsqrt",
    "negate", "maximum", "minimum", "abs", "cosine", "sine", "logistic",
    "floor", "ceil", "round-nearest-afz", "sign", "atan2", "erf",
    "remainder", "cbrt",
}
_FREE = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "reshape", "broadcast", "iota", "copy-start", "copy-done",
    "after-all", "partition-id", "replica-id", "custom-call", "infeed",
    "outfeed", "rng-bit-generator", "optimization-barrier",
}


def np_prod(dims) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


class _Instr:
    __slots__ = ("name", "result", "opcode", "line")

    def __init__(self, name, result, opcode, line):
        self.name, self.result, self.opcode, self.line = \
            name, result, opcode, line


def _parse_computations(text: str) -> dict[str, list[_Instr]]:
    comps: dict[str, list[_Instr]] = {}
    cur: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            s = line.strip()
            m = _COMP_RE.match(s) or _COMP_BARE_RE.match(s)
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                comps[cur] = []
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            comps[cur].append(_Instr(m.group(1), m.group(2), m.group(3),
                                     line))
    return comps


def _operand_section(line: str, opcode: str) -> str:
    try:
        start = line.index(opcode + "(") + len(opcode) + 1
    except ValueError:
        return ""
    depth, end = 1, start
    while end < len(line) and depth:
        if line[end] == "(":
            depth += 1
        elif line[end] == ")":
            depth -= 1
        end += 1
    return line[start:end - 1]


def _shape_dims(shape_text: str) -> list[tuple[str, list[int]]]:
    return [(dt, [_dim_int(d) for d in dims.split(",")] if dims else [])
            for dt, dims in _SHAPE_RE.findall(shape_text)]


def called_computations(line: str) -> list[str]:
    """Names of computations referenced by calls/to_apply/body/... attrs."""
    out = []
    for m in _CALLED_RE.finditer(line):
        for name in m.group(1).split(","):
            out.append(name.strip().lstrip("%"))
    return out


def resolve_trip_count(comps: dict[str, list["_Instr"]], while_line: str,
                       cond_name: Optional[str]) -> Optional[int]:
    """Trip count of a `while` op: frontend `known_trip_count` metadata if
    present, else the loop-bound constant found in the condition
    computation (possibly fusion-wrapped). None if unresolvable."""
    m = _TRIP_RE.search(while_line)
    if m:
        return int(m.group(1))
    if cond_name is None:
        return None
    seen, frontier = set(), [cond_name]
    while frontier:
        c = frontier.pop()
        if c in seen:
            continue
        seen.add(c)
        for ins in comps.get(c, []):
            if ins.opcode == "constant":
                m = _CONST_CMP_RE.search(ins.line)
                if m:
                    return int(m.group(1))
            frontier.extend(called_computations(ins.line))
    return None


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_operand_bytes: float = 0.0
    collective_wire_bytes: float = 0.0
    collectives: list = dataclasses.field(default_factory=list)
    unresolved_loops: int = 0


class HloCostModel:
    """Trip-count-aware cost walk over a post-optimization HLO module."""

    def __init__(self, text: str, num_devices: int):
        self.text = text
        self.num_devices = num_devices
        self.comps = _parse_computations(text)
        self.entry = self._find_entry(text)
        self._memo: dict[str, ModuleCost] = {}
        # per-computation name -> result-shape text (operands are printed
        # name-only in post-scheduling dumps)
        self._shapes: dict[str, dict[str, str]] = {
            comp: {i.name: i.result for i in instrs}
            for comp, instrs in self.comps.items()}

    def _operand_bytes(self, ins: _Instr, comp: str) -> int:
        sec = _operand_section(ins.line, ins.opcode)
        inline = shape_bytes(sec)
        if inline:
            return inline
        local = self._shapes.get(comp, {})
        total = 0
        for ref in _REF_RE.findall(sec):
            total += shape_bytes(local.get(ref, ""))
        return total

    def _operand_shapes(self, ins: _Instr, comp: str) -> list:
        sec = _operand_section(ins.line, ins.opcode)
        inline = _shape_dims(sec)
        if inline:
            return inline
        local = self._shapes.get(comp, {})
        out = []
        for ref in _REF_RE.findall(sec):
            out.extend(_shape_dims(local.get(ref, "")))
        return out

    @staticmethod
    def _find_entry(text: str) -> Optional[str]:
        for line in text.splitlines():
            s = line.strip()
            if s.startswith("ENTRY"):
                m = _COMP_RE.match(s) or _COMP_BARE_RE.match(s)
                if m:
                    return m.group(1)
        return None

    def analyze(self) -> ModuleCost:
        if self.entry is None:
            return ModuleCost()
        return self._cost_of(self.entry)

    # -- internals ----------------------------------------------------------

    def _trip_count(self, while_line: str, cond_name: Optional[str]
                    ) -> Optional[int]:
        return resolve_trip_count(self.comps, while_line, cond_name)

    def _flops_only(self, comp: str) -> float:
        """Arithmetic inside a fused computation (bytes stay at boundary)."""
        total = 0.0
        for ins in self.comps.get(comp, []):
            total += self._instr_flops(ins, comp)
            called = self._called(ins)
            if ins.opcode == "fusion" or ins.opcode in ("call", "map"):
                for c in called:
                    total += self._flops_only(c)
        return total

    def _called(self, ins: _Instr) -> list[str]:
        return called_computations(ins.line)

    def _instr_flops(self, ins: _Instr, comp: str) -> float:
        op = ins.opcode
        if op == "dot":
            out_elems = 1.0
            for _, dims in _shape_dims(ins.result):
                for d in dims:
                    out_elems *= d
            operands = self._operand_shapes(ins, comp)
            contract = 1.0
            m = _CONTRACT_RE.search(ins.line)
            if m and operands:
                lhs_dims = operands[0][1]
                idxs = [int(i) for i in m.group(1).split(",") if i != ""]
                for i in idxs:
                    if i < len(lhs_dims):
                        contract *= lhs_dims[i]
            return 2.0 * out_elems * contract
        if op == "reduce":
            ops_ = self._operand_shapes(ins, comp)
            elems = 1.0
            if ops_:
                for d in ops_[0][1]:
                    elems *= d
            return elems
        if op in _ELEMWISE:
            elems = 1.0
            for _, dims in _shape_dims(ins.result):
                for d in dims:
                    elems *= d
            return elems
        return 0.0

    def _fusion_is_inplace_update(self, ins: _Instr) -> bool:
        """kLoop fusions wrapping a dynamic-update-slice write only the
        updated region in-place; the big buffer passes through aliased."""
        seen, frontier = set(), list(self._called(ins))
        while frontier:
            c = frontier.pop()
            if c in seen:
                continue
            seen.add(c)
            for sub in self.comps.get(c, []):
                if sub.opcode == "dynamic-update-slice":
                    return True
                if sub.opcode == "fusion":
                    frontier.extend(self._called(sub))
        return False

    def _instr_bytes(self, ins: _Instr, comp: str) -> float:
        """HBM-traffic model per top-level op (TPU-fusion-calibrated):

        * dot / reduce / concatenate / sort: operands + output (real
          streaming reads/writes),
        * dynamic-slice / gather: 2x output (read region + write result),
        * dynamic-update-slice (incl. fused): 2x update operand — the
          buffer is updated in place (XLA aliases it), not copied,
        * everything else (elementwise, fusions, transposes): 2x output —
          one write plus one read of equal order by the consumer; operand
          re-counting would double-bill every producer-consumer edge, which
          on TPU is fused away.
        """
        op = ins.opcode
        if op in _FREE or op in ("while", "conditional"):
            return 0.0
        out_b = shape_bytes(ins.result)
        if op == "dot" or op in ("reduce", "concatenate", "sort", "pad",
                                 "reduce-window"):
            return float(out_b + self._operand_bytes(ins, comp))
        if op in ("dynamic-slice", "gather"):
            return float(2 * out_b)
        if op == "dynamic-update-slice":
            shapes = self._operand_shapes(ins, comp)
            upd = 0
            if len(shapes) >= 2:
                dt, dims = shapes[1]
                n = 1
                for d in dims:
                    n *= d
                upd = n * _DTYPE_BYTES.get(dt, 4)
            return float(2 * upd) if upd else float(out_b)
        if op == "fusion":
            if self._fusion_is_trivial_init(ins):
                # zero/constant buffer fills are aliased or hoisted on TPU
                return 0.0
            if self._fusion_is_inplace_update(ins):
                # charge the non-aliased operands (update + indices); drop
                # ONE operand matching the output size (the aliased buffer)
                sizes = [(_DTYPE_BYTES.get(dt, 4) * int(np_prod(dims)))
                         for dt, dims in self._operand_shapes(ins, comp)]
                if sizes:
                    for i, sz in enumerate(sizes):
                        if sz == out_b:
                            sizes.pop(i)
                            break
                    return float(2 * sum(sizes))
                return float(out_b)
        return float(2 * out_b)

    def _fusion_is_trivial_init(self, ins: _Instr) -> bool:
        for c in self._called(ins):
            ops = {s.opcode for s in self.comps.get(c, [])}
            if ops <= {"parameter", "constant", "broadcast", "bitcast",
                       "iota", "convert"}:
                return True
        return False

    def _cost_of(self, comp: str) -> ModuleCost:
        if comp in self._memo:
            return self._memo[comp]
        total = ModuleCost()
        self._memo[comp] = total  # break cycles defensively
        for ins in self.comps.get(comp, []):
            op = ins.opcode
            called = self._called(ins)
            if op == "while":
                mb = re.search(r"body=%?([\w.\-]+)", ins.line)
                mc = re.search(r"condition=%?([\w.\-]+)", ins.line)
                body = mb.group(1) if mb else None
                cond = mc.group(1) if mc else None
                trip = self._trip_count(ins.line, cond)
                if trip is None:
                    trip = 1
                    total.unresolved_loops += 1
                sub = self._cost_of(body) if body else ModuleCost()
                total.flops += trip * sub.flops
                total.bytes += trip * sub.bytes
                total.collective_operand_bytes += \
                    trip * sub.collective_operand_bytes
                total.collective_wire_bytes += trip * sub.collective_wire_bytes
                total.unresolved_loops += sub.unresolved_loops
                continue
            if op == "fusion":
                for c in called:
                    total.flops += self._flops_only(c)
                total.bytes += self._instr_bytes(ins, comp)
                continue
            if op in ("call", "map", "conditional", "sort",
                      "reduce", "reduce-window", "scatter", "select-and-scatter",
                      "all-reduce", "reduce-scatter"):
                # reductions/collectives carry to_apply computations (tiny),
                # conditionals take the max branch
                if op == "conditional" and called:
                    branches = [self._cost_of(c) for c in called]
                    best = max(branches, key=lambda c: c.flops)
                    total.flops += best.flops
                    total.bytes += best.bytes
                    total.collective_operand_bytes += \
                        best.collective_operand_bytes
                    total.collective_wire_bytes += best.collective_wire_bytes
                    continue
                if op in ("call", "map") and called:
                    for c in called:
                        sub = self._cost_of(c)
                        total.flops += sub.flops
                        total.bytes += sub.bytes
                        total.collective_operand_bytes += \
                            sub.collective_operand_bytes
                        total.collective_wire_bytes += sub.collective_wire_bytes
                    continue
            base = _base_opcode(op)
            if base is not None:
                obytes = self._operand_bytes(ins, comp)
                k = _parse_group_size(ins.line, self.num_devices)
                wire = CollectiveOp.ring_wire_bytes(base, obytes, k)
                total.collective_operand_bytes += obytes
                total.collective_wire_bytes += wire
                total.collectives.append(
                    CollectiveOp(opcode=base, name=ins.name,
                                 operand_bytes=obytes, group_size=k,
                                 wire_bytes=wire))
            total.flops += self._instr_flops(ins, comp)
            total.bytes += self._instr_bytes(ins, comp)
        self._memo[comp] = total
        return total


def analyze_module(text: str, num_devices: int) -> ModuleCost:
    return HloCostModel(text, num_devices).analyze()


# Public aliases for the instruction-graph walk (used by `repro.audit`).
def parse_computations(text: str) -> dict[str, list[_Instr]]:
    return _parse_computations(text)


def operand_section(line: str, opcode: str) -> str:
    return _operand_section(line, opcode)


def shape_dims(shape_text: str) -> list[tuple[str, list[int]]]:
    return _shape_dims(shape_text)


def find_entry(text: str) -> Optional[str]:
    return HloCostModel._find_entry(text)


# ---------------------------------------------------------------------------
# cost/memory analysis normalization (JAX version tolerant)
# ---------------------------------------------------------------------------


def cost_analysis_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "temp_size_in_bytes",
                 "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                 "host_argument_size_in_bytes", "host_output_size_in_bytes",
                 "host_temp_size_in_bytes", "host_alias_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out and hasattr(ma, "__dict__"):
        out = {k: v for k, v in vars(ma).items() if isinstance(v, int)}
    return out


def flops_and_bytes(compiled) -> tuple[float, float]:
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    nbytes = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    return flops, nbytes
