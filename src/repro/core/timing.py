"""Calibrated TPU v5e timing model for the VMEM scatter/accumulate unit.

This module is the *hardware* that ``core.microbench`` measures.  The paper
(Dong & Pai 2025) measures ``T(n, e, c)`` on a real Titan V / A6000 with a
wall-clock microbenchmark; this container is CPU-only with TPU as the
*target*, so wall-clock timing of Pallas ``interpret=True`` runs would
measure the Python interpreter, not the TPU.  Instead we encode a
documented, swap-in-replaceable latency model of the v5e vector-unit
scatter pipeline.  On real hardware, ``microbench.build_table(mode="hw")``
would time the same kernels and produce a table of identical shape; every
consumer downstream (qmodel, profiler, roofline) is agnostic to the source.

The model reproduces the three qualitative behaviours of paper Fig. 1:

  * ``S`` *decreases* with load ``n`` — pipelining amortizes the fill
    latency ``L`` across jobs (``S(n) = L/n + (n-1)/n * I`` falls from
    ``L`` at ``n=1`` to the issue interval ``I`` as ``n → n_max``),
  * ``S`` *increases* with serialization degree ``e`` — duplicate indices
    inside a vector wave must commit sequentially, like bank-conflicting
    lanes in a GPU shared-memory atomic unit,
  * job-class mix shifts ``S`` roughly linearly in ``c`` (paper §3.1), with
    RMW-class (CAS-analogue) jobs costing ~2x cheap-accumulate (FAO) jobs,
    and the POPC-class (Ampere ``ATOMS.POPC.INC`` analogue: one-hot
    row-sum increment, conflict-free by construction) costing the least.

Constants below are *calibration choices*, not measurements — they are
plausible for a ~940 MHz VPU with a VMEM round-trip of a few tens of
cycles, and they put the dynamic range of ``S`` above 10x, matching the
paper's observation that atomic cost "can vary more than ten times
depending on launch and access patterns".
"""

from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

Array = np.ndarray
FloatOrArray = Union[float, Array]

# Job classes (paper §2).
FAO = 0   # fetch-and-op analogue: cheap vector accumulate (add/min/max/...)
CAS = 1   # compare-and-swap analogue: read-modify-verify loop (e.g. exact
          # f32 accumulation or non-associative updates)
POPC = 2  # ATOMS.POPC.INC analogue: one-hot population-count increment


@dataclasses.dataclass(frozen=True)
class ScatterUnitParams:
    """Latency parameters of the modeled VMEM scatter pipeline (cycles)."""

    clock_hz: float = 0.94e9      # v5e TensorCore clock
    # Pipeline fill latency for the first job: L(e) = fill + fill_e * e.
    fill_cycles: float = 25.0
    fill_per_conflict: float = 0.5
    # Steady-state issue interval per job class: I(e) = base + slope * e.
    fao_base: float = 4.0
    fao_slope: float = 1.0
    cas_base: float = 8.0
    cas_slope: float = 2.0
    popc_base: float = 2.0
    popc_slope: float = 0.0       # conflict-free by construction
    # Maximum jobs in flight per core: Pallas double-buffered pipeline (2)
    # x 32 concurrent wave slots of the 8x128 VPU commit path.  Mirrors the
    # paper's n_max = 64 (Volta warps/SM); Ampere used 48.
    n_max: int = 64
    # Serialization-degree table axis: degrees are bucketed to [1, 32]
    # (a wave whose 1024 lanes all hit one bin has raw degree 1024; the
    # pipeline saturates well before that, like the paper's e > 32 case).
    e_max: int = 32


V5E_SCATTER = ScatterUnitParams()


def total_time_cycles(
    n: FloatOrArray,
    e: FloatOrArray,
    c: FloatOrArray,
    p: FloatOrArray = 0.0,
    params: ScatterUnitParams = V5E_SCATTER,
) -> FloatOrArray:
    """Modeled total time T(n, e, c) in cycles for a closed batch of jobs.

    ``n`` jobs arrive at once (the microbenchmark's controlled-arrival
    setup, paper §3.2), of which ``c`` are CAS-class, ``p`` are POPC-class
    and the remaining ``n - c - p`` are FAO-class, each with average
    serialization degree ``e``.  Job flow balance holds by construction
    (all ``n`` jobs complete inside the measurement window), so the
    operational law gives ``S = T / n``.
    """
    n = np.asarray(n, dtype=np.float64)
    e = np.clip(np.asarray(e, dtype=np.float64), 1.0, params.e_max)
    c = np.asarray(c, dtype=np.float64)
    p = np.asarray(p, dtype=np.float64)
    n_fao = np.maximum(n - c - p, 0.0)

    fill = params.fill_cycles + params.fill_per_conflict * e
    i_fao = params.fao_base + params.fao_slope * e
    i_cas = params.cas_base + params.cas_slope * e
    i_popc = params.popc_base + params.popc_slope * e
    # One pipeline: fill once, then one issue interval per job.  The first
    # job's issue overlaps the fill, hence the "- max interval" correction
    # is folded into using fill as latency-to-first-completion.
    t = fill + n_fao * i_fao + c * i_cas + p * i_popc
    return np.where(n > 0, t, 0.0)


def seconds_per_cycle(params: ScatterUnitParams = V5E_SCATTER) -> float:
    return 1.0 / params.clock_hz


# ---------------------------------------------------------------------------
# Timing for the *other* modeled servers (paper §6: "our method is also
# applicable to other GPU functional units").  These are simple throughput
# servers used by core.profiler to place the scatter unit's utilization in
# context; the load-dependent queue treatment is reserved for the scatter
# unit, which is the paper's subject.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChipParams:
    """TPU v5e per-chip constants (from the task spec / public docs)."""

    peak_bf16_flops: float = 197e12   # FLOP/s
    hbm_bw: float = 819e9             # bytes/s
    ici_bw_per_link: float = 50e9     # bytes/s/link
    clock_hz: float = 0.94e9
    vmem_bytes: int = 128 * 1024 * 1024
    hbm_bytes: int = 16 * 1024**3


V5E = ChipParams()


def mxu_busy_seconds(flops: float, chip: ChipParams = V5E) -> float:
    return flops / chip.peak_bf16_flops


def hbm_busy_seconds(bytes_moved: float, chip: ChipParams = V5E) -> float:
    return bytes_moved / chip.hbm_bw


def ici_busy_seconds(bytes_moved: float, chip: ChipParams = V5E) -> float:
    return bytes_moved / chip.ici_bw_per_link
