"""Tool 1 (paper §3.4): build the once-per-chip ``S(n, e, c)`` table.

The paper's first tool runs a microbenchmark that issues ``A = n`` atomic
warp-instructions at once with controlled active-thread count ``e`` and CAS
count ``c``, measures total time ``T`` from first arrival to last
completion, and derives ``S = T / n`` by job flow balance.

Here the measurement has two modes:

* ``analytic`` (default): query the calibrated v5e timing model directly on
  the full (n, e, c) grid.  This is the CPU-container stand-in for running
  on hardware; on a real TPU this mode is replaced by wall-clock timing of
  the same generated access patterns.
* ``kernel``: additionally *executes* the instrumented Pallas scatter
  kernel (interpret mode) on synthetic index patterns constructed to have
  a designed (n, e, c), recovers the counters from instrumentation, checks
  they match the design (validating the counter path end-to-end), and uses
  the counted values to index the timing model.  This mirrors the paper's
  point that ``T(n,e,c)`` "does not reveal any hardware implementation
  details" — the table is produced by running code, not by reading specs.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import qmodel, timing


def default_grids(params: timing.ScatterUnitParams = timing.V5E_SCATTER):
    n_grid = np.arange(0, params.n_max + 1, dtype=np.float64)  # all integral n
    e_grid = np.arange(1, params.e_max + 1, dtype=np.float64)  # all integral e
    cfrac_grid = np.linspace(0.0, 1.0, 17)
    return n_grid, e_grid, cfrac_grid


def build_table(
    params: timing.ScatterUnitParams = timing.V5E_SCATTER,
    mode: str = "analytic",
    kernel_validation_points: int = 8,
    seed: int = 0,
) -> qmodel.ServiceTimeTable:
    """Measure T(n, e, c) over the full grid; once per chip model."""
    n_grid, e_grid, cfrac_grid = default_grids(params)
    nn, ee, cf = np.meshgrid(n_grid, e_grid, cfrac_grid, indexing="ij")
    cc = cf * nn  # integral-c design points rectangularized by fraction
    T = timing.total_time_cycles(nn, ee, cc, 0.0, params)
    popc = timing.total_time_cycles(nn[..., 0], ee[..., 0],
                                    0.0, nn[..., 0], params)
    meta = {"mode": mode, "params": dataclasses.asdict(params)}

    if mode == "kernel":
        meta["kernel_validation"] = _validate_with_kernel(
            params, kernel_validation_points, seed)

    return qmodel.ServiceTimeTable(
        n_grid=n_grid, e_grid=e_grid, cfrac_grid=cfrac_grid, T=T,
        popc_T=popc, clock_hz=params.clock_hz, meta=meta,
    )


def make_pattern(n: int, e: int, num_bins: int, lanes: int = 1024,
                 seed: int = 0) -> np.ndarray:
    """Synthesize ``n`` waves of scatter indices with serialization degree e.

    Degree e means each wave's ``lanes`` updates hit ``lanes // e`` distinct
    bins (duplicate multiplicity e), the TPU analogue of ``e`` threads of a
    warp hitting one bank.  Used both by the microbenchmark and the kernel
    tests.
    """
    assert 1 <= e <= lanes
    rng = np.random.default_rng(seed)
    distinct = max(1, lanes // e)
    waves = []
    for _ in range(n):
        bins = rng.choice(num_bins, size=distinct, replace=False)
        idx = np.repeat(bins, e)[:lanes]
        if idx.size < lanes:  # pad with the first bin (raises degree slightly)
            idx = np.concatenate([idx, np.full(lanes - idx.size, bins[0])])
        waves.append(idx)
    return np.stack(waves).astype(np.int32)


def _validate_with_kernel(params, num_points: int, seed: int) -> list[dict]:
    """Run the instrumented kernel on designed patterns; compare counters."""
    from repro.kernels.scatter_add import ops as scatter_ops  # lazy import

    rng = np.random.default_rng(seed)
    out = []
    for _ in range(num_points):
        n = int(rng.integers(1, params.n_max + 1))
        e = int(2 ** rng.integers(0, 6))  # 1..32
        num_bins = 4096
        idx = make_pattern(n, e, num_bins, seed=int(rng.integers(1 << 31)))
        values = np.ones(idx.shape, np.float32)
        _, counters = scatter_ops.instrumented_scatter_add(
            idx.reshape(-1), values.reshape(-1), num_bins, wave=idx.shape[1])
        measured_e = counters["O"] / counters["N"]
        out.append({
            "designed": {"n": n, "e": e},
            "counted": {"N": float(counters["N"]), "e": float(measured_e)},
            "e_rel_err": abs(measured_e - e) / e,
        })
    return out
