"""Render a contention ``Heatmap`` as text, json, or csv.

Same renderer contract as ``SweepResult.render``: one function, three
formats, the string goes to stdout or an artifact file.  The text form
is the operator view — a unicode sparkline of the per-wave contention
series plus a bar grid of the hottest bins; json carries the full
attribution for tooling; csv is the per-bin table.
"""

from __future__ import annotations

import csv as _csv
import io
import json
from typing import List

import numpy as np

__all__ = ["render", "render_text", "render_json", "render_csv",
           "sparkline"]

_BLOCKS = "▁▂▃▄▅▆▇█"  # ▁▂▃▄▅▆▇█


def sparkline(values, width: int = 64) -> str:
    """Downsample ``values`` to ``width`` buckets (max within a bucket)
    and map each to an eighth-block glyph.  Empty input -> empty string."""
    vals = np.asarray(values, np.float64).reshape(-1)
    if not vals.size:
        return ""
    width = max(1, min(int(width), vals.size))
    edges = np.linspace(0, vals.size, width + 1).astype(np.int64)
    buckets = np.array([vals[a:b].max() if b > a else vals[min(a, vals.size - 1)]
                        for a, b in zip(edges[:-1], edges[1:])])
    lo, hi = float(vals.min()), float(vals.max())
    if hi <= lo:
        return _BLOCKS[0] * width
    scaled = (buckets - lo) / (hi - lo) * (len(_BLOCKS) - 1)
    return "".join(_BLOCKS[int(round(s))] for s in scaled)


def render(hm, fmt: str = "text", top_k: int = 16) -> str:
    if fmt == "text":
        return render_text(hm, top_k=top_k)
    if fmt == "json":
        return render_json(hm, top_k=top_k)
    if fmt == "csv":
        return render_csv(hm)
    raise ValueError(f"unknown heat-map format {fmt!r}")


def _bin_rows(hm, top_k=None) -> List[dict]:
    idx = hm.top(top_k) if top_k else np.arange(hm.bins.size)
    total = hm.total_hits or 1
    hot = hm.hot_mask
    return [{
        "bin": int(hm.bins[i]),
        "hits": int(hm.hits[i]),
        "replays": int(hm.replays[i]),
        "max_wave_degree": float(hm.max_wave_degree[i]),
        "replay_share": float(hm.replays[i]) / total,
        "hot": bool(hot[i]),
    } for i in idx]


def render_text(hm, top_k: int = 16) -> str:
    c = hm.counters
    out = [f"contention heat map — {hm.label or '(unlabeled)'}"]
    op = hm.meta.get("op")
    if op:
        out[0] += f" [{op}/{hm.meta.get('variant')}]"
    out.append(
        f"  slots {hm.num_slots} · touched {hm.bins.size} · "
        f"hits {hm.total_hits} · waves {hm.num_waves} · "
        f"e {c.e:.2f} · O {c.total_O:.1f}")
    if hm.num_waves:
        out.append(
            f"  wave contention (degree over time, peak "
            f"{hm.peak_degree:.1f} @ wave {hm.peak_wave}):")
        out.append("    " + sparkline(hm.wave_degree))
    n_hot = int(hm.hot_mask.sum())
    out.append(f"  hot bins: {n_hot} of {hm.bins.size} touched "
               f"(wave degree >= {hm.hot_degree:g} with replays)")
    rows = _bin_rows(hm, top_k)
    if rows:
        out.append(f"  top {len(rows)} bins by serialized replays:")
        out.append("    {:>8} {:>10} {:>10} {:>7} {:>7}  {}".format(
            "bin", "hits", "replays", "maxdeg", "share", ""))
        peak = max(r["replays"] for r in rows) or 1
        for r in rows:
            bar = _BLOCKS[-1] * max(1 if r["replays"] else 0,
                                    round(10 * r["replays"] / peak))
            out.append(
                "    {bin:>8} {hits:>10} {replays:>10} "
                "{max_wave_degree:>7.1f} {pct:>6.1f}%  {bar}{mark}".format(
                    pct=100.0 * r["replay_share"], bar=bar,
                    mark=" *" if r["hot"] else "",
                    **{k: v for k, v in r.items() if k != "hot"}))
    if hm.top_bin is not None:
        out.append(f"  top-bin share {100.0 * hm.top_bin_share:.1f}% "
                   f"(bin {hm.top_bin})")
    else:
        out.append("  no serialized replays — stream is contention-free")
    return "\n".join(out)


def render_json(hm, top_k: int = 16) -> str:
    c = hm.counters
    body = {
        "label": hm.label,
        "meta": hm.meta,
        "num_slots": hm.num_slots,
        "touched_bins": int(hm.bins.size),
        "total_hits": hm.total_hits,
        "num_waves": hm.num_waves,
        "lanes": hm.lanes,
        "commit_group": hm.commit_group,
        "hot_degree": hm.hot_degree,
        "hot_bins": [int(b) for b in hm.hot_bins],
        "top_bin": hm.top_bin,
        "top_bin_share": hm.top_bin_share,
        "peak_wave": hm.peak_wave,
        "peak_degree": hm.peak_degree,
        "counters": {
            "total_O": c.total_O,
            "total_jobs": c.total_jobs,
            "e": c.e,
            "num_waves": c.num_waves,
            "lanes_active": c.lanes_active,
        },
        "bins": _bin_rows(hm, top_k),
        "wave_degree": [float(d) for d in hm.wave_degree],
    }
    return json.dumps(body, indent=2, sort_keys=True)


def render_csv(hm) -> str:
    buf = io.StringIO()
    w = _csv.writer(buf)
    w.writerow(["bin", "hits", "replays", "max_wave_degree",
                "replay_share", "hot"])
    for r in _bin_rows(hm, top_k=None):
        w.writerow([r["bin"], r["hits"], r["replays"],
                    f"{r['max_wave_degree']:.6g}",
                    f"{r['replay_share']:.6g}", int(r["hot"])])
    return buf.getvalue()
