"""Process-wide metrics registry and lightweight tracing spans.

The observability substrate for the whole Session -> provider ->
resilience -> service pipeline.  Two halves:

* **Metrics** — a thread-safe registry of counters, gauges, and
  histograms with *bounded* label sets (a metric never grows more than
  ``max_series`` distinct label-value combinations; the excess collapses
  into a reserved ``__overflow__`` series so a hostile or buggy caller
  cannot blow up the registry).  ``render()`` emits the Prometheus text
  exposition format (``text/plain; version=0.0.4``) using only the
  stdlib — no client library dependency.

* **Spans** — ``trace_scope()`` opens a trace (with a propagated or
  freshly minted trace id) in a ``contextvars`` context, and ``span()``
  records named, timed sections into it.  The service worker wraps every
  job in a scope so ``/v1/jobs`` responses can carry per-job span
  summaries and an ``X-Repro-Trace-Id`` header.

Everything here is stdlib-only and imports nothing from the rest of
``repro`` — the analysis and service layers import *us*, never the
other way around.

A global enable switch (``set_enabled``) turns every write into a no-op
so the ``heatmap_overhead`` benchmark can measure the instrumented
pipeline with telemetry off.
"""

from __future__ import annotations

import contextlib
import contextvars
import re
import threading
import time
import uuid
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

__all__ = [
    "MetricsRegistry", "Counter", "Gauge", "Histogram",
    "REGISTRY", "counter", "gauge", "histogram", "render", "reset",
    "set_enabled", "enabled", "disabled",
    "new_trace_id", "trace_scope", "span", "current_trace_id",
    "span_summaries", "OVERFLOW",
]

# ---------------------------------------------------------------------------
# global enable switch

_ENABLED = True


def set_enabled(flag: bool) -> None:
    """Globally enable/disable all metric writes and span recording."""
    global _ENABLED
    _ENABLED = bool(flag)


def enabled() -> bool:
    return _ENABLED


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Context manager: telemetry off inside, previous state restored."""
    prev = _ENABLED
    set_enabled(False)
    try:
        yield
    finally:
        set_enabled(prev)


# ---------------------------------------------------------------------------
# metrics

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

#: reserved label value absorbing series beyond the cardinality bound
OVERFLOW = "__overflow__"

DEFAULT_BUCKETS = (0.001, 0.005, 0.01, 0.05, 0.1, 0.5, 1.0, 5.0, 30.0)


def _escape(value: str) -> str:
    return (value.replace("\\", r"\\").replace("\n", r"\n")
            .replace('"', r'\"'))


class _Metric:
    """Shared series bookkeeping (the label-cardinality bound lives here)."""

    kind = "untyped"

    def __init__(self, name: str, help_text: str,
                 labelnames: Sequence[str], max_series: int,
                 lock: threading.Lock) -> None:
        if not _NAME_RE.match(name):
            raise ValueError(f"bad metric name {name!r}")
        for ln in labelnames:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"bad label name {ln!r}")
        self.name = name
        self.help = help_text
        self.labelnames = tuple(labelnames)
        self.max_series = int(max_series)
        self._lock = lock
        self._series: Dict[Tuple[str, ...], object] = {}

    def _key(self, labels: Dict[str, object]) -> Tuple[str, ...]:
        """Label values -> series key, collapsing past the bound.

        Caller must hold ``self._lock``.
        """
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        key = tuple(str(labels[ln]) for ln in self.labelnames)
        if key in self._series or len(self._series) < self.max_series:
            return key
        return (OVERFLOW,) * len(self.labelnames)

    def _zero(self) -> object:
        raise NotImplementedError

    def _slot(self, labels: Dict[str, object]) -> object:
        key = self._key(labels)
        slot = self._series.get(key)
        if slot is None:
            slot = self._series[key] = self._zero()
        return slot

    def series(self) -> Dict[Tuple[str, ...], object]:
        """Snapshot of {label-values: value} (for tests / status)."""
        with self._lock:
            return dict(self._series)

    def _render_lines(self) -> List[str]:
        raise NotImplementedError

    def _fmt(self, key: Tuple[str, ...],
             extra: Tuple[Tuple[str, str], ...] = ()) -> str:
        pairs = [f'{ln}="{_escape(v)}"'
                 for ln, v in zip(self.labelnames, key)]
        pairs += [f'{ln}="{_escape(v)}"' for ln, v in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""


class Counter(_Metric):
    kind = "counter"

    def _zero(self) -> List[float]:
        return [0.0]

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        if not _ENABLED:
            return
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._slot(labels)[0] += amount

    def value(self, **labels: object) -> float:
        with self._lock:
            key = tuple(str(labels[ln]) for ln in self.labelnames)
            slot = self._series.get(key)
            return float(slot[0]) if slot else 0.0

    def _render_lines(self) -> List[str]:
        return [f"{self.name}{self._fmt(k)} {_num(v[0])}"
                for k, v in sorted(self._series.items())]


class Gauge(_Metric):
    kind = "gauge"

    def _zero(self) -> List[float]:
        return [0.0]

    def set(self, value: float, **labels: object) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._slot(labels)[0] = float(value)

    def inc(self, amount: float = 1.0, **labels: object) -> None:
        if not _ENABLED:
            return
        with self._lock:
            self._slot(labels)[0] += amount

    def dec(self, amount: float = 1.0, **labels: object) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels: object) -> float:
        with self._lock:
            key = tuple(str(labels[ln]) for ln in self.labelnames)
            slot = self._series.get(key)
            return float(slot[0]) if slot else 0.0

    def _render_lines(self) -> List[str]:
        return [f"{self.name}{self._fmt(k)} {_num(v[0])}"
                for k, v in sorted(self._series.items())]


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name: str, help_text: str,
                 labelnames: Sequence[str], max_series: int,
                 lock: threading.Lock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS) -> None:
        super().__init__(name, help_text, labelnames, max_series, lock)
        self.buckets = tuple(sorted(float(b) for b in buckets))
        if not self.buckets:
            raise ValueError("histogram needs at least one bucket bound")

    def _zero(self) -> Dict[str, object]:
        return {"bucket": [0] * len(self.buckets), "sum": 0.0, "count": 0}

    def observe(self, value: float, **labels: object) -> None:
        if not _ENABLED:
            return
        with self._lock:
            slot = self._slot(labels)
            for i, bound in enumerate(self.buckets):
                if value <= bound:
                    slot["bucket"][i] += 1
            slot["sum"] += float(value)
            slot["count"] += 1

    def _render_lines(self) -> List[str]:
        lines: List[str] = []
        for key, slot in sorted(self._series.items()):
            for bound, n in zip(self.buckets, slot["bucket"]):
                extra = (("le", _num(bound)),)
                lines.append(f"{self.name}_bucket"
                             f"{self._fmt(key, extra)} {n}")
            lines.append(f"{self.name}_bucket"
                         f"{self._fmt(key, (('le', '+Inf'),))} "
                         f"{slot['count']}")
            lines.append(f"{self.name}_sum{self._fmt(key)} "
                         f"{_num(slot['sum'])}")
            lines.append(f"{self.name}_count{self._fmt(key)} "
                         f"{slot['count']}")
        return lines


def _num(v: float) -> str:
    """Prometheus-friendly number formatting (ints without trailing .0)."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class MetricsRegistry:
    """Named metric instruments with idempotent registration."""

    def __init__(self, max_series: int = 64) -> None:
        self.max_series = int(max_series)
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Metric] = {}

    def _get_or_make(self, cls, name: str, help_text: str,
                     labelnames: Sequence[str], **kw) -> _Metric:
        with self._lock:
            existing = self._metrics.get(name)
            if existing is not None:
                if (type(existing) is not cls
                        or existing.labelnames != tuple(labelnames)):
                    raise ValueError(
                        f"metric {name!r} re-registered with a different "
                        f"type or label set")
                return existing
            metric = cls(name, help_text, labelnames, self.max_series,
                         self._lock, **kw)
            self._metrics[name] = metric
            return metric

    def counter(self, name: str, help_text: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help_text, labelnames)

    def gauge(self, name: str, help_text: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help_text, labelnames)

    def histogram(self, name: str, help_text: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help_text, labelnames,
                                 buckets=buckets)

    def render(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        out: List[str] = []
        with self._lock:
            for name in sorted(self._metrics):
                m = self._metrics[name]
                if m.help:
                    out.append(f"# HELP {name} {_escape(m.help)}")
                out.append(f"# TYPE {name} {m.kind}")
                out.extend(m._render_lines())
        return "\n".join(out) + "\n"

    def reset(self) -> None:
        """Drop all recorded series (instrument definitions survive)."""
        with self._lock:
            for m in self._metrics.values():
                m._series.clear()


#: the process-wide default registry every instrumented layer writes to
REGISTRY = MetricsRegistry()


def counter(name: str, help_text: str = "",
            labelnames: Sequence[str] = ()) -> Counter:
    return REGISTRY.counter(name, help_text, labelnames)


def gauge(name: str, help_text: str = "",
          labelnames: Sequence[str] = ()) -> Gauge:
    return REGISTRY.gauge(name, help_text, labelnames)


def histogram(name: str, help_text: str = "",
              labelnames: Sequence[str] = (),
              buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
    return REGISTRY.histogram(name, help_text, labelnames, buckets)


def render() -> str:
    return REGISTRY.render()


def reset() -> None:
    REGISTRY.reset()


# ---------------------------------------------------------------------------
# tracing spans

#: spans recorded per trace are capped so a pathological job can't grow
#: the response body without bound
MAX_SPANS = 256

_TRACE: contextvars.ContextVar[Optional[dict]] = contextvars.ContextVar(
    "repro_obs_trace", default=None)


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


@contextlib.contextmanager
def trace_scope(trace_id: Optional[str] = None) -> Iterator[dict]:
    """Open a trace: mint/propagate an id and collect spans inside.

    Nested scopes stack — the inner scope gets its own span list, and
    the outer one is restored on exit (mirrors ``resilience_scope``).
    """
    rec = {"id": str(trace_id) if trace_id else new_trace_id(),
           "spans": [], "t0": time.perf_counter()}
    token = _TRACE.set(rec)
    try:
        yield rec
    finally:
        _TRACE.reset(token)


def current_trace_id() -> Optional[str]:
    rec = _TRACE.get()
    return rec["id"] if rec is not None else None


def span_summaries() -> List[dict]:
    """Spans recorded so far in the enclosing trace (empty outside one)."""
    rec = _TRACE.get()
    return list(rec["spans"]) if rec is not None else []


@contextlib.contextmanager
def span(name: str, **attrs: object) -> Iterator[None]:
    """Record a named, timed section into the enclosing trace scope.

    Cheap no-op when telemetry is disabled or no scope is open.
    """
    rec = _TRACE.get()
    if not _ENABLED or rec is None:
        yield
        return
    t0 = time.perf_counter()
    try:
        yield
    finally:
        if len(rec["spans"]) < MAX_SPANS:
            entry = {
                "name": str(name),
                "start_ms": round((t0 - rec["t0"]) * 1e3, 3),
                "dur_ms": round((time.perf_counter() - t0) * 1e3, 3),
            }
            if attrs:
                entry["attrs"] = {k: _jsonable(v) for k, v in attrs.items()}
            rec["spans"].append(entry)


def _jsonable(v: object) -> object:
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)
